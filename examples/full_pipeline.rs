//! End-to-end driver: the full system on a real (synthetic) workload.
//!
//! Exercises every layer in one run, proving they compose:
//!
//! 1. generate the seven benchmark databases (scaled);
//! 2. run the Möbius Join through the **coordinator** worker pool;
//! 3. route bulk ct-algebra through the **AOT XLA artifacts** (PJRT) when
//!    they are available, checking bit-identity against the native engine;
//! 4. cross-check MJ vs the cross-product baseline where CP is feasible;
//! 5. run the downstream apps (CFS + rules + BN) on one dataset;
//! 6. report the paper's headline metrics (#statistics, extra time,
//!    compression ratio, near-linear extra-time fit of Figure 7).
//!
//! Run: `cargo run --release --example full_pipeline [scale]`
//! (default scale 0.1; EXPERIMENTS.md records a full run.)

use mrss::apps::{apriori, bayesnet, cfs};
use mrss::baseline::CpBudget;
use mrss::coordinator::{run_suite, PoolConfig, SuiteJob};
use mrss::datagen;
use mrss::mobius::{CtEngine, MobiusJoin};
use mrss::runtime::{XlaEngine, XlaRuntime};
use mrss::util::format_duration;
use mrss::util::table::{commas, TextTable};
use std::time::Duration;

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let seed = 7;
    println!("=== full pipeline @ scale {scale} ===\n");

    // Stage 1+2: the benchmark suite through the coordinator.
    let jobs: Vec<SuiteJob> = datagen::BENCHMARKS
        .iter()
        .map(|b| {
            let mut j = SuiteJob::new(b.name, scale, seed);
            // CP cross-check on the small schemas only (the paper's CP
            // "N.T." datasets stay infeasible even scaled down).
            if matches!(b.name, "mutagenesis" | "mondial" | "uwcse" | "movielens") {
                j = j.with_cp(CpBudget {
                    max_time: Duration::from_secs(60),
                    max_tuples: 100_000_000,
                });
            }
            j
        })
        .collect();
    let reports = run_suite(jobs, PoolConfig { workers: 1, queue_depth: 2 });

    let mut t = TextTable::new(vec![
        "Dataset", "#Tuples", "MJ-time", "#Stats", "#Extra", "ExtraTime", "CP", "Compress",
    ]);
    let mut pairs: Vec<(f64, f64)> = Vec::new(); // (extra stats, extra secs)
    for rep in &reports {
        let r = rep.as_ref().expect("job failed");
        pairs.push((r.extra_statistics as f64, r.extra_time.as_secs_f64()));
        let (cp_cell, ratio) = match (&r.cp, r.compression_ratio()) {
            (Some(cp), Some(ratio)) if !cp.non_termination => {
                (format_duration(cp.elapsed), format!("{ratio:.1}"))
            }
            (Some(cp), _) if cp.non_termination => ("N.T.".into(), "-".into()),
            _ => ("-".into(), "-".into()),
        };
        t.row(vec![
            r.dataset.clone(),
            commas(r.tuples as u128),
            format_duration(r.mj_time),
            commas(r.statistics as u128),
            commas(r.extra_statistics as u128),
            format_duration(r.extra_time),
            cp_cell,
            ratio,
        ]);
    }
    print!("{}", t.render());

    // Headline metric: extra time is near-linear in #extra statistics
    // (paper Figure 7). Report the linear-fit R^2.
    let r2 = linear_fit_r2(&pairs);
    println!("\nFigure-7 check: extra-time vs #extra-statistics linear fit R^2 = {r2:.3}");

    // Stage 3: XLA engine (if artifacts present) vs native on one dataset.
    let db = datagen::generate("financial", scale, seed).expect("gen");
    let native = MobiusJoin::new(&db).run();
    match XlaRuntime::load_default() {
        Ok(rt) => {
            let engine = XlaEngine::new(&rt);
            println!(
                "\nXLA engine loaded ({} artifacts); engine = {}",
                rt.num_artifacts(),
                engine.name()
            );
            let xla = MobiusJoin::with_engine(&db, &engine).run();
            assert_eq!(
                native.joint_ct(),
                xla.joint_ct(),
                "XLA and native joints must be bit-identical"
            );
            println!(
                "financial joint via XLA == native ({} statistics) | native {} vs xla {}",
                commas(xla.num_statistics() as u128),
                format_duration(native.metrics.total),
                format_duration(xla.metrics.total),
            );
        }
        Err(e) => println!("\n(XLA artifacts unavailable, native only: {e})"),
    }

    // Stage 4: downstream statistical apps on financial.
    let schema = &db.schema;
    let joint = native.joint_ct();
    let target = schema.var_by_name(datagen::info("financial").unwrap().target).unwrap();
    let all: Vec<usize> = (0..schema.random_vars.len()).collect();
    let sel = cfs::cfs_select(joint, target, &all, None);
    println!(
        "\nCFS(balance(T)) selected {} features, merit {:.3}",
        sel.selected.len(),
        sel.merit
    );
    let rules = apriori::apriori(schema, joint, Default::default(), None);
    println!(
        "Apriori: {} rules, {} use relationship variables",
        rules.len(),
        rules.iter().filter(|r| r.uses_rel_var(schema)).count()
    );
    let bn = bayesnet::learn_structure(schema, &native, true, Default::default());
    let m = bayesnet::score_structure(schema, &bn.bn, joint, None);
    println!(
        "BN (link on): loglik {:.2}, {} params, {} R2R + {} A2R edges, learned in {}",
        m.loglik,
        m.params,
        m.r2r,
        m.a2r,
        format_duration(bn.elapsed)
    );
    println!("\npipeline complete");
}

/// R^2 of the least-squares line through (x, y) pairs.
fn linear_fit_r2(pairs: &[(f64, f64)]) -> f64 {
    let n = pairs.len() as f64;
    let (sx, sy): (f64, f64) = pairs.iter().fold((0.0, 0.0), |(a, b), &(x, y)| (a + x, b + y));
    let (mx, my) = (sx / n, sy / n);
    let (mut sxx, mut sxy, mut syy) = (0.0, 0.0, 0.0);
    for &(x, y) in pairs {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 1.0;
    }
    (sxy * sxy) / (sxx * syy)
}
