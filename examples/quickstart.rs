//! Quickstart: the paper's running example end to end.
//!
//! Builds the university database of Figure 2 (3 students, 3 courses,
//! 3 professors, Registration + RA), runs the Möbius Join, and prints the
//! joint contingency table — the analogue of the paper's Figure 3 — plus
//! the `ct_F` construction of Figure 5 and the lattice of Figure 4.
//!
//! Run: `cargo run --release --example quickstart`

use mrss::ct::render_ct;
use mrss::db::{Database, DatabaseBuilder};
use mrss::mobius::MobiusJoin;
use mrss::schema::university_schema;
use std::sync::Arc;

/// The exact database instance of the paper's Figure 2.
fn university_db() -> Database {
    let schema = Arc::new(university_schema());
    let mut b = DatabaseBuilder::new(schema);
    // Students (intelligence, ranking): jack(3,1) kim(2,1) paul(1,2)
    let jack = b.add_entity(0, &[2, 0]);
    let kim = b.add_entity(0, &[1, 0]);
    let paul = b.add_entity(0, &[0, 1]);
    // Courses (rating, difficulty): 101(3,2) 102(2,1) 103(2,1)
    let c101 = b.add_entity(1, &[2, 1]);
    let c102 = b.add_entity(1, &[1, 0]);
    let _c103 = b.add_entity(1, &[1, 0]);
    // Professors (popularity, teachingability): jim(2,1) oliver(3,1) david(2,2)
    let jim = b.add_entity(2, &[1, 0]);
    let oliver = b.add_entity(2, &[2, 0]);
    let david = b.add_entity(2, &[1, 1]);
    // Registration(S,C) with (grade, satisfaction)
    b.add_rel(0, jack, c101, &[0, 0]);
    b.add_rel(0, jack, c102, &[1, 1]);
    b.add_rel(0, kim, c102, &[2, 0]);
    b.add_rel(0, paul, c101, &[1, 0]);
    // RA(P,S) with (capability, salary)
    b.add_rel(1, oliver, jack, &[2, 2]);
    b.add_rel(1, oliver, kim, &[0, 0]);
    b.add_rel(1, jim, paul, &[1, 1]);
    b.add_rel(1, david, kim, &[1, 2]);
    b.finish()
}

fn main() {
    let db = university_db();
    let schema = &db.schema;
    println!("== University database (paper Figure 2): {} tuples ==\n", db.total_tuples());

    let res = MobiusJoin::new(&db).run();

    // Figure 4: the relationship-chain lattice.
    println!("Lattice ({} chains + {} entity tables):", res.lattice.len(), res.entity_cts.len());
    for chain in &res.lattice.chains {
        let names: Vec<String> =
            chain.iter().map(|&r| schema.var_name(schema.rel_ind_var(r))).collect();
        println!("  level {}: {}", chain.len(), names.join(", "));
    }

    // Figure 5: ct table for the RA chain, F rows carry n/a 2Atts.
    let ra_table = &res.tables[&vec![1usize]];
    println!("\n== ct table for RA(P,S) (Figure 5), total {} = |P|x|S| ==", ra_table.total());
    println!("{}", render_ct(ra_table, schema, 12));

    // Figure 3: excerpt of the joint contingency table.
    let joint = res.joint_ct();
    println!(
        "== Joint contingency table (Figure 3): {} statistics, total {} = |S|x|C|x|P| ==",
        joint.len(),
        joint.total()
    );
    println!("{}", render_ct(joint, schema, 15));

    println!("Link-off statistics: {}", res.link_off().len());
    println!("Extra (negative-relationship) statistics: {}", res.num_extra_statistics());
    println!("\nMetrics:\n{}", res.metrics.breakdown());

    // Sanity checks mirroring the paper's numbers.
    assert_eq!(joint.total(), 27);
    assert_eq!(ra_table.total(), 9);
    let f_rows = ra_table.select(&[(schema.rel_ind_var(1), 0)]);
    assert_eq!(f_rows.total(), 5, "9 pairs - 4 RA tuples = 5 false pairs");
    println!("all Figure 2-5 invariants hold");
}
