//! Domain example: Bayesian-network learning from the joint contingency
//! table, link analysis on vs off (the paper's §6.3 / Tables 7-8 workload).
//!
//! Learns two structures with the learn-and-join lattice walk — one from
//! positive-only statistics, one from the full table — scores both against
//! the same link-on table, and prints the learned relationship edges.
//!
//! Run: `cargo run --release --example bn_learning [dataset] [scale]`

use mrss::apps::bayesnet;
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::util::format_duration;
use mrss::util::table::TextTable;

fn main() {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "financial".into());
    let scale: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let db = datagen::generate(&dataset, scale, 7).expect("unknown dataset");
    let schema = &db.schema;

    println!("== {dataset} @ scale {scale}: {} tuples ==", db.total_tuples());
    let res = MobiusJoin::new(&db).run();
    let joint = res.joint_ct();
    println!("joint ct: {} statistics\n", joint.len());

    let mut t = TextTable::new(vec![
        "Mode", "learn-time", "log-likelihood", "#params", "edges", "R2R", "A2R",
    ]);
    let mut learned = Vec::new();
    for link_on in [false, true] {
        let out = bayesnet::learn_structure(schema, &res, link_on, Default::default());
        let m = bayesnet::score_structure(schema, &out.bn, joint, None);
        t.row(vec![
            if link_on { "Link Analysis On" } else { "Link Analysis Off" }.to_string(),
            format_duration(out.elapsed),
            format!("{:.3}", m.loglik),
            m.params.to_string(),
            out.bn.num_edges().to_string(),
            m.r2r.to_string(),
            m.a2r.to_string(),
        ]);
        learned.push((link_on, out.bn));
    }
    println!("Tables 7-8 (structure learning time + statistical scores):");
    print!("{}", t.render());

    for (link_on, bn) in learned {
        if link_on {
            println!("\nEdges learned with link analysis ON:");
            print!("{}", bn.render(schema));
        }
    }
}
