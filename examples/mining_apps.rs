//! Domain example: feature selection + association-rule mining over the
//! Möbius-Join statistics (the paper's §6.1-6.2 workloads).
//!
//! Mirrors the motivating use case from the paper's introduction: "if user
//! u performs a web search for item i, is it likely that u watches a video
//! about i?" — here: does a user's rating behaviour predict movie genre,
//! and which rules connect relationship existence with attributes?
//!
//! Run: `cargo run --release --example mining_apps [dataset] [scale]`

use mrss::apps::{apriori, cfs};
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::schema::RandomVar;
use mrss::util::table::TextTable;

fn main() {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "movielens".into());
    let scale: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let db = datagen::generate(&dataset, scale, 7).expect("unknown dataset");
    let schema = &db.schema;
    let info = datagen::info(&dataset).expect("benchmark info");

    println!("== {dataset} @ scale {scale}: {} tuples ==", db.total_tuples());
    let res = MobiusJoin::new(&db).run();
    let joint = res.joint_ct();
    println!(
        "joint ct: {} statistics ({} with negative relationships)\n",
        joint.len(),
        res.num_extra_statistics()
    );

    // ---- Table 5: CFS link-off vs link-on ----
    let target = schema.var_by_name(info.target).expect("target var");
    let attrs: Vec<usize> = (0..schema.random_vars.len())
        .filter(|&v| !matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
        .collect();
    let all: Vec<usize> = (0..schema.random_vars.len()).collect();
    let off_ct = res.link_off();
    let off = cfs::cfs_select(&off_ct, target, &attrs, None);
    let on = cfs::cfs_select(joint, target, &all, None);
    let rvars_on = on
        .selected
        .iter()
        .filter(|&&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
        .count();

    let mut t = TextTable::new(vec!["Mode", "#Selected", "Rvars", "Features"]);
    let names = |vs: &[usize]| {
        vs.iter().map(|&v| schema.var_name(v)).collect::<Vec<_>>().join(", ")
    };
    t.row(vec![
        "Link Analysis Off".to_string(),
        off.selected.len().to_string(),
        "0".to_string(),
        if off_ct.is_empty() { "Empty CT".into() } else { names(&off.selected) },
    ]);
    t.row(vec![
        "Link Analysis On".to_string(),
        on.selected.len().to_string(),
        rvars_on.to_string(),
        names(&on.selected),
    ]);
    println!("CFS feature selection for target {} (Table 5):", info.target);
    print!("{}", t.render());
    println!("distinctness = {:.2}\n", cfs::distinctness(&off.selected, &on.selected));

    // ---- Table 6: association rules with relationship variables ----
    let rules = apriori::apriori(schema, joint, Default::default(), None);
    let with_rel = rules.iter().filter(|r| r.uses_rel_var(schema)).count();
    println!("Top {} association rules by lift — {}/{} use relationship variables (Table 6):",
        rules.len(), with_rel, rules.len());
    for (i, r) in rules.iter().enumerate() {
        println!(
            "  {:>2}. lift {:.2} sup {:.3} conf {:.2}  {}",
            i + 1,
            r.lift,
            r.support,
            r.confidence,
            r.render(schema)
        );
    }
}
