//! End-to-end coverage of the two-word (65–128-bit) packed tier.
//!
//! A schema whose joint contingency-table layout is wider than 64 bits —
//! the regime of the paper's hepatitis/imdb benchmarks — must run the whole
//! Möbius Join on packed integer kernels, with **zero** routings into the
//! row-major reference operators. This binary holds only wide-tier tests so
//! the process-global fallback counter delta is meaningful (the lib tests
//! exercise the fallback path deliberately and would inflate it).

use mrss::ct::reference::reference_op_fallbacks;
use mrss::db::{Database, DatabaseBuilder};
use mrss::mobius::MobiusJoin;
use mrss::schema::SchemaBuilder;
use mrss::util::Pcg64;
use std::sync::Arc;

/// Two populations, two parallel relationships between them, and enough
/// 8-ary attributes that every chain's table layout lands in 65..=128 bits:
///
/// * entity tables: 10 attrs x 3 bits = 30 bits each (one-word tier);
/// * `ct_T(R_i)`: 30 + 30 + 2 x 4 = 68 bits (two-word tier);
/// * full chain table `{R1, R2}`: 60 + 2 x 1 + 4 x 4 = 78 bits.
fn wide_db(seed: u64) -> Database {
    let mut sb = SchemaBuilder::new("wide-tier");
    let pa = sb.population("Alpha");
    let pb = sb.population("Beta");
    for i in 0..10 {
        sb.attr(pa, &format!("a{i}"), &["0", "1", "2", "3", "4", "5", "6", "7"]);
        sb.attr(pb, &format!("b{i}"), &["0", "1", "2", "3", "4", "5", "6", "7"]);
    }
    let r1 = sb.relationship("R1", pa, pb);
    sb.rel_attr(r1, "r1x", &["0", "1", "2", "3", "4", "5", "6", "7"]);
    sb.rel_attr(r1, "r1y", &["0", "1", "2", "3", "4", "5", "6", "7"]);
    let r2 = sb.relationship("R2", pa, pb);
    sb.rel_attr(r2, "r2x", &["0", "1", "2", "3", "4", "5", "6", "7"]);
    sb.rel_attr(r2, "r2y", &["0", "1", "2", "3", "4", "5", "6", "7"]);
    let schema = Arc::new(sb.finish());

    let mut rng = Pcg64::seeded(seed);
    let mut b = DatabaseBuilder::new(schema);
    let na = 6u32;
    let nb = 5u32;
    let mut alphas = Vec::new();
    let mut betas = Vec::new();
    for _ in 0..na {
        let codes: Vec<u16> = (0..10).map(|_| rng.below(8) as u16).collect();
        alphas.push(b.add_entity(pa, &codes));
    }
    for _ in 0..nb {
        let codes: Vec<u16> = (0..10).map(|_| rng.below(8) as u16).collect();
        betas.push(b.add_entity(pb, &codes));
    }
    for &x in &alphas {
        for &y in &betas {
            if rng.chance(0.6) {
                b.add_rel(r1, x, y, &[rng.below(8) as u16, rng.below(8) as u16]);
            }
            if rng.chance(0.5) {
                b.add_rel(r2, x, y, &[rng.below(8) as u16, rng.below(8) as u16]);
            }
        }
    }
    b.finish()
}

#[test]
fn wide_joint_runs_packed_end_to_end_without_fallbacks() {
    let db = wide_db(42);
    let before = reference_op_fallbacks();
    let res = MobiusJoin::new(&db).run();
    let after = reference_op_fallbacks();

    // The acceptance bar for the two-word operator tier: not one ct-algebra
    // call left the packed path across the whole dynamic program.
    assert_eq!(after - before, 0, "row-major reference fallbacks occurred");
    assert_eq!(res.metrics.reference_fallbacks, 0);

    // The joint table really is in the two-word regime.
    let joint = res.joint_ct();
    let bits = joint.layout().total_bits();
    assert!((65..=128).contains(&bits), "joint layout is {bits} bits");
    assert!(joint.is_packed2(), "joint tier is {}", joint.tier());
    joint.check_invariants().unwrap();

    // Proposition 1: the joint covers every entity instantiation once.
    let expect: u128 = db
        .schema
        .fo_vars
        .iter()
        .map(|f| db.entity_counts[f.pop] as u128)
        .product();
    assert_eq!(joint.total(), expect);

    // Every chain table (levels 1 and 2) is on a packed tier too.
    for (chain, table) in &res.tables {
        assert!(table.is_packed(), "chain {chain:?} on tier {}", table.tier());
        table.check_invariants().unwrap();
    }

    // Consistency: conditioning the joint on both indicators true must
    // reproduce the positive-only statistics (still fallback-free).
    let link_off = res.link_off();
    assert!(link_off.total() > 0);
    assert_eq!(reference_op_fallbacks() - before, 0);
}

#[test]
fn wide_parallel_run_matches_serial() {
    let db = wide_db(7);
    let serial = MobiusJoin::new(&db).run();
    let parallel = MobiusJoin::new(&db).workers(4).run();
    assert_eq!(serial.joint_ct(), parallel.joint_ct());
    assert_eq!(serial.tables.len(), parallel.tables.len());
    for (chain, table) in &serial.tables {
        assert_eq!(table, &parallel.tables[chain], "chain {chain:?} differs");
    }
    assert_eq!(serial.metrics.reference_fallbacks, 0);
    assert_eq!(parallel.metrics.reference_fallbacks, 0);
}

#[test]
fn wide_depth_capped_run_stays_packed() {
    let db = wide_db(9);
    let before = reference_op_fallbacks();
    let capped = MobiusJoin::new(&db).max_chain_len(1).run();
    assert_eq!(reference_op_fallbacks() - before, 0);
    assert!(capped.joint.is_none());
    for table in capped.tables.values() {
        assert!(table.is_packed2(), "level-1 table tier {}", table.tier());
    }
}
