//! Observability over the wire: `EXPLAIN` span trees (Möbius subtraction
//! visible on a positives-only store), `METRICS` through the Prometheus
//! validator, `DUMP` flight-recorder contents, the sampled access log,
//! and the continuous profiler (`PROFILE` captures, per-thread CPU in
//! `STATS`, process telemetry in `HISTORY`) — all exercised against a
//! live TCP server.
//!
//! These tests live in their own binary and serialize on a lock: the
//! flight recorder is process-global, and the dump assertions need to
//! know whose traces are in it.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::obs;
use mrss::schema::{RandomVar, Schema};
use mrss::serve::{serve, ServeConfig, ServeHandle};
use mrss::store::{CountServer, CtStore, PersistConfig, StoreSink};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static SEQ: Mutex<()> = Mutex::new(());

fn seq() -> MutexGuard<'static, ()> {
    let g = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    obs::recorder::reset();
    g
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn build_store(tag: &str, cfg: PersistConfig) -> (PathBuf, Schema) {
    let dir = tmpdir(tag);
    let db = datagen::generate("uwcse", 0.1, 7).unwrap();
    let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
    {
        let sink = StoreSink::new(&store, &db.schema, cfg);
        MobiusJoin::new(&db).sink(&sink).run();
        sink.take_error().unwrap();
    }
    (dir, (*db.schema).clone())
}

fn start(dir: &Path, cfg: ServeConfig) -> ServeHandle {
    let count = Arc::new(CountServer::open(dir).unwrap());
    serve(count, cfg).unwrap()
}

struct Client {
    w: BufWriter<TcpStream>,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        Client { w: BufWriter::new(s.try_clone().unwrap()), r: BufReader::new(s) }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.w, "{line}").unwrap();
        self.w.flush().unwrap();
        let mut out = String::new();
        self.r.read_line(&mut out).unwrap();
        out.trim_end().to_string()
    }

    /// `METRICS` is the protocol's one multi-line response: read until
    /// the `# EOF` terminator, keeping it (the validator skips comments).
    fn scrape(&mut self) -> String {
        writeln!(self.w, "METRICS").unwrap();
        self.w.flush().unwrap();
        let mut doc = String::new();
        loop {
            let mut l = String::new();
            assert_ne!(self.r.read_line(&mut l).unwrap(), 0, "EOF before `# EOF`:\n{doc}");
            let done = l.trim_end() == "# EOF";
            doc.push_str(&l);
            if done {
                return doc;
            }
        }
    }
}

/// Pull the first `"key":<uint>` value out of a JSON response (enough for
/// the flat documents these tests assert on).
fn json_u64(doc: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let i = doc.find(&pat).unwrap_or_else(|| panic!("no {key} in {doc}"));
    doc[i + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("non-numeric {key} in {doc}"))
}

/// Sum every `"key":<uint>` occurrence in a JSON series.
fn json_u64_sum(doc: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let mut sum = 0;
    let mut rest = doc;
    while let Some(i) = rest.find(&pat) {
        rest = &rest[i + pat.len()..];
        let n: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        sum += n.parse::<u64>().unwrap_or(0);
    }
    sum
}

/// A query with a negative relationship condition — the shape that can
/// only be answered by Möbius subtraction when no indicator-bearing
/// table exists.
fn negative_query(schema: &Schema) -> String {
    let v = (0..schema.random_vars.len())
        .find(|&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
        .expect("uwcse has relationship variables");
    format!("{}=F", schema.var_name(v))
}

#[test]
fn explain_on_a_negative_query_names_the_mobius_subtraction_span() {
    let _g = seq();
    // Positives-only store: no chain/joint tables, so the negative
    // condition forces the Möbius peel — and the trace must say so.
    let (dir, schema) = build_store("explain", PersistConfig::positives_only());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    let q = negative_query(&schema);

    let line = c.send(&format!("EXPLAIN {q}"));
    assert!(line.starts_with("{\"query\":"), "{line}");
    assert!(line.contains("\"count\":"), "{line}");
    assert!(line.contains("\"trace\":{"), "{line}");
    assert!(line.contains("\"outcome\":\"ok\""), "{line}");
    for span in ["plan.parse", "plan.normalize", "plan.fo_groups", "mobius.subtract", "table.count"]
    {
        assert!(line.contains(&format!("\"name\":\"{span}\"")), "missing span {span}: {line}");
    }

    // The trace carries the full per-query cost block, and the Möbius peel
    // the positives-only store forced is charged to subtract_depth.
    assert!(line.contains("\"cost\":{\"tables_loaded\":"), "{line}");
    for key in ["\"bytes_scanned\":", "\"adtree_nodes_probed\":", "\"rows_merged\":", "\"units\":"] {
        assert!(line.contains(key), "missing cost key {key}: {line}");
    }
    let depth = json_u64(&line, "subtract_depth");
    assert!(depth >= 1, "expected a Möbius subtraction charged, got depth {depth}: {line}");

    // EXPLAIN of a broken query still answers, with the error inline.
    let line = c.send("EXPLAIN nope(X)=1");
    assert!(line.contains("\"error\":"), "{line}");
    assert!(line.contains("\"outcome\":\"error\""), "{line}");

    // A plain COUNT of the same query is unaffected by EXPLAIN traffic.
    let line = c.send(&q);
    assert!(line.contains("\"count\":"), "{line}");

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn metrics_scrape_passes_the_validator() {
    let _g = seq();
    let (dir, schema) = build_store("metrics", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    // Some traffic first so the counters and histograms are non-trivial.
    for q in mrss::store::gen_queries(&schema, 5, 42) {
        c.send(&q);
    }
    let doc = c.scrape();
    obs::prom::validate(&doc).unwrap_or_else(|e| panic!("{e}\n---\n{doc}"));
    for family in [
        "mrss_queries_total",
        "mrss_exec_latency_us_bucket",
        "mrss_queue_wait_us_count",
        "mrss_store_hits_total",
        "mrss_adtree_builds_total",
        "mrss_mj_ct_ops_total{op=\"subtract\"}",
        "mrss_traces_started_total",
    ] {
        assert!(doc.contains(family), "missing {family} in\n{doc}");
    }
    assert!(doc.ends_with("# EOF\n"), "unterminated scrape");
    assert!(doc.contains("mrss_queries_total 5"), "{doc}");
    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampled_requests_land_in_dump_access_log_and_queue_stats() {
    let _g = seq();
    let (dir, schema) = build_store("dump", PersistConfig::default());
    let log_path = dir.join("access.log");
    let cfg = ServeConfig {
        trace_sample: 1,
        access_log: Some(log_path.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let handle = start(&dir, cfg);
    let mut c = Client::connect(handle.addr());

    let q = negative_query(&schema);
    assert!(c.send(&q).contains("\"count\":"));
    assert!(c.send("nope(X)=1").contains("\"error\":"));

    // Both requests were sampled (1/1): the flight recorder holds full
    // traces for them, queryable over the wire.
    let dump = c.send("DUMP");
    assert!(dump.starts_with("{\"recorded\":"), "{dump}");
    assert!(dump.contains(&format!("\"query\":\"{q}\"")), "{dump}");
    assert!(dump.contains("\"query\":\"nope(X)=1\""), "{dump}");
    assert!(dump.contains("\"outcome\":\"error\""), "{dump}");
    assert!(dump.contains("\"name\":\"parse\""), "{dump}");
    assert!(dump.contains("\"name\":\"render\""), "{dump}");
    assert!(dump.contains("\"slowest\":["), "{dump}");

    // STATS splits queue wait from exec latency.
    let stats = c.send("STATS");
    assert!(stats.contains("\"queue\":{\"p50_us\":"), "{stats}");
    assert!(stats.contains("\"dataset\":\"uwcse\""), "{stats}");

    // The access log has one wide-event line per sampled request.
    let log = std::fs::read_to_string(&log_path).unwrap();
    let lines: Vec<&str> = log.lines().collect();
    assert_eq!(lines.len(), 2, "{log}");
    assert!(lines[0].contains(&format!("\"query\":\"{q}\"")), "{log}");
    assert!(lines[0].contains("\"outcome\":\"ok\""), "{log}");
    assert!(lines[1].contains("\"outcome\":\"error\""), "{log}");
    for key in ["\"conn\":", "\"queue_us\":", "\"exec_us\":", "\"bytes\":", "\"batch\":1"] {
        assert!(lines[0].contains(key), "missing {key}: {log}");
    }

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn untraced_server_answers_dump_with_an_empty_recorder() {
    let _g = seq();
    let (dir, _schema) = build_store("cold", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    // trace_sample = 0 and no EXPLAIN: healthy requests leave no trace —
    // but the heavy-hitter sketch still sees the query, and DUMP folds the
    // sketch in after the recorder fields.
    assert!(c.send("position(P1)=faculty").contains("\"count\":"));
    let dump = c.send("DUMP");
    assert!(dump.starts_with("{\"recorded\":0,\"last\":[],\"slowest\":[],\"top\":{"), "{dump}");
    assert_eq!(json_u64(&dump, "entries"), 1, "{dump}");
    assert!(dump.contains("\"sig\":\"attrs:1\""), "{dump}");
    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn top_ranks_the_hot_plan_signature_first_with_exact_counts() {
    let _g = seq();
    let (dir, schema) = build_store("top", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());

    // Skewed mix: one hot negative-condition shape, a cold attribute
    // shape. Two distinct signatures, far below the sketch capacity, so
    // Misra-Gries degrades to exact counting.
    let hot = negative_query(&schema);
    for _ in 0..6 {
        assert!(c.send(&hot).contains("\"count\":"), "hot query failed");
    }
    for _ in 0..2 {
        assert!(c.send("position(P1)=faculty").contains("\"count\":"));
    }

    let top = c.send("TOP 3");
    assert!(top.starts_with("{\"entries\":"), "{top}");
    assert_eq!(json_u64(&top, "entries"), 2, "{top}");
    assert_eq!(json_u64(&top, "total"), 8, "{top}");
    assert_eq!(json_u64(&top, "decrements"), 0, "exact below capacity: {top}");
    // The hot signature ranks first in by_count, with its exact count.
    let by_count = &top[top.find("\"by_count\":[").expect("by_count ranking")..];
    let first = &by_count[..by_count.find('}').unwrap()];
    assert!(first.contains("\"count\":6"), "hot shape not first: {top}");
    assert!(top.contains("\"sig\":\"attrs:1\""), "{top}");
    assert!(top.contains("\"count\":2"), "{top}");

    // TOP is an admin verb: it must not count itself into the query load.
    let stats = c.send("STATS");
    assert_eq!(json_u64(&stats, "queries"), 8, "{stats}");
    assert!(json_u64(&stats, "admin_requests") >= 2, "{stats}");

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn history_ring_advances_and_slots_sum_to_the_request_counter() {
    let _g = seq();
    let (dir, schema) = build_store("history", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());

    let queries = mrss::store::gen_queries(&schema, 5, 42);
    for q in &queries {
        c.send(q);
    }
    // Let the shard-0 tick flush the first window, issue one more query,
    // then wait out another flush: the ring must keep advancing.
    std::thread::sleep(Duration::from_millis(1600));
    let early = c.send("HISTORY 30");
    assert!(early.starts_with("{\"slots\":"), "{early}");
    let early_slots = json_u64(&early, "slots");
    assert!(early_slots >= 1, "no slot flushed after 1.6s: {early}");

    c.send(&queries[0]);
    std::thread::sleep(Duration::from_millis(2200));
    let hist = c.send("HISTORY 30");
    assert!(json_u64(&hist, "slots") > early_slots, "ring did not advance: {hist}");
    assert_eq!(json_u64(&hist, "window_secs"), 30, "{hist}");
    assert!(hist.contains("\"series\":[{\"t\":"), "{hist}");

    // Every count query landed in exactly one slot; admin traffic
    // (HISTORY itself, STATS below) stays out of the per-second qps.
    assert_eq!(json_u64_sum(&hist, "queries"), 6, "slot sums != requests served: {hist}");
    let stats = c.send("STATS");
    assert_eq!(json_u64(&stats, "queries"), 6, "{stats}");

    // Cost flows into the windows too: the slots that saw traffic carry
    // non-zero cost units.
    assert!(json_u64_sum(&hist, "cost_units") > 0, "{hist}");

    // Process telemetry rides the same tick: every flushed slot carries
    // the point-in-time resident set (Linux /proc only — zero elsewhere).
    if cfg!(target_os = "linux") {
        assert!(json_u64_sum(&hist, "rss_bytes") > 0, "no rss in slots: {hist}");
        assert!(json_u64_sum(&hist, "open_fds") > 0, "no fds in slots: {hist}");
    }

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn profile_capture_pins_the_injected_delay_as_the_hot_frame() {
    let _g = seq();
    let (dir, schema) = build_store("profile", PersistConfig::default());
    // Every query sleeps inside the `worker.exec.delay` span, so a 1 s
    // capture under load must attribute most non-idle leaf samples to it.
    let cfg = ServeConfig {
        exec_delay: Duration::from_millis(10),
        profile_hz: 241,
        ..Default::default()
    };
    let handle = start(&dir, cfg);
    let addr = handle.addr();

    // Keep one connection busy for the whole capture window.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let load = {
        let stop = Arc::clone(&stop);
        let q = negative_query(&schema);
        std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                assert!(c.send(&q).contains("\"count\":"), "load query failed");
            }
        })
    };

    let mut admin = Client::connect(addr);
    let line = admin.send("PROFILE 1");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    load.join().unwrap();

    assert!(line.starts_with("{\"secs\":1,"), "{line}");
    let ticks = json_u64(&line, "ticks");
    assert!(ticks > 0, "sampler took no ticks: {line}");

    // Conservation: every sampler tick folds into exactly one stack.
    let folded = obs::profile::parse_folded(&line);
    assert!(!folded.is_empty(), "no folded stacks: {line}");
    let sum: u64 = folded.iter().map(|&(_, n)| n).sum();
    assert_eq!(sum, ticks, "folded mass != sampler ticks: {line}");

    // Leaf attribution: the injected delay dominates non-idle self time.
    let mut self_time = std::collections::HashMap::<&str, u64>::new();
    for (stack, n) in &folded {
        let leaf = stack.rsplit(';').next().unwrap();
        if leaf == "<torn>" || leaf.ends_with(".idle") {
            continue;
        }
        *self_time.entry(leaf).or_default() += n;
    }
    let (hot, hot_n) = self_time
        .iter()
        .max_by_key(|&(_, n)| *n)
        .map(|(f, n)| (f.to_string(), *n))
        .unwrap_or_else(|| panic!("no non-idle frames sampled: {line}"));
    assert_eq!(hot, "worker.exec.delay", "wrong hot frame ({hot}: {hot_n}): {line}");
    assert!(line.contains("serve.exec;worker.exec.delay"), "delay lost its parent: {line}");

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn thread_cpu_counters_rise_between_stats_snapshots() {
    let _g = seq();
    let (dir, schema) = build_store("cpu", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());

    let queries = mrss::store::gen_queries(&schema, 5, 42);
    for q in &queries {
        c.send(q);
    }
    let s1 = c.send("STATS");
    // The worker role leads the `threads` object, so the first
    // busy_us/idle_us in the document are the worker pool's.
    assert!(s1.contains("\"threads\":{\"worker\":{\"busy_us\":"), "{s1}");
    let (busy1, idle1) = (json_u64(&s1, "busy_us"), json_u64(&s1, "idle_us"));

    for _ in 0..40 {
        for q in &queries {
            c.send(q);
        }
    }
    let s2 = c.send("STATS");
    let (busy2, idle2) = (json_u64(&s2, "busy_us"), json_u64(&s2, "idle_us"));
    assert!(busy2 > busy1, "worker busy_us did not advance: {busy1} -> {busy2}\n{s2}");
    assert!(busy2 + idle2 > busy1 + idle1, "worker CPU clock stalled: {s2}");

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
