//! Observability over the wire: `EXPLAIN` span trees (Möbius subtraction
//! visible on a positives-only store), `METRICS` through the Prometheus
//! validator, `DUMP` flight-recorder contents, and the sampled access
//! log — all exercised against a live TCP server.
//!
//! These tests live in their own binary and serialize on a lock: the
//! flight recorder is process-global, and the dump assertions need to
//! know whose traces are in it.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::obs;
use mrss::schema::{RandomVar, Schema};
use mrss::serve::{serve, ServeConfig, ServeHandle};
use mrss::store::{CountServer, CtStore, PersistConfig, StoreSink};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static SEQ: Mutex<()> = Mutex::new(());

fn seq() -> MutexGuard<'static, ()> {
    let g = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    obs::recorder::reset();
    g
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn build_store(tag: &str, cfg: PersistConfig) -> (PathBuf, Schema) {
    let dir = tmpdir(tag);
    let db = datagen::generate("uwcse", 0.1, 7).unwrap();
    let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
    {
        let sink = StoreSink::new(&store, &db.schema, cfg);
        MobiusJoin::new(&db).sink(&sink).run();
        sink.take_error().unwrap();
    }
    (dir, (*db.schema).clone())
}

fn start(dir: &Path, cfg: ServeConfig) -> ServeHandle {
    let count = Arc::new(CountServer::open(dir).unwrap());
    serve(count, cfg).unwrap()
}

struct Client {
    w: BufWriter<TcpStream>,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        Client { w: BufWriter::new(s.try_clone().unwrap()), r: BufReader::new(s) }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.w, "{line}").unwrap();
        self.w.flush().unwrap();
        let mut out = String::new();
        self.r.read_line(&mut out).unwrap();
        out.trim_end().to_string()
    }

    /// `METRICS` is the protocol's one multi-line response: read until
    /// the `# EOF` terminator, keeping it (the validator skips comments).
    fn scrape(&mut self) -> String {
        writeln!(self.w, "METRICS").unwrap();
        self.w.flush().unwrap();
        let mut doc = String::new();
        loop {
            let mut l = String::new();
            assert_ne!(self.r.read_line(&mut l).unwrap(), 0, "EOF before `# EOF`:\n{doc}");
            let done = l.trim_end() == "# EOF";
            doc.push_str(&l);
            if done {
                return doc;
            }
        }
    }
}

/// A query with a negative relationship condition — the shape that can
/// only be answered by Möbius subtraction when no indicator-bearing
/// table exists.
fn negative_query(schema: &Schema) -> String {
    let v = (0..schema.random_vars.len())
        .find(|&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
        .expect("uwcse has relationship variables");
    format!("{}=F", schema.var_name(v))
}

#[test]
fn explain_on_a_negative_query_names_the_mobius_subtraction_span() {
    let _g = seq();
    // Positives-only store: no chain/joint tables, so the negative
    // condition forces the Möbius peel — and the trace must say so.
    let (dir, schema) = build_store("explain", PersistConfig::positives_only());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    let q = negative_query(&schema);

    let line = c.send(&format!("EXPLAIN {q}"));
    assert!(line.starts_with("{\"query\":"), "{line}");
    assert!(line.contains("\"count\":"), "{line}");
    assert!(line.contains("\"trace\":{"), "{line}");
    assert!(line.contains("\"outcome\":\"ok\""), "{line}");
    for span in ["plan.parse", "plan.normalize", "plan.fo_groups", "mobius.subtract", "table.count"]
    {
        assert!(line.contains(&format!("\"name\":\"{span}\"")), "missing span {span}: {line}");
    }

    // EXPLAIN of a broken query still answers, with the error inline.
    let line = c.send("EXPLAIN nope(X)=1");
    assert!(line.contains("\"error\":"), "{line}");
    assert!(line.contains("\"outcome\":\"error\""), "{line}");

    // A plain COUNT of the same query is unaffected by EXPLAIN traffic.
    let line = c.send(&q);
    assert!(line.contains("\"count\":"), "{line}");

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn metrics_scrape_passes_the_validator() {
    let _g = seq();
    let (dir, schema) = build_store("metrics", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    // Some traffic first so the counters and histograms are non-trivial.
    for q in mrss::store::gen_queries(&schema, 5, 42) {
        c.send(&q);
    }
    let doc = c.scrape();
    obs::prom::validate(&doc).unwrap_or_else(|e| panic!("{e}\n---\n{doc}"));
    for family in [
        "mrss_queries_total",
        "mrss_exec_latency_us_bucket",
        "mrss_queue_wait_us_count",
        "mrss_store_hits_total",
        "mrss_adtree_builds_total",
        "mrss_mj_ct_ops_total{op=\"subtract\"}",
        "mrss_traces_started_total",
    ] {
        assert!(doc.contains(family), "missing {family} in\n{doc}");
    }
    assert!(doc.ends_with("# EOF\n"), "unterminated scrape");
    assert!(doc.contains("mrss_queries_total 5"), "{doc}");
    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampled_requests_land_in_dump_access_log_and_queue_stats() {
    let _g = seq();
    let (dir, schema) = build_store("dump", PersistConfig::default());
    let log_path = dir.join("access.log");
    let cfg = ServeConfig {
        trace_sample: 1,
        access_log: Some(log_path.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let handle = start(&dir, cfg);
    let mut c = Client::connect(handle.addr());

    let q = negative_query(&schema);
    assert!(c.send(&q).contains("\"count\":"));
    assert!(c.send("nope(X)=1").contains("\"error\":"));

    // Both requests were sampled (1/1): the flight recorder holds full
    // traces for them, queryable over the wire.
    let dump = c.send("DUMP");
    assert!(dump.starts_with("{\"recorded\":"), "{dump}");
    assert!(dump.contains(&format!("\"query\":\"{q}\"")), "{dump}");
    assert!(dump.contains("\"query\":\"nope(X)=1\""), "{dump}");
    assert!(dump.contains("\"outcome\":\"error\""), "{dump}");
    assert!(dump.contains("\"name\":\"parse\""), "{dump}");
    assert!(dump.contains("\"name\":\"render\""), "{dump}");
    assert!(dump.contains("\"slowest\":["), "{dump}");

    // STATS splits queue wait from exec latency.
    let stats = c.send("STATS");
    assert!(stats.contains("\"queue\":{\"p50_us\":"), "{stats}");
    assert!(stats.contains("\"dataset\":\"uwcse\""), "{stats}");

    // The access log has one wide-event line per sampled request.
    let log = std::fs::read_to_string(&log_path).unwrap();
    let lines: Vec<&str> = log.lines().collect();
    assert_eq!(lines.len(), 2, "{log}");
    assert!(lines[0].contains(&format!("\"query\":\"{q}\"")), "{log}");
    assert!(lines[0].contains("\"outcome\":\"ok\""), "{log}");
    assert!(lines[1].contains("\"outcome\":\"error\""), "{log}");
    for key in ["\"conn\":", "\"queue_us\":", "\"exec_us\":", "\"bytes\":", "\"batch\":1"] {
        assert!(lines[0].contains(key), "missing {key}: {log}");
    }

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn untraced_server_answers_dump_with_an_empty_recorder() {
    let _g = seq();
    let (dir, _schema) = build_store("cold", PersistConfig::default());
    let handle = start(&dir, ServeConfig::default());
    let mut c = Client::connect(handle.addr());
    // trace_sample = 0 and no EXPLAIN: healthy requests leave no trace.
    assert!(c.send("position(P1)=faculty").contains("\"count\":"));
    let dump = c.send("DUMP");
    assert_eq!(dump, "{\"recorded\":0,\"last\":[],\"slowest\":[]}");
    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
