//! Integration: the XLA engine (AOT PJRT artifacts) must be bit-identical
//! to the native engine across whole Möbius Join runs. Skips (with a
//! message) when `make artifacts` has not been run.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::runtime::{XlaEngine, XlaRuntime};

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping xla integration test: {e}");
            None
        }
    }
}

#[test]
fn whole_mj_bit_identical_on_three_schemas() {
    let Some(rt) = runtime() else { return };
    let engine = XlaEngine::new(&rt);
    for (name, scale) in [("mutagenesis", 0.2), ("mondial", 0.3), ("uwcse", 0.5)] {
        let db = datagen::generate(name, scale, 7).unwrap();
        let native = MobiusJoin::new(&db).run();
        let xla = MobiusJoin::with_engine(&db, &engine).run();
        assert_eq!(native.joint_ct(), xla.joint_ct(), "{name}: joint differs");
        for (chain, table) in &native.tables {
            assert_eq!(table, &xla.tables[chain], "{name}: chain {chain:?} differs");
        }
    }
}

#[test]
fn batched_scores_match_native() {
    let Some(rt) = runtime() else { return };
    use mrss::apps::info::{family_loglik_batch, family_loglik_native, su_batch, JointCounts};
    let joints: Vec<JointCounts> = (1..20)
        .map(|i| {
            let v1 = 2 + (i % 4);
            let v2 = 2 + (i % 3);
            let data: Vec<f64> = (0..v1 * v2).map(|k| ((i * k + 3) % 17) as f64).collect();
            JointCounts { data, v1, v2 }
        })
        .collect();
    let with_rt = su_batch(&joints, Some(&rt));
    let without = su_batch(&joints, None);
    for (a, b) in with_rt.iter().zip(&without) {
        assert!((a - b).abs() < 1e-9, "su {a} vs {b}");
    }
    let fams: Vec<(Vec<f64>, usize, usize)> = (1..12)
        .map(|i| {
            let p = 2 + (i % 5);
            let c = 2 + (i % 3);
            let data: Vec<f64> = (0..p * c).map(|k| ((i * 7 + k) % 23) as f64).collect();
            (data, p, c)
        })
        .collect();
    let with_rt = family_loglik_batch(&fams, Some(&rt));
    for ((m, p, c), got) in fams.iter().zip(&with_rt) {
        let want = family_loglik_native(m, *p, *c);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}
