//! Integration: the statistical applications on generated benchmarks —
//! the paper's qualitative claims as assertions.

use mrss::apps::{apriori, bayesnet, cfs};
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::schema::RandomVar;

#[test]
fn mondial_link_off_ct_is_empty_so_cfs_returns_nothing() {
    let db = datagen::generate("mondial", 0.5, 7).unwrap();
    let res = MobiusJoin::new(&db).run();
    let off = res.link_off();
    assert!(off.is_empty(), "paper §6.3.1: Mondial all-true table empty");
    let target = db.schema.var_by_name("percentage(C1)").unwrap();
    let feats: Vec<usize> = (0..db.schema.random_vars.len()).collect();
    let sel = cfs::cfs_select(&off, target, &feats, None);
    assert!(sel.selected.is_empty());
}

#[test]
fn uwcse_link_off_statistics_tiny() {
    let db = datagen::generate("uwcse", 1.0, 7).unwrap();
    let res = MobiusJoin::new(&db).run();
    // Paper Table 4: 2 link-off statistics for UW-CSE. In our schema the
    // isolated Course table cross-multiplies the joint, so the bound is
    // 2 x (observed course combos); the relationship part itself must be 2.
    let jc = mrss::db::JoinCounter::new(&db);
    assert_eq!(jc.positive_ct(&[0, 1]).total(), 2, "exactly two overlapping advisor pairs");
    let course_fo = db.schema.populations[1].fo_vars[0];
    let course_combos = db.ct_entity(course_fo).len();
    assert!(
        res.link_off().len() <= 2 * course_combos.max(1),
        "got {} (course combos {})",
        res.link_off().len(),
        course_combos
    );
    assert!(res.num_extra_statistics() > 100);
}

#[test]
fn rules_with_rel_vars_only_appear_link_on() {
    let db = datagen::generate("mutagenesis", 0.3, 7).unwrap();
    let res = MobiusJoin::new(&db).run();
    let schema = &db.schema;
    let on_rules = apriori::apriori(schema, res.joint_ct(), Default::default(), None);
    let off_rules = apriori::apriori(schema, &res.link_off(), Default::default(), None);
    // Link-off: indicators constant T => they never appear with value F and
    // lift of a constant-T item is 1 (filtered); realistically no rel-var
    // rule should survive.
    assert!(off_rules
        .iter()
        .all(|r| !r.uses_rel_var(schema) || r.lift < 1.2));
    assert!(
        on_rules.iter().any(|r| r.uses_rel_var(schema)),
        "link-on should surface relationship rules"
    );
}

#[test]
fn bn_link_on_can_learn_rel_edges_off_cannot() {
    for name in ["financial", "mutagenesis"] {
        let db = datagen::generate(name, 0.1, 7).unwrap();
        let res = MobiusJoin::new(&db).run();
        let schema = &db.schema;
        let off = bayesnet::learn_structure(schema, &res, false, Default::default());
        let (r2r, a2r) = off.bn.edge_kinds(schema);
        assert_eq!(r2r + a2r, 0, "{name}: off learned rel edges");
        let on = bayesnet::learn_structure(schema, &res, true, Default::default());
        let m_on = bayesnet::score_structure(schema, &on.bn, res.joint_ct(), None);
        let m_off = bayesnet::score_structure(schema, &off.bn, res.joint_ct(), None);
        // Link-on sees strictly more information; its fit on the link-on
        // table must be at least as good.
        assert!(
            m_on.loglik >= m_off.loglik - 1e-9,
            "{name}: on {} < off {}",
            m_on.loglik,
            m_off.loglik
        );
    }
}

#[test]
fn cfs_selects_rel_feature_on_planted_schema() {
    // financial plants balance(T) <- account freq via HasTrans; with link
    // on, CFS must select a different set than link off (Table 5 shape).
    let db = datagen::generate("financial", 0.15, 7).unwrap();
    let res = MobiusJoin::new(&db).run();
    let schema = &db.schema;
    let target = schema.var_by_name("balance(T)").unwrap();
    let attrs: Vec<usize> = (0..schema.random_vars.len())
        .filter(|&v| !matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
        .collect();
    let all: Vec<usize> = (0..schema.random_vars.len()).collect();
    let off = cfs::cfs_select(&res.link_off(), target, &attrs, None);
    let on = cfs::cfs_select(res.joint_ct(), target, &all, None);
    assert!(cfs::distinctness(&off.selected, &on.selected) > 0.0);
}
