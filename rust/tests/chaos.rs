//! Fault-injection integration: the serve/store stack driven through its
//! armed failpoints (`--features failpoints`, which the Cargo manifest
//! requires for this target). Each scenario arms a spec, injects the
//! fault, and asserts the self-healing contract: the server stays up,
//! every in-flight request gets a terminal reply, damaged tables are
//! quarantined rather than trusted, and unaffected answers match a clean
//! store byte for byte.
//!
//! The failpoint registry is process-global, so scenarios serialize on
//! one mutex and disarm on entry — a panicking test leaves the registry
//! armed, and the next scenario must not inherit its faults.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::serve::protocol::{json_field, parse_count_response};
use mrss::serve::{serve, ServeConfig, ServeHandle};
use mrss::store::{
    gen_queries, needs_table, CountServer, CtStore, PersistConfig, StoreSink, TableKind,
};
use mrss::util::failpoint;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static FP_LOCK: Mutex<()> = Mutex::new(());

/// Serialize scenarios and start each from a disarmed registry — and an
/// empty flight recorder, which is process-global for the same reason.
fn fp_guard() -> MutexGuard<'static, ()> {
    let g = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::disarm_all();
    mrss::obs::recorder::reset();
    g
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Persist uwcse and return the in-memory baseline answers for a
/// generated query batch — the byte-identity reference for every
/// degraded-store assertion.
fn build_store(dir: &PathBuf, n_queries: usize, qseed: u64) -> Vec<(String, u128)> {
    let db = datagen::generate("uwcse", 0.1, 7).unwrap();
    let store = CtStore::create(dir, "uwcse", 0.1, 7).unwrap();
    {
        let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
        MobiusJoin::new(&db).sink(&sink).run();
        sink.take_error().unwrap();
    }
    drop(store);
    let server = CountServer::open(dir).unwrap();
    gen_queries(&db.schema, n_queries, qseed)
        .into_iter()
        .map(|q| {
            let c = server.count_query(&q).unwrap();
            (q, c)
        })
        .collect()
}

fn start_server(dir: &PathBuf, cfg: ServeConfig) -> ServeHandle {
    let count = Arc::new(CountServer::open(dir).unwrap());
    serve(count, cfg).unwrap()
}

/// Connect with a read timeout so an injected fault that swallows a reply
/// fails the test instead of hanging it.
fn connect(addr: SocketAddr) -> (BufWriter<TcpStream>, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    (BufWriter::new(stream.try_clone().unwrap()), BufReader::new(stream))
}

fn roundtrip_on(
    w: &mut BufWriter<TcpStream>,
    r: &mut BufReader<TcpStream>,
    line: &str,
) -> String {
    writeln!(w, "{line}").unwrap();
    w.flush().unwrap();
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    assert!(!resp.is_empty(), "connection closed instead of replying to `{line}`");
    resp.trim().to_string()
}

#[test]
fn worker_panic_is_isolated_and_the_server_keeps_serving() {
    let _g = fp_guard();
    let dir = tmpdir("panic");
    let baseline = build_store(&dir, 4, 11);
    failpoint::arm("worker.exec.panic=hit:2").unwrap();
    let handle = start_server(&dir, ServeConfig { threads: 2, ..Default::default() });

    // Sequential queries on one connection: the first two hit the armed
    // panic and must come back as terminal ERR replies — the worker, the
    // connection, and the process all survive.
    let (mut w, mut r) = connect(handle.addr());
    for (i, (q, expect)) in baseline.iter().enumerate() {
        let resp = roundtrip_on(&mut w, &mut r, q);
        if i < 2 {
            let e = parse_count_response(&resp).unwrap_err();
            assert!(e.contains("worker panicked"), "query {i}: {resp}");
        } else {
            assert_eq!(parse_count_response(&resp), Ok(*expect), "query {i}: {resp}");
        }
    }

    let stats = roundtrip_on(&mut w, &mut r, "STATS");
    assert_eq!(json_field(&stats, "worker_panics").as_deref(), Some("2"), "{stats}");

    drop((w, r));
    handle.request_shutdown();
    let snap = handle.wait();
    assert_eq!(snap.active, 0, "a connection was stranded: {snap:?}");
    assert_eq!(snap.worker_panics, 2);
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flight_recorder_captures_the_panicking_query_even_unsampled() {
    let _g = fp_guard();
    let dir = tmpdir("fr_panic");
    let baseline = build_store(&dir, 1, 66);
    failpoint::arm("worker.exec.panic=hit:1").unwrap();
    // Default config: trace_sample is 0, so nothing about this request
    // is sampled — the abnormal outcome alone must put it on record.
    let handle = start_server(&dir, ServeConfig::default());

    let (mut w, mut r) = connect(handle.addr());
    let q = &baseline[0].0;
    let resp = roundtrip_on(&mut w, &mut r, q);
    assert!(resp.contains("worker panicked"), "{resp}");

    let dump = roundtrip_on(&mut w, &mut r, "DUMP");
    assert!(dump.contains(&format!("\"query\":\"{q}\"")), "{dump}");
    assert!(dump.contains("\"outcome\":\"panic\""), "{dump}");

    // The follow-up healthy query stays off the record.
    let resp = roundtrip_on(&mut w, &mut r, q);
    assert!(parse_count_response(&resp).is_ok(), "{resp}");
    let dump = roundtrip_on(&mut w, &mut r, "DUMP");
    assert!(dump.contains("\"recorded\":1,"), "{dump}");

    drop((w, r));
    handle.request_shutdown();
    handle.wait();
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_write_is_quarantined_on_reopen_and_surviving_answers_match_clean() {
    let _g = fp_guard();
    let dir = tmpdir("torn");
    let baseline = build_store(&dir, 30, 22);

    // Re-persist one complete-chain table with the torn-write failpoint
    // armed: the file lands truncated behind a valid manifest entry — the
    // exact damage a crash between write and sync leaves behind.
    let victim = {
        let store = CtStore::open(&dir).unwrap();
        let meta = store
            .tables()
            .into_iter()
            .find(|m| matches!(m.kind, TableKind::Chain(_)))
            .expect("default store must hold a chain table");
        let table = store.get(&meta.key).unwrap();
        failpoint::arm("store.write.torn=hit:1").unwrap();
        store.put(meta.kind.clone(), &meta.scope, &table).unwrap();
        meta.key
    };
    assert_eq!(failpoint::fired_count("store.write.torn"), 1);

    // Reopen: the scrub must catch the damage, quarantine the file, and
    // keep serving — every baseline answer still byte-identical via the
    // surviving tables (the joint covers any one lost chain).
    let server = CountServer::open(&dir).unwrap();
    assert_eq!(server.quarantined(), &[victim.clone()]);
    assert_eq!(server.store().stats().quarantined_tables, 1);
    assert!(dir.join(format!("{victim}.ct.bad")).exists(), "evidence file missing");
    assert!(!dir.join(format!("{victim}.ct")).exists(), "damaged file still live");
    for (q, expect) in &baseline {
        let got = server.count_query(q).unwrap();
        assert_eq!(got, *expect, "degraded store diverged on `{q}`");
    }

    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn read_corruption_quarantines_the_table_and_the_retry_self_heals() {
    let _g = fp_guard();
    let dir = tmpdir("corrupt");
    let baseline = build_store(&dir, 24, 33);
    // A relationship-indicator query must read a chain/positive/joint
    // table from disk (the open reads only the manifest), so the armed
    // corruption deterministically lands on this query's first read.
    let (q, expect) = baseline
        .iter()
        .find(|(q, _)| q.contains("=T") || q.contains("=F") || q.contains("=n/a"))
        .expect("batch of 24 must contain a relationship indicator query");

    let server = CountServer::open(&dir).unwrap();
    failpoint::arm("store.read.corrupt=hit:1").unwrap();

    let err = server.count_query(q).unwrap_err();
    assert!(err.to_string().contains("quarantined"), "{err}");
    assert_eq!(server.store().stats().quarantined_tables, 1);

    // Same query again: the quarantined table is out of the manifest, so
    // the service derives the count from the survivors — exactly.
    match server.count_query(q) {
        Ok(got) => assert_eq!(got, *expect, "self-healed answer diverged on `{q}`"),
        Err(e) => panic!(
            "full store must derive around one lost table, got {e} (needs: {:?})",
            needs_table(&e)
        ),
    }

    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn accept_errors_delay_but_do_not_lose_connections() {
    let _g = fp_guard();
    let dir = tmpdir("accept");
    let _ = build_store(&dir, 1, 44);
    failpoint::arm("net.accept.err=hit:1").unwrap();
    let handle = start_server(&dir, ServeConfig::default());

    // The first readiness event eats the injected error; the second
    // connection re-arms readiness and both get accepted and served.
    let (mut w1, mut r1) = connect(handle.addr());
    let (mut w2, mut r2) = connect(handle.addr());
    assert!(roundtrip_on(&mut w2, &mut r2, "PING").contains("pong"));
    assert!(roundtrip_on(&mut w1, &mut r1, "PING").contains("pong"));
    assert_eq!(failpoint::fired_count("net.accept.err"), 1);

    drop((w1, r1, w2, r2));
    handle.request_shutdown();
    assert_eq!(handle.wait().active, 0);
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_slow_worker_trips_the_request_deadline_and_stats_show_it() {
    let _g = fp_guard();
    let dir = tmpdir("deadline");
    let baseline = build_store(&dir, 1, 55);
    failpoint::arm("worker.exec.delay=always@300").unwrap();
    let handle = start_server(
        &dir,
        ServeConfig { request_timeout: Some(Duration::from_millis(50)), ..Default::default() },
    );

    // The injected 300 ms stall blows the 50 ms budget: the client gets a
    // terminal deadline error, and the connection survives to PING.
    let (mut w, mut r) = connect(handle.addr());
    let resp = roundtrip_on(&mut w, &mut r, &baseline[0].0);
    assert!(resp.contains("deadline exceeded"), "{resp}");
    assert!(roundtrip_on(&mut w, &mut r, "PING").contains("pong"));

    // All four robustness counters ride the same STATS document.
    failpoint::disarm_all();
    let stats = roundtrip_on(&mut w, &mut r, "STATS");
    for key in ["worker_panics", "conn_timeouts", "request_timeouts", "quarantined_tables"] {
        assert!(json_field(&stats, key).is_some(), "STATS missing {key}: {stats}");
    }
    assert_eq!(json_field(&stats, "request_timeouts").as_deref(), Some("1"), "{stats}");

    // The blown deadline is only classified when the stalled worker
    // finally finishes, ~250 ms after the reactor already answered —
    // poll DUMP until the flight recorder shows it.
    let mut dump = String::new();
    for _ in 0..100 {
        dump = roundtrip_on(&mut w, &mut r, "DUMP");
        if dump.contains("\"outcome\":\"deadline_exceeded\"") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(dump.contains("\"outcome\":\"deadline_exceeded\""), "{dump}");
    assert!(dump.contains(&format!("\"query\":\"{}\"", baseline[0].0)), "{dump}");

    drop((w, r));
    handle.request_shutdown();
    // The stalled worker finishes after the deadline fired; the late
    // completion must be discarded, not strand the connection.
    let snap = handle.wait();
    assert_eq!(snap.active, 0, "{snap:?}");
    assert_eq!(snap.request_timeouts, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
