//! Event-loop integration for the `serve` subsystem: the
//! connections ≫ threads claim (1k+ idle connections on the default
//! worker pool while a hot client's latency stays flat), accept-time
//! admission control, and the BATCH fan-out property — members execute
//! concurrently across the pool yet replies stay byte-identical and
//! in-order vs serial execution.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::serve::protocol::json_field;
use mrss::serve::{max_open_files, serve, ServeConfig, ServeHandle};
use mrss::store::{CountServer, CtStore, PersistConfig, StoreSink};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_serveev_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn start_uwcse(tag: &str, cfg: ServeConfig) -> (PathBuf, ServeHandle) {
    let dir = tmpdir(tag);
    let db = datagen::generate("uwcse", 0.1, 7).unwrap();
    let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
    {
        let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
        MobiusJoin::new(&db).sink(&sink).run();
        sink.take_error().unwrap();
    }
    drop(store);
    let count = Arc::new(CountServer::open(&dir).unwrap());
    let handle = serve(count, cfg).unwrap();
    (dir, handle)
}

/// One request/response roundtrip on an existing connection.
fn roundtrip_on(
    w: &mut BufWriter<TcpStream>,
    r: &mut BufReader<TcpStream>,
    line: &str,
) -> String {
    writeln!(w, "{line}").unwrap();
    w.flush().unwrap();
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    resp.trim().to_string()
}

fn connect(addr: SocketAddr) -> (BufWriter<TcpStream>, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    (BufWriter::new(stream.try_clone().unwrap()), BufReader::new(stream))
}

/// p99 (by index) of per-request STATS latencies on one hot connection.
fn stats_p99(addr: SocketAddr, rounds: usize) -> Duration {
    let (mut w, mut r) = connect(addr);
    let mut lats = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t = Instant::now();
        let resp = roundtrip_on(&mut w, &mut r, "STATS");
        lats.push(t.elapsed());
        assert!(resp.contains("\"qps\""), "{resp}");
    }
    lats.sort();
    lats[(rounds * 99) / 100]
}

/// Open `n` idle connections (held by the returned vec).
fn idle_pool(addr: SocketAddr, n: usize) -> Vec<TcpStream> {
    (0..n).map(|_| TcpStream::connect(addr).unwrap()).collect()
}

/// How many idle connections this process can afford: both ends live in
/// this one process, so each idle connection costs two fds.
fn idle_budget(want: usize) -> usize {
    let lim = max_open_files().unwrap_or(1024) as usize;
    want.min(lim.saturating_sub(256) / 2)
}

#[test]
fn a_thousand_idle_connections_leave_hot_stats_latency_flat() {
    let (dir, handle) = start_uwcse("idle1k", ServeConfig::default());
    let addr = handle.addr();

    let base_p99 = stats_p99(addr, 100);

    let n = idle_budget(1000);
    assert!(n >= 100, "fd limit too low to say anything ({n} idle connections)");
    let pool = idle_pool(addr, n);
    // Wait until every idle connection is registered server-side.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if handle.snapshot().active as usize >= n {
            break;
        }
        assert!(Instant::now() < deadline, "server never registered {n} idle connections");
        std::thread::sleep(Duration::from_millis(10));
    }

    let snap = handle.snapshot();
    assert!(snap.active as usize >= n, "active {} < {n}", snap.active);
    assert!(snap.registered_fds as usize >= n, "registered_fds {} < {n}", snap.registered_fds);
    assert!(snap.conns_p99 as usize >= n / 2, "conns histogram missed the pool: {snap:?}");

    let idle_p99 = stats_p99(addr, 100);
    // Flatness with CI-proof slack: idle fds must not put the hot path on
    // an O(connections) cliff. Absolute floor absorbs scheduler noise.
    let bound = base_p99 * 20 + Duration::from_millis(50);
    assert!(
        idle_p99 <= bound,
        "hot STATS p99 {idle_p99:?} with {n} idle connections vs {base_p99:?} baseline"
    );

    drop(pool);
    handle.request_shutdown();
    let fin = handle.wait();
    assert_eq!(fin.active, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full 10k soak — needs `ulimit -n` ≥ ~21k; run with `--ignored`.
#[test]
#[ignore = "10k fds: raise ulimit -n and run explicitly"]
fn soak_ten_thousand_idle_connections() {
    let (dir, handle) = start_uwcse("idle10k", ServeConfig::default());
    let addr = handle.addr();
    let n = idle_budget(10_000);
    assert!(n >= 10_000, "raise ulimit -n (can only open {n} idle connections)");
    let pool = idle_pool(addr, n);
    let deadline = Instant::now() + Duration::from_secs(60);
    while (handle.snapshot().active as usize) < n {
        assert!(Instant::now() < deadline, "server never registered {n} idle connections");
        std::thread::sleep(Duration::from_millis(50));
    }
    let p99 = stats_p99(addr, 200);
    assert!(p99 < Duration::from_millis(250), "hot STATS p99 {p99:?} under 10k idle");
    drop(pool);
    handle.request_shutdown();
    assert_eq!(handle.wait().active, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_fanout_is_concurrent_and_byte_identical_to_serial() {
    // Parallel server: 4 workers with a stall long enough that overlap is
    // observable; serial reference: 1 worker, no stall.
    let delay = Duration::from_millis(50);
    let (dir_p, parallel) = start_uwcse(
        "fanout_par",
        ServeConfig { threads: 4, exec_delay: delay, ..Default::default() },
    );
    let (dir_s, serial) =
        start_uwcse("fanout_ser", ServeConfig { threads: 1, ..Default::default() });

    let batch = "BATCH position(P1)=faculty ; student(P1)=yes ; nope=1 ; position(P1)=faculty";
    let k = 4;

    let read_k = |addr: SocketAddr| -> Vec<String> {
        let (mut w, mut r) = connect(addr);
        writeln!(w, "{batch}").unwrap();
        w.flush().unwrap();
        (0..k)
            .map(|_| {
                let mut l = String::new();
                r.read_line(&mut l).unwrap();
                l
            })
            .collect()
    };

    let t0 = Instant::now();
    let par_lines = read_k(parallel.addr());
    let par_wall = t0.elapsed();
    let ser_lines = read_k(serial.addr());

    // Byte-identical and in member order, fan-out or not.
    assert_eq!(par_lines, ser_lines, "fan-out must not change a single reply byte");
    assert!(par_lines[0].contains("position(P1)=faculty"));
    assert!(par_lines[1].contains("student(P1)=yes"));
    assert!(par_lines[2].contains("\"error\""));
    assert!(par_lines[3].contains("position(P1)=faculty"));

    // Concurrency, observed two ways: the server-side peak counter and the
    // wall clock (4 members x 50 ms stall would take ≥ 200 ms serially).
    let snap = parallel.snapshot();
    assert!(
        snap.batch_peak >= 2,
        "batch members never overlapped: batch_peak = {}",
        snap.batch_peak
    );
    assert!(
        par_wall < delay * (k as u32),
        "fan-out took {par_wall:?}, not faster than serial {:?}",
        delay * (k as u32)
    );

    // STATS carries the fan-out peak for observability.
    let (mut w, mut r) = connect(parallel.addr());
    let stats = roundtrip_on(&mut w, &mut r, "STATS");
    let peak: u64 = json_field(&stats, "batch_peak").unwrap().parse().unwrap();
    assert!(peak >= 2, "{stats}");

    for h in [parallel, serial] {
        h.request_shutdown();
        assert_eq!(h.wait().active, 0);
    }
    let _ = std::fs::remove_dir_all(&dir_p);
    let _ = std::fs::remove_dir_all(&dir_s);
}

#[test]
fn max_conns_sheds_at_accept_time_with_a_busy_answer() {
    let cfg = ServeConfig { max_conns: 2, ..Default::default() };
    let (dir, handle) = start_uwcse("maxconns", cfg);
    let addr = handle.addr();

    // Fill both seats; the PING roundtrip proves each is registered (the
    // `active` gauge the admission check reads is bumped at admit time).
    let (mut w1, mut r1) = connect(addr);
    assert!(roundtrip_on(&mut w1, &mut r1, "PING").contains("pong"));
    let (mut w2, mut r2) = connect(addr);
    assert!(roundtrip_on(&mut w2, &mut r2, "PING").contains("pong"));

    // Third seat: BUSY at accept time, then close.
    let third = TcpStream::connect(addr).unwrap();
    let mut r3 = BufReader::new(third);
    let mut line = String::new();
    r3.read_line(&mut line).unwrap();
    assert!(line.contains("busy"), "expected accept-time BUSY, got {line:?}");
    line.clear();
    assert_eq!(r3.read_line(&mut line).unwrap(), 0, "rejected connection must be closed");
    assert!(handle.snapshot().busy_rejects >= 1);

    // The admitted pair keeps working.
    assert!(roundtrip_on(&mut w1, &mut r1, "PING").contains("pong"));
    assert!(roundtrip_on(&mut w2, &mut r2, "position(P1)=faculty").contains("count"));

    drop((w1, r1, w2, r2));
    handle.request_shutdown();
    assert_eq!(handle.wait().active, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
