//! Concurrency integration for the `serve` subsystem: many client threads
//! hammer one TCP server over a cold store and every answer must be
//! byte-identical to fresh in-memory evaluation (`joint.select`), while
//! the build-coalescing counters prove no ADtree was ever built twice.
//! A second server under a tight `mem_bytes` budget must evict (tables
//! and/or trees) without changing a single answer.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::serve::protocol::{parse_count_response, render_answers};
use mrss::serve::{serve, LoadgenConfig, ServeConfig};
use mrss::store::{gen_queries, parse_query, CountServer, CtStore, PersistConfig, StoreSink};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_serveit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Persist a uwcse run and return the in-memory baseline for a generated
/// batch: the `--fresh` answers the server must reproduce byte for byte.
fn build_store(tag: &str, n_queries: usize, qseed: u64) -> (PathBuf, Vec<(String, u128)>) {
    let dir = tmpdir(tag);
    let db = datagen::generate("uwcse", 0.2, 7).unwrap();
    let store = CtStore::create(&dir, "uwcse", 0.2, 7).unwrap();
    let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
    let res = MobiusJoin::new(&db).sink(&sink).run();
    sink.take_error().unwrap();
    let joint = res.joint_ct();
    let baseline = gen_queries(&db.schema, n_queries, qseed)
        .into_iter()
        .map(|q| {
            let expect = joint.select(&parse_query(&db.schema, &q).unwrap()).total();
            (q, expect)
        })
        .collect();
    (dir, baseline)
}

/// One client: send every query on one connection, return the answers in
/// order. A PING is interleaved to exercise keyword traffic under load.
fn client_run(addr: std::net::SocketAddr, queries: &[(String, u128)]) -> Vec<(String, u128)> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    writeln!(w, "PING").unwrap();
    w.flush().unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "{line}");
    let mut out = Vec::with_capacity(queries.len());
    for (q, _) in queries {
        writeln!(w, "{q}").unwrap();
        w.flush().unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        let count = parse_count_response(&line)
            .unwrap_or_else(|e| panic!("query `{q}` answered an error: {e}"));
        out.push((q.clone(), count));
    }
    out
}

#[test]
fn concurrent_clients_get_identical_answers_and_no_duplicate_builds() {
    const CLIENTS: usize = 8;
    let (dir, baseline) = build_store("hammer", 40, 2026);
    let count = Arc::new(CountServer::open(&dir).unwrap());
    let n_tables = count.store().len() as u64;
    let handle = serve(count, ServeConfig { threads: 4, ..Default::default() }).unwrap();
    let addr = handle.addr();

    // Round 1: N threads, all sending the full batch concurrently — every
    // thread races every other onto the same cold tables.
    let expected = render_answers(&baseline);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| s.spawn(|| client_run(addr, &baseline)))
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(render_answers(&got), expected, "answers must be byte-identical");
        }
    });

    // The coalescing proof: with no eviction pressure, each ADtree is
    // built at most once however many threads raced on it.
    let snap1 = handle.snapshot();
    assert!(snap1.trees.builds > 0);
    assert!(
        snap1.trees.builds <= n_tables,
        "{} builds for {} stored tables: some tree was built twice",
        snap1.trees.builds,
        n_tables
    );
    assert_eq!(snap1.queries, (CLIENTS * baseline.len()) as u64);
    assert_eq!(snap1.errors, 0);

    // Round 2: everything is warm — not a single additional build.
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| s.spawn(|| client_run(addr, &baseline)))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let snap2 = handle.snapshot();
    assert_eq!(
        snap2.trees.builds, snap1.trees.builds,
        "warm re-run must not rebuild any tree"
    );
    assert!(snap2.trees.hits > snap1.trees.hits);

    // Wire shutdown: BYE ack, then the whole pool drains cleanly.
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    let mut r = BufReader::new(stream);
    writeln!(w, "SHUTDOWN").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("bye"), "{line}");
    let fin = handle.wait();
    assert_eq!(fin.active, 0, "drained server must have no active connections");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tight_budget_server_evicts_but_stays_correct_under_load() {
    let (dir, baseline) = build_store("budget", 80, 909);
    let count = Arc::new(CountServer::open(&dir).unwrap());
    // Far below the working set: tables and trees fight for the one budget.
    count.store().set_mem_budget(Some(16 * 1024));
    let handle = serve(count, ServeConfig { threads: 4, ..Default::default() }).unwrap();
    let addr = handle.addr().to_string();

    // Drive it with the load generator (the bench-serve path), same
    // deterministic batch as the baseline.
    let schema = datagen::schema_of("uwcse").unwrap();
    let report = mrss::serve::loadgen::run(
        &schema,
        &LoadgenConfig {
            addr,
            clients: 8,
            queries: 80,
            seed: 909,
            stats: true,
            shutdown: true,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.errors.is_empty(), "first error: {:?}", report.errors.first());
    assert_eq!(
        report.answers_json(),
        render_answers(&baseline),
        "answers under a tight budget must match in-memory evaluation"
    );

    let fin = handle.wait(); // loadgen sent SHUTDOWN; wait must return
    assert!(
        fin.store.evictions + fin.trees.evictions > 0,
        "16 KiB budget must force evictions: {fin:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_and_stats_over_the_wire() {
    let (dir, baseline) = build_store("batchwire", 6, 4242);
    let count = Arc::new(CountServer::open(&dir).unwrap());
    let handle = serve(count, ServeConfig::default()).unwrap();
    let stream = TcpStream::connect(handle.addr()).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    let mut r = BufReader::new(stream);

    // One BATCH line answers one line per query, in order.
    let joined: Vec<String> = baseline.iter().map(|(q, _)| q.clone()).collect();
    writeln!(w, "BATCH {}", joined.join(" ; ")).unwrap();
    w.flush().unwrap();
    for (q, expect) in &baseline {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(
            parse_count_response(&line).as_ref(),
            Ok(expect),
            "batch member `{q}`"
        );
    }

    // STATS reflects the six batched queries.
    writeln!(w, "STATS").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert_eq!(
        mrss::serve::protocol::json_field(&line, "queries").as_deref(),
        Some("6"),
        "{line}"
    );

    handle.request_shutdown();
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
