//! Integration: the Möbius Join against the cross-product oracle on every
//! benchmark schema (small scales), plus suite-level consistency checks.

use mrss::baseline::{cross_product_ct, CpBudget};
use mrss::coordinator::{run_job, SuiteJob};
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use std::time::Duration;

/// Every benchmark, scaled down, must agree exactly with brute force.
#[test]
fn mj_equals_cp_on_all_benchmarks_small() {
    for b in datagen::BENCHMARKS {
        // Scale so the cross product stays enumerable.
        let scale = match b.name {
            "movielens" => 0.01,
            "imdb" => 0.002,
            "financial" => 0.005,
            "hepatitis" => 0.01,
            "mutagenesis" => 0.02,
            _ => 0.1,
        };
        let db = datagen::generate(b.name, scale, 11).unwrap();
        let res = MobiusJoin::new(&db).run();
        let cp = cross_product_ct(
            &db,
            CpBudget { max_time: Duration::from_secs(120), max_tuples: 50_000_000 },
        );
        let cp_ct = cp.ct().unwrap_or_else(|| panic!("{}: CP did not terminate", b.name));
        assert_eq!(res.joint_ct(), cp_ct, "{}: MJ != CP", b.name);
    }
}

#[test]
fn joint_total_is_population_product_everywhere() {
    for b in datagen::BENCHMARKS {
        let db = datagen::generate(b.name, 0.02, 3).unwrap();
        let res = MobiusJoin::new(&db).run();
        let expect: u128 = db
            .schema
            .fo_vars
            .iter()
            .map(|f| db.entity_counts[f.pop] as u128)
            .product();
        assert_eq!(res.joint_ct().total(), expect, "{}", b.name);
    }
}

#[test]
fn report_identities_hold() {
    let rep = run_job(&SuiteJob::new("mutagenesis", 0.05, 5)).unwrap();
    assert_eq!(rep.statistics, rep.link_off_statistics + rep.extra_statistics);
    assert!(rep.mj_time >= rep.extra_time);
    assert_eq!(rep.rel_tables, 2);
    assert_eq!(rep.attributes, 11);
}

#[test]
fn seeds_change_data_not_invariants() {
    for seed in [1u64, 2, 3] {
        let db = datagen::generate("uwcse", 0.3, seed).unwrap();
        let res = MobiusJoin::new(&db).run();
        res.joint_ct().check_invariants().unwrap();
        let expect: u128 = db
            .schema
            .fo_vars
            .iter()
            .map(|f| db.entity_counts[f.pop] as u128)
            .product();
        assert_eq!(res.joint_ct().total(), expect);
    }
}

/// Proposition 1 equivalence on two generated datasets: the joint table
/// must cover every entity instantiation exactly once, so its total equals
/// the entity cross-product size — and a parallel run (4 workers on the
/// per-level chain loop) must produce bit-identical tables to the serial
/// run, chain by chain.
#[test]
fn proposition1_totals_and_parallel_determinism() {
    for (name, scale) in [("uwcse", 0.3), ("mutagenesis", 0.05)] {
        let db = datagen::generate(name, scale, 13).unwrap();
        let serial = MobiusJoin::new(&db).run();
        let parallel = MobiusJoin::new(&db).workers(4).run();

        // Proposition 1: joint total == Π |population of FO var|.
        let expect: u128 = db
            .schema
            .fo_vars
            .iter()
            .map(|f| db.entity_counts[f.pop] as u128)
            .product();
        assert_eq!(serial.joint_ct().total(), expect, "{name}: joint total");
        serial.joint_ct().check_invariants().unwrap();

        // Per-chain totals also satisfy the proposition (restricted to the
        // chain's FO variables).
        for (chain, table) in &serial.tables {
            let chain_expect: u128 = db
                .schema
                .fo_vars_of_rels(chain)
                .iter()
                .map(|&f| db.entity_counts[db.schema.fo_vars[f].pop] as u128)
                .product();
            assert_eq!(table.total(), chain_expect, "{name}: chain {chain:?} total");
        }

        // Serial vs parallel: identical output, table by table.
        assert_eq!(serial.joint_ct(), parallel.joint_ct(), "{name}: joint differs");
        assert_eq!(serial.tables.len(), parallel.tables.len());
        for (chain, table) in &serial.tables {
            assert_eq!(table, &parallel.tables[chain], "{name}: chain {chain:?} differs");
        }
        assert_eq!(serial.num_extra_statistics(), parallel.num_extra_statistics());
    }
}

#[test]
fn depth_cap_tables_match_full_run_prefix() {
    let db = datagen::generate("hepatitis", 0.05, 7).unwrap();
    let full = MobiusJoin::new(&db).run();
    let capped = MobiusJoin::new(&db).max_chain_len(2).run();
    for (chain, table) in &capped.tables {
        assert_eq!(table, &full.tables[chain], "chain {chain:?} differs under cap");
    }
    assert!(capped.tables.len() < full.tables.len());
}
