//! Two-phase store integration: phase 1 runs the Möbius Join on a datagen
//! dataset and persists every table to a `CtStore`; phase 2 — with the
//! database and the in-memory result dropped — answers a mixed
//! positive/negative query batch from the cold store alone and must match
//! the in-memory answers byte for byte, including under a tight LRU
//! `mem_bytes` budget that forces evictions.

use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::store::{
    gen_queries, parse_query, CountServer, CtStore, PersistConfig, StoreSink,
};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mrss_itest_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Phase 1 for one dataset: run, persist, and compute the in-memory
/// baseline answers for a generated query batch. Everything database-side
/// is dropped before returning.
fn phase1(
    dir: &PathBuf,
    dataset: &str,
    scale: f64,
    cfg: PersistConfig,
    n_queries: usize,
    qseed: u64,
) -> Vec<(String, u128)> {
    let db = datagen::generate(dataset, scale, 7).unwrap();
    let store = CtStore::create(dir, dataset, scale, 7).unwrap();
    let sink = StoreSink::new(&store, &db.schema, cfg);
    let res = MobiusJoin::new(&db).sink(&sink).run();
    sink.take_error().unwrap();
    let joint = res.joint_ct();
    gen_queries(&db.schema, n_queries, qseed)
        .into_iter()
        .map(|q| {
            let conds = parse_query(&db.schema, &q).unwrap();
            let expect = joint.select(&conds).total();
            (q, expect)
        })
        .collect()
    // db, res dropped here: phase 2 sees only the files.
}

#[test]
fn two_phase_cold_store_answers_match_in_memory() {
    let dir = tmpdir("two_phase");
    let baseline = phase1(&dir, "uwcse", 0.3, PersistConfig::default(), 60, 2024);
    assert!(baseline.iter().any(|(_, c)| *c > 0), "degenerate batch: all zero");

    // Phase 2: cold open, database gone.
    let server = CountServer::open(&dir).unwrap();
    for (q, expect) in &baseline {
        let got = server.count_query(q).unwrap();
        assert_eq!(got, *expect, "cold-store mismatch on `{q}`");
    }
    let warm = server.stats();
    assert!(warm.misses > 0, "cold store must read from disk: {warm:?}");

    // Tight budget — smaller than any one table, so every second load must
    // evict: answers must stay identical while evictions > 0.
    let tight = CountServer::open(&dir).unwrap();
    let budget = 256;
    tight.store().set_mem_budget(Some(budget));
    for (q, expect) in &baseline {
        let got = tight.count_query(q).unwrap();
        assert_eq!(got, *expect, "tight-budget mismatch on `{q}`");
    }
    let s = tight.stats();
    assert!(
        s.evictions > 0,
        "a {budget}-byte budget should evict (stats {s:?})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn two_phase_positives_only_store_serves_negative_queries() {
    // The paper's pre-counting regime: persist only entity + all-true
    // chain tables; every negative-relationship count must come from
    // Möbius subtraction at query time.
    let dir = tmpdir("posonly");
    let baseline = phase1(&dir, "mutagenesis", 0.05, PersistConfig::positives_only(), 40, 31);

    let server = CountServer::open(&dir).unwrap();
    assert!(!server.store().contains("joint"), "positives-only store must omit the joint");
    let mut negatives = 0usize;
    for (q, expect) in &baseline {
        let got = server.count_query(q).unwrap();
        assert_eq!(got, *expect, "positives-only mismatch on `{q}`");
        if q.contains("=F") || q.contains("=n/a") {
            negatives += 1;
        }
    }
    assert!(negatives > 0, "query batch never exercised the subtraction path");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_survives_reopen_with_identical_bytes() {
    // Persist, then re-encode every decoded table and compare files:
    // decode(encode(x)) == x implies encode(decode(f)) == f only when the
    // codec is canonical — which it is (delta varints have one encoding).
    let dir = tmpdir("canonical");
    let _ = phase1(&dir, "uwcse", 0.15, PersistConfig::default(), 1, 1);
    let store = CtStore::open(&dir).unwrap();
    for meta in store.tables() {
        let table = store.get(&meta.key).unwrap();
        let reencoded = mrss::store::codec::encode(&table);
        let on_disk = std::fs::read(dir.join(format!("{}.ct", meta.key))).unwrap();
        assert_eq!(reencoded, on_disk, "non-canonical encoding for {}", meta.key);
        assert_eq!(meta.rows, table.len() as u64);
        assert_eq!(meta.total, table.total());
        assert_eq!(meta.tier, table.tier());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
