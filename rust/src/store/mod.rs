//! Persisted sufficient-statistics repository + count-query service.
//!
//! The Möbius Join's output — the contingency tables — is a *sufficient
//! statistic*: once computed, every downstream consumer (feature
//! selection, rule mining, Bayes-net scoring, ad-hoc counting) should read
//! from it instead of touching the database. This module makes that split
//! real, in three layers:
//!
//! 1. [`codec`] — a compact, versioned binary format for [`CtTable`]:
//!    header carries the column specs (from which the exact [`CtLayout`]
//!    and storage tier reconstruct), sorted packed keys are delta-encoded
//!    varints, counts are varints, and a trailing checksum catches
//!    corruption. All three storage tiers round-trip bit-identically.
//! 2. [`CtStore`] — a directory-backed repository keyed by `(dataset,
//!    chain signature)`: a `manifest.tsv` plus one `.ct` file per entity /
//!    positive-chain / complete-chain / joint table, written on completion
//!    by a [`StoreSink`] hooked into the Möbius Join, and read back
//!    through an LRU cache bounded by a `mem_bytes` budget.
//! 3. [`CountServer`] — a lazily-loading query service answering arbitrary
//!    positive-and-negative conjunctive count queries via cached
//!    [`AdTree`](crate::ct::AdTree)s, with Möbius subtraction for
//!    indicator variables absent from the stored tables (the paper's
//!    pre-counting regime: persist positives, derive negatives on demand).
//!
//! The `mrss query` / `mrss serve` CLI subcommands expose the service;
//! `mrss ct|suite --store DIR` populates stores; `mrss mine|bn --store`
//! re-score from a warm store with the database gone.
//!
//! [`CtTable`]: crate::ct::CtTable
//! [`CtLayout`]: crate::ct::CtLayout

pub mod codec;
mod repo;
mod service;

pub use repo::{CtStore, PersistConfig, StoreSink, StoreStats, TableKind, TableMeta, MANIFEST};
pub use service::{
    gen_queries, needs_level, needs_table, normalize, parse_query, CountServer, TreeStats,
};
