//! `CtStore` — the directory-backed sufficient-statistics repository.
//!
//! One store directory holds the Möbius Join output of one `(dataset,
//! scale, seed)` run: a `manifest.tsv` plus one `.ct` file per table
//! ([`codec`](super::codec) format). Tables are keyed by their provenance
//! in the chain lattice:
//!
//! * `entity_<fo>` — `ct(1Atts(X))` for one FO variable;
//! * `pos_<r1>_<r2>…` — the all-true ("positive") table of one chain,
//!   `ct(Atts(C) | C = T)`, straight from the join counter — no indicator
//!   columns (the paper's *pre-counting* statistics);
//! * `chain_<r1>_<r2>…` — the complete per-chain table with indicator
//!   columns and n/a rows (the Möbius Join's per-chain output);
//! * `joint` — the joint table over the whole database.
//!
//! The manifest records per table: row count, grand total, storage tier,
//! file size, the *scope* (which FO variables the counts range over — what
//! lets the query service rescale counts between tables), and the column
//! `VarId`s — enough for query planning without touching the `.ct` files.
//!
//! Reads go through an LRU cache bounded by a `mem_bytes` budget
//! ([`CtStore::set_mem_budget`]): the ROADMAP's backpressure item. Hits,
//! misses, and evictions are counted ([`CtStore::stats`]) and surfaced in
//! run reports next to `MjMetrics::reference_fallbacks`.

use crate::anyhow;
use crate::bail;
use crate::ct::CtTable;
use crate::obs::trace;
use crate::mobius::{CtSink, MjResult};
use crate::schema::{FoVarId, RelId, Schema, VarId};
use crate::util::error::{Context, Result};
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::codec;

/// Manifest file name inside a store directory.
pub const MANIFEST: &str = "manifest.tsv";

/// What a stored table is, parsed from (and rendered to) its key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TableKind {
    /// `ct(1Atts(X))` for one FO variable.
    Entity(FoVarId),
    /// All-true table of one chain (no indicator columns).
    Positive(Vec<RelId>),
    /// Complete per-chain table (indicators + n/a rows).
    Chain(Vec<RelId>),
    /// Joint table over the whole database.
    Joint,
}

impl TableKind {
    /// Canonical store key (doubles as the file stem).
    pub fn key(&self) -> String {
        fn rels(prefix: &str, rs: &[RelId]) -> String {
            let mut s = String::from(prefix);
            for r in rs {
                s.push('_');
                s.push_str(&r.to_string());
            }
            s
        }
        match self {
            TableKind::Entity(fo) => format!("entity_{fo}"),
            TableKind::Positive(rs) => rels("pos", rs),
            TableKind::Chain(rs) => rels("chain", rs),
            TableKind::Joint => "joint".to_string(),
        }
    }

    /// Parse a store key back into its kind.
    pub fn parse(key: &str) -> Result<TableKind> {
        fn rels(body: &str) -> Result<Vec<RelId>> {
            body.split('_')
                .map(|t| t.parse::<RelId>().map_err(|_| anyhow!("bad rel id `{t}`")))
                .collect()
        }
        if key == "joint" {
            return Ok(TableKind::Joint);
        }
        if let Some(body) = key.strip_prefix("entity_") {
            return Ok(TableKind::Entity(body.parse().map_err(|_| anyhow!("bad fo id"))?));
        }
        if let Some(body) = key.strip_prefix("pos_") {
            return Ok(TableKind::Positive(rels(body)?));
        }
        if let Some(body) = key.strip_prefix("chain_") {
            return Ok(TableKind::Chain(rels(body)?));
        }
        bail!("unrecognized store key `{key}`")
    }
}

/// Per-table manifest record.
#[derive(Debug, Clone)]
pub struct TableMeta {
    pub key: String,
    pub kind: TableKind,
    pub rows: u64,
    /// Sum of all counts (`CtTable::total`).
    pub total: u128,
    /// Storage tier name (`packed64` / `packed128` / `rowmajor`).
    pub tier: String,
    /// Encoded file size in bytes.
    pub bytes: u64,
    /// FO variables the counts range over (sorted).
    pub scope: Vec<FoVarId>,
    /// Column variables (sorted — ct invariant).
    pub vars: Vec<VarId>,
}

/// Cache / IO counters for one store handle.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes read from disk (encoded size, before decode).
    pub bytes_read: u64,
    /// Tables renamed to `.ct.bad` and dropped from the manifest — by the
    /// open-time scrub or after a decode failure on read.
    pub quarantined_tables: u64,
}

struct CacheEntry {
    table: Arc<CtTable>,
    mem: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    tables: BTreeMap<String, TableMeta>,
    cache: FxHashMap<String, CacheEntry>,
    cached_bytes: usize,
    /// Bytes charged by external caches sharing this budget (the
    /// `CountServer` ADtree cache): the table LRU makes room for them.
    external_bytes: usize,
    tick: u64,
    mem_budget: Option<usize>,
    stats: StoreStats,
}

/// A directory-backed repository of contingency tables for one dataset run.
pub struct CtStore {
    dir: PathBuf,
    /// Dataset name (matches `datagen` benchmark names).
    pub dataset: String,
    /// Generation scale the statistics were computed at.
    pub scale: f64,
    /// Generation seed.
    pub seed: u64,
    inner: Mutex<Inner>,
}

impl CtStore {
    /// Create (or truncate) a store directory for one run. Any `.ct`
    /// files and manifest from a previous run are removed, so the
    /// directory always matches the new manifest exactly.
    pub fn create(
        dir: impl Into<PathBuf>,
        dataset: &str,
        scale: f64,
        seed: u64,
    ) -> Result<CtStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))?
        {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let stale =
                name.starts_with(MANIFEST) || name.ends_with(".ct") || name.ends_with(".ct.tmp");
            if stale {
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing stale {}", path.display()))?;
            }
        }
        let store = CtStore {
            dir,
            dataset: dataset.to_string(),
            scale,
            seed,
            inner: Mutex::new(Inner::default()),
        };
        store.write_manifest(&store.inner.lock().unwrap())?;
        Ok(store)
    }

    /// Open an existing store directory: reads the manifest, then scrubs —
    /// stale `*.tmp` litter from a crashed writer is removed, and every
    /// manifest entry is verified against its `.ct` file (existence, size,
    /// full checksummed decode). Damaged tables are quarantined: renamed to
    /// `<key>.ct.bad`, dropped from the manifest, and counted in
    /// [`StoreStats::quarantined_tables`], so the query layer degrades to
    /// the surviving tables instead of tripping over bad bytes later.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CtStore> {
        let dir = dir.into();
        let path = dir.join(MANIFEST);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading store manifest {}", path.display()))?;
        let mut lines = text.lines().enumerate();
        let (_, head) = lines.next().context("empty manifest")?;
        let mut hf = head.split('\t');
        if hf.next() != Some("mrss-ctstore") || hf.next() != Some("1") {
            bail!("{}: not a v1 ctstore manifest", path.display());
        }
        let mut dataset = String::new();
        let mut scale = 0.0f64;
        let mut seed = 0u64;
        let mut tables = BTreeMap::new();
        for (ln, line) in lines {
            let mut f = line.split('\t');
            let tag = f.next().unwrap_or("");
            let ctx = || format!("{}:{}", path.display(), ln + 1);
            match tag {
                "" => continue,
                "dataset" => dataset = f.next().with_context(ctx)?.to_string(),
                "scale" => scale = f.next().with_context(ctx)?.parse().with_context(ctx)?,
                "seed" => seed = f.next().with_context(ctx)?.parse().with_context(ctx)?,
                "table" => {
                    let key = f.next().with_context(ctx)?.to_string();
                    let kind = TableKind::parse(&key).with_context(ctx)?;
                    let rows = f.next().with_context(ctx)?.parse().with_context(ctx)?;
                    let total = f.next().with_context(ctx)?.parse().with_context(ctx)?;
                    let tier = f.next().with_context(ctx)?.to_string();
                    let bytes = f.next().with_context(ctx)?.parse().with_context(ctx)?;
                    let scope = parse_ids(f.next().with_context(ctx)?).with_context(ctx)?;
                    let vars = parse_ids(f.next().with_context(ctx)?).with_context(ctx)?;
                    tables.insert(
                        key.clone(),
                        TableMeta { key, kind, rows, total, tier, bytes, scope, vars },
                    );
                }
                other => bail!("{}: unknown manifest tag `{other}`", ctx()),
            }
        }
        if dataset.is_empty() {
            bail!("{}: manifest has no dataset line", path.display());
        }
        let store = CtStore {
            dir,
            dataset,
            scale,
            seed,
            inner: Mutex::new(Inner { tables, ..Inner::default() }),
        };
        store.scrub()?;
        Ok(store)
    }

    /// Reconcile the manifest against the directory (see [`CtStore::open`]).
    /// Cost is one full read+decode per table — O(store bytes) — paid once
    /// per open in exchange for never serving from a damaged file.
    fn scrub(&self) -> Result<()> {
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.ends_with(".tmp") {
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing stale {}", path.display()))?;
            }
        }
        let keys: Vec<String> = {
            let g = self.inner.lock().unwrap();
            g.tables.keys().cloned().collect()
        };
        let mut bad = Vec::new();
        for key in keys {
            let (expect_bytes, path) = {
                let g = self.inner.lock().unwrap();
                let meta = match g.tables.get(&key) {
                    Some(m) => m,
                    None => continue,
                };
                (meta.bytes, self.dir.join(format!("{key}.ct")))
            };
            let healthy = match std::fs::read(&path) {
                Ok(bytes) => {
                    bytes.len() as u64 == expect_bytes && codec::decode(&bytes).is_ok()
                }
                Err(_) => false,
            };
            if !healthy {
                bad.push(key);
            }
        }
        if !bad.is_empty() {
            let mut g = self.inner.lock().unwrap();
            for key in &bad {
                quarantine_locked(&self.dir, &mut g, key);
            }
            self.write_manifest(&g)?;
        }
        Ok(())
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Bound the in-memory cache (`None` = unbounded). Eviction is LRU and
    /// never drops the most recently touched table, so a budget smaller
    /// than one table still serves queries (it just stops caching).
    pub fn set_mem_budget(&self, bytes: Option<usize>) {
        let mut g = self.inner.lock().unwrap();
        g.mem_budget = bytes;
        evict_over_budget(&mut g);
    }

    /// Current cache budget.
    pub fn mem_budget(&self) -> Option<usize> {
        self.inner.lock().unwrap().mem_budget
    }

    /// Charge (positive) or release (negative) bytes held by an external
    /// cache against this store's `mem_bytes` budget. The table LRU evicts
    /// to make room, so one budget truly bounds tables *and* whatever the
    /// caller keeps alongside them (the `CountServer` ADtree cache).
    pub fn charge_external(&self, delta: isize) {
        let mut g = self.inner.lock().unwrap();
        g.external_bytes = g.external_bytes.saturating_add_signed(delta);
        evict_over_budget(&mut g);
    }

    /// Bytes currently charged by external caches.
    pub fn external_bytes(&self) -> usize {
        self.inner.lock().unwrap().external_bytes
    }

    /// Bytes currently held by the table LRU cache itself.
    pub fn cached_bytes(&self) -> usize {
        self.inner.lock().unwrap().cached_bytes
    }

    /// Snapshot of the cache/IO counters.
    pub fn stats(&self) -> StoreStats {
        self.inner.lock().unwrap().stats
    }

    /// Manifest records, in key order.
    pub fn tables(&self) -> Vec<TableMeta> {
        self.inner.lock().unwrap().tables.values().cloned().collect()
    }

    /// Manifest record of one key.
    pub fn meta(&self, key: &str) -> Option<TableMeta> {
        self.inner.lock().unwrap().tables.get(key).cloned()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().tables.contains_key(key)
    }

    /// Number of stored tables.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().tables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total encoded bytes across all stored tables.
    pub fn disk_bytes(&self) -> u64 {
        self.inner.lock().unwrap().tables.values().map(|m| m.bytes).sum()
    }

    /// Persist one table. Writes the `.ct` file (via a temp file + rename,
    /// so a crash never leaves a half-written table behind a manifest
    /// entry) and rewrites the manifest.
    ///
    /// The manifest rewrite-and-rename per put is deliberate: it keeps the
    /// store openable (as a complete prefix of the run) at every instant,
    /// crash included. The manifest is lattice-sized — tens of KB — so the
    /// O(tables²) rewrite bytes are noise next to the table encodes, and
    /// only this small rewrite happens under the store mutex; the encode
    /// and table-file IO above run outside it, so parallel sink callbacks
    /// still overlap on the expensive part.
    pub fn put(&self, kind: TableKind, scope: &[FoVarId], ct: &CtTable) -> Result<()> {
        let key = kind.key();
        let bytes = codec::encode(ct);
        let path = self.dir.join(format!("{key}.ct"));
        let tmp = self.dir.join(format!("{key}.ct.tmp"));
        // `store.write.torn` simulates a crash mid-write that still managed
        // to rename: the table lands truncated behind a manifest entry, the
        // exact damage the open-time scrub must catch.
        let written: &[u8] = if crate::util::failpoint::fire("store.write.torn") {
            &bytes[..bytes.len() / 2]
        } else {
            &bytes
        };
        write_atomic(&self.dir, &tmp, &path, written)?;
        let meta = TableMeta {
            key: key.clone(),
            kind,
            rows: ct.len() as u64,
            total: ct.total(),
            tier: ct.tier().to_string(),
            bytes: bytes.len() as u64,
            scope: scope.to_vec(),
            vars: ct.vars.clone(),
        };
        let mut g = self.inner.lock().unwrap();
        g.tables.insert(key.clone(), meta);
        // A re-put invalidates any cached copy of the old bytes.
        if let Some(e) = g.cache.remove(&key) {
            g.cached_bytes -= e.mem;
        }
        self.write_manifest(&g)
    }

    /// Load a table, going through the LRU cache. Disk IO and decode run
    /// outside the store mutex, so concurrent readers only serialize on
    /// the cheap cache bookkeeping (two misses racing on one key both
    /// decode; the loser's copy is dropped).
    pub fn get(&self, key: &str) -> Result<Arc<CtTable>> {
        {
            let mut guard = self.inner.lock().unwrap();
            let g = &mut *guard;
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.cache.get_mut(key) {
                e.last_used = tick;
                g.stats.hits += 1;
                trace::event("table.cache_hit", || key.to_string());
                return Ok(Arc::clone(&e.table));
            }
            if !g.tables.contains_key(key) {
                bail!("store has no table `{key}` (dataset {})", self.dataset);
            }
        }
        let _sp = trace::span_detailed("table.load", || key.to_string());
        let path = self.dir.join(format!("{key}.ct"));
        let mut bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        corrupt_failpoint(&mut bytes);
        let table = match codec::decode(&bytes) {
            Ok(t) => Arc::new(t),
            Err(e) => return Err(self.quarantine_on_decode_error(key, &path, e)),
        };
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        g.stats.misses += 1;
        g.stats.bytes_read += bytes.len() as u64;
        if let Some(e) = g.cache.get(key) {
            // Raced with another miss on the same key: keep the cached one.
            return Ok(Arc::clone(&e.table));
        }
        g.tick += 1;
        let tick = g.tick;
        let mem = table.mem_bytes();
        g.cache.insert(
            key.to_string(),
            CacheEntry { table: Arc::clone(&table), mem, last_used: tick },
        );
        g.cached_bytes += mem;
        evict_over_budget(g);
        Ok(table)
    }

    /// Read and decode one table directly, bypassing the LRU cache — for
    /// bulk loads that keep the table alive themselves (a cached copy
    /// would double peak memory). Misses/bytes are still counted.
    fn read_table(&self, key: &str) -> Result<CtTable> {
        if !self.contains(key) {
            bail!("store has no table `{key}` (dataset {})", self.dataset);
        }
        let path = self.dir.join(format!("{key}.ct"));
        let mut bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        corrupt_failpoint(&mut bytes);
        let table = match codec::decode(&bytes) {
            Ok(t) => t,
            Err(e) => return Err(self.quarantine_on_decode_error(key, &path, e)),
        };
        let mut g = self.inner.lock().unwrap();
        g.stats.misses += 1;
        g.stats.bytes_read += bytes.len() as u64;
        Ok(table)
    }

    /// A read produced undecodable bytes: the on-disk file is damaged, so
    /// quarantine it (rename to `.ct.bad`, drop the manifest entry) rather
    /// than fail the same way on every future query. The caller's query
    /// still errors; later queries see a consistent "no table" miss, which
    /// the query layer can answer by Möbius derivation from survivors.
    fn quarantine_on_decode_error(
        &self,
        key: &str,
        path: &Path,
        e: crate::util::error::Error,
    ) -> crate::util::error::Error {
        let mut g = self.inner.lock().unwrap();
        if g.tables.contains_key(key) {
            quarantine_locked(&self.dir, &mut g, key);
            // Manifest rewrite is best-effort: the in-memory drop already
            // protects readers; a failed rewrite is re-scrubbed at next open.
            let _ = self.write_manifest(&g);
        }
        e.context(format!("decoding {} (table quarantined)", path.display()))
    }

    /// Reassemble an [`MjResult`] from the stored entity/chain/joint tables
    /// — what lets `apps` (cfs/apriori/bayesnet) score from a warm store
    /// with the database tables gone. Tables are decoded straight into the
    /// result (not through the LRU cache), so each lives in memory once.
    pub fn load_mj_result(&self, schema: &Schema) -> Result<MjResult> {
        let metas = self.tables();
        let mut entity_cts: FxHashMap<FoVarId, CtTable> = FxHashMap::default();
        let mut tables: FxHashMap<Vec<RelId>, CtTable> = FxHashMap::default();
        let mut joint: Option<CtTable> = None;
        for m in metas {
            match m.kind {
                TableKind::Entity(fo) => {
                    entity_cts.insert(fo, self.read_table(&m.key)?);
                }
                TableKind::Chain(rels) => {
                    tables.insert(rels, self.read_table(&m.key)?);
                }
                TableKind::Joint => joint = Some(self.read_table(&m.key)?),
                TableKind::Positive(_) => {}
            }
        }
        if entity_cts.len() != schema.fo_vars.len() {
            bail!(
                "store has {} entity tables, schema {} needs {}",
                entity_cts.len(),
                schema.name,
                schema.fo_vars.len()
            );
        }
        if joint.is_none() {
            bail!(
                "store for {} has no joint table (depth-capped or positives-only run) — \
                 mine/bn need a full-depth persisted run",
                self.dataset
            );
        }
        Ok(MjResult::assemble(schema, entity_cts, tables, joint))
    }

    fn write_manifest(&self, g: &Inner) -> Result<()> {
        let mut out = String::from("mrss-ctstore\t1\n");
        out.push_str(&format!("dataset\t{}\n", self.dataset));
        out.push_str(&format!("scale\t{}\n", self.scale));
        out.push_str(&format!("seed\t{}\n", self.seed));
        for m in g.tables.values() {
            out.push_str(&format!(
                "table\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                m.key,
                m.rows,
                m.total,
                m.tier,
                m.bytes,
                render_ids(&m.scope),
                render_ids(&m.vars),
            ));
        }
        let path = self.dir.join(MANIFEST);
        let tmp = self.dir.join(format!("{MANIFEST}.tmp"));
        write_atomic(&self.dir, &tmp, &path, out.as_bytes())
    }
}

/// Durable temp+rename: write, `sync_all` the data file (so the rename can
/// never promote unflushed bytes), rename, then `sync_all` the directory so
/// the rename itself survives a power cut. The directory fsync is
/// best-effort — some filesystems reject opening a directory for sync, and
/// the fallback (a post-crash scrub catching the missing file) is exactly
/// what [`CtStore::open`] does anyway.
fn write_atomic(dir: &Path, tmp: &Path, path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut f =
        std::fs::File::create(tmp).with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    drop(f);
    std::fs::rename(tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// `store.read.corrupt`: flip one mid-file byte after a successful read, so
/// the checksummed decode fails exactly as it would on real bit rot.
fn corrupt_failpoint(bytes: &mut [u8]) {
    if crate::util::failpoint::fire("store.read.corrupt") && !bytes.is_empty() {
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
    }
}

/// Quarantine one table under the store lock: rename its file to
/// `<key>.ct.bad` (kept for post-mortem, never re-read), drop it from the
/// manifest map and the LRU cache, and bump the counter. The caller decides
/// when to rewrite the manifest file.
fn quarantine_locked(dir: &Path, g: &mut Inner, key: &str) {
    if g.tables.remove(key).is_none() {
        return;
    }
    if let Some(e) = g.cache.remove(key) {
        g.cached_bytes -= e.mem;
    }
    g.stats.quarantined_tables += 1;
    let path = dir.join(format!("{key}.ct"));
    let _ = std::fs::rename(&path, dir.join(format!("{key}.ct.bad")));
}

/// Evict least-recently-used entries until the cache (plus any external
/// charge sharing the budget) fits, always keeping the most recently
/// touched entry.
fn evict_over_budget(g: &mut Inner) {
    let Some(budget) = g.mem_budget else { return };
    while g.cached_bytes.saturating_add(g.external_bytes) > budget && g.cache.len() > 1 {
        let newest = g.cache.values().map(|e| e.last_used).max().unwrap_or(0);
        let victim = g
            .cache
            .iter()
            .filter(|(_, e)| e.last_used != newest)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone());
        let Some(k) = victim else { break };
        if let Some(e) = g.cache.remove(&k) {
            g.cached_bytes -= e.mem;
            g.stats.evictions += 1;
        }
    }
}

fn render_ids(ids: &[usize]) -> String {
    if ids.is_empty() {
        return "-".to_string();
    }
    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
}

fn parse_ids(s: &str) -> Result<Vec<usize>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    s.split(',').map(|t| t.parse::<usize>().map_err(|_| anyhow!("bad id `{t}`"))).collect()
}

/// Which tables a [`StoreSink`] persists. Defaults to everything; a
/// positives-only store is the paper's *pre-counting* regime — negative
/// counts are then derived at query time by Möbius subtraction
/// ([`super::CountServer`]).
#[derive(Debug, Clone, Copy)]
pub struct PersistConfig {
    pub entities: bool,
    pub positives: bool,
    pub chains: bool,
    pub joint: bool,
}

impl Default for PersistConfig {
    fn default() -> Self {
        PersistConfig { entities: true, positives: true, chains: true, joint: true }
    }
}

impl PersistConfig {
    /// Entity + positive tables only (no complete chain tables, no joint).
    pub fn positives_only() -> Self {
        PersistConfig { entities: true, positives: true, chains: false, joint: false }
    }
}

/// Write-on-complete hook bridging [`MobiusJoin`](crate::mobius::MobiusJoin)
/// to a [`CtStore`]: every table is persisted the moment the dynamic
/// program finishes it, so a completed run leaves a complete store with no
/// separate export pass. Sink callbacks may fire from worker threads; IO
/// errors are latched and surfaced through [`StoreSink::take_error`].
pub struct StoreSink<'a> {
    store: &'a CtStore,
    schema: &'a Schema,
    cfg: PersistConfig,
    error: Mutex<Option<crate::util::error::Error>>,
}

impl<'a> StoreSink<'a> {
    pub fn new(store: &'a CtStore, schema: &'a Schema, cfg: PersistConfig) -> Self {
        StoreSink { store, schema, cfg, error: Mutex::new(None) }
    }

    fn record(&self, r: Result<()>) {
        if let Err(e) = r {
            let mut g = self.error.lock().unwrap();
            if g.is_none() {
                *g = Some(e);
            }
        }
    }

    /// The first persistence error, if any (call after the join finishes).
    pub fn take_error(&self) -> Result<()> {
        match self.error.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl CtSink for StoreSink<'_> {
    fn on_entity(&self, fo: FoVarId, ct: &CtTable) {
        if self.cfg.entities {
            self.record(self.store.put(TableKind::Entity(fo), &[fo], ct));
        }
    }

    fn on_positive(&self, chain: &[RelId], ct: &CtTable) {
        if self.cfg.positives {
            let scope = self.schema.fo_vars_of_rels(chain);
            self.record(self.store.put(TableKind::Positive(chain.to_vec()), &scope, ct));
        }
    }

    fn on_chain(&self, chain: &[RelId], ct: &CtTable) {
        if self.cfg.chains {
            let scope = self.schema.fo_vars_of_rels(chain);
            self.record(self.store.put(TableKind::Chain(chain.to_vec()), &scope, ct));
        }
    }

    fn on_joint(&self, ct: &CtTable) {
        if self.cfg.joint {
            let scope: Vec<FoVarId> = (0..self.schema.fo_vars.len()).collect();
            self.record(self.store.put(TableKind::Joint, &scope, ct));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mrss_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_ct(seed: u64) -> CtTable {
        CtTable::from_raw(vec![0, 1], vec![0, 0, 0, 1, 1, 0], vec![seed + 1, 2, 3])
    }

    #[test]
    fn put_get_roundtrip_and_manifest_reload() {
        let dir = tmpdir("roundtrip");
        let store = CtStore::create(&dir, "uwcse", 0.3, 7).unwrap();
        let ct = small_ct(4);
        store.put(TableKind::Chain(vec![0]), &[0, 1], &ct).unwrap();
        store.put(TableKind::Entity(2), &[2], &CtTable::scalar(9)).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(*store.get("chain_0").unwrap(), ct);

        // Re-open cold: manifest metadata and bytes must survive.
        let again = CtStore::open(&dir).unwrap();
        assert_eq!(again.dataset, "uwcse");
        assert_eq!(again.scale, 0.3);
        assert_eq!(again.seed, 7);
        let meta = again.meta("chain_0").unwrap();
        assert_eq!(meta.kind, TableKind::Chain(vec![0]));
        assert_eq!(meta.rows, ct.len() as u64);
        assert_eq!(meta.total, ct.total());
        assert_eq!(meta.scope, vec![0, 1]);
        assert_eq!(meta.vars, ct.vars);
        assert_eq!(*again.get("chain_0").unwrap(), ct);
        assert_eq!(again.get("entity_2").unwrap().total(), 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_parse_roundtrip() {
        for kind in [
            TableKind::Entity(3),
            TableKind::Positive(vec![0, 2, 5]),
            TableKind::Chain(vec![1]),
            TableKind::Joint,
        ] {
            assert_eq!(TableKind::parse(&kind.key()).unwrap(), kind);
        }
        assert!(TableKind::parse("weird").is_err());
    }

    #[test]
    fn lru_eviction_respects_budget_and_counts() {
        let dir = tmpdir("lru");
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        for i in 0..4usize {
            store.put(TableKind::Entity(i), &[i], &small_ct(i as u64)).unwrap();
        }
        let one = store.get("entity_0").unwrap().mem_bytes();
        // Budget for ~2 tables.
        store.set_mem_budget(Some(one * 2 + one / 2));
        for i in 0..4usize {
            store.get(&format!("entity_{i}")).unwrap();
        }
        let s = store.stats();
        assert!(s.evictions > 0, "expected evictions under a 2-table budget: {s:?}");
        assert_eq!(s.misses, 4, "{s:?}");
        // Most recent table stays cached: an immediate re-read is a hit.
        store.get("entity_3").unwrap();
        assert_eq!(store.stats().hits, s.hits + 1);
        // Answers survive eviction (reload from disk).
        assert_eq!(*store.get("entity_1").unwrap(), small_ct(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn external_charge_shares_the_budget() {
        let dir = tmpdir("external");
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        for i in 0..3usize {
            store.put(TableKind::Entity(i), &[i], &small_ct(i as u64)).unwrap();
        }
        let one = store.get("entity_0").unwrap().mem_bytes();
        store.set_mem_budget(Some(one * 3 + one / 2));
        for i in 0..3usize {
            store.get(&format!("entity_{i}")).unwrap();
        }
        assert_eq!(store.stats().evictions, 0, "3 tables fit a 3.5-table budget");
        assert_eq!(store.cached_bytes(), one * 3);
        // An external cache claiming ~2 tables' worth forces the table LRU
        // down to what fits alongside it.
        store.charge_external((one * 2) as isize);
        assert_eq!(store.external_bytes(), one * 2);
        assert!(store.stats().evictions >= 1, "external charge must evict tables");
        assert!(store.cached_bytes() + store.external_bytes() <= one * 3 + one / 2);
        // Releasing the charge stops further pressure; reads still work.
        store.charge_external(-((one * 2) as isize));
        assert_eq!(store.external_bytes(), 0);
        assert_eq!(*store.get("entity_1").unwrap(), small_ct(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_scrubs_truncated_tables_and_tmp_litter() {
        let dir = tmpdir("scrub");
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        store.put(TableKind::Entity(0), &[0], &small_ct(0)).unwrap();
        store.put(TableKind::Entity(1), &[1], &small_ct(1)).unwrap();
        drop(store);
        // Simulate a crash mid-run: one table truncated behind its manifest
        // entry, plus temp-file litter.
        let victim = dir.join("entity_0.ct");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
        std::fs::write(dir.join("entity_9.ct.tmp"), b"junk").unwrap();

        let again = CtStore::open(&dir).unwrap();
        assert!(!again.contains("entity_0"), "damaged table must leave the manifest");
        assert_eq!(again.stats().quarantined_tables, 1);
        assert!(dir.join("entity_0.ct.bad").exists());
        assert!(!dir.join("entity_0.ct").exists());
        assert!(!dir.join("entity_9.ct.tmp").exists());
        assert_eq!(*again.get("entity_1").unwrap(), small_ct(1));
        // A second open finds nothing further to quarantine.
        drop(again);
        let third = CtStore::open(&dir).unwrap();
        assert_eq!(third.stats().quarantined_tables, 0);
        assert!(third.contains("entity_1"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_failure_on_read_quarantines() {
        let dir = tmpdir("readquarantine");
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        store.put(TableKind::Entity(0), &[0], &small_ct(0)).unwrap();
        // Flip a mid-file byte on disk; the next read must fail decode,
        // quarantine the table, and keep failing consistently afterwards.
        let victim = dir.join("entity_0.ct");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();

        let err = store.get("entity_0").unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");
        assert_eq!(store.stats().quarantined_tables, 1);
        assert!(!store.contains("entity_0"));
        assert!(dir.join("entity_0.ct.bad").exists());
        // Now a consistent "no table" miss, not a decode error.
        let err2 = store.get("entity_0").unwrap_err();
        assert!(err2.to_string().contains("no table"), "{err2}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_table_and_missing_manifest_error() {
        let dir = tmpdir("missing");
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        assert!(store.get("joint").is_err());
        assert!(CtStore::open(dir.join("nope")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
