//! `CountServer` — answers conjunctive count queries from a [`CtStore`]
//! with the database tables gone.
//!
//! A query is a conjunction `var=val, …` over the schema's random
//! variables, mixing attribute values, `n/a`, and **positive and negative
//! relationship conditions** (`R=T` / `R=F`). The answer is the number of
//! instantiations of *all* the schema's first-order variables satisfying
//! the conjunction — exactly the count the joint contingency table's
//! selection would give (`joint.select(q).total()`), which is what the
//! store-smoke CI job diffs against.
//!
//! ## Planning
//!
//! The full cross-product measure factorizes over first-order variables,
//! so for any stored table `T` whose columns cover the queried variables:
//!
//! ```text
//! count(Q) = adtree(T).count(Q) / Π pop(X) [X ∈ scope(T) \ fo(Q)]
//!                               × Π pop(X) [X ∈ all fos \ fo(Q)]
//! ```
//!
//! (the division is exact: a table's counts over FO variables the query
//! does not constrain are uniform multiples of the population sizes). The
//! planner therefore:
//!
//! 1. splits the query into independent groups (connected components of
//!    the "shares an FO variable" relation) and multiplies their counts;
//! 2. per group, answers from the **smallest** stored complete table
//!    (entity / chain / joint) covering the group's variables, via a
//!    cached [`AdTree`];
//! 3. when no complete table covers the group — a *positives-only* store,
//!    the paper's pre-counting regime — applies **Möbius subtraction**
//!    (Proposition 1) to the negative relationship conditions:
//!    `count(Q ∧ R=F) = count(Q) − count(Q ∧ R=T)`, recursing until the
//!    all-positive base case, which the indicator-free `pos_*` tables
//!    answer directly.
//!
//! Queries are normalized first: duplicate conditions collapse,
//! contradictions (two values for one variable, a real 2Att value under
//! `R=F`, `n/a` under `R=T`) short-circuit to zero, and a bare
//! `2Att = n/a` condition rewrites to `R=F` (they are equivalent by the
//! paper's §2.2 convention).

use crate::bail;
use crate::ct::{AdTree, AdTreeConfig};
use crate::obs::{cost, trace};
use crate::schema::{Attribute, FoVarId, RandomVar, RelId, Schema, VarId, NA};
use crate::util::error::{Context, Result};
use crate::util::fxhash::FxHashMap;
use crate::util::Pcg64;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};

use super::repo::{CtStore, StoreStats, TableKind, TableMeta};

/// Counters of the shared ADtree cache ([`CountServer::tree_stats`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TreeStats {
    /// Lookups answered by an already-built tree.
    pub hits: u64,
    /// Trees actually constructed. While a tree stays cached this is at
    /// most one per table, however many threads race on it — the
    /// no-duplicate-build guarantee the concurrency tests assert.
    pub builds: u64,
    /// Readers that found a build in progress and blocked on its latch
    /// instead of constructing a duplicate (counted once per waiter).
    pub coalesced_waits: u64,
    /// Trees evicted under the shared `mem_bytes` budget.
    pub evictions: u64,
    /// Bytes currently charged against the store budget for live trees.
    pub bytes: u64,
    /// Builds in progress right now (latched `Building` slots) — a gauge
    /// the serving layer exposes so a reactor stall can be told apart
    /// from a long ADtree construction on the worker pool.
    pub building: u64,
}

/// One slot of the ADtree cache.
enum TreeSlot {
    /// A builder thread is constructing this tree; readers wait on the
    /// cache condvar (build coalescing) instead of duplicating the work.
    Building,
    Ready { tree: Arc<AdTree>, mem: usize, last_used: u64 },
}

#[derive(Default)]
struct TreeSlots {
    map: FxHashMap<String, TreeSlot>,
    /// Bytes of all `Ready` trees (mirrored into the store's external
    /// charge so tables and trees share one budget).
    bytes: usize,
    tick: u64,
    hits: u64,
    builds: u64,
    coalesced_waits: u64,
    evictions: u64,
}

/// Concurrency-safe lazily-built ADtree cache: per-table build coalescing
/// via a `Building` latch + condvar, LRU eviction under the store's
/// `mem_bytes` budget, bytes charged to the store as an external load.
#[derive(Default)]
struct TreeCache {
    slots: Mutex<TreeSlots>,
    cv: Condvar,
}

/// Lazily-loading count-query service over one store. All methods take
/// `&self` and are safe to call from many threads at once — the serving
/// front-end (`crate::serve`) shares one instance across its worker pool.
pub struct CountServer {
    schema: Schema,
    store: CtStore,
    trees: TreeCache,
    /// Manifest snapshot (immutable after open): spares the planner a
    /// lock-and-clone of the full metadata map per group evaluation.
    metas: Vec<TableMeta>,
    /// Population size per FO variable (entity-table totals).
    popsizes: Vec<u128>,
    /// Longest relationship chain the store holds a table for (the joint
    /// counts as full depth). Queries whose positive support is deeper
    /// get the structured `needs level k` error instead of a generic one.
    max_stored_chain: usize,
    /// Key stems of `.ct.bad` files in the store directory — tables the
    /// scrub quarantined. Queries that only such a table could have
    /// answered get the structured `needs table <key>` error
    /// ([`needs_table`] parses it) instead of a generic miss.
    quarantined: Vec<String>,
}

impl CountServer {
    /// Open a store directory; the schema is regenerated from the
    /// dataset name recorded in the manifest.
    pub fn open(dir: impl AsRef<Path>) -> Result<CountServer> {
        let store = CtStore::open(dir.as_ref())?;
        let schema = crate::datagen::schema_of(&store.dataset)?;
        CountServer::new(store, schema)
    }

    /// Serve from an already-open store.
    pub fn new(store: CtStore, schema: Schema) -> Result<CountServer> {
        let metas = store.tables();
        let mut popsizes: Vec<Option<u128>> = vec![None; schema.fo_vars.len()];
        for m in &metas {
            if let TableKind::Entity(fo) = m.kind {
                if fo < popsizes.len() {
                    popsizes[fo] = Some(m.total);
                }
            }
        }
        let popsizes: Vec<u128> = popsizes
            .into_iter()
            .enumerate()
            .map(|(fo, p)| {
                p.with_context(|| {
                    // Structured (`needs_table` parses it): entity tables
                    // are tiny but load-bearing — every rescale needs the
                    // popsize — so a quarantined/missing one is fatal and
                    // names exactly what to restore.
                    format!(
                        "needs table entity_{fo}: store is missing the entity table \
                         for FO variable {fo}"
                    )
                })
            })
            .collect::<Result<_>>()?;
        let max_stored_chain = metas
            .iter()
            .map(|m| match &m.kind {
                TableKind::Joint => schema.num_rel_vars(),
                TableKind::Chain(rs) | TableKind::Positive(rs) => rs.len(),
                TableKind::Entity(_) => 0,
            })
            .max()
            .unwrap_or(0);
        let mut quarantined: Vec<String> = Vec::new();
        if let Ok(rd) = std::fs::read_dir(store.dir()) {
            for e in rd.flatten() {
                let name = e.file_name();
                if let Some(stem) = name.to_string_lossy().strip_suffix(".ct.bad") {
                    quarantined.push(stem.to_string());
                }
            }
        }
        quarantined.sort();
        Ok(CountServer {
            schema,
            store,
            trees: TreeCache::default(),
            metas,
            popsizes,
            max_stored_chain,
            quarantined,
        })
    }

    /// Keys of tables the open-time scrub quarantined (`.ct.bad` stems).
    pub fn quarantined(&self) -> &[String] {
        &self.quarantined
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn store(&self) -> &CtStore {
        &self.store
    }

    /// Cache/IO counters of the underlying store.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Counters of the shared ADtree cache.
    pub fn tree_stats(&self) -> TreeStats {
        let g = self.trees.slots.lock().unwrap();
        TreeStats {
            hits: g.hits,
            builds: g.builds,
            coalesced_waits: g.coalesced_waits,
            evictions: g.evictions,
            bytes: g.bytes as u64,
            building: g.map.values().filter(|s| matches!(s, TreeSlot::Building)).count() as u64,
        }
    }

    /// Longest stored chain (the joint counts as full depth).
    pub fn max_stored_chain(&self) -> usize {
        self.max_stored_chain
    }

    /// Count of a conjunctive query over the full database scope.
    pub fn count(&self, conds: &[(VarId, u16)]) -> Result<u128> {
        let norm = {
            let _sp = trace::span("plan.normalize");
            normalize(&self.schema, conds)
        };
        let Some(conds) = norm else { return Ok(0) };
        let insts = self.insts(&conds)?;
        let fo_q = self.fo_set(&conds);
        let mut out = insts;
        for (fo, &pop) in self.popsizes.iter().enumerate() {
            if !fo_q.contains(&fo) {
                out = out.checked_mul(pop).context("count overflows u128")?;
            }
        }
        Ok(out)
    }

    /// Parse-and-count convenience for the CLI / serve loop.
    pub fn count_query(&self, query: &str) -> Result<u128> {
        let conds = {
            let _sp = trace::span("plan.parse");
            parse_query(&self.schema, query)?
        };
        self.count(&conds)
    }

    /// Normalize a query to its *plan signature*: the sorted set of
    /// relationship indicator conditions with their sign pattern, plus
    /// the number of attribute conditions. Two queries share a signature
    /// exactly when the planner walks the same shape for them (same
    /// tables, same Möbius peels) — the attribute *values* only change
    /// which tree branches are taken, not the plan. This is the key the
    /// heavy-hitter sketch aggregates by
    /// ([`TopSketch`](crate::obs::sketch::TopSketch)).
    ///
    /// Unparseable queries map to `"invalid"`, provably-zero ones to
    /// `"zero"` — both legitimate (and rankable) workload shapes.
    pub fn plan_signature(&self, query: &str) -> String {
        let Ok(conds) = parse_query(&self.schema, query) else {
            return "invalid".to_string();
        };
        let Some(conds) = normalize(&self.schema, &conds) else {
            return "zero".to_string();
        };
        let mut rels: Vec<String> = Vec::new();
        let mut attrs = 0usize;
        for &(v, code) in &conds {
            match self.schema.random_vars[v] {
                RandomVar::RelInd { .. } => rels.push(format!(
                    "{}={}",
                    self.schema.var_name(v),
                    if code == 1 { "T" } else { "F" }
                )),
                RandomVar::RelAttr { .. } | RandomVar::EntityAttr { .. } => attrs += 1,
            }
        }
        rels.sort_unstable();
        match (rels.is_empty(), attrs) {
            (true, _) => format!("attrs:{attrs}"),
            (false, 0) => rels.join("&"),
            (false, _) => format!("{}|attrs:{attrs}", rels.join("&")),
        }
    }

    /// FO variables a set of conditions ranges over.
    fn fo_set(&self, conds: &[(VarId, u16)]) -> BTreeSet<FoVarId> {
        conds.iter().flat_map(|&(v, _)| fos_of_var(&self.schema, v)).collect()
    }

    /// Number of instantiations of `fo_set(conds)` satisfying `conds`
    /// (normalized input).
    fn insts(&self, conds: &[(VarId, u16)]) -> Result<u128> {
        if conds.is_empty() {
            return Ok(1);
        }
        let groups = split_groups(&self.schema, conds);
        trace::event("plan.fo_groups", || format!("groups={}", groups.len()));
        cost::add_fo_groups(groups.len() as u64);
        if groups.len() > 1 {
            let mut out = 1u128;
            for g in &groups {
                out = out.checked_mul(self.insts(g)?).context("count overflows u128")?;
            }
            return Ok(out);
        }
        self.insts_group(conds)
    }

    /// One FO-connected group: direct cover, positive tables, or Möbius
    /// subtraction.
    fn insts_group(&self, conds: &[(VarId, u16)]) -> Result<u128> {
        let cond_vars: Vec<VarId> = conds.iter().map(|&(v, _)| v).collect();
        let fo_q = self.fo_set(conds);

        // 1. Smallest complete stored table covering every queried var.
        if let Some(meta) = self.best_cover(&cond_vars) {
            let cnt = self.table_count(meta, conds)?;
            return self.shrink_scope(cnt, &meta.scope, &fo_q);
        }

        let negs: Vec<usize> = conds
            .iter()
            .enumerate()
            .filter(|&(_, &(v, code))| {
                matches!(self.schema.random_vars[v], RandomVar::RelInd { .. }) && code == 0
            })
            .map(|(i, _)| i)
            .collect();

        // 2. All-positive base case: the chain's positive table has every
        //    2Att/1Att column but no indicators — indicator conditions are
        //    implied true and drop.
        if negs.is_empty() {
            let mut rels: Vec<RelId> =
                conds.iter().filter_map(|&(v, _)| self.schema.random_vars[v].rel()).collect();
            rels.sort_unstable();
            rels.dedup();
            if !rels.is_empty() {
                let key = TableKind::Positive(rels.clone()).key();
                if let Some(meta) = self.metas.iter().find(|m| m.key == key) {
                    let att_conds: Vec<(VarId, u16)> = conds
                        .iter()
                        .copied()
                        .filter(|&(v, _)| {
                            !matches!(self.schema.random_vars[v], RandomVar::RelInd { .. })
                        })
                        .collect();
                    if covers(&meta.vars, &att_conds) {
                        let cnt = self.table_count(meta, &att_conds)?;
                        return self.shrink_scope(cnt, &meta.scope, &fo_q);
                    }
                }
                // Depth-capped store: the query's positive support spans a
                // chain longer than anything persisted. Structured signal
                // (`needs_level` parses it) instead of a generic failure.
                if rels.len() > self.max_stored_chain {
                    bail!(
                        "needs level {}: the query's positive support spans {} relationships \
                         but this store holds chains only up to length {} — re-persist with \
                         --max-chain-len {} or more (or at full depth)",
                        rels.len(),
                        rels.len(),
                        self.max_stored_chain,
                        rels.len()
                    );
                }
            }
            // No derivation exists. If the exact table that would have
            // answered sits quarantined on disk, say so by name —
            // structured (`needs_table` parses it), so a front-end can
            // distinguish "restore/re-persist this table" from a plain
            // bad query.
            let mut candidates = Vec::new();
            if !rels.is_empty() {
                candidates.push(TableKind::Positive(rels.clone()).key());
                candidates.push(TableKind::Chain(rels.clone()).key());
            }
            candidates.push(TableKind::Joint.key());
            for key in candidates {
                if self.quarantined.binary_search(&key).is_ok() {
                    bail!(
                        "needs table {key}: it was quarantined as {key}.ct.bad and no \
                         surviving table derives this count — restore the file or \
                         re-persist the run"
                    );
                }
            }
            bail!(
                "no stored table covers query variables [{}]",
                cond_vars
                    .iter()
                    .map(|&v| self.schema.var_name(v))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }

        // 3. Möbius subtraction: peel one negative indicator (Equation 1).
        let (peel_var, _) = conds[negs[0]];
        let _sp =
            trace::span_detailed("mobius.subtract", || self.schema.var_name(peel_var).to_string());
        cost::add_subtract_depth(1);
        let rest: Vec<(VarId, u16)> =
            conds.iter().copied().filter(|&(v, _)| v != peel_var).collect();
        // count(rest) at the scope of the full group: unconstrained FO
        // variables of the peeled relationship multiply in freely.
        let fo_rest = self.fo_set(&rest);
        let mut star = self.insts(&rest)?;
        for &fo in &fo_q {
            if !fo_rest.contains(&fo) {
                star = star.checked_mul(self.popsizes[fo]).context("count overflows u128")?;
            }
        }
        let mut pos = rest;
        pos.push((peel_var, 1));
        pos.sort_unstable_by_key(|c| c.0);
        let truthy = self.insts(&pos)?;
        star.checked_sub(truthy).with_context(|| {
            format!(
                "inconsistent store: ct({}=T) exceeds the unconditioned count",
                self.schema.var_name(peel_var)
            )
        })
    }

    /// Rescale a table-scope count down to the query's FO scope. Exact by
    /// the factorization of the cross-product measure.
    fn shrink_scope(
        &self,
        cnt: u128,
        scope: &[FoVarId],
        fo_q: &BTreeSet<FoVarId>,
    ) -> Result<u128> {
        let mut extra = 1u128;
        for &fo in scope {
            if !fo_q.contains(&fo) {
                extra = extra.checked_mul(self.popsizes[fo]).context("scope factor overflow")?;
            }
        }
        if extra == 0 {
            // An empty population in scope forces every count to zero.
            return Ok(0);
        }
        if cnt % extra != 0 {
            bail!("inconsistent store: count {cnt} not divisible by scope factor {extra}");
        }
        Ok(cnt / extra)
    }

    /// Smallest (by rows) complete stored table whose columns cover `vars`.
    fn best_cover(&self, vars: &[VarId]) -> Option<&TableMeta> {
        self.metas
            .iter()
            .filter(|m| !matches!(m.kind, TableKind::Positive(_)))
            .filter(|m| vars.iter().all(|v| m.vars.binary_search(v).is_ok()))
            .min_by_key(|m| m.rows)
    }

    /// Count lookup on one stored table. ADtree node counts are `u64`, so
    /// the tree path is only sound while the table's grand total fits
    /// `u64`; beyond that (huge population products) the lookup routes
    /// through exact `u128` selection instead of silently wrapping.
    fn table_count(&self, meta: &TableMeta, conds: &[(VarId, u16)]) -> Result<u128> {
        let _sp = trace::span_detailed("table.count", || meta.key.clone());
        if meta.total > u64::MAX as u128 {
            let ct = self.store.get(&meta.key)?;
            cost::add_rows_merged(ct.len() as u64);
            cost::add_bytes_scanned(ct.mem_bytes() as u64);
            return Ok(ct.select(conds).total());
        }
        Ok(self.tree(&meta.key)?.count(conds) as u128)
    }

    /// Get-or-build the cached ADtree of one stored table.
    ///
    /// Build coalescing: the first thread to miss installs a `Building`
    /// latch and constructs the tree *outside* the lock; concurrent
    /// readers of the same key block on the cache condvar and wake to the
    /// finished tree, so no table's tree is ever built twice while cached.
    /// The new tree's exact `mem_bytes` are charged to the store's shared
    /// budget ([`CtStore::charge_external`]) and the tree cache itself
    /// evicts least-recently-used trees beyond it — tables and trees
    /// compete for the same memory, as one `--mem-budget` flag promises.
    fn tree(&self, key: &str) -> Result<Arc<AdTree>> {
        /// Owned view of one probe, so the map borrow ends before we act.
        enum Probe {
            Ready(Arc<AdTree>),
            Building,
            Missing,
        }
        let mut g = self.trees.slots.lock().unwrap();
        let mut waited = false;
        loop {
            g.tick += 1;
            let tick = g.tick;
            let probe = match g.map.get_mut(key) {
                Some(TreeSlot::Ready { tree, last_used, .. }) => {
                    *last_used = tick;
                    Probe::Ready(Arc::clone(tree))
                }
                Some(TreeSlot::Building) => Probe::Building,
                None => Probe::Missing,
            };
            match probe {
                Probe::Ready(tree) => {
                    g.hits += 1;
                    trace::event("adtree.hit", || key.to_string());
                    cost::add_tables_cached(1);
                    return Ok(tree);
                }
                Probe::Building => {
                    if !waited {
                        g.coalesced_waits += 1;
                        waited = true;
                        trace::event("adtree.coalesced_wait", || key.to_string());
                    }
                    g = self.trees.cv.wait(g).unwrap();
                }
                Probe::Missing => {
                    g.map.insert(key.to_string(), TreeSlot::Building);
                    g.builds += 1;
                    break;
                }
            }
        }
        drop(g);

        // This thread owns the build. The table load goes through the
        // store's own LRU (and may itself evict); tree construction is the
        // expensive part and runs with no lock held — span-wrapped so cold
        // cache misses show up in EXPLAIN trees and profiler stacks alike.
        let built = {
            let _sp = trace::span_detailed("adtree.build", || key.to_string());
            self.store.get(key).map(|ct| AdTree::build(&ct, AdTreeConfig::default()))
        };

        let mut g = self.trees.slots.lock().unwrap();
        let tree = match built {
            Err(e) => {
                // Clear the latch so waiters retry (one becomes the new
                // builder) instead of hanging on a failed build.
                g.map.remove(key);
                self.trees.cv.notify_all();
                return Err(e);
            }
            Ok(t) => Arc::new(t),
        };
        let mem = tree.mem_bytes();
        cost::add_tables_loaded(1);
        cost::add_bytes_scanned(mem as u64);
        g.tick += 1;
        let tick = g.tick;
        g.map.insert(
            key.to_string(),
            TreeSlot::Ready { tree: Arc::clone(&tree), mem, last_used: tick },
        );
        g.bytes += mem;
        let freed = self.evict_trees(&mut g);
        // Net charge against the shared store budget, applied while the
        // tree lock is still held so the external charge can never drift
        // from the live tree bytes under concurrent builds (the store
        // lock nests inside the tree lock here; the store never takes the
        // tree lock, so the trees → store order is acyclic).
        self.store.charge_external(mem as isize - freed as isize);
        drop(g);
        self.trees.cv.notify_all();
        Ok(tree)
    }

    /// Evict least-recently-used `Ready` trees until the tree bytes alone
    /// fit the store's budget, keeping the most recently used. Returns the
    /// bytes freed (to be released from the store's external charge).
    fn evict_trees(&self, g: &mut TreeSlots) -> usize {
        let Some(budget) = self.store.mem_budget() else { return 0 };
        let mut freed = 0usize;
        loop {
            let ready: Vec<(&String, u64)> = g
                .map
                .iter()
                .filter_map(|(k, s)| match s {
                    TreeSlot::Ready { last_used, .. } => Some((k, *last_used)),
                    TreeSlot::Building => None,
                })
                .collect();
            if g.bytes <= budget || ready.len() <= 1 {
                return freed;
            }
            let newest = ready.iter().map(|&(_, t)| t).max().unwrap_or(0);
            let victim = ready
                .iter()
                .filter(|&&(_, t)| t != newest)
                .min_by_key(|&&(_, t)| t)
                .map(|&(k, _)| k.clone());
            let Some(k) = victim else { return freed };
            if let Some(TreeSlot::Ready { mem, .. }) = g.map.remove(&k) {
                g.bytes -= mem;
                freed += mem;
                g.evictions += 1;
            }
        }
    }
}

/// If `err` carries the structured depth-cap signal (`needs level k`),
/// extract the chain-lattice level the store would have to hold to answer
/// — what lets a front-end distinguish "re-persist deeper" from a plain
/// bad query. Context wrapping is tolerated anywhere around it.
pub fn needs_level(err: &crate::util::error::Error) -> Option<usize> {
    let msg = err.to_string();
    let idx = msg.find("needs level ")?;
    let digits: String = msg[idx + "needs level ".len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

/// If `err` carries the structured quarantine signal (`needs table <key>`),
/// extract the store key that would have to be restored or re-persisted.
/// Context wrapping is tolerated anywhere around it.
pub fn needs_table(err: &crate::util::error::Error) -> Option<String> {
    let msg = err.to_string();
    let idx = msg.find("needs table ")?;
    let key: String = msg[idx + "needs table ".len()..]
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if key.is_empty() {
        None
    } else {
        Some(key)
    }
}

/// FO variables one random variable ranges over.
fn fos_of_var(schema: &Schema, v: VarId) -> Vec<FoVarId> {
    match schema.random_vars[v] {
        RandomVar::EntityAttr { fo, .. } => vec![fo],
        RandomVar::RelAttr { rel, .. } | RandomVar::RelInd { rel } => {
            let mut fos = schema.relationships[rel].fo_vars.to_vec();
            fos.dedup(); // self-relationships repeat the FO variable
            fos
        }
    }
}

/// Whether `sorted_vars` covers every variable of `conds`.
fn covers(sorted_vars: &[VarId], conds: &[(VarId, u16)]) -> bool {
    conds.iter().all(|&(v, _)| sorted_vars.binary_search(&v).is_ok())
}

/// Split conditions into independent groups: connected components of the
/// "shares an FO variable" relation. Groups factorize exactly because the
/// underlying measure is the cross product of the populations.
fn split_groups(schema: &Schema, conds: &[(VarId, u16)]) -> Vec<Vec<(VarId, u16)>> {
    let n = conds.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut [usize], i: usize) -> usize {
        if p[i] != i {
            let r = find(p, p[i]);
            p[i] = r;
        }
        p[i]
    }
    let mut by_fo: BTreeMap<FoVarId, usize> = BTreeMap::new();
    for (i, &(v, _)) in conds.iter().enumerate() {
        for fo in fos_of_var(schema, v) {
            match by_fo.get(&fo).copied() {
                Some(j) => {
                    let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                    parent[ri] = rj;
                }
                None => {
                    by_fo.insert(fo, i);
                }
            }
        }
    }
    let mut groups: BTreeMap<usize, Vec<(VarId, u16)>> = BTreeMap::new();
    for i in 0..n {
        let r = find(&mut parent, i);
        groups.entry(r).or_default().push(conds[i]);
    }
    groups.into_values().collect()
}

/// Normalize a conjunctive query. `None` means the count is provably zero
/// (contradictory or unrepresentable conditions). Otherwise the result is
/// deduplicated, sorted by `VarId`, with the 2Att/indicator coupling
/// resolved: `2Att = n/a` becomes `R = F`, and conditions implied by an
/// indicator condition are dropped.
pub fn normalize(schema: &Schema, conds: &[(VarId, u16)]) -> Option<Vec<(VarId, u16)>> {
    let mut m: BTreeMap<VarId, u16> = BTreeMap::new();
    for &(v, code) in conds {
        if v >= schema.random_vars.len() {
            return None;
        }
        match m.get(&v).copied() {
            Some(prev) if prev != code => return None,
            _ => {
                m.insert(v, code);
            }
        }
    }
    // Indicator conditions first: they decide how 2Atts are interpreted.
    let mut ind: BTreeMap<RelId, u16> = BTreeMap::new();
    for (&v, &code) in &m {
        if let RandomVar::RelInd { rel } = schema.random_vars[v] {
            if code > 1 {
                return None;
            }
            ind.insert(rel, code);
        }
    }
    let mut out: Vec<(VarId, u16)> = Vec::with_capacity(m.len());
    let mut implied_negs: BTreeSet<RelId> = BTreeSet::new();
    let mut real_atts: BTreeSet<RelId> = BTreeSet::new();
    for (&v, &code) in &m {
        match schema.random_vars[v] {
            RandomVar::EntityAttr { .. } => {
                if (code as usize) >= schema.var_arity(v) {
                    return None;
                }
                out.push((v, code));
            }
            RandomVar::RelInd { .. } => out.push((v, code)),
            RandomVar::RelAttr { rel, .. } => {
                // var_arity counts the n/a slot; real codes are below it.
                let real_arity = schema.var_arity(v) - 1;
                match ind.get(&rel) {
                    Some(0) => {
                        // R=F: every 2Att is n/a. A real value contradicts.
                        if code != NA {
                            return None;
                        }
                    }
                    Some(_) => {
                        if code == NA || (code as usize) >= real_arity {
                            return None;
                        }
                        real_atts.insert(rel);
                        out.push((v, code));
                    }
                    None => {
                        if code == NA {
                            implied_negs.insert(rel);
                        } else if (code as usize) >= real_arity {
                            return None;
                        } else {
                            real_atts.insert(rel);
                            out.push((v, code));
                        }
                    }
                }
            }
        }
    }
    for rel in implied_negs {
        // n/a and a real 2Att value on the same relationship contradict.
        if real_atts.contains(&rel) {
            return None;
        }
        out.push((schema.rel_ind_var(rel), 0));
    }
    out.sort_unstable_by_key(|c| c.0);
    Some(out)
}

/// Parse a query string: whitespace-separated `name=value` terms
/// (trailing commas tolerated), e.g.
/// `RA(P,S)=F intelligence(S)=1 capability(P,S)=n/a`.
pub fn parse_query(schema: &Schema, s: &str) -> Result<Vec<(VarId, u16)>> {
    let mut out = Vec::new();
    for tok in s.split_whitespace() {
        let tok = tok.trim_matches(',');
        if tok.is_empty() {
            continue;
        }
        let (name, val) =
            tok.split_once('=').with_context(|| format!("expected name=value, got `{tok}`"))?;
        let v = schema
            .var_by_name(name)
            .with_context(|| format!("unknown variable `{name}` in schema {}", schema.name))?;
        out.push((v, parse_value(schema, v, val)?));
    }
    Ok(out)
}

fn parse_value(schema: &Schema, v: VarId, val: &str) -> Result<u16> {
    match schema.random_vars[v] {
        RandomVar::RelInd { .. } => match val {
            "T" | "t" | "true" | "1" => Ok(1),
            "F" | "f" | "false" | "0" => Ok(0),
            other => bail!("bad indicator value `{other}` (want T/F)"),
        },
        RandomVar::RelAttr { attr, .. } => {
            if matches!(val, "n/a" | "na" | "NA" | "N/A") {
                Ok(NA)
            } else {
                value_code(&schema.attributes[attr], val)
            }
        }
        RandomVar::EntityAttr { attr, .. } => value_code(&schema.attributes[attr], val),
    }
}

fn value_code(attr: &Attribute, val: &str) -> Result<u16> {
    if let Some(i) = attr.values.iter().position(|x| x == val) {
        return Ok(i as u16);
    }
    val.parse::<u16>()
        .map_err(|_| crate::anyhow!("`{val}` is neither a value of {} nor a code", attr.name))
}

/// Deterministically generate `n` random query strings for a schema —
/// feeds the store-smoke CI job and the two-phase integration test.
/// Queries mix entity attributes, 2Atts (including `n/a`), and positive
/// and negative indicator conditions; value codes may be unobserved (the
/// count is then zero, which both paths must agree on).
pub fn gen_queries(schema: &Schema, n: usize, seed: u64) -> Vec<String> {
    let mut rng = Pcg64::seeded(seed);
    let nvars = schema.random_vars.len();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let k = 1 + rng.index(3usize.min(nvars));
        let picks = rng.sample_indices(nvars, k);
        let mut terms = Vec::with_capacity(k);
        for v in picks {
            let codes = schema.var_codes(v);
            let code = codes[rng.index(codes.len())];
            terms.push(format!("{}={}", schema.var_name(v), schema.value_name(v, code)));
        }
        out.push(terms.join(" "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::mobius::MobiusJoin;
    use crate::store::repo::{PersistConfig, StoreSink};
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mrss_service_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Persist a uwcse run and return (dir, schema, in-memory joint).
    fn build_store(tag: &str, cfg: PersistConfig) -> (PathBuf, Schema, crate::ct::CtTable) {
        let dir = tmpdir(tag);
        let db = datagen::generate("uwcse", 0.2, 7).unwrap();
        let store = CtStore::create(&dir, "uwcse", 0.2, 7).unwrap();
        let sink = StoreSink::new(&store, &db.schema, cfg);
        let res = MobiusJoin::new(&db).sink(&sink).run();
        sink.take_error().unwrap();
        let joint = res.joint_ct().clone();
        (dir, (*db.schema).clone(), joint)
    }

    #[test]
    fn normalize_handles_coupling_and_contradictions() {
        let s = crate::schema::university_schema();
        let ra = s.var_by_name("RA(P,S)").unwrap();
        let cap = s.var_by_name("capability(P,S)").unwrap();
        let intel = s.var_by_name("intelligence(S)").unwrap();

        // n/a alone rewrites to R=F.
        assert_eq!(normalize(&s, &[(cap, NA)]), Some(vec![(ra, 0)]));
        // duplicate conds collapse; conflicting are zero.
        assert_eq!(normalize(&s, &[(intel, 1), (intel, 1)]), Some(vec![(intel, 1)]));
        assert_eq!(normalize(&s, &[(intel, 1), (intel, 0)]), None);
        // real value under R=F is zero; n/a under R=T is zero.
        assert_eq!(normalize(&s, &[(ra, 0), (cap, 1)]), None);
        assert_eq!(normalize(&s, &[(ra, 1), (cap, NA)]), None);
        // implied n/a drops under an explicit R=F.
        assert_eq!(normalize(&s, &[(ra, 0), (cap, NA)]), Some(vec![(ra, 0)]));
        // out-of-range codes are zero, not errors.
        assert_eq!(normalize(&s, &[(intel, 200)]), None);
    }

    #[test]
    fn parse_query_names_and_values() {
        let s = crate::schema::university_schema();
        let q = parse_query(&s, "RA(P,S)=F intelligence(S)=1, capability(P,S)=n/a").unwrap();
        assert_eq!(q.len(), 3);
        let ra = s.var_by_name("RA(P,S)").unwrap();
        assert!(q.contains(&(ra, 0)));
        assert!(parse_query(&s, "nope(X)=1").is_err());
        assert!(parse_query(&s, "RA(P,S)=maybe").is_err());
    }

    #[test]
    fn full_store_matches_joint_selection() {
        let (dir, schema, joint) = build_store("full", PersistConfig::default());
        let server = CountServer::open(&dir).unwrap();
        for q in gen_queries(&schema, 40, 99) {
            let conds = parse_query(&schema, &q).unwrap();
            let expect = joint.select(&conds).total();
            let got = server.count(&conds).unwrap();
            assert_eq!(got, expect, "query `{q}`");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn positives_only_store_uses_mobius_subtraction() {
        let (dir, schema, joint) = build_store("posonly", PersistConfig::positives_only());
        let server = CountServer::open(&dir).unwrap();
        // No complete chain tables or joint on disk: negative-relationship
        // answers can only come from Möbius subtraction over pos_* tables.
        assert!(!server.store().contains("joint"));
        assert!(server.store().tables().iter().all(|m| !matches!(m.kind, TableKind::Chain(_))));
        for q in gen_queries(&schema, 40, 123) {
            let conds = parse_query(&schema, &q).unwrap();
            let expect = joint.select(&conds).total();
            let got = server.count(&conds).unwrap();
            assert_eq!(got, expect, "query `{q}`");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_query_counts_the_whole_cross_product() {
        let (dir, _schema, joint) = build_store("empty", PersistConfig::default());
        let server = CountServer::open(&dir).unwrap();
        assert_eq!(server.count(&[]).unwrap(), joint.total());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_signature_groups_shapes_not_values() {
        let (dir, schema, _joint) = build_store("sig", PersistConfig::default());
        let server = CountServer::open(&dir).unwrap();
        // An entity attribute with ≥2 values: different values, same shape.
        let att = (0..schema.random_vars.len())
            .find(|&v| {
                matches!(schema.random_vars[v], RandomVar::EntityAttr { .. })
                    && schema.var_arity(v) >= 2
            })
            .unwrap();
        let name = schema.var_name(att);
        let s0 = server.plan_signature(&format!("{name}=0"));
        let s1 = server.plan_signature(&format!("{name}=1"));
        assert_eq!(s0, s1, "attribute values must not split the signature");
        assert_eq!(s0, "attrs:1");

        // Relationship sign flips the signature; sort order is canonical.
        let ind = (0..schema.random_vars.len())
            .find(|&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
            .unwrap();
        let rname = schema.var_name(ind);
        let pos = server.plan_signature(&format!("{rname}=T"));
        let neg = server.plan_signature(&format!("{rname}=F"));
        assert_ne!(pos, neg);
        assert_eq!(pos, format!("{rname}=T"));
        assert_eq!(neg, format!("{rname}=F"));
        let mixed = server.plan_signature(&format!("{rname}=F {name}=1"));
        assert_eq!(mixed, format!("{rname}=F|attrs:1"));

        // Degenerate shapes are named, not errors.
        assert_eq!(server.plan_signature("nope(X)=1"), "invalid");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn count_accumulates_query_cost() {
        use crate::obs::cost;
        let (dir, schema, _joint) = build_store("cost", PersistConfig::positives_only());
        let server = CountServer::open(&dir).unwrap();
        let ind = (0..schema.random_vars.len())
            .find(|&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
            .unwrap();
        // Cold negative query on a positives-only store: a Möbius peel
        // plus at least one fresh ADtree build.
        cost::begin();
        server.count(&[(ind, 0)]).unwrap();
        let c1 = cost::take().unwrap();
        assert!(c1.subtract_depth >= 1, "{c1:?}");
        assert!(c1.fo_groups >= 1, "{c1:?}");
        assert!(c1.tables_loaded >= 1, "{c1:?}");
        assert!(c1.bytes_scanned > 0, "{c1:?}");
        assert!(c1.adtree_nodes_probed >= 1, "{c1:?}");
        // Warm re-run: same plan shape, but now every table cache-hits.
        cost::begin();
        server.count(&[(ind, 0)]).unwrap();
        let c2 = cost::take().unwrap();
        assert_eq!(c2.tables_loaded, 0, "{c2:?}");
        assert!(c2.tables_cached >= 1, "{c2:?}");
        assert_eq!(c2.subtract_depth, c1.subtract_depth, "plan shape is stable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gen_queries_is_deterministic() {
        let s = crate::schema::university_schema();
        assert_eq!(gen_queries(&s, 5, 3), gen_queries(&s, 5, 3));
        assert_ne!(gen_queries(&s, 5, 3), gen_queries(&s, 5, 4));
    }

    /// Two RelInd vars sharing an FO variable (uwcse's two self-rels over
    /// Person) — the smallest query whose positive support needs level 2.
    fn two_connected_rel_inds(schema: &Schema) -> (VarId, VarId) {
        let inds: Vec<VarId> = (0..schema.random_vars.len())
            .filter(|&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
            .collect();
        for (i, &a) in inds.iter().enumerate() {
            for &b in &inds[i + 1..] {
                let fa = fos_of_var(schema, a);
                if fos_of_var(schema, b).iter().any(|f| fa.contains(f)) {
                    return (a, b);
                }
            }
        }
        panic!("schema has no FO-connected relationship pair");
    }

    #[test]
    fn depth_capped_store_returns_structured_needs_level_error() {
        let dir = tmpdir("capped");
        let db = datagen::generate("uwcse", 0.2, 7).unwrap();
        let store = CtStore::create(&dir, "uwcse", 0.2, 7).unwrap();
        let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
        // Persist only level-1 chains: no level-2 tables, no joint.
        let res = MobiusJoin::new(&db).max_chain_len(1).sink(&sink).run();
        sink.take_error().unwrap();
        assert!(res.joint.is_none());
        drop(res);

        let server = CountServer::open(&dir).unwrap();
        assert_eq!(server.max_stored_chain(), 1);
        let (a, b) = two_connected_rel_inds(server.schema());

        // Level-1 queries still answer.
        server.count(&[(a, 1)]).unwrap();
        // A level-2 positive support is a structured error, not a generic
        // one — both all-positive and Möbius-subtraction (negative) paths.
        for codes in [(1u16, 1u16), (0, 0), (1, 0)] {
            let err = server.count(&[(a, codes.0), (b, codes.1)]).unwrap_err();
            assert_eq!(
                needs_level(&err),
                Some(2),
                "expected `needs level 2` in: {err}"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn needs_level_parses_only_the_structured_signal() {
        use crate::util::error::Error;
        assert_eq!(needs_level(&Error::msg("ctx: needs level 3: deeper")), Some(3));
        assert_eq!(needs_level(&Error::msg("no stored table covers [x]")), None);
    }

    #[test]
    fn needs_table_parses_only_the_structured_signal() {
        use crate::util::error::Error;
        assert_eq!(
            needs_table(&Error::msg("ctx: needs table pos_0_2: gone")),
            Some("pos_0_2".to_string())
        );
        assert_eq!(needs_table(&Error::msg("no stored table covers [x]")), None);
        assert_eq!(needs_table(&Error::msg("needs table ")), None);
    }

    /// Truncate a table file in place, as a torn write would leave it.
    fn corrupt_file(path: &std::path::Path) {
        let bytes = std::fs::read(path).unwrap();
        std::fs::write(path, &bytes[..bytes.len() / 2]).unwrap();
    }

    #[test]
    fn scrubbed_store_degrades_to_surviving_tables() {
        let (dir, schema, joint) = build_store("quarantine", PersistConfig::default());
        let victim = {
            let store = CtStore::open(&dir).unwrap();
            let t = store.tables();
            t.iter().find(|m| matches!(m.kind, TableKind::Chain(_))).unwrap().key.clone()
        };
        corrupt_file(&dir.join(format!("{victim}.ct")));

        // Open quarantines the damaged chain table; every query must still
        // answer — and byte-identical to the clean joint — from survivors.
        let server = CountServer::open(&dir).unwrap();
        assert_eq!(server.quarantined().to_vec(), vec![victim.clone()]);
        assert_eq!(server.store().stats().quarantined_tables, 1);
        assert!(!server.store().contains(&victim));
        for q in gen_queries(&schema, 40, 99) {
            let conds = parse_query(&schema, &q).unwrap();
            let expect = joint.select(&conds).total();
            assert_eq!(server.count(&conds).unwrap(), expect, "query `{q}`");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantined_positive_table_yields_structured_needs_table_error() {
        let (dir, schema, _joint) = build_store("needstable", PersistConfig::positives_only());
        let (a, _) = two_connected_rel_inds(&schema);
        let rel = schema.random_vars[a].rel().unwrap();
        let key = TableKind::Positive(vec![rel]).key();
        corrupt_file(&dir.join(format!("{key}.ct")));

        // Positives-only store with its only cover for `rel` quarantined:
        // no derivation exists, so the miss must name the table.
        let server = CountServer::open(&dir).unwrap();
        let err = server.count(&[(a, 1)]).unwrap_err();
        assert_eq!(needs_table(&err), Some(key.clone()), "expected `needs table {key}`: {err}");
        // The negative query peels through the same missing positive table.
        let err = server.count(&[(a, 0)]).unwrap_err();
        assert_eq!(needs_table(&err), Some(key), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantined_entity_table_fails_open_with_needs_table() {
        let (dir, _schema, _joint) = build_store("entgone", PersistConfig::default());
        corrupt_file(&dir.join("entity_0.ct"));
        // Entity tables carry the popsizes every rescale needs: opening
        // without one is a structured failure naming the table.
        let err = CountServer::open(&dir).unwrap_err();
        assert_eq!(needs_table(&err), Some("entity_0".to_string()), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tree_cache_counts_builds_and_hits_once_per_table() {
        let (dir, schema, _joint) = build_store("treestats", PersistConfig::default());
        let server = CountServer::open(&dir).unwrap();
        let q = gen_queries(&schema, 20, 77);
        for s in &q {
            server.count_query(s).unwrap();
        }
        let t1 = server.tree_stats();
        assert!(t1.builds > 0);
        assert!(t1.bytes > 0, "live trees must charge bytes");
        // Re-running the same batch builds nothing new: every lookup hits.
        for s in &q {
            server.count_query(s).unwrap();
        }
        let t2 = server.tree_stats();
        assert_eq!(t2.builds, t1.builds, "re-query must not rebuild trees");
        assert!(t2.hits > t1.hits);
        assert_eq!(t2.coalesced_waits, 0, "single-threaded: no build overlap");
        // The external charge mirrors the live tree bytes.
        assert_eq!(server.store().external_bytes() as u64, t2.bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tight_budget_evicts_trees_and_answers_stay_correct() {
        let (dir, schema, joint) = build_store("treelru", PersistConfig::default());
        let server = CountServer::open(&dir).unwrap();
        // Budget far below one table: every tree insert pushes the cache
        // over, so older trees evict, yet answers must not change.
        server.store().set_mem_budget(Some(4096));
        for q in gen_queries(&schema, 40, 2025) {
            let conds = parse_query(&schema, &q).unwrap();
            assert_eq!(
                server.count(&conds).unwrap(),
                joint.select(&conds).total(),
                "query `{q}`"
            );
        }
        let t = server.tree_stats();
        assert!(t.evictions > 0, "expected tree evictions under 4 KiB: {t:?}");
        // Evicted trees released their charge: bytes only counts live ones.
        assert_eq!(server.store().external_bytes() as u64, t.bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
