//! Versioned binary codec for [`CtTable`] — the on-disk row format of the
//! [`CtStore`](super::CtStore).
//!
//! Layout of a `.ct` file:
//!
//! ```text
//! magic      8 bytes   b"MRSSCT01"
//! version    u16 LE    format version (currently 1)
//! tier       u8        0 = packed64, 1 = packed128, 2 = row-major wide
//! flags      u8        reserved (0)
//! width      varint    number of columns
//! columns    width ×   var id (varint), cap (varint), na flag (u8)
//! nrows      varint
//! rows       …         tier 0/1: first key absolute, then strictly
//!                      positive deltas, all varints — the sorted-unique
//!                      key invariant makes deltas small and dense;
//!                      tier 2: nrows × width codes as varints (NA = 65535)
//! counts     nrows ×   varint (all positive)
//! checksum   u64 LE    FNV-1a over everything above
//! ```
//!
//! The header stores only each column's `(var, cap, na)` spec: bit widths
//! and shifts are a pure function of the specs ([`CtLayout::from_specs`]),
//! so the decoded table carries the *identical* layout — and therefore the
//! identical packed keys — as the encoded one. Decoding re-checks the
//! magic, version, checksum, tier/layout consistency, key ordering, and
//! the full [`CtTable::check_invariants`], so a truncated or bit-flipped
//! file surfaces as an error, never as silently wrong counts.

use crate::anyhow;
use crate::bail;
use crate::ct::{CtLayout, CtTable, RowStore};
use crate::schema::VarId;
use crate::util::error::Result;

/// File magic: "MRSS contingency table, format generation 01".
pub const MAGIC: [u8; 8] = *b"MRSSCT01";

/// Current format version (bumped on incompatible changes).
pub const FORMAT_VERSION: u16 = 1;

const TIER_PACKED64: u8 = 0;
const TIER_PACKED128: u8 = 1;
const TIER_WIDE: u8 = 2;

/// FNV-1a over a byte slice — the trailing corruption check.
fn fnv1a(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// LEB128 varint (7 bits per byte, low group first). One routine covers
/// u16 codes through u128 keys.
fn put_varint(out: &mut Vec<u8>, mut v: u128) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Bounds-checked reader over the (already checksum-verified) body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("ct file truncated: wanted {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16le(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn varint(&mut self) -> Result<u128> {
        let mut v: u128 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 128 || (shift == 126 && b & 0x7c != 0) {
                bail!("ct file corrupt: varint overflows 128 bits");
            }
            v |= ((b & 0x7f) as u128) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn varint_u64(&mut self) -> Result<u64> {
        let v = self.varint()?;
        u64::try_from(v).map_err(|_| anyhow!("ct file corrupt: value {v} exceeds u64"))
    }

    fn varint_u16(&mut self) -> Result<u16> {
        let v = self.varint()?;
        u16::try_from(v).map_err(|_| anyhow!("ct file corrupt: value {v} exceeds u16"))
    }
}

/// Serialize a table (any storage tier) to the versioned binary format.
pub fn encode(ct: &CtTable) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + ct.len() * 4);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    let tier = match &ct.store {
        RowStore::Packed(_) => TIER_PACKED64,
        RowStore::Packed2(_) => TIER_PACKED128,
        RowStore::Wide(_) => TIER_WIDE,
    };
    out.push(tier);
    out.push(0); // flags, reserved
    let width = ct.width();
    put_varint(&mut out, width as u128);
    for (c, &v) in ct.vars.iter().enumerate() {
        let (cap, na) = ct.layout.spec(c);
        put_varint(&mut out, v as u128);
        put_varint(&mut out, cap as u128);
        out.push(na as u8);
    }
    put_varint(&mut out, ct.len() as u128);
    match &ct.store {
        RowStore::Packed(keys) => {
            let mut prev = 0u64;
            for (i, &k) in keys.iter().enumerate() {
                put_varint(&mut out, if i == 0 { k as u128 } else { (k - prev) as u128 });
                prev = k;
            }
        }
        RowStore::Packed2(keys) => {
            let mut prev = 0u128;
            for (i, &k) in keys.iter().enumerate() {
                put_varint(&mut out, if i == 0 { k } else { k - prev });
                prev = k;
            }
        }
        RowStore::Wide(rows) => {
            for &code in rows {
                put_varint(&mut out, code as u128);
            }
        }
    }
    for &c in &ct.counts {
        put_varint(&mut out, c as u128);
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Deserialize a table, validating the checksum, header, tier/layout
/// consistency, and every [`CtTable`] invariant.
pub fn decode(bytes: &[u8]) -> Result<CtTable> {
    // 8 magic + 2 version + 2 tier/flags + 1 width + 1 nrows + 8 checksum.
    if bytes.len() < 22 {
        bail!("ct file truncated: only {} bytes", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let expect = u64::from_le_bytes(sum_bytes.try_into().expect("split_at gave 8 bytes"));
    if fnv1a(body) != expect {
        bail!("ct file checksum mismatch (corrupt or truncated)");
    }
    let mut r = Reader::new(body);
    if r.bytes(8)? != MAGIC.as_slice() {
        bail!("not a ct file: bad magic");
    }
    let version = r.u16le()?;
    if version != FORMAT_VERSION {
        bail!("unsupported ct format version {version} (this build reads {FORMAT_VERSION})");
    }
    let tier = r.u8()?;
    let flags = r.u8()?;
    if flags != 0 {
        // Reserved for forward compatibility: a future writer setting a
        // flag signals semantics this reader does not know.
        bail!("unsupported ct file: unknown flags {flags:#04x}");
    }
    let width = usize::try_from(r.varint_u64()?)
        .map_err(|_| anyhow!("ct file corrupt: width out of range"))?;
    if width > u16::MAX as usize {
        bail!("ct file corrupt: width {width} out of range");
    }
    let mut vars: Vec<VarId> = Vec::with_capacity(width);
    let mut specs: Vec<(u16, bool)> = Vec::with_capacity(width);
    for _ in 0..width {
        let v = r.varint_u64()? as VarId;
        if let Some(&last) = vars.last() {
            if v <= last {
                bail!("ct file corrupt: vars not strictly increasing");
            }
        }
        let cap = r.varint_u16()?;
        if cap == 0 {
            bail!("ct file corrupt: zero column cap");
        }
        let na = match r.u8()? {
            0 => false,
            1 => true,
            b => bail!("ct file corrupt: bad na flag {b}"),
        };
        vars.push(v);
        specs.push((cap, na));
    }
    let layout = CtLayout::from_specs(&specs);
    let nrows = usize::try_from(r.varint_u64()?)
        .map_err(|_| anyhow!("ct file corrupt: row count out of range"))?;
    // Every varint is ≥ 1 byte: packed rows need a key byte + a count
    // byte, wide rows `width` code bytes + a count byte, nullary rows just
    // the count byte. A cheap bound that stops a corrupt-but-checksummed
    // header from asking for a huge allocation.
    let min_row_bytes = match tier {
        _ if width == 0 => 1,
        TIER_WIDE => width + 1,
        _ => 2,
    };
    if nrows.saturating_mul(min_row_bytes) > r.remaining() {
        bail!("ct file corrupt: {nrows} rows cannot fit {} payload bytes", r.remaining());
    }
    let store = match tier {
        // Nullary tables (the × identity / scalar): no key section at all.
        _ if width == 0 => {
            if tier != TIER_PACKED64 {
                bail!("ct file corrupt: nullary table with tier {tier}");
            }
            RowStore::Packed(Vec::new())
        }
        TIER_PACKED64 => {
            if !layout.fits() {
                bail!("ct file corrupt: one-word tier with a {}-bit layout", layout.total_bits());
            }
            let mut keys: Vec<u64> = Vec::with_capacity(nrows);
            for i in 0..nrows {
                let d = r.varint_u64()?;
                if i == 0 {
                    keys.push(d);
                } else {
                    if d == 0 {
                        bail!("ct file corrupt: zero key delta (keys not strictly increasing)");
                    }
                    let k = keys[i - 1]
                        .checked_add(d)
                        .ok_or_else(|| anyhow!("ct file corrupt: key delta overflows u64"))?;
                    keys.push(k);
                }
            }
            RowStore::Packed(keys)
        }
        TIER_PACKED128 => {
            if layout.fits() || !layout.fits2() {
                bail!("ct file corrupt: two-word tier with a {}-bit layout", layout.total_bits());
            }
            let mut keys: Vec<u128> = Vec::with_capacity(nrows);
            for i in 0..nrows {
                let d = r.varint()?;
                if i == 0 {
                    keys.push(d);
                } else {
                    if d == 0 {
                        bail!("ct file corrupt: zero key delta (keys not strictly increasing)");
                    }
                    let k = keys[i - 1]
                        .checked_add(d)
                        .ok_or_else(|| anyhow!("ct file corrupt: key delta overflows u128"))?;
                    keys.push(k);
                }
            }
            RowStore::Packed2(keys)
        }
        TIER_WIDE => {
            // Symmetric to the packed tiers: the wide store is only ever
            // produced for layouts past 128 bits, and every cell must be
            // representable under its column spec.
            if layout.fits2() {
                bail!("ct file corrupt: wide tier with a {}-bit layout", layout.total_bits());
            }
            let mut rows: Vec<u16> = Vec::with_capacity(nrows * width);
            for i in 0..nrows * width {
                let code = r.varint_u16()?;
                if layout.try_encode(i % width, code).is_none() {
                    bail!("ct file corrupt: code {code} outside column {} spec", i % width);
                }
                rows.push(code);
            }
            RowStore::Wide(rows)
        }
        t => bail!("ct file corrupt: unknown storage tier {t}"),
    };
    let mut counts: Vec<u64> = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        counts.push(r.varint_u64()?);
    }
    if r.remaining() != 0 {
        bail!("ct file corrupt: {} trailing bytes", r.remaining());
    }
    let ct = CtTable { vars, counts, layout, store };
    ct.check_invariants().map_err(|e| anyhow!("decoded ct violates invariants: {e}"))?;
    Ok(ct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::NA;
    use crate::util::Pcg64;

    /// Random normalized table: `width` columns of the given arities, with
    /// optional n/a injection on odd columns. The first row pins every
    /// column to its maximum code (and, with `with_na`, a second row pins
    /// the n/a flag), so the observed layout — and therefore the storage
    /// tier — is a deterministic function of `arities`.
    fn random_ct(seed: u64, n: usize, arities: &[u16], with_na: bool) -> CtTable {
        let mut rng = Pcg64::seeded(seed);
        let vars: Vec<VarId> = (0..arities.len()).map(|i| i * 3).collect(); // sparse ids
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        rows.extend(arities.iter().map(|&a| a - 1));
        counts.push(1);
        if with_na {
            rows.extend(
                arities.iter().enumerate().map(|(c, &a)| if c % 2 == 1 { NA } else { a - 1 }),
            );
            counts.push(1);
        }
        for _ in 0..n {
            for (c, &a) in arities.iter().enumerate() {
                if with_na && c % 2 == 1 && rng.chance(0.3) {
                    rows.push(NA);
                } else {
                    rows.push(rng.below(a as u64) as u16);
                }
            }
            counts.push(rng.below(1000) + 1);
        }
        CtTable::from_raw(vars, rows, counts)
    }

    fn assert_roundtrip(ct: &CtTable) {
        let bytes = encode(ct);
        let back = decode(&bytes).expect("decode");
        assert_eq!(&back, ct, "logical equality");
        assert_eq!(back.tier(), ct.tier(), "storage tier preserved");
        assert_eq!(back.layout(), ct.layout(), "layout preserved");
        back.check_invariants().unwrap();
    }

    #[test]
    fn roundtrip_packed64_random_layouts() {
        let mut rng = Pcg64::seeded(42);
        for trial in 0..20 {
            let width = rng.index(6) + 1;
            let arities: Vec<u16> = (0..width).map(|_| rng.below(9) as u16 + 2).collect();
            let ct = random_ct(100 + trial, rng.index(300), &arities, trial % 2 == 0);
            assert!(ct.layout().fits(), "trial {trial} should stay one-word");
            assert_roundtrip(&ct);
        }
    }

    #[test]
    fn roundtrip_packed128_random_layouts() {
        let mut rng = Pcg64::seeded(43);
        for trial in 0..10 {
            // 24-29 columns × ≥3 bits (arities ≥ 5, max codes pinned by
            // the generator) lands in the 65..=128-bit band.
            let width = 24 + rng.index(6);
            let arities: Vec<u16> = (0..width).map(|_| rng.below(7) as u16 + 5).collect();
            let ct = random_ct(200 + trial, 50 + rng.index(150), &arities, true);
            assert!(ct.is_packed2(), "trial {trial}: got tier {}", ct.tier());
            assert_roundtrip(&ct);
        }
    }

    #[test]
    fn roundtrip_wide_random_layouts() {
        let mut rng = Pcg64::seeded(44);
        for trial in 0..5 {
            // 66+ columns × ≥2 bits (arities ≥ 3, max codes pinned) always
            // exceeds 128 bits.
            let width = 66 + rng.index(10);
            let arities: Vec<u16> = (0..width).map(|_| rng.below(2) as u16 + 3).collect();
            let ct = random_ct(300 + trial, 30 + rng.index(50), &arities, true);
            assert_eq!(ct.tier(), "rowmajor", "trial {trial}");
            assert_roundtrip(&ct);
        }
    }

    #[test]
    fn roundtrip_empty_scalar_and_nullary() {
        assert_roundtrip(&CtTable::empty(vec![2, 5, 9]));
        assert_roundtrip(&CtTable::scalar(12345));
        assert_roundtrip(&CtTable::from_raw(vec![], vec![], vec![])); // empty nullary
    }

    #[test]
    fn roundtrip_na_values() {
        let ct = CtTable::from_raw(vec![3, 9], vec![0, NA, 1, 2, 0, 0], vec![4, 5, 6]);
        assert_eq!(ct.count_of(&[0, NA]), 4);
        assert_roundtrip(&ct);
        let back = decode(&encode(&ct)).unwrap();
        assert_eq!(back.count_of(&[0, NA]), 4);
    }

    #[test]
    fn truncated_file_is_an_error() {
        let bytes = encode(&random_ct(1, 100, &[3, 4, 2], false));
        for cut in [0, 5, 9, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode(&bytes[..cut]).is_err(), "truncation at {cut} accepted");
        }
    }

    #[test]
    fn corrupt_bytes_are_an_error() {
        let bytes = encode(&random_ct(2, 80, &[4, 4], true));
        // Flip one byte at every position: header, payload, or checksum —
        // every single-byte corruption must be caught.
        for pos in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x41;
            assert!(decode(&bad).is_err(), "bit flip at {pos} accepted");
        }
    }

    #[test]
    fn bad_magic_and_version_are_errors() {
        let good = encode(&CtTable::scalar(3));
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        // Re-checksum so the magic check itself is what fires.
        let body_len = bad_magic.len() - 8;
        let sum = fnv1a(&bad_magic[..body_len]);
        bad_magic[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&bad_magic).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut bad_ver = good;
        bad_ver[8] = 99;
        let body_len = bad_ver.len() - 8;
        let sum = fnv1a(&bad_ver[..body_len]);
        bad_ver[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&bad_ver).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn delta_encoding_is_compact_on_dense_keys() {
        // 1000 dense one-word rows: deltas are tiny, so the file should be
        // far smaller than the 8-bytes-per-key naive encoding.
        let vars = vec![0, 1, 2];
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for a in 0..10u16 {
            for b in 0..10u16 {
                for c in 0..10u16 {
                    rows.extend_from_slice(&[a, b, c]);
                    counts.push(1 + (a + b + c) as u64);
                }
            }
        }
        let ct = CtTable::from_raw(vars, rows, counts);
        let bytes = encode(&ct);
        assert!(
            bytes.len() < ct.len() * 4,
            "{} bytes for {} rows — delta varints should beat 4 B/row",
            bytes.len(),
            ct.len()
        );
        assert_roundtrip(&ct);
    }
}
