//! The relationship-chain lattice (paper §3, Figure 4).
//!
//! A relationship set is a *chain* if it can be ordered so that each
//! relationship variable shares a first-order variable with the union of its
//! predecessors — i.e. the set is connected in the graph whose nodes are
//! relationship variables and whose edges are shared FO variables. The
//! Möbius Join computes one contingency table per chain, level by level
//! (level = chain length).

use crate::schema::{RelId, Schema};
use crate::util::fxhash::FxHashMap;

/// The lattice of relationship chains for a schema.
#[derive(Debug)]
pub struct Lattice {
    /// All chains, sorted by (length, lexicographic), each a sorted rel set.
    pub chains: Vec<Vec<RelId>>,
    index: FxHashMap<Vec<RelId>, usize>,
    max_level: usize,
}

impl Lattice {
    /// Enumerate every chain (connected relationship subset) of the schema,
    /// optionally capped at `max_len` (the paper §8 "prespecified relatively
    /// small chain length" option; `None` = all levels).
    pub fn build(schema: &Schema, max_len: Option<usize>) -> Lattice {
        let m = schema.num_rel_vars();
        let cap = max_len.unwrap_or(m).min(m);
        let mut chains: Vec<Vec<RelId>> = Vec::new();
        let mut seen: FxHashMap<Vec<RelId>, ()> = FxHashMap::default();
        // Level 1: singletons.
        let mut frontier: Vec<Vec<RelId>> = (0..m).map(|r| vec![r]).collect();
        for c in &frontier {
            seen.insert(c.clone(), ());
        }
        chains.extend(frontier.iter().cloned());
        // Grow: a chain of length l+1 = chain of length l + one rel sharing
        // an FO variable with it.
        for _level in 2..=cap {
            let mut next = Vec::new();
            for chain in &frontier {
                let fos = schema.fo_vars_of_rels(chain);
                for r in 0..m {
                    if chain.contains(&r) {
                        continue;
                    }
                    if !schema.relationships[r].fo_vars.iter().any(|f| fos.contains(f)) {
                        continue;
                    }
                    let mut c = chain.clone();
                    c.push(r);
                    c.sort_unstable();
                    if seen.insert(c.clone(), ()).is_none() {
                        next.push(c);
                    }
                }
            }
            chains.extend(next.iter().cloned());
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }
        chains.sort_by(|a, b| a.len().cmp(&b.len()).then(a.cmp(b)));
        let index = chains.iter().enumerate().map(|(i, c)| (c.clone(), i)).collect();
        let max_level = chains.iter().map(|c| c.len()).max().unwrap_or(0);
        Lattice { chains, index, max_level }
    }

    /// Index of a chain, if it is one.
    pub fn chain_index(&self, rels: &[RelId]) -> Option<usize> {
        let mut k = rels.to_vec();
        k.sort_unstable();
        self.index.get(&k).copied()
    }

    pub fn is_chain(&self, rels: &[RelId]) -> bool {
        self.chain_index(rels).is_some()
    }

    /// All chains of a given length.
    pub fn level(&self, len: usize) -> impl Iterator<Item = &Vec<RelId>> {
        self.chains.iter().filter(move |c| c.len() == len)
    }

    /// Deepest level present.
    pub fn max_level(&self) -> usize {
        self.max_level
    }

    pub fn len(&self) -> usize {
        self.chains.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chains.is_empty()
    }
}

/// Split a relationship set into connected components (each a chain).
/// Disconnected sets factorize: their joint contingency table is the cross
/// product of the component tables.
pub fn components(schema: &Schema, rels: &[RelId]) -> Vec<Vec<RelId>> {
    let mut remaining: Vec<RelId> = rels.to_vec();
    remaining.sort_unstable();
    let mut out = Vec::new();
    while let Some(seed) = remaining.first().copied() {
        let mut comp = vec![seed];
        remaining.retain(|&r| r != seed);
        loop {
            let fos = schema.fo_vars_of_rels(&comp);
            let more: Vec<RelId> = remaining
                .iter()
                .copied()
                .filter(|&r| schema.relationships[r].fo_vars.iter().any(|f| fos.contains(f)))
                .collect();
            if more.is_empty() {
                break;
            }
            for r in &more {
                comp.push(*r);
            }
            remaining.retain(|r| !more.contains(r));
        }
        comp.sort_unstable();
        out.push(comp);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::builder::university_schema;
    use crate::schema::SchemaBuilder;

    #[test]
    fn university_lattice() {
        let s = university_schema();
        let l = Lattice::build(&s, None);
        // Reg(S,C) and RA(P,S) share S: 2 singletons + 1 pair.
        assert_eq!(l.len(), 3);
        assert_eq!(l.max_level(), 2);
        assert!(l.is_chain(&[0]));
        assert!(l.is_chain(&[1, 0])); // order-insensitive
    }

    /// Three relationships where only some pairs connect:
    /// R0 = Reg(S,C), R1 = RA(P,S), R2 = Teaches(P,C) — the paper's Figure 4.
    fn figure4_schema() -> crate::schema::Schema {
        let mut b = SchemaBuilder::new("fig4");
        let s = b.population("Student");
        b.attr(s, "intelligence", &["1", "2"]);
        let c = b.population("Course");
        b.attr(c, "rating", &["1", "2"]);
        let p = b.population("Professor");
        b.attr(p, "popularity", &["1", "2"]);
        b.relationship("Registration", s, c);
        b.relationship("RA", p, s);
        b.relationship("Teaches", p, c);
        b.finish()
    }

    #[test]
    fn figure4_lattice_has_seven_chains() {
        // All three relationships pairwise share an FO var, so every subset
        // is a chain: 3 + 3 + 1 = 7 (Figure 4 shows these plus 3 entity
        // tables = 10 ct-tables).
        let s = figure4_schema();
        let l = Lattice::build(&s, None);
        assert_eq!(l.len(), 7);
        assert_eq!(l.level(2).count(), 3);
        assert_eq!(l.level(3).count(), 1);
    }

    #[test]
    fn max_len_caps_levels() {
        let s = figure4_schema();
        let l = Lattice::build(&s, Some(2));
        assert_eq!(l.max_level(), 2);
        assert_eq!(l.len(), 6);
    }

    #[test]
    fn disconnected_sets_are_not_chains() {
        // Two self-relationships over different populations never connect
        // (the UW-CSE shape).
        let mut b = SchemaBuilder::new("uw");
        let p = b.population("Person");
        b.attr(p, "position", &["fac", "stu"]);
        let c = b.population("Course");
        b.attr(c, "level", &["ug", "gr"]);
        b.relationship("AdvisedBy", p, p);
        b.relationship("Prereq", c, c);
        let s = b.finish();
        let l = Lattice::build(&s, None);
        assert_eq!(l.len(), 2); // singletons only
        assert!(!l.is_chain(&[0, 1]));
        let comps = components(&s, &[0, 1]);
        assert_eq!(comps, vec![vec![0], vec![1]]);
    }

    #[test]
    fn components_of_connected_set_is_single() {
        let s = figure4_schema();
        let comps = components(&s, &[0, 1, 2]);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0], vec![0, 1, 2]);
    }
}
