//! Statistical applications consuming the sufficient statistics (paper §6):
//!
//! * [`cfs`] — correlation-based feature selection (Table 5);
//! * [`apriori`] — association-rule mining with lift (Table 6);
//! * [`bayesnet`] — learn-and-join Bayesian-network structure learning
//!   (Tables 7-8);
//! * [`info`] — shared information-theoretic helpers (entropy, symmetric
//!   uncertainty, family log-likelihood) with native implementations and
//!   optional XLA offload through [`crate::runtime::XlaRuntime`].

pub mod info;
pub mod cfs;
pub mod apriori;
pub mod bayesnet;
