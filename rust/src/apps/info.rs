//! Information-theoretic primitives over contingency tables.
//!
//! Native implementations mirror the L1/L2 kernels exactly (same formulas
//! as `python/compile/kernels/ref.py`); when an [`XlaRuntime`] is supplied
//! the batched entry points route through the AOT-compiled artifacts
//! instead.

use crate::ct::CtTable;
use crate::runtime::XlaRuntime;
use crate::schema::VarId;
use crate::util::fxhash::FxHashMap;

/// x·ln(x) with 0·ln 0 = 0.
#[inline]
pub fn xlogx(x: f64) -> f64 {
    if x > 0.0 {
        x * x.ln()
    } else {
        0.0
    }
}

/// Shannon entropy (nats) of an unnormalized count slice.
pub fn entropy(counts: &[f64]) -> f64 {
    let n: f64 = counts.iter().sum();
    if n <= 0.0 {
        return 0.0;
    }
    n.ln() - counts.iter().map(|&x| xlogx(x)).sum::<f64>() / n
}

/// A dense joint count matrix for a pair of ct variables.
#[derive(Debug, Clone)]
pub struct JointCounts {
    pub data: Vec<f64>, // row-major v1 x v2
    pub v1: usize,
    pub v2: usize,
}

/// Densify the joint distribution of `(x, y)` from a contingency table.
/// Value codes (including the `NA` code) are mapped to dense indices in
/// first-observed order — SU/entropy are permutation-invariant.
pub fn joint_counts(ct: &CtTable, x: VarId, y: VarId) -> JointCounts {
    let cx = ct.col_of(x).expect("joint_counts: x not in ct");
    let cy = ct.col_of(y).expect("joint_counts: y not in ct");
    let mut ix: FxHashMap<u16, usize> = FxHashMap::default();
    let mut iy: FxHashMap<u16, usize> = FxHashMap::default();
    let mut cells: Vec<(usize, usize, f64)> = Vec::with_capacity(ct.len());
    // Decode the packed table once; per-row `iter()` would allocate.
    let w = ct.width();
    let matrix = ct.decode_rows();
    for (i, &c) in ct.counts.iter().enumerate() {
        let row = &matrix[i * w..(i + 1) * w];
        let nx = ix.len();
        let xi = *ix.entry(row[cx]).or_insert(nx);
        let ny = iy.len();
        let yi = *iy.entry(row[cy]).or_insert(ny);
        cells.push((xi, yi, c as f64));
    }
    let (v1, v2) = (ix.len().max(1), iy.len().max(1));
    let mut data = vec![0.0; v1 * v2];
    for (xi, yi, c) in cells {
        data[xi * v2 + yi] += c;
    }
    JointCounts { data, v1, v2 }
}

/// Symmetric uncertainty from a dense joint: `2·(Hx + Hy − Hxy)/(Hx + Hy)`.
pub fn su_native(j: &JointCounts) -> f64 {
    let mut mx = vec![0.0; j.v1];
    let mut my = vec![0.0; j.v2];
    for r in 0..j.v1 {
        for c in 0..j.v2 {
            mx[r] += j.data[r * j.v2 + c];
            my[c] += j.data[r * j.v2 + c];
        }
    }
    let hx = entropy(&mx);
    let hy = entropy(&my);
    let hxy = entropy(&j.data);
    let denom = hx + hy;
    if denom <= 0.0 {
        return 0.0;
    }
    (2.0 * (hx + hy - hxy).max(0.0)) / denom
}

/// Batched symmetric uncertainty: XLA when available (and fitting the
/// bucket ladder), else native. Both paths agree to ~1e-12.
pub fn su_batch(joints: &[JointCounts], rt: Option<&XlaRuntime>) -> Vec<f64> {
    if let Some(rt) = rt {
        let args: Vec<(Vec<f64>, usize, usize)> =
            joints.iter().map(|j| (j.data.clone(), j.v1, j.v2)).collect();
        if let Ok(out) = rt.su_batch(&args) {
            return out;
        }
    }
    joints.iter().map(su_native).collect()
}

/// Relational pseudo log-likelihood of one BN family (frequency-normalized,
/// Schulte 2011): `Σ_pc n_pc (ln n_pc − ln n_p) / N`.
pub fn family_loglik_native(counts: &[f64], p: usize, c: usize) -> f64 {
    debug_assert_eq!(counts.len(), p * c);
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let n_pc: f64 = counts.iter().map(|&x| xlogx(x)).sum();
    let n_p: f64 = (0..p)
        .map(|r| xlogx(counts[r * c..(r + 1) * c].iter().sum()))
        .sum();
    (n_pc - n_p) / total
}

/// Batched family log-likelihood with optional XLA offload.
pub fn family_loglik_batch(
    families: &[(Vec<f64>, usize, usize)],
    rt: Option<&XlaRuntime>,
) -> Vec<f64> {
    if let Some(rt) = rt {
        if let Ok(out) = rt.bnscore_batch(families) {
            return out;
        }
    }
    families.iter().map(|(m, p, c)| family_loglik_native(m, *p, *c)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform_and_point() {
        assert!((entropy(&[5.0, 5.0]) - std::f64::consts::LN_2).abs() < 1e-12);
        assert_eq!(entropy(&[7.0, 0.0]), 0.0);
        assert_eq!(entropy(&[]), 0.0);
    }

    #[test]
    fn su_extremes() {
        // Perfect dependence -> 1; independence -> 0.
        let dep = JointCounts { data: vec![5.0, 0.0, 0.0, 5.0], v1: 2, v2: 2 };
        assert!((su_native(&dep) - 1.0).abs() < 1e-12);
        let ind = JointCounts { data: vec![4.0, 4.0, 4.0, 4.0], v1: 2, v2: 2 };
        assert!(su_native(&ind).abs() < 1e-12);
    }

    #[test]
    fn joint_counts_from_ct() {
        let ct = CtTable::from_raw(
            vec![1, 2],
            vec![0, 0, 0, 1, 1, 0],
            vec![3, 4, 5],
        );
        let j = joint_counts(&ct, 1, 2);
        assert_eq!(j.v1, 2);
        assert_eq!(j.v2, 2);
        let total: f64 = j.data.iter().sum();
        assert_eq!(total, 12.0);
    }

    #[test]
    fn family_loglik_hand_checked() {
        // counts [[3,1],[0,4]]: L = (3ln3 + 1ln1 + 4ln4 - 4ln4 - 4ln4)/8
        let expect = (3.0 * 3f64.ln() + 4.0 * 4f64.ln() - 2.0 * (4.0 * 4f64.ln())) / 8.0;
        let got = family_loglik_native(&[3.0, 1.0, 0.0, 4.0], 2, 2);
        assert!((got - expect).abs() < 1e-12);
        // Deterministic child given parent: maximal (zero) loss.
        assert_eq!(family_loglik_native(&[4.0, 0.0, 0.0, 4.0], 2, 2), 0.0);
    }

    #[test]
    fn batch_matches_native_without_runtime() {
        let joints = vec![
            JointCounts { data: vec![1.0, 2.0, 3.0, 4.0], v1: 2, v2: 2 },
            JointCounts { data: vec![9.0, 0.0, 0.0, 9.0], v1: 2, v2: 2 },
        ];
        let out = su_batch(&joints, None);
        assert_eq!(out.len(), 2);
        assert!((out[1] - 1.0).abs() < 1e-12);
    }
}
