//! Correlation-based Feature Selection (CFS, Hall 1999) over a contingency
//! table — the paper's §6.1 experiment (Table 5).
//!
//! CFS scores a feature subset S for target C by the merit
//!
//! ```text
//! merit(S) = k·r̄_cf / sqrt(k + k(k−1)·r̄_ff)
//! ```
//!
//! where `r̄_cf` is the mean feature-target symmetric uncertainty and
//! `r̄_ff` the mean feature-feature SU, and searches subsets best-first
//! with a non-improvement stopping patience of 5 (Weka defaults).
//! All correlations come from ct-table projections — no access to raw data.

use super::info::{joint_counts, su_batch, JointCounts};
use crate::ct::CtTable;
use crate::runtime::XlaRuntime;
use crate::schema::VarId;

/// Result of a CFS run.
#[derive(Debug, Clone)]
pub struct CfsResult {
    /// Selected feature subset, sorted by VarId.
    pub selected: Vec<VarId>,
    /// Merit of the selected subset.
    pub merit: f64,
}

/// Pairwise-SU provider with lazy caching.
struct SuCache<'a> {
    ct: &'a CtTable,
    rt: Option<&'a XlaRuntime>,
    cache: crate::util::fxhash::FxHashMap<(VarId, VarId), f64>,
}

impl<'a> SuCache<'a> {
    fn new(ct: &'a CtTable, rt: Option<&'a XlaRuntime>) -> Self {
        SuCache { ct, rt, cache: Default::default() }
    }

    /// Batch-prime SU values for a list of pairs (one XLA dispatch).
    fn prime(&mut self, pairs: &[(VarId, VarId)]) {
        let missing: Vec<(VarId, VarId)> = pairs
            .iter()
            .map(|&(a, b)| (a.min(b), a.max(b)))
            .filter(|k| !self.cache.contains_key(k))
            .collect();
        if missing.is_empty() {
            return;
        }
        let joints: Vec<JointCounts> =
            missing.iter().map(|&(a, b)| joint_counts(self.ct, a, b)).collect();
        let sus = su_batch(&joints, self.rt);
        for (k, su) in missing.into_iter().zip(sus) {
            self.cache.insert(k, su);
        }
    }

    fn su(&mut self, a: VarId, b: VarId) -> f64 {
        let k = (a.min(b), a.max(b));
        if let Some(&v) = self.cache.get(&k) {
            return v;
        }
        self.prime(&[k]);
        self.cache[&k]
    }
}

/// CFS merit of a subset.
fn merit(subset: &[VarId], target: VarId, su: &mut SuCache) -> f64 {
    let k = subset.len() as f64;
    if subset.is_empty() {
        return 0.0;
    }
    let rcf: f64 = subset.iter().map(|&f| su.su(f, target)).sum::<f64>() / k;
    let mut rff = 0.0;
    let mut pairs = 0.0;
    for (i, &a) in subset.iter().enumerate() {
        for &b in &subset[i + 1..] {
            rff += su.su(a, b);
            pairs += 1.0;
        }
    }
    let rff = if pairs > 0.0 { rff / pairs } else { 0.0 };
    let denom = (k + k * (k - 1.0) * rff).sqrt();
    if denom <= 0.0 {
        0.0
    } else {
        k * rcf / denom
    }
}

/// Run CFS: select a feature subset for `target` from `features`, using
/// only the contingency table. Returns an empty selection for an empty ct
/// (the paper's Mondial link-off case: "Empty CT").
pub fn cfs_select(
    ct: &CtTable,
    target: VarId,
    features: &[VarId],
    rt: Option<&XlaRuntime>,
) -> CfsResult {
    if ct.is_empty() {
        return CfsResult { selected: Vec::new(), merit: 0.0 };
    }
    let feats: Vec<VarId> = features
        .iter()
        .copied()
        .filter(|&f| f != target && ct.col_of(f).is_some())
        .collect();
    let mut su = SuCache::new(ct, rt);
    // Prime all feature-target correlations in one batch.
    let ft: Vec<(VarId, VarId)> = feats.iter().map(|&f| (f, target)).collect();
    su.prime(&ft);

    // Best-first search with patience 5 (Weka CFS defaults).
    let mut best: (Vec<VarId>, f64) = (Vec::new(), 0.0);
    let mut frontier: Vec<(Vec<VarId>, f64)> = vec![(Vec::new(), 0.0)];
    let mut visited: std::collections::HashSet<Vec<VarId>> = Default::default();
    let mut stale = 0usize;
    while let Some(pos) = frontier
        .iter()
        .enumerate()
        .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
        .map(|(i, _)| i)
    {
        let (subset, _) = frontier.swap_remove(pos);
        let mut improved = false;
        for &f in &feats {
            if subset.contains(&f) {
                continue;
            }
            let mut next = subset.clone();
            next.push(f);
            next.sort_unstable();
            if !visited.insert(next.clone()) {
                continue;
            }
            let m = merit(&next, target, &mut su);
            if m > best.1 + 1e-12 {
                best = (next.clone(), m);
                improved = true;
            }
            frontier.push((next, m));
        }
        stale = if improved { 0 } else { stale + 1 };
        if stale >= 5 || frontier.is_empty() {
            break;
        }
    }
    CfsResult { selected: best.0, merit: best.1 }
}

/// 1 − Jaccard coefficient between two feature sets (paper §6.1
/// "Distinctness"); 0.0 when both sets are empty.
pub fn distinctness(a: &[VarId], b: &[VarId]) -> f64 {
    let sa: std::collections::HashSet<_> = a.iter().collect();
    let sb: std::collections::HashSet<_> = b.iter().collect();
    let union = sa.union(&sb).count();
    if union == 0 {
        return 0.0;
    }
    let inter = sa.intersection(&sb).count();
    1.0 - inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// ct where var 1 predicts target 0 perfectly and var 2 is noise.
    fn predictive_ct(seed: u64) -> CtTable {
        let mut rng = Pcg64::seeded(seed);
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..200 {
            let t = rng.below(2) as u16;
            let good = t; // copies target
            let noise = rng.below(3) as u16;
            rows.extend_from_slice(&[t, good, noise]);
            counts.push(1);
        }
        CtTable::from_raw(vec![0, 1, 2], rows, counts)
    }

    #[test]
    fn selects_predictive_feature() {
        let ct = predictive_ct(5);
        let res = cfs_select(&ct, 0, &[1, 2], None);
        assert!(res.selected.contains(&1), "selected: {:?}", res.selected);
        assert!(res.merit > 0.5);
    }

    #[test]
    fn empty_ct_selects_nothing() {
        let ct = CtTable::empty(vec![0, 1, 2]);
        let res = cfs_select(&ct, 0, &[1, 2], None);
        assert!(res.selected.is_empty());
    }

    #[test]
    fn distinctness_extremes() {
        assert_eq!(distinctness(&[1, 2], &[1, 2]), 0.0);
        assert_eq!(distinctness(&[1], &[2]), 1.0);
        assert_eq!(distinctness(&[], &[]), 0.0);
        assert!((distinctness(&[1, 2], &[2, 3]) - (1.0 - 1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn constant_feature_never_selected() {
        // Var 3 constant: SU = 0 always.
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        let mut rng = Pcg64::seeded(9);
        for _ in 0..100 {
            let t = rng.below(2) as u16;
            rows.extend_from_slice(&[t, t, 7u16]);
            counts.push(1);
        }
        let ct = CtTable::from_raw(vec![0, 1, 3], rows, counts);
        let res = cfs_select(&ct, 0, &[1, 3], None);
        assert!(!res.selected.contains(&3));
    }
}
