//! Bayesian-network structure learning over the contingency table — the
//! paper's §6.3 experiment (Tables 7 and 8), in the style of the
//! learn-and-join (LAJ) method of Schulte & Khosravi (2012).
//!
//! LAJ walks the relationship-chain lattice bottom-up: at each lattice
//! point it hill-climbs over that point's contingency table, *inheriting*
//! (freezing) all edges learned at smaller points and proposing only edges
//! that touch a variable new to this point. The score is the relational
//! pseudo log-likelihood (frequency-normalized, Schulte 2011) with a
//! BIC-style penalty; all family statistics come from ct projections.
//!
//! With link analysis OFF the input table is conditioned on all
//! relationships being true, so relationship indicators are constant and
//! can never be learned as children — R2R/A2R edges (Table 8) only appear
//! with link analysis ON.

use super::info::{family_loglik_batch, family_loglik_native};
use crate::ct::CtTable;
use crate::mobius::MjResult;
use crate::runtime::XlaRuntime;
use crate::schema::{Schema, VarId, VarKind};
use crate::util::fxhash::FxHashMap;
use std::time::{Duration, Instant};

/// A learned Bayesian network structure over ct variables.
#[derive(Debug, Clone, Default)]
pub struct BayesNet {
    /// Nodes (ct variables), sorted.
    pub nodes: Vec<VarId>,
    /// `parents[i]` = parent VarIds of `nodes[i]`.
    pub parents: Vec<Vec<VarId>>,
}

impl BayesNet {
    fn node_index(&self, v: VarId) -> usize {
        self.nodes.binary_search(&v).expect("not a node")
    }

    /// Would adding `parent -> child` create a directed cycle?
    fn creates_cycle(&self, parent: VarId, child: VarId) -> bool {
        // DFS from `parent` upward: if we can reach `child` via parent
        // links... direction check: cycle iff child is an ancestor of
        // parent, i.e. path parent ~> ... following parents reaches child?
        // Edges point parent -> child; a cycle appears iff there is a
        // directed path child ~> parent already.
        let mut stack = vec![child];
        let mut seen = std::collections::HashSet::new();
        while let Some(v) = stack.pop() {
            if v == parent {
                return true;
            }
            if !seen.insert(v) {
                continue;
            }
            // children of v: nodes having v as parent
            for (i, ps) in self.parents.iter().enumerate() {
                if ps.contains(&v) {
                    stack.push(self.nodes[i]);
                }
            }
        }
        false
    }

    /// Count edges by kind: (R2R, A2R) — relationship-to-relationship and
    /// attribute-to-relationship edges (Table 8).
    pub fn edge_kinds(&self, schema: &Schema) -> (usize, usize) {
        let mut r2r = 0;
        let mut a2r = 0;
        for (i, ps) in self.parents.iter().enumerate() {
            let child = self.nodes[i];
            if schema.random_vars[child].kind() != VarKind::RelInd {
                continue;
            }
            for &p in ps {
                if schema.random_vars[p].kind() == VarKind::RelInd {
                    r2r += 1;
                } else {
                    a2r += 1;
                }
            }
        }
        (r2r, a2r)
    }

    pub fn num_edges(&self) -> usize {
        self.parents.iter().map(|p| p.len()).sum()
    }

    /// Number of free parameters: Σ nodes (arity−1)·Π parent arities.
    pub fn num_params(&self, schema: &Schema) -> u64 {
        self.nodes
            .iter()
            .zip(&self.parents)
            .map(|(&n, ps)| {
                let child = schema.var_arity(n) as u64 - 1;
                let parent_cfg: u64 =
                    ps.iter().map(|&p| schema.var_arity(p) as u64).product();
                child * parent_cfg
            })
            .sum()
    }

    /// Render edges as `parent -> child` lines.
    pub fn render(&self, schema: &Schema) -> String {
        let mut s = String::new();
        for (i, ps) in self.parents.iter().enumerate() {
            for &p in ps {
                s.push_str(&format!(
                    "{} -> {}\n",
                    schema.var_name(p),
                    schema.var_name(self.nodes[i])
                ));
            }
        }
        s
    }
}

/// Learning configuration.
#[derive(Debug, Clone, Copy)]
pub struct BnConfig {
    /// Maximum parents per node (keeps family tables within the bnscore
    /// bucket ladder).
    pub max_parents: usize,
    /// BIC penalty weight (0.5·ln N per parameter when 1.0).
    pub penalty: f64,
}

impl Default for BnConfig {
    fn default() -> Self {
        BnConfig { max_parents: 3, penalty: 1.0 }
    }
}

/// Output of structure learning.
#[derive(Debug)]
pub struct LearnOutcome {
    pub bn: BayesNet,
    pub elapsed: Duration,
    pub score_evals: usize,
}

/// Family sufficient statistics from a ct: dense (parent configs × child
/// values) count matrix. Value codes map to dense indices in
/// first-observed order.
fn family_counts(ct: &CtTable, child: VarId, parents: &[VarId]) -> (Vec<f64>, usize, usize) {
    let mut vars = parents.to_vec();
    vars.push(child);
    let proj = ct.project(&vars);
    let ccol = proj.col_of(child).unwrap();
    let pcols: Vec<usize> = parents.iter().map(|&p| proj.col_of(p).unwrap()).collect();
    let mut pidx: FxHashMap<Vec<u16>, usize> = FxHashMap::default();
    let mut cidx: FxHashMap<u16, usize> = FxHashMap::default();
    let mut cells: Vec<(usize, usize, f64)> = Vec::with_capacity(proj.len());
    let mut pbuf = vec![0u16; pcols.len()];
    // Decode the packed projection once; per-row `iter()` would allocate.
    let w = proj.width();
    let matrix = proj.decode_rows();
    for (i, &c) in proj.counts.iter().enumerate() {
        let row = &matrix[i * w..(i + 1) * w];
        for (slot, &pc) in pcols.iter().enumerate() {
            pbuf[slot] = row[pc];
        }
        let np = pidx.len();
        let pi = *pidx.entry(pbuf.clone()).or_insert(np);
        let nc = cidx.len();
        let ci = *cidx.entry(row[ccol]).or_insert(nc);
        cells.push((pi, ci, c as f64));
    }
    let (p, c) = (pidx.len().max(1), cidx.len().max(1));
    let mut data = vec![0.0; p * c];
    for (pi, ci, v) in cells {
        data[pi * c + ci] += v;
    }
    (data, p, c)
}

/// Score (pseudo log-likelihood − BIC penalty) of one family.
fn family_score(
    ct: &CtTable,
    schema: &Schema,
    child: VarId,
    parents: &[VarId],
    cfg: &BnConfig,
    cache: &mut FxHashMap<(VarId, Vec<VarId>), f64>,
    evals: &mut usize,
) -> f64 {
    let key = (child, parents.to_vec());
    if let Some(&s) = cache.get(&key) {
        return s;
    }
    let (data, p, c) = family_counts(ct, child, parents);
    let ll = family_loglik_native(&data, p, c);
    *evals += 1;
    let n = ct.total() as f64;
    let params = (schema.var_arity(child) as f64 - 1.0)
        * parents.iter().map(|&q| schema.var_arity(q) as f64).product::<f64>();
    // Frequency-normalized likelihood ⇒ the BIC term is scaled by 1/N too.
    let score = ll - cfg.penalty * 0.5 * n.max(2.0).ln() * params / n.max(1.0);
    cache.insert(key, score);
    score
}

/// Hill-climb over `active` variables of `ct`, starting from `bn`
/// (inherited edges frozen), only proposing edges touching `new_vars`.
#[allow(clippy::too_many_arguments)]
fn hill_climb(
    ct: &CtTable,
    schema: &Schema,
    bn: &mut BayesNet,
    active: &[VarId],
    new_vars: &[VarId],
    frozen: &std::collections::HashSet<(VarId, VarId)>,
    cfg: &BnConfig,
    cache: &mut FxHashMap<(VarId, Vec<VarId>), f64>,
    evals: &mut usize,
) {
    if ct.is_empty() {
        return;
    }
    loop {
        let mut best: Option<(f64, usize, Vec<VarId>)> = None; // (delta, node idx, new parents)
        for &child in active {
            // Only children that are new, or gaining a new-var parent.
            let ci = bn.node_index(child);
            let cur_parents = bn.parents[ci].clone();
            // A family whose parents span another lattice branch cannot be
            // rescored on this point's table — leave it to the branch that
            // owns it (LAJ inheritance).
            if cur_parents.iter().any(|&p| ct.col_of(p).is_none()) {
                continue;
            }
            let cur =
                family_score(ct, schema, child, &cur_parents, cfg, cache, evals);
            // Try adding a parent.
            for &cand in active {
                if cand == child
                    || cur_parents.contains(&cand)
                    || cur_parents.len() >= cfg.max_parents
                {
                    continue;
                }
                if !new_vars.contains(&child) && !new_vars.contains(&cand) {
                    continue; // LAJ: at least one endpoint must be new here
                }
                // Never point an edge *into* a constant variable; a
                // constant child is never improved, the score handles it.
                if bn.creates_cycle(cand, child) {
                    continue;
                }
                let mut np = cur_parents.clone();
                np.push(cand);
                np.sort_unstable();
                let s = family_score(ct, schema, child, &np, cfg, cache, evals);
                let delta = s - cur;
                if delta > 1e-9 && best.as_ref().is_none_or(|b| delta > b.0) {
                    best = Some((delta, ci, np));
                }
            }
            // Try removing a non-frozen parent.
            for &p in &cur_parents {
                if frozen.contains(&(p, child)) {
                    continue;
                }
                let np: Vec<VarId> =
                    cur_parents.iter().copied().filter(|&q| q != p).collect();
                let s = family_score(ct, schema, child, &np, cfg, cache, evals);
                let delta = s - cur;
                if delta > 1e-9 && best.as_ref().is_none_or(|b| delta > b.0) {
                    best = Some((delta, ci, np));
                }
            }
        }
        match best {
            Some((_, ci, np)) => bn.parents[ci] = np,
            None => break,
        }
    }
}

/// Learn a BN with the learn-and-join lattice walk. `link_on` selects
/// whether relationship indicators (and n/a-bearing 2Atts rows) are
/// visible: OFF conditions every table on all its relationships being true.
pub fn learn_structure(
    schema: &Schema,
    mj: &MjResult,
    link_on: bool,
    cfg: BnConfig,
) -> LearnOutcome {
    let t0 = Instant::now();
    let mut evals = 0usize;
    let mut cache_store: FxHashMap<Vec<VarId>, FxHashMap<(VarId, Vec<VarId>), f64>> =
        FxHashMap::default();

    // Node set: all variables of the joint table; with link off the
    // indicators are still nodes but constant (never children/parents).
    let joint = mj.joint_ct();
    let nodes: Vec<VarId> = joint.vars.clone();
    let mut bn = BayesNet { nodes: nodes.clone(), parents: vec![Vec::new(); nodes.len()] };
    let mut frozen: std::collections::HashSet<(VarId, VarId)> = Default::default();
    let mut seen_vars: std::collections::HashSet<VarId> = Default::default();

    // Phase 1: entity tables (attribute dependencies within one
    // population's FO variable).
    let mut points: Vec<(Vec<VarId>, CtTable)> = Vec::new();
    for (fo, ct) in &mj.entity_cts {
        let vars = schema.one_atts_of_fo(*fo);
        points.push((vars, ct.clone()));
    }
    points.sort_by(|a, b| a.0.cmp(&b.0));
    // Phase 2: relationship chains, level order.
    let mut chains: Vec<&Vec<usize>> = mj.tables.keys().collect();
    chains.sort_by(|a, b| a.len().cmp(&b.len()).then(a.cmp(b)));
    for chain in chains {
        let table = &mj.tables[chain];
        let table = if link_on {
            table.clone()
        } else {
            // Link analysis off: condition on all chain relationships true.
            let conds: Vec<(VarId, u16)> =
                chain.iter().map(|&r| (schema.rel_ind_var(r), 1)).collect();
            table.select(&conds)
        };
        points.push((table.vars.clone(), table));
    }

    for (vars, ct) in points {
        let new_vars: Vec<VarId> =
            vars.iter().copied().filter(|v| !seen_vars.contains(v)).collect();
        let cache = cache_store.entry(vars.clone()).or_default();
        hill_climb(&ct, schema, &mut bn, &vars, &new_vars, &frozen, &cfg, cache, &mut evals);
        for v in &vars {
            seen_vars.insert(*v);
        }
        // Freeze everything learned so far.
        for (i, ps) in bn.parents.iter().enumerate() {
            for &p in ps {
                frozen.insert((p, bn.nodes[i]));
            }
        }
    }

    LearnOutcome { bn, elapsed: t0.elapsed(), score_evals: evals }
}

/// Model metrics of a structure evaluated against a (link-on) joint table:
/// total pseudo log-likelihood, #parameters, R2R/A2R edge counts (Table 8).
#[derive(Debug, Clone)]
pub struct BnMetrics {
    pub loglik: f64,
    pub params: u64,
    pub r2r: usize,
    pub a2r: usize,
}

/// Score a learned structure with maximum-likelihood parameters on `joint`
/// (both link-on and link-off structures are scored on the same table so
/// numbers are comparable, paper §6.3).
pub fn score_structure(
    schema: &Schema,
    bn: &BayesNet,
    joint: &CtTable,
    rt: Option<&XlaRuntime>,
) -> BnMetrics {
    let families: Vec<(Vec<f64>, usize, usize)> = bn
        .nodes
        .iter()
        .zip(&bn.parents)
        .map(|(&n, ps)| family_counts(joint, n, ps))
        .collect();
    let lls = family_loglik_batch(&families, rt);
    let (r2r, a2r) = bn.edge_kinds(schema);
    BnMetrics { loglik: lls.iter().sum(), params: bn.num_params(schema), r2r, a2r }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::university_db;
    use crate::mobius::MobiusJoin;

    #[test]
    fn learns_acyclic_structure_on_university() {
        let db = university_db();
        let mj = MobiusJoin::new(&db).run();
        let out = learn_structure(&db.schema, &mj, true, BnConfig::default());
        // Acyclicity: a topological order must exist (Kahn's algorithm).
        let n = out.bn.nodes.len();
        let mut indeg: Vec<usize> = out.bn.parents.iter().map(|p| p.len()).collect();
        let mut removed = 0;
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(i) = queue.pop() {
            removed += 1;
            let v = out.bn.nodes[i];
            for (j, ps) in out.bn.parents.iter().enumerate() {
                if ps.contains(&v) {
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        queue.push(j);
                    }
                }
            }
        }
        assert_eq!(removed, n, "graph has a directed cycle");
        assert!(out.score_evals > 0);
    }

    #[test]
    fn link_off_learns_no_rel_children() {
        let db = university_db();
        let mj = MobiusJoin::new(&db).run();
        let out = learn_structure(&db.schema, &mj, false, BnConfig::default());
        let (r2r, a2r) = out.bn.edge_kinds(&db.schema);
        assert_eq!(r2r + a2r, 0, "link-off must not learn edges into indicators");
    }

    #[test]
    fn params_counting() {
        let s = crate::schema::university_schema();
        let intel = s.var_by_name("intelligence(S)").unwrap(); // arity 3
        let rank = s.var_by_name("ranking(S)").unwrap(); // arity 2
        let bn = BayesNet { nodes: vec![intel.min(rank), intel.max(rank)], parents: vec![vec![], vec![]] };
        assert_eq!(bn.num_params(&s), (3 - 1) + (2 - 1));
        let mut bn2 = bn.clone();
        // rank -> intelligence
        let ii = bn2.node_index(intel);
        bn2.parents[ii] = vec![rank];
        assert_eq!(bn2.num_params(&s), 2 * 2 + 1);
    }

    #[test]
    fn cycle_detection() {
        let bn = BayesNet { nodes: vec![0, 1, 2], parents: vec![vec![], vec![0], vec![1]] };
        // 0 -> 1 -> 2 exists; adding 2 -> 0 closes a cycle.
        assert!(bn.creates_cycle(2, 0));
        assert!(!bn.creates_cycle(0, 2));
    }

    #[test]
    fn family_counts_shape() {
        let ct = CtTable::from_raw(
            vec![0, 1],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![3, 1, 2, 4],
        );
        let (data, p, c) = family_counts(&ct, 1, &[0]);
        assert_eq!((p, c), (2, 2));
        assert_eq!(data.iter().sum::<f64>(), 10.0);
    }

    #[test]
    fn score_structure_reports_edge_kinds() {
        let db = university_db();
        let mj = MobiusJoin::new(&db).run();
        let s = &db.schema;
        let joint = mj.joint_ct();
        // Hand-build: intelligence(S) -> RA(P,S) is an A2R edge.
        let intel = s.var_by_name("intelligence(S)").unwrap();
        let ra = s.var_by_name("RA(P,S)").unwrap();
        let mut bn =
            BayesNet { nodes: joint.vars.clone(), parents: vec![Vec::new(); joint.vars.len()] };
        let ri = bn.node_index(ra);
        bn.parents[ri] = vec![intel];
        let m = score_structure(s, &bn, joint, None);
        assert_eq!(m.a2r, 1);
        assert_eq!(m.r2r, 0);
        assert!(m.loglik <= 0.0);
        assert!(m.params > 0);
    }
}
