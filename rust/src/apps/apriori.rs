//! Apriori association-rule mining over a contingency table — the paper's
//! §6.2 experiment (Table 6, interestingness metric = lift).
//!
//! The ct-table *is* the (weighted) transaction database: an item is a
//! `(variable = value)` pair and the support of an itemset is the projected
//! count. Level-wise mining therefore reduces to ct-algebra projections:
//! the frequent itemsets over a variable set `S` are exactly the rows of
//! `π_S(ct)` with count ≥ minsup·N, and Apriori's subset pruning runs on
//! variable sets before any projection is taken.

use crate::ct::CtTable;
use crate::runtime::XlaRuntime;
use crate::schema::{Schema, VarId};
use crate::util::fxhash::FxHashMap;

/// One association rule `body → head`.
#[derive(Debug, Clone)]
pub struct Rule {
    pub body: Vec<(VarId, u16)>,
    pub head: (VarId, u16),
    pub support: f64,
    pub confidence: f64,
    pub lift: f64,
}

impl Rule {
    /// Does the rule mention a relationship indicator variable (the
    /// quantity Table 6 counts)?
    pub fn uses_rel_var(&self, schema: &Schema) -> bool {
        let is_rel = |v: VarId| {
            matches!(schema.random_vars[v], crate::schema::RandomVar::RelInd { .. })
        };
        is_rel(self.head.0) || self.body.iter().any(|&(v, _)| is_rel(v))
    }

    /// Render like `statement_freq(A)=monthly → HasLoan(A,L)=T`.
    pub fn render(&self, schema: &Schema) -> String {
        let item = |&(v, c): &(VarId, u16)| {
            format!("{}={}", schema.var_name(v), schema.value_name(v, c))
        };
        let body: Vec<String> = self.body.iter().map(item).collect();
        format!("{} -> {}", body.join(" & "), item(&self.head))
    }
}

/// Mining configuration (defaults mirror Weka Apriori with lift ranking).
#[derive(Debug, Clone, Copy)]
pub struct AprioriConfig {
    pub min_support: f64,
    pub min_lift: f64,
    pub max_itemset: usize,
    pub num_rules: usize,
    /// Cap on the number of ct variables considered (widest-first mining is
    /// exponential in variables; the paper's tables have ≤ ~30).
    pub max_vars: usize,
}

impl Default for AprioriConfig {
    fn default() -> Self {
        AprioriConfig {
            min_support: 0.05,
            min_lift: 1.1,
            max_itemset: 3,
            num_rules: 20,
            max_vars: 16,
        }
    }
}

/// Mine the top rules by lift from a contingency table.
pub fn apriori(
    schema: &Schema,
    ct: &CtTable,
    cfg: AprioriConfig,
    rt: Option<&XlaRuntime>,
) -> Vec<Rule> {
    if ct.is_empty() {
        return Vec::new();
    }
    // Variable preselection: indicators first (they are what Table 6 is
    // about), then the rest in schema order.
    let mut vars: Vec<VarId> = ct
        .vars
        .iter()
        .copied()
        .filter(|&v| matches!(schema.random_vars[v], crate::schema::RandomVar::RelInd { .. }))
        .collect();
    for &v in &ct.vars {
        if !vars.contains(&v) {
            vars.push(v);
        }
    }
    vars.truncate(cfg.max_vars);
    vars.sort_unstable();
    let base = if vars.len() == ct.width() { ct.clone() } else { ct.project(&vars) };
    let total = base.total() as f64;
    let min_count = (cfg.min_support * total).max(1.0);

    // Level 1: frequent single items per variable.
    let mut item_support: FxHashMap<(VarId, u16), f64> = FxHashMap::default();
    let mut freq_vars: Vec<VarId> = Vec::new();
    for &v in &vars {
        let p = base.project(&[v]);
        let mut any = false;
        let codes = p.decode_rows(); // width 1: one code per row
        for (&code, &c) in codes.iter().zip(&p.counts) {
            if (c as f64) >= min_count {
                item_support.insert((v, code), c as f64);
                any = true;
            }
        }
        if any {
            freq_vars.push(v);
        }
    }

    // Levels 2..max: frequent itemsets grouped by variable set; a var set
    // is a candidate only if every (k-1)-subset produced a frequent set.
    let mut freq_sets: Vec<(Vec<(VarId, u16)>, f64)> = Vec::new();
    let mut prev_varsets: Vec<Vec<VarId>> = freq_vars.iter().map(|&v| vec![v]).collect();
    for _level in 2..=cfg.max_itemset {
        let mut next_varsets: Vec<Vec<VarId>> = Vec::new();
        let candidates = extend_varsets(&prev_varsets, &freq_vars);
        for vs in candidates {
            let p = base.project(&vs);
            let mut any = false;
            let w = p.width();
            let matrix = p.decode_rows(); // decode once, not per row
            for (i, &c) in p.counts.iter().enumerate() {
                if (c as f64) < min_count {
                    continue;
                }
                let row = &matrix[i * w..(i + 1) * w];
                // Apriori pruning at the item level: all single items must
                // be frequent.
                let items: Vec<(VarId, u16)> =
                    vs.iter().copied().zip(row.iter().copied()).collect();
                if !items.iter().all(|it| item_support.contains_key(it)) {
                    continue;
                }
                freq_sets.push((items, c as f64));
                any = true;
            }
            if any {
                next_varsets.push(vs);
            }
        }
        if next_varsets.is_empty() {
            break;
        }
        prev_varsets = next_varsets;
    }

    // Rule generation: every item of a frequent set as head.
    // Collect (body_support, head_support, joint) then compute metrics
    // (batched through XLA when available).
    let mut protos: Vec<(Vec<(VarId, u16)>, (VarId, u16), f64, f64, f64)> = Vec::new();
    for (items, sup) in &freq_sets {
        for (hi, &head) in items.iter().enumerate() {
            let body: Vec<(VarId, u16)> = items
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != hi)
                .map(|(_, &it)| it)
                .collect();
            let body_sup = support_of(&base, &body, &mut Default::default());
            let head_sup = item_support.get(&head).copied().unwrap_or_else(|| {
                support_of(&base, std::slice::from_ref(&head), &mut Default::default())
            });
            protos.push((body, head, body_sup, head_sup, *sup));
        }
    }
    let bodies: Vec<f64> = protos.iter().map(|p| p.2).collect();
    let heads: Vec<f64> = protos.iter().map(|p| p.3).collect();
    let joints: Vec<f64> = protos.iter().map(|p| p.4).collect();
    let metrics: Vec<(f64, f64, f64)> = match rt {
        Some(rt) => rt
            .lift_batch(&bodies, &heads, &joints, total)
            .unwrap_or_else(|_| native_metrics(&bodies, &heads, &joints, total)),
        None => native_metrics(&bodies, &heads, &joints, total),
    };
    let mut rules: Vec<Rule> = protos
        .into_iter()
        .zip(metrics)
        .filter(|((body, ..), _)| !body.is_empty())
        .map(|((body, head, ..), (support, confidence, lift))| Rule {
            body,
            head,
            support,
            confidence,
            lift,
        })
        .filter(|r| r.lift >= cfg.min_lift)
        .collect();
    rules.sort_by(|a, b| b.lift.total_cmp(&a.lift).then(b.support.total_cmp(&a.support)));
    rules.truncate(cfg.num_rules);
    rules
}

fn native_metrics(body: &[f64], head: &[f64], joint: &[f64], total: f64) -> Vec<(f64, f64, f64)> {
    body.iter()
        .zip(head)
        .zip(joint)
        .map(|((&b, &h), &j)| {
            let support = if total > 0.0 { j / total } else { 0.0 };
            let confidence = if b > 0.0 { j / b } else { 0.0 };
            let lift =
                if b > 0.0 && h > 0.0 && total > 0.0 { j * total / (b * h) } else { 0.0 };
            (support, confidence, lift)
        })
        .collect()
}

/// Support (count) of an itemset via selection.
fn support_of(
    base: &CtTable,
    items: &[(VarId, u16)],
    cache: &mut FxHashMap<Vec<(VarId, u16)>, f64>,
) -> f64 {
    if items.is_empty() {
        return base.total() as f64;
    }
    let key = items.to_vec();
    if let Some(&v) = cache.get(&key) {
        return v;
    }
    let v = base.select(items).total() as f64;
    cache.insert(key, v);
    v
}

/// Candidate variable sets of size k+1 from the size-k survivors.
fn extend_varsets(prev: &[Vec<VarId>], freq_vars: &[VarId]) -> Vec<Vec<VarId>> {
    let mut out: Vec<Vec<VarId>> = Vec::new();
    let prev_set: std::collections::HashSet<&Vec<VarId>> = prev.iter().collect();
    for vs in prev {
        for &v in freq_vars {
            if *vs.last().unwrap() >= v {
                continue; // keep sorted, avoid duplicates
            }
            let mut cand = vs.clone();
            cand.push(v);
            // All k-subsets must be survivors.
            let ok = (0..cand.len()).all(|skip| {
                let sub: Vec<VarId> = cand
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != skip)
                    .map(|(_, &x)| x)
                    .collect();
                sub.len() < 2 || prev_set.contains(&sub)
            });
            if ok && !out.contains(&cand) {
                out.push(cand);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::university_schema;

    /// ct over intelligence(S) [var a] and RA indicator [var ind] with a
    /// strong implication a=2 -> ind=T.
    fn implication_ct(a: VarId, ind: VarId) -> CtTable {
        CtTable::from_raw(
            vec![a, ind],
            vec![
                0, 0, //
                0, 1, //
                1, 0, //
                1, 1, //
                2, 1, //
            ],
            vec![40, 10, 25, 25, 50],
        )
    }

    #[test]
    fn finds_high_lift_rule() {
        let s = university_schema();
        let a = s.var_by_name("intelligence(S)").unwrap();
        let ind = s.var_by_name("RA(P,S)").unwrap();
        let ct = implication_ct(a, ind);
        let rules = apriori(&s, &ct, AprioriConfig::default(), None);
        assert!(!rules.is_empty());
        // The strongest rule should be intelligence=2 -> RA=T (lift
        // = 1.0/ (85/150) ≈ 1.76).
        let top = &rules[0];
        assert!(top.lift > 1.5, "top rule: {} lift {}", top.render(&s), top.lift);
        assert!(top.uses_rel_var(&s));
    }

    #[test]
    fn respects_min_support() {
        let s = university_schema();
        let a = s.var_by_name("intelligence(S)").unwrap();
        let ind = s.var_by_name("RA(P,S)").unwrap();
        let ct = implication_ct(a, ind);
        let cfg = AprioriConfig { min_support: 0.9, ..Default::default() };
        assert!(apriori(&s, &ct, cfg, None).is_empty());
    }

    #[test]
    fn empty_ct_no_rules() {
        let s = university_schema();
        let ct = CtTable::empty(vec![0, 1]);
        assert!(apriori(&s, &ct, AprioriConfig::default(), None).is_empty());
    }

    #[test]
    fn rule_rendering() {
        let s = university_schema();
        let a = s.var_by_name("intelligence(S)").unwrap();
        let ind = s.var_by_name("RA(P,S)").unwrap();
        let r = Rule {
            body: vec![(a, 2)],
            head: (ind, 1),
            support: 0.3,
            confidence: 1.0,
            lift: 1.7,
        };
        assert_eq!(r.render(&s), "intelligence(S)=3 -> RA(P,S)=T");
    }

    #[test]
    fn lift_consistency_native() {
        let m = native_metrics(&[50.0], &[60.0], &[30.0], 100.0);
        let (sup, conf, lift) = m[0];
        assert!((sup - 0.3).abs() < 1e-12);
        assert!((conf - 0.6).abs() < 1e-12);
        assert!((lift - 1.0).abs() < 1e-12);
    }
}
