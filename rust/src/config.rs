//! Run configuration: hand-rolled CLI/key-value parsing (no `clap`/`serde`
//! offline). Shared by the `mrss` binary and the bench harnesses.
//!
//! Precedence: defaults < config file (`--config path`, `KEY = VALUE`
//! lines, `#` comments) < command-line flags.

use crate::bail;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::time::Duration;

/// Which ct-algebra engine executes the bulk operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Xla,
}

/// Parsed configuration for a run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Subcommand (`datasets`, `ct`, `cp`, `suite`, `mine`, `bn`).
    pub command: String,
    pub dataset: String,
    /// Whether `dataset` was set explicitly (flag or config file) rather
    /// than left at the default — lets store-reading commands reject a
    /// `--dataset`/manifest mismatch without breaking the default case.
    pub dataset_explicit: bool,
    /// Same for `scale` and `seed`: a store-reading command serves the
    /// manifest's configuration, so explicitly asking for a different one
    /// is an error, not a silent override.
    pub scale_explicit: bool,
    pub seed_explicit: bool,
    pub scale: f64,
    pub seed: u64,
    pub engine: EngineKind,
    pub workers: usize,
    pub cp_budget_secs: u64,
    pub cp_max_tuples: u128,
    pub max_chain_len: Option<usize>,
    /// Print the first N rows of the joint table (0 = skip).
    pub excerpt: usize,
    /// Ct-store root directory: `ct`/`suite` persist into it, `query`/
    /// `serve`/`mine`/`bn` read from it.
    pub store: Option<String>,
    /// `query`: batch file of queries, one per line (`#` comments).
    pub queries: Option<String>,
    /// `query`: a single inline query string.
    pub query: Option<String>,
    /// `query`: write the JSON answers here instead of stdout.
    pub json: Option<String>,
    /// `query --gen N`: emit N generated queries instead of answering.
    pub gen: Option<usize>,
    /// `query --fresh`: answer from a fresh in-memory Möbius Join instead
    /// of the store (the store-smoke diff baseline).
    pub fresh: bool,
    /// LRU cache budget in bytes for store reads.
    pub mem_budget: Option<usize>,
    /// `serve --listen ADDR`: serve the wire protocol on a TCP socket
    /// instead of the legacy stdin/stdout loop (`:0` = ephemeral port).
    pub listen: Option<String>,
    /// `bench-serve --addr ADDR`: target an already-running server
    /// (without it, `--store` self-hosts one on an ephemeral port).
    pub addr: Option<String>,
    /// `bench-serve --clients N`: concurrent client connections.
    pub clients: usize,
    /// `serve --threads N`: worker thread pool size.
    pub serve_threads: usize,
    /// `serve --shards N`: reactor (acceptor/event-loop) thread count.
    pub shards: usize,
    /// `serve --max-conns N`: connection limit (past it ⇒ BUSY at accept).
    pub max_conns: usize,
    /// `serve --poller poll|epoll`: readiness backend override (defaults
    /// to the best the OS offers).
    pub poller: Option<String>,
    /// `serve --queue-depth N`: bounded execution-queue depth (full ⇒ BUSY).
    pub queue_depth: usize,
    /// `serve --max-requests N`: per-connection request cap (⇒ BUSY).
    pub max_requests: usize,
    /// `bench-serve --mix uniform|zipf:<s>`: query selection skew.
    pub mix: String,
    /// `bench-serve --idle N`: idle connections held open during the run.
    pub idle: usize,
    /// `serve --idle-timeout MS`: close connections idle past this long
    /// (no complete request line arriving — slow-loris defense).
    pub idle_timeout_ms: Option<u64>,
    /// `serve --request-timeout MS`: answer `ERR deadline exceeded` when a
    /// query executes past this long; the connection survives.
    pub request_timeout_ms: Option<u64>,
    /// `serve --failpoints SPEC`: arm fault-injection points (builds with
    /// `--features failpoints` only; errors out otherwise).
    pub failpoints: Option<String>,
    /// `serve --trace-sample N` (or `1/N`): trace every Nth request
    /// (1 = all, 0 = off).
    pub trace_sample: u64,
    /// `serve --access-log FILE`: append one JSON line per sampled
    /// request.
    pub access_log: Option<String>,
    /// `serve --profile-hz N`: span-stack sampling profiler frequency
    /// behind the `PROFILE` verb (0 = sampler off, span publication
    /// short-circuits).
    pub profile_hz: u64,
    /// `profile --secs N`: capture window for the one-shot profile client.
    pub secs: u64,
    /// `profile --folded FILE`: write the collapsed stacks here
    /// (flamegraph.pl / inferno input) instead of stdout only.
    pub folded: Option<String>,
    /// `validate-metrics --file FILE`: Prometheus exposition document to
    /// check (stdin when omitted).
    pub file: Option<String>,
    /// `validate-metrics --prev FILE`: an earlier scrape of the same
    /// server; counters in it must be ≤ their values in `--file`
    /// (monotonicity — catches silent counter resets between scrapes).
    pub prev: Option<String>,
    /// `ct`/`suite --progress`: print live per-level Möbius build
    /// progress lines to stderr.
    pub progress: bool,
    /// `serve --wire text|json`: response rendering (JSON is the default).
    pub wire_text: bool,
    /// `bench-serve --bench-json FILE`: where the perf report lands.
    pub bench_json: Option<String>,
    /// `bench-serve --shutdown`: send SHUTDOWN after the run.
    pub send_shutdown: bool,
    /// Extra free-form options (forward-compatible).
    pub extra: HashMap<String, String>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            command: "datasets".into(),
            dataset: "university".into(),
            dataset_explicit: false,
            scale_explicit: false,
            seed_explicit: false,
            scale: 0.1,
            seed: 7,
            engine: EngineKind::Native,
            workers: 1,
            cp_budget_secs: 120,
            cp_max_tuples: 200_000_000,
            max_chain_len: None,
            excerpt: 0,
            store: None,
            queries: None,
            query: None,
            json: None,
            gen: None,
            fresh: false,
            mem_budget: None,
            listen: None,
            addr: None,
            clients: 8,
            serve_threads: 4,
            shards: 2,
            max_conns: 16_384,
            poller: None,
            queue_depth: 64,
            max_requests: 100_000,
            mix: "uniform".into(),
            idle: 0,
            idle_timeout_ms: None,
            request_timeout_ms: None,
            failpoints: None,
            trace_sample: 0,
            access_log: None,
            profile_hz: 99,
            secs: 2,
            folded: None,
            file: None,
            prev: None,
            progress: false,
            wire_text: false,
            bench_json: None,
            send_shutdown: false,
            extra: HashMap::new(),
        }
    }
}

impl Config {
    /// Parse from CLI args (`args` excludes the program name). The first
    /// non-flag token is the subcommand.
    pub fn from_args(args: &[String]) -> Result<Config> {
        let mut cfg = Config::default();
        let mut it = args.iter().peekable();
        let mut saw_command = false;
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                let take = |it: &mut std::iter::Peekable<std::slice::Iter<String>>| -> Result<String> {
                    it.next().cloned().with_context(|| format!("--{flag} needs a value"))
                };
                match flag {
                    "dataset" => {
                        cfg.dataset = take(&mut it)?;
                        cfg.dataset_explicit = true;
                    }
                    "scale" => {
                        cfg.scale = take(&mut it)?.parse().context("--scale")?;
                        cfg.scale_explicit = true;
                    }
                    "seed" => {
                        cfg.seed = take(&mut it)?.parse().context("--seed")?;
                        cfg.seed_explicit = true;
                    }
                    "engine" => {
                        cfg.engine = match take(&mut it)?.as_str() {
                            "native" => EngineKind::Native,
                            "xla" => EngineKind::Xla,
                            other => bail!("unknown engine `{other}` (native|xla)"),
                        }
                    }
                    "workers" => cfg.workers = take(&mut it)?.parse().context("--workers")?,
                    "cp-budget-secs" => {
                        cfg.cp_budget_secs = take(&mut it)?.parse().context("--cp-budget-secs")?
                    }
                    "cp-max-tuples" => {
                        cfg.cp_max_tuples = take(&mut it)?.parse().context("--cp-max-tuples")?
                    }
                    "max-chain-len" => {
                        cfg.max_chain_len =
                            Some(take(&mut it)?.parse().context("--max-chain-len")?)
                    }
                    "excerpt" => cfg.excerpt = take(&mut it)?.parse().context("--excerpt")?,
                    "store" => cfg.store = Some(take(&mut it)?),
                    "queries" => cfg.queries = Some(take(&mut it)?),
                    "query" => cfg.query = Some(take(&mut it)?),
                    "json" => cfg.json = Some(take(&mut it)?),
                    "gen" => cfg.gen = Some(take(&mut it)?.parse().context("--gen")?),
                    "fresh" => cfg.fresh = true,
                    "mem-budget" => {
                        cfg.mem_budget = Some(take(&mut it)?.parse().context("--mem-budget")?)
                    }
                    "listen" => cfg.listen = Some(take(&mut it)?),
                    "addr" => cfg.addr = Some(take(&mut it)?),
                    "clients" => cfg.clients = take(&mut it)?.parse().context("--clients")?,
                    "threads" => {
                        cfg.serve_threads = take(&mut it)?.parse().context("--threads")?
                    }
                    "shards" => cfg.shards = take(&mut it)?.parse().context("--shards")?,
                    "max-conns" => {
                        cfg.max_conns = take(&mut it)?.parse().context("--max-conns")?
                    }
                    "poller" => cfg.poller = Some(take(&mut it)?),
                    "mix" => cfg.mix = take(&mut it)?,
                    "idle" => cfg.idle = take(&mut it)?.parse().context("--idle")?,
                    "queue-depth" => {
                        cfg.queue_depth = take(&mut it)?.parse().context("--queue-depth")?
                    }
                    "max-requests" => {
                        cfg.max_requests = take(&mut it)?.parse().context("--max-requests")?
                    }
                    "idle-timeout" => {
                        cfg.idle_timeout_ms =
                            Some(take(&mut it)?.parse().context("--idle-timeout")?)
                    }
                    "request-timeout" => {
                        cfg.request_timeout_ms =
                            Some(take(&mut it)?.parse().context("--request-timeout")?)
                    }
                    "failpoints" => cfg.failpoints = Some(take(&mut it)?),
                    "trace-sample" => {
                        // Accept both `N` and the scrape-config idiom `1/N`.
                        let v = take(&mut it)?;
                        let n = v.strip_prefix("1/").unwrap_or(&v);
                        cfg.trace_sample = n.parse().context("--trace-sample")?;
                    }
                    "access-log" => cfg.access_log = Some(take(&mut it)?),
                    "profile-hz" => {
                        cfg.profile_hz = take(&mut it)?.parse().context("--profile-hz")?
                    }
                    "secs" => cfg.secs = take(&mut it)?.parse().context("--secs")?,
                    "folded" => cfg.folded = Some(take(&mut it)?),
                    "file" => cfg.file = Some(take(&mut it)?),
                    "prev" => cfg.prev = Some(take(&mut it)?),
                    "progress" => cfg.progress = true,
                    "wire" => {
                        cfg.wire_text = match take(&mut it)?.as_str() {
                            "text" => true,
                            "json" => false,
                            other => bail!("unknown wire mode `{other}` (text|json)"),
                        }
                    }
                    "bench-json" => cfg.bench_json = Some(take(&mut it)?),
                    "shutdown" => cfg.send_shutdown = true,
                    "config" => {
                        let path = take(&mut it)?;
                        cfg.apply_file(&path)?;
                    }
                    other => {
                        let v = take(&mut it)?;
                        cfg.extra.insert(other.to_string(), v);
                    }
                }
            } else if !saw_command {
                cfg.command = a.clone();
                saw_command = true;
            } else {
                bail!("unexpected positional argument `{a}`");
            }
        }
        if cfg.scale <= 0.0 {
            bail!("scale must be positive");
        }
        if cfg.workers == 0 {
            bail!("workers must be >= 1");
        }
        if cfg.clients == 0 || cfg.serve_threads == 0 || cfg.queue_depth == 0 {
            bail!("--clients, --threads, and --queue-depth must be >= 1");
        }
        if cfg.shards == 0 || cfg.max_conns == 0 {
            bail!("--shards and --max-conns must be >= 1");
        }
        if cfg.idle_timeout_ms == Some(0) || cfg.request_timeout_ms == Some(0) {
            bail!("--idle-timeout and --request-timeout must be >= 1 ms (omit to disable)");
        }
        if cfg.access_log.is_some() && cfg.trace_sample == 0 {
            bail!("--access-log needs --trace-sample N (only sampled requests are logged)");
        }
        if cfg.profile_hz > 1000 {
            bail!("--profile-hz must be <= 1000 (0 disables the sampler)");
        }
        if cfg.secs == 0 {
            bail!("--secs must be >= 1");
        }
        Ok(cfg)
    }

    /// Apply `KEY = VALUE` lines from a config file (lower precedence than
    /// flags that come after `--config` on the command line).
    pub fn apply_file(&mut self, path: &str) -> Result<()> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path}:{}: expected KEY = VALUE", ln + 1))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "dataset" => {
                    self.dataset = v.to_string();
                    self.dataset_explicit = true;
                }
                "scale" => {
                    self.scale = v.parse().context("scale")?;
                    self.scale_explicit = true;
                }
                "seed" => {
                    self.seed = v.parse().context("seed")?;
                    self.seed_explicit = true;
                }
                "workers" => self.workers = v.parse().context("workers")?,
                "engine" => {
                    self.engine = match v {
                        "native" => EngineKind::Native,
                        "xla" => EngineKind::Xla,
                        other => bail!("unknown engine `{other}`"),
                    }
                }
                "cp_budget_secs" => self.cp_budget_secs = v.parse().context("cp_budget_secs")?,
                "max_chain_len" => self.max_chain_len = Some(v.parse().context("max_chain_len")?),
                "store" => self.store = Some(v.to_string()),
                "mem_budget" => self.mem_budget = Some(v.parse().context("mem_budget")?),
                "listen" => self.listen = Some(v.to_string()),
                "clients" => self.clients = v.parse().context("clients")?,
                "threads" => self.serve_threads = v.parse().context("threads")?,
                other => {
                    self.extra.insert(other.to_string(), v.to_string());
                }
            }
        }
        Ok(())
    }

    pub fn cp_budget(&self) -> crate::baseline::CpBudget {
        crate::baseline::CpBudget {
            max_time: Duration::from_secs(self.cp_budget_secs),
            max_tuples: self.cp_max_tuples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = Config::from_args(&args("ct --dataset imdb --scale 0.25 --engine xla")).unwrap();
        assert_eq!(c.command, "ct");
        assert_eq!(c.dataset, "imdb");
        assert_eq!(c.scale, 0.25);
        assert_eq!(c.engine, EngineKind::Xla);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Config::from_args(&args("ct --scale -1")).is_err());
        assert!(Config::from_args(&args("ct --engine gpu")).is_err());
        assert!(Config::from_args(&args("ct --scale")).is_err());
        assert!(Config::from_args(&args("ct stray")).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("mrss_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(&path, "dataset = hepatitis\nscale = 0.5 # half\nworkers=2\n").unwrap();
        let c = Config::from_args(&args(&format!(
            "suite --config {} --seed 9",
            path.display()
        )))
        .unwrap();
        assert_eq!(c.dataset, "hepatitis");
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.workers, 2);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn extra_flags_preserved() {
        let c = Config::from_args(&args("mine --min-support 0.1")).unwrap();
        assert_eq!(c.extra["min-support"], "0.1");
    }

    #[test]
    fn store_and_query_flags_parse() {
        let c = Config::from_args(&args(
            "query --store /tmp/s --queries q.txt --json out.json --mem-budget 65536 --fresh",
        ))
        .unwrap();
        assert_eq!(c.command, "query");
        assert_eq!(c.store.as_deref(), Some("/tmp/s"));
        assert_eq!(c.queries.as_deref(), Some("q.txt"));
        assert_eq!(c.json.as_deref(), Some("out.json"));
        assert_eq!(c.mem_budget, Some(65536));
        assert!(c.fresh);
        let g = Config::from_args(&args("query --store /tmp/s --gen 50")).unwrap();
        assert_eq!(g.gen, Some(50));
        assert!(!g.fresh);
    }

    #[test]
    fn serve_and_bench_serve_flags_parse() {
        let c = Config::from_args(&args(
            "serve --store /tmp/s --listen 127.0.0.1:7171 --threads 6 --queue-depth 32 \
             --max-requests 500 --wire text --shards 4 --max-conns 20000 --poller poll \
             --idle-timeout 30000 --request-timeout 2000 \
             --failpoints worker.exec.panic=hit:2",
        ))
        .unwrap();
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:7171"));
        assert_eq!(c.serve_threads, 6);
        assert_eq!(c.queue_depth, 32);
        assert_eq!(c.max_requests, 500);
        assert!(c.wire_text);
        assert_eq!(c.shards, 4);
        assert_eq!(c.max_conns, 20_000);
        assert_eq!(c.poller.as_deref(), Some("poll"));
        assert_eq!(c.idle_timeout_ms, Some(30_000));
        assert_eq!(c.request_timeout_ms, Some(2_000));
        assert_eq!(c.failpoints.as_deref(), Some("worker.exec.panic=hit:2"));

        let t = Config::from_args(&args(
            "serve --store /tmp/s --trace-sample 1/16 --access-log /tmp/access.log",
        ))
        .unwrap();
        assert_eq!(t.trace_sample, 16);
        assert_eq!(t.access_log.as_deref(), Some("/tmp/access.log"));
        let t = Config::from_args(&args("serve --trace-sample 4")).unwrap();
        assert_eq!(t.trace_sample, 4);
        let v = Config::from_args(&args(
            "validate-metrics --file /tmp/m.prom --prev /tmp/m0.prom",
        ))
        .unwrap();
        assert_eq!(v.command, "validate-metrics");
        assert_eq!(v.file.as_deref(), Some("/tmp/m.prom"));
        assert_eq!(v.prev.as_deref(), Some("/tmp/m0.prom"));
        // --progress is a bare boolean flag: it must not eat a value.
        let p = Config::from_args(&args("suite --progress --workers 3")).unwrap();
        assert!(p.progress);
        assert_eq!(p.workers, 3);
        assert!(!Config::from_args(&args("ct")).unwrap().progress);
        // An access log without sampling would silently log nothing.
        assert!(Config::from_args(&args("serve --access-log /tmp/a.log")).is_err());
        assert!(Config::from_args(&args("serve --trace-sample nope")).is_err());

        let b = Config::from_args(&args(
            "bench-serve --addr 127.0.0.1:7171 --clients 8 --queries 200 \
             --bench-json BENCH_serve.json --shutdown --mix zipf:1.1 --idle 1000",
        ))
        .unwrap();
        assert_eq!(b.addr.as_deref(), Some("127.0.0.1:7171"));
        assert_eq!(b.clients, 8);
        assert_eq!(b.queries.as_deref(), Some("200"));
        assert_eq!(b.bench_json.as_deref(), Some("BENCH_serve.json"));
        assert!(b.send_shutdown);
        assert_eq!(b.mix, "zipf:1.1");
        assert_eq!(b.idle, 1000);

        let d = Config::from_args(&args("serve")).unwrap();
        assert_eq!(d.shards, 2);
        assert_eq!(d.max_conns, 16_384);
        assert_eq!(d.poller, None);
        assert_eq!(d.mix, "uniform");
        assert_eq!(d.idle, 0);
        assert_eq!(d.idle_timeout_ms, None);
        assert_eq!(d.request_timeout_ms, None);
        assert_eq!(d.failpoints, None);

        assert!(Config::from_args(&args("serve --wire yaml")).is_err());
        assert!(Config::from_args(&args("bench-serve --clients 0")).is_err());
        assert!(Config::from_args(&args("serve --shards 0")).is_err());
        assert!(Config::from_args(&args("serve --max-conns 0")).is_err());
        assert!(Config::from_args(&args("serve --idle-timeout 0")).is_err());
        assert!(Config::from_args(&args("serve --request-timeout 0")).is_err());
    }

    #[test]
    fn profile_flags_parse() {
        let s = Config::from_args(&args("serve --store /tmp/s --profile-hz 0")).unwrap();
        assert_eq!(s.profile_hz, 0);
        let d = Config::from_args(&args("serve")).unwrap();
        assert_eq!(d.profile_hz, 99, "sampler defaults on at 99 Hz");
        assert_eq!(d.secs, 2);
        assert_eq!(d.folded, None);
        let p = Config::from_args(&args(
            "profile --addr 127.0.0.1:7171 --secs 5 --folded /tmp/out.folded",
        ))
        .unwrap();
        assert_eq!(p.command, "profile");
        assert_eq!(p.addr.as_deref(), Some("127.0.0.1:7171"));
        assert_eq!(p.secs, 5);
        assert_eq!(p.folded.as_deref(), Some("/tmp/out.folded"));
        assert!(Config::from_args(&args("serve --profile-hz 100000")).is_err());
        assert!(Config::from_args(&args("profile --secs 0")).is_err());
    }
}
