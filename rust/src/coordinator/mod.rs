//! L3 pipeline coordinator: orchestrates benchmark jobs (generate → Möbius
//! Join → baseline → statistical apps) across a bounded worker pool with
//! backpressure, and aggregates per-job reports.
//!
//! This is the streaming-orchestrator layer of the three-layer
//! architecture: the rust binary owns the event loop and process topology;
//! compute kernels are the AOT XLA artifacts behind
//! [`crate::runtime::XlaRuntime`]. (On the single-core paper testbed the
//! pool degenerates gracefully to serial execution — the ablation bench
//! measures both.)

mod report;

pub use report::{CpReport, SuiteReport};

use crate::anyhow;
use crate::baseline::{cross_product_ct, CpBudget};
use crate::datagen;
use crate::mobius::MobiusJoin;
use crate::store::{CtStore, PersistConfig, StoreSink};
use crate::util::error::{Context, Result};
use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One benchmark job.
#[derive(Debug, Clone)]
pub struct SuiteJob {
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    /// Also run the cross-product baseline (Table 3)?
    pub run_cp: bool,
    pub cp_budget: CpBudget,
    /// Cap the chain length (paper §8 option; None = full lattice).
    pub max_chain_len: Option<usize>,
    /// Worker threads for the Möbius Join's per-level chain loop (1 =
    /// serial). Output is identical for any value.
    pub mj_workers: usize,
    /// Persist every computed table to `<store_dir>/<dataset>` and verify
    /// the stored joint by reading it back (`None` = no persistence).
    pub store_dir: Option<String>,
    /// Stream per-level Möbius-build progress lines to stderr.
    pub progress: bool,
}

impl SuiteJob {
    pub fn new(dataset: &str, scale: f64, seed: u64) -> Self {
        SuiteJob {
            dataset: dataset.to_string(),
            scale,
            seed,
            run_cp: false,
            cp_budget: CpBudget::default(),
            max_chain_len: None,
            mj_workers: 1,
            store_dir: None,
            progress: false,
        }
    }

    pub fn with_cp(mut self, budget: CpBudget) -> Self {
        self.run_cp = true;
        self.cp_budget = budget;
        self
    }

    pub fn with_mj_workers(mut self, workers: usize) -> Self {
        self.mj_workers = workers.max(1);
        self
    }

    /// Persist this job's tables under `dir/<dataset>`.
    pub fn with_store(mut self, dir: &str) -> Self {
        self.store_dir = Some(dir.to_string());
        self
    }

    /// Stream per-level build-progress lines while the join runs.
    pub fn with_progress(mut self, on: bool) -> Self {
        self.progress = on;
        self
    }
}

/// Worker-pool configuration.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Number of worker threads (1 = serial).
    pub workers: usize,
    /// Bounded queue depth between the feeder and the workers
    /// (backpressure: the feeder blocks when workers fall behind).
    pub queue_depth: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            queue_depth: 2,
        }
    }
}

/// Execute one job (generation + MJ [+ CP] [+ persistence]) and build its
/// report.
pub fn run_job(job: &SuiteJob) -> Result<SuiteReport> {
    let t0 = Instant::now();
    let db = datagen::generate(&job.dataset, job.scale, job.seed)?;
    let gen_time = t0.elapsed();

    // With persistence on, a write-on-complete sink streams every finished
    // table into the store while the join runs.
    let store = match &job.store_dir {
        Some(dir) => Some(CtStore::create(
            Path::new(dir).join(&job.dataset),
            &job.dataset,
            job.scale,
            job.seed,
        )?),
        None => None,
    };
    let sink = store.as_ref().map(|s| StoreSink::new(s, &db.schema, PersistConfig::default()));

    let mut mj = MobiusJoin::new(&db).workers(job.mj_workers).progress(job.progress);
    if let Some(l) = job.max_chain_len {
        mj = mj.max_chain_len(l);
    }
    if let Some(s) = &sink {
        mj = mj.sink(s);
    }
    let mut res = mj.run();

    if let (Some(store), Some(sink)) = (&store, &sink) {
        sink.take_error()?;
        // Cold readback verification: re-open the store, decode the joint,
        // and require bit-for-bit logical equality with the in-memory
        // table; a second read exercises the cache-hit path. The handle's
        // counters become the run's store metrics.
        if let Some(joint) = &res.joint {
            let cold = CtStore::open(store.dir())?;
            let back = cold.get("joint").context("store readback")?;
            if *back != *joint {
                return Err(anyhow!(
                    "store readback mismatch for {}: persisted joint differs",
                    job.dataset
                ));
            }
            let _ = cold.get("joint")?;
            let s = cold.stats();
            res.metrics.store_hits = s.hits;
            res.metrics.store_misses = s.misses;
            res.metrics.store_evictions = s.evictions;
        }
    }

    let cp = if job.run_cp {
        let out = cross_product_ct(&db, job.cp_budget);
        Some(CpReport::from_outcome(&out))
    } else {
        None
    };

    // Consistency cross-check when both paths completed (paper §5.2 did the
    // same validation).
    if let (Some(cp_rep), Some(joint)) = (&cp, res.joint.as_ref()) {
        if let Some(ct) = cp_rep.verified_rows {
            debug_assert_eq!(ct, joint.len() as u64, "MJ/CP mismatch");
        }
    }

    Ok(SuiteReport::build(job, &db, &res, cp, gen_time))
}

/// Run a batch of jobs over a bounded worker pool; reports come back in
/// job order.
pub fn run_suite(jobs: Vec<SuiteJob>, pool: PoolConfig) -> Vec<Result<SuiteReport>> {
    let n = jobs.len();
    if pool.workers <= 1 || n <= 1 {
        return jobs.iter().map(run_job).collect();
    }
    let (job_tx, job_rx) = mpsc::sync_channel::<(usize, SuiteJob)>(pool.queue_depth);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (rep_tx, rep_rx) = mpsc::channel::<(usize, Result<SuiteReport>)>();

    let mut handles = Vec::new();
    for _ in 0..pool.workers.min(n) {
        let rx = Arc::clone(&job_rx);
        let tx = rep_tx.clone();
        handles.push(std::thread::spawn(move || {
            loop {
                let next = { rx.lock().unwrap().recv() };
                match next {
                    Ok((idx, job)) => {
                        let rep = run_job(&job);
                        if tx.send((idx, rep)).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        }));
    }
    drop(rep_tx);

    // Feeder (blocks on the bounded channel: backpressure).
    for (i, job) in jobs.into_iter().enumerate() {
        job_tx.send((i, job)).expect("workers died");
    }
    drop(job_tx);

    let mut slots: Vec<Option<Result<SuiteReport>>> = (0..n).map(|_| None).collect();
    for (idx, rep) in rep_rx {
        slots[idx] = Some(rep);
    }
    for h in handles {
        let _ = h.join();
    }
    slots.into_iter().map(|s| s.expect("missing report")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_job_produces_report() {
        let job = SuiteJob::new("mutagenesis", 0.02, 7);
        let rep = run_job(&job).unwrap();
        assert_eq!(rep.dataset, "mutagenesis");
        assert!(rep.tuples > 0);
        assert!(rep.statistics > 0);
        assert!(rep.statistics >= rep.link_off_statistics);
    }

    #[test]
    fn run_job_with_cp_verifies() {
        let job = SuiteJob::new("uwcse", 0.1, 7).with_cp(CpBudget::default());
        let rep = run_job(&job).unwrap();
        let cp = rep.cp.as_ref().unwrap();
        assert!(!cp.non_termination);
        assert_eq!(cp.verified_rows, Some(rep.statistics));
    }

    #[test]
    fn suite_serial_and_parallel_agree() {
        let jobs = vec![
            SuiteJob::new("mutagenesis", 0.02, 7),
            SuiteJob::new("uwcse", 0.2, 7),
            SuiteJob::new("mondial", 0.1, 7),
        ];
        let serial = run_suite(jobs.clone(), PoolConfig { workers: 1, queue_depth: 1 });
        let parallel = run_suite(jobs, PoolConfig { workers: 3, queue_depth: 2 });
        for (a, b) in serial.iter().zip(&parallel) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.dataset, b.dataset);
            assert_eq!(a.statistics, b.statistics);
            assert_eq!(a.extra_statistics, b.extra_statistics);
        }
    }

    #[test]
    fn mj_workers_do_not_change_results() {
        let serial = run_job(&SuiteJob::new("uwcse", 0.2, 7)).unwrap();
        let parallel = run_job(&SuiteJob::new("uwcse", 0.2, 7).with_mj_workers(4)).unwrap();
        assert_eq!(serial.statistics, parallel.statistics);
        assert_eq!(serial.extra_statistics, parallel.extra_statistics);
        assert_eq!(serial.link_off_statistics, parallel.link_off_statistics);
    }

    #[test]
    fn run_job_with_store_persists_and_verifies() {
        let dir = std::env::temp_dir()
            .join(format!("mrss_coord_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let job = SuiteJob::new("uwcse", 0.1, 7).with_store(dir.to_str().unwrap());
        let rep = run_job(&job).unwrap();
        // Readback verification ran: one cold miss + one warm hit.
        assert_eq!(rep.metrics.store_misses, 1);
        assert_eq!(rep.metrics.store_hits, 1);
        // The store on disk holds entities + positives + chains + joint.
        let store = CtStore::open(dir.join("uwcse")).unwrap();
        assert!(store.contains("joint"));
        assert!(store.len() > 3, "only {} tables persisted", store.len());
        assert_eq!(store.get("joint").unwrap().len() as u64, rep.statistics);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_dataset_reports_error() {
        let out = run_suite(
            vec![SuiteJob::new("nope", 1.0, 1)],
            PoolConfig { workers: 1, queue_depth: 1 },
        );
        assert!(out[0].is_err());
    }
}
