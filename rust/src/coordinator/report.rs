//! Per-job reports aggregated by the coordinator — the rows of the paper's
//! Tables 2-4 come straight from these.

use crate::baseline::CpOutcome;
use crate::coordinator::SuiteJob;
use crate::db::Database;
use crate::mobius::{MjMetrics, MjResult};
use std::time::Duration;

/// Cross-product baseline outcome (Table 3 columns).
#[derive(Debug, Clone)]
pub struct CpReport {
    pub cp_tuples: u128,
    pub elapsed: Duration,
    /// The paper's "N.T." — budget exhausted before completion.
    pub non_termination: bool,
    /// Row count of the CP table when it completed (for MJ cross-checks).
    pub verified_rows: Option<u64>,
}

impl CpReport {
    pub fn from_outcome(out: &CpOutcome) -> CpReport {
        match out {
            CpOutcome::Done { ct, cp_tuples, elapsed } => CpReport {
                cp_tuples: *cp_tuples,
                elapsed: *elapsed,
                non_termination: false,
                verified_rows: Some(ct.len() as u64),
            },
            CpOutcome::NonTermination { cp_tuples, elapsed } => CpReport {
                cp_tuples: *cp_tuples,
                elapsed: *elapsed,
                non_termination: true,
                verified_rows: None,
            },
        }
    }
}

/// Full report for one benchmark job.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    pub dataset: String,
    pub scale: f64,
    // Table 2 columns.
    pub rel_tables: usize,
    pub total_tables: usize,
    pub self_rels: usize,
    pub tuples: u64,
    pub attributes: usize,
    // Table 3 / 4 columns.
    pub gen_time: Duration,
    pub mj_time: Duration,
    pub statistics: u64,
    pub link_off_statistics: u64,
    pub extra_statistics: u64,
    pub extra_time: Duration,
    pub metrics: MjMetrics,
    pub cp: Option<CpReport>,
}

impl SuiteReport {
    pub fn build(
        job: &SuiteJob,
        db: &Database,
        res: &MjResult,
        cp: Option<CpReport>,
        gen_time: Duration,
    ) -> SuiteReport {
        let (stats, off, extra) = if res.joint.is_some() {
            (
                res.num_statistics() as u64,
                res.link_off().len() as u64,
                res.num_extra_statistics() as u64,
            )
        } else {
            (0, 0, 0)
        };
        SuiteReport {
            dataset: job.dataset.clone(),
            scale: job.scale,
            rel_tables: db.schema.num_rel_vars(),
            total_tables: db.schema.num_tables(),
            self_rels: db.schema.num_self_rels(),
            tuples: db.total_tuples(),
            attributes: db.schema.num_attributes(),
            gen_time,
            mj_time: res.metrics.total,
            statistics: stats,
            link_off_statistics: off,
            extra_statistics: extra,
            extra_time: res.metrics.extra_time(),
            metrics: res.metrics.clone(),
            cp,
        }
    }

    /// Row-major reference fallbacks during the job's Möbius Join (zero
    /// for every ≤128-bit benchmark schema).
    pub fn reference_fallbacks(&self) -> u64 {
        self.metrics.reference_fallbacks
    }

    /// Ct-store cache counters `(hits, misses, evictions)` from the job's
    /// persistence readback — all zero when the job ran without a store.
    /// Reported alongside [`reference_fallbacks`](Self::reference_fallbacks)
    /// so suite output shows both the fast-path and the storage health.
    pub fn store_counters(&self) -> (u64, u64, u64) {
        (self.metrics.store_hits, self.metrics.store_misses, self.metrics.store_evictions)
    }

    /// Table 3 "Compress Ratio" = CP-#tuples / #Statistics.
    pub fn compression_ratio(&self) -> Option<f64> {
        let cp = self.cp.as_ref()?;
        if self.statistics == 0 {
            return None;
        }
        Some(cp.cp_tuples as f64 / self.statistics as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{cross_product_ct, CpBudget};
    use crate::datagen;
    use crate::mobius::MobiusJoin;

    #[test]
    fn compression_ratio_matches_definition() {
        let db = datagen::generate("uwcse", 0.1, 7).unwrap();
        let res = MobiusJoin::new(&db).run();
        let cp = cross_product_ct(&db, CpBudget::default());
        let job = crate::coordinator::SuiteJob::new("uwcse", 0.1, 7);
        let rep = SuiteReport::build(
            &job,
            &db,
            &res,
            Some(CpReport::from_outcome(&cp)),
            Duration::ZERO,
        );
        let ratio = rep.compression_ratio().unwrap();
        let expect = cp.cp_tuples() as f64 / rep.statistics as f64;
        assert!((ratio - expect).abs() < 1e-9);
    }

    #[test]
    fn store_counters_surface_in_report() {
        let dir = std::env::temp_dir()
            .join(format!("mrss_report_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let job = crate::coordinator::SuiteJob::new("uwcse", 0.1, 7)
            .with_store(dir.to_str().unwrap());
        let rep = crate::coordinator::run_job(&job).unwrap();
        let (hits, misses, evictions) = rep.store_counters();
        assert_eq!((hits, misses, evictions), (1, 1, 0));
        // reference_fallbacks is attributed by process-global delta, so
        // concurrent lib tests can bump it — only assert it is exposed.
        let _ = rep.reference_fallbacks();
        // And the no-store path reports zeros.
        let plain = crate::coordinator::run_job(&crate::coordinator::SuiteJob::new(
            "uwcse", 0.1, 7,
        ))
        .unwrap();
        assert_eq!(plain.store_counters(), (0, 0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn nt_report_has_no_verified_rows() {
        let db = datagen::generate("mondial", 0.3, 7).unwrap();
        let cp = cross_product_ct(
            &db,
            CpBudget { max_time: Duration::from_secs(60), max_tuples: 10 },
        );
        let rep = CpReport::from_outcome(&cp);
        assert!(rep.non_termination);
        assert_eq!(rep.verified_rows, None);
    }
}
