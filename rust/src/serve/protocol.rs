//! Line-delimited wire protocol of the count server.
//!
//! Every request is exactly one `\n`-terminated line; every request line
//! produces one or more response lines (a `BATCH` of *k* queries answers
//! with exactly *k* lines, in order), so the protocol needs no framing
//! beyond the newline and a plain `nc`/`telnet` session works as a client.
//!
//! ## Requests
//!
//! ```text
//! <query>                      count a conjunctive query (the `query` CLI
//!                              grammar: `RA(P,S)=F intelligence(S)=1 …`)
//! COUNT <query>                explicit form of the same
//! BATCH <q1> ; <q2> ; …        many queries on one line, `;`-separated
//! EXPLAIN <query>              execute with tracing forced; answer is the
//!                              count plus the span tree (always JSON)
//! STATS                        live metrics snapshot (always JSON)
//! METRICS                      Prometheus text exposition — the one
//!                              multi-line response, read until `# EOF`
//! DUMP                         flight-recorder contents + heavy-hitter
//!                              summary (always JSON)
//! TOP [k]                      top-k query plan signatures by count /
//!                              cost / latency (default k=10, always JSON)
//! HISTORY [secs]               per-second metrics series for the last
//!                              `secs` seconds (default 60, always JSON)
//! PROFILE [secs]               timed sampling-profiler capture: folded
//!                              flamegraph stacks + self-time table
//!                              (default 2s, always JSON)
//! PING                         liveness probe
//! SHUTDOWN                     stop the server after in-flight work drains
//! ```
//!
//! Keywords are matched case-insensitively; anything that is not a keyword
//! is a query. A query that *starts* with a keyword spelling can always be
//! sent via the `COUNT` prefix.
//!
//! ## Responses
//!
//! Two renderings, chosen by the server's `--wire` flag (JSON is the
//! default and matches the legacy stdin/stdout loop's output):
//!
//! | response   | text mode            | json mode                              |
//! |------------|----------------------|----------------------------------------|
//! | count      | `COUNT <n>`          | `{"query":"…","count":n}`              |
//! | error      | `ERR <msg>`          | `{"query":"…","error":"…"}`            |
//! | pong       | `PONG`               | `{"pong":true}`                        |
//! | busy       | `BUSY <why>`         | `{"busy":true,"error":"…"}`            |
//! | stats      | *(json object)*      | *(json object)*                        |
//! | explain    | *(json object)*      | *(json object)*                        |
//! | dump       | *(json object)*      | *(json object)*                        |
//! | top        | *(json object)*      | *(json object)*                        |
//! | history    | *(json object)*      | *(json object)*                        |
//! | profile    | *(json object)*      | *(json object)*                        |
//! | metrics    | *(text exposition)*  | *(text exposition)*                    |
//! | bye        | `BYE`                | `{"bye":true}`                         |
//!
//! `BUSY` is the admission-control answer (accept queue full, or the
//! per-connection request cap reached) — clients back off and retry.

/// Longest accepted request line, in bytes. A line past this is answered
/// with an error and the connection is closed (it is either abuse or a
/// framing bug; resynchronizing mid-line is not worth the ambiguity).
pub const MAX_LINE: usize = 64 * 1024;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Count one conjunctive query (the `query` CLI grammar).
    Count(String),
    /// Count many queries from one line (`;`-separated).
    Batch(Vec<String>),
    /// Count one query with tracing forced on, answering the span tree.
    Explain(String),
    Stats,
    /// Prometheus text exposition of every counter and histogram.
    Metrics,
    /// Flight-recorder dump: last-N + slowest-K request traces, plus the
    /// heavy-hitter summary.
    Dump,
    /// Top-k query plan signatures by count / cost / latency
    /// (`None` = server default k).
    Top(Option<usize>),
    /// Per-second metrics series for the last `secs` seconds
    /// (`None` = server default window).
    History(Option<u64>),
    /// Timed sampling-profiler capture over `secs` seconds
    /// (`None` = server default, 2s): folded stacks + self-time table.
    Profile(Option<u64>),
    Ping,
    Shutdown,
}

/// Parse one trimmed request line. Never fails: unknown text is a query
/// (the count path reports its own parse errors with full context).
pub fn parse_request(line: &str) -> Request {
    let line = line.trim();
    let keyword = line.split_whitespace().next().unwrap_or("");
    match keyword.to_ascii_uppercase().as_str() {
        "PING" if line.len() == keyword.len() => Request::Ping,
        "STATS" if line.len() == keyword.len() => Request::Stats,
        "METRICS" if line.len() == keyword.len() => Request::Metrics,
        "DUMP" if line.len() == keyword.len() => Request::Dump,
        "TOP" if line.len() == keyword.len() => Request::Top(None),
        // `TOP 5` takes an argument; non-numeric trailing text falls
        // through to a query, same as every other keyword.
        "TOP" => match line[keyword.len()..].trim().parse::<usize>() {
            Ok(k) => Request::Top(Some(k)),
            Err(_) => Request::Count(line.to_string()),
        },
        "HISTORY" if line.len() == keyword.len() => Request::History(None),
        "HISTORY" => match line[keyword.len()..].trim().parse::<u64>() {
            Ok(secs) => Request::History(Some(secs)),
            Err(_) => Request::Count(line.to_string()),
        },
        "PROFILE" if line.len() == keyword.len() => Request::Profile(None),
        "PROFILE" => match line[keyword.len()..].trim().parse::<u64>() {
            Ok(secs) => Request::Profile(Some(secs)),
            Err(_) => Request::Count(line.to_string()),
        },
        "SHUTDOWN" if line.len() == keyword.len() => Request::Shutdown,
        "COUNT" => Request::Count(line[keyword.len()..].trim().to_string()),
        "EXPLAIN" => Request::Explain(line[keyword.len()..].trim().to_string()),
        "BATCH" => Request::Batch(
            line[keyword.len()..]
                .split(';')
                .map(str::trim)
                .filter(|q| !q.is_empty())
                .map(str::to_string)
                .collect(),
        ),
        _ => Request::Count(line.to_string()),
    }
}

/// One response line (pre-render).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Count { query: String, count: u128 },
    Error { query: String, msg: String },
    Pong,
    Busy { msg: String },
    /// Pre-rendered JSON object (the metrics snapshot).
    Stats { json: String },
    /// Pre-rendered JSON object: count + span tree for `EXPLAIN`.
    Explain { json: String },
    /// Pre-rendered JSON object: the flight-recorder dump.
    Dump { json: String },
    /// Pre-rendered JSON object: the heavy-hitter rankings for `TOP`.
    Top { json: String },
    /// Pre-rendered JSON object: the per-second series for `HISTORY`.
    History { json: String },
    /// Pre-rendered JSON object: the sampling-profiler capture for
    /// `PROFILE` (folded stacks, self-time table, thread CPU split,
    /// process stats).
    Profile { json: String },
    /// Prometheus text exposition. The protocol's only multi-line
    /// response; the body already ends with its `# EOF` terminator
    /// line, so clients read until that marker.
    Metrics { text: String },
    Bye,
}

impl Response {
    /// Render as a single line (no trailing newline). `json` selects the
    /// wire mode; `STATS` is a JSON object in both.
    pub fn render(&self, json: bool) -> String {
        match self {
            Response::Count { query, count } => {
                if json {
                    format!("{{\"query\":\"{}\",\"count\":{count}}}", json_escape(query))
                } else {
                    format!("COUNT {count}")
                }
            }
            Response::Error { query, msg } => {
                if json {
                    format!(
                        "{{\"query\":\"{}\",\"error\":\"{}\"}}",
                        json_escape(query),
                        json_escape(msg)
                    )
                } else {
                    format!("ERR {}", msg.replace('\n', " "))
                }
            }
            Response::Pong => {
                if json {
                    "{\"pong\":true}".to_string()
                } else {
                    "PONG".to_string()
                }
            }
            Response::Busy { msg } => {
                if json {
                    format!("{{\"busy\":true,\"error\":\"{}\"}}", json_escape(msg))
                } else {
                    format!("BUSY {}", msg.replace('\n', " "))
                }
            }
            Response::Stats { json: obj } => obj.clone(),
            Response::Explain { json: obj } => obj.clone(),
            Response::Dump { json: obj } => obj.clone(),
            Response::Top { json: obj } => obj.clone(),
            Response::History { json: obj } => obj.clone(),
            Response::Profile { json: obj } => obj.clone(),
            // Multi-line body ending in the `# EOF` line; the trailing
            // newline is stripped here because the server appends one
            // newline per rendered response.
            Response::Metrics { text } => text.trim_end().to_string(),
            Response::Bye => {
                if json {
                    "{\"bye\":true}".to_string()
                } else {
                    "BYE".to_string()
                }
            }
        }
    }
}

/// A client-side parse of one response line: `Ok(count)` or `Err(message)`.
/// Understands both wire modes (detects JSON by the leading `{`), so the
/// load generator works against a server in either.
pub fn parse_count_response(line: &str) -> Result<u128, String> {
    let line = line.trim();
    if let Some(rest) = line.strip_prefix("COUNT ") {
        return rest.trim().parse::<u128>().map_err(|e| format!("bad count `{rest}`: {e}"));
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        return Err(rest.to_string());
    }
    if let Some(rest) = line.strip_prefix("BUSY") {
        return Err(format!("busy{rest}"));
    }
    if line.starts_with('{') {
        if let Some(v) = json_field(line, "count") {
            return v.parse::<u128>().map_err(|e| format!("bad count `{v}`: {e}"));
        }
        if let Some(e) = json_field(line, "error") {
            return Err(e);
        }
    }
    Err(format!("unparseable response `{line}`"))
}

/// Is this response line the admission-control `BUSY` answer (either wire
/// mode)? A busy reply is retryable — the server shed the request before
/// doing any work — unlike a terminal `ERR`, which reports a real failure
/// for the query itself. The load generator backs off and resends on busy.
pub fn is_busy_response(line: &str) -> bool {
    let line = line.trim();
    line.starts_with("BUSY")
        || (line.starts_with('{') && json_field(line, "busy").as_deref() == Some("true"))
}

/// Extract one scalar field from a flat one-line JSON object — enough for
/// the wire responses this module itself renders (no nesting, strings have
/// no escaped quotes after `json_escape` other than `\"`).
pub fn json_field(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let start = obj.find(&pat)? + pat.len();
    let rest = &obj[start..];
    if let Some(s) = rest.strip_prefix('"') {
        // String value: scan to the closing unescaped quote.
        let mut out = String::new();
        let mut chars = s.chars();
        while let Some(c) = chars.next() {
            match c {
                '\\' => {
                    if let Some(n) = chars.next() {
                        out.push(n);
                    }
                }
                '"' => return Some(out),
                c => out.push(c),
            }
        }
        None
    } else {
        let end = rest.find(|c| c == ',' || c == '}').unwrap_or(rest.len());
        Some(rest[..end].trim().to_string())
    }
}

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A resumable per-connection line assembler for nonblocking reads: the
/// reactor pushes whatever bytes arrived, then drains complete lines one
/// at a time. The 64 KiB [`MAX_LINE`] cap is enforced *incrementally* —
/// an endless unterminated stream errors out as soon as the buffer passes
/// the cap, it never grows memory waiting for a `\n` that isn't coming.
///
/// `scanned` remembers how far the newline scan got, so feeding N bytes
/// across many partial reads stays O(N) total, not O(N²).
#[derive(Debug, Default)]
pub struct LineBuffer {
    buf: Vec<u8>,
    scanned: usize,
}

impl LineBuffer {
    pub fn new() -> LineBuffer {
        LineBuffer::default()
    }

    /// Append freshly-read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (complete or partial).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Is a full `\n`-terminated line (or a cap overrun) ready to take?
    /// Cheap to call repeatedly: only unscanned bytes are examined, and
    /// `scanned` halts on the decision point so the state stays stable.
    pub fn has_line(&mut self) -> bool {
        while self.scanned < self.buf.len() {
            if self.buf[self.scanned] == b'\n' {
                return true;
            }
            if self.scanned >= MAX_LINE {
                // MAX_LINE+1 bytes and no newline: the *current* line has
                // overrun the cap (later pipelined lines don't matter).
                return true;
            }
            self.scanned += 1;
        }
        false
    }

    /// Take the next complete line, with the terminator (`\n` or `\r\n`)
    /// stripped. `Ok(None)` means "no full line yet — read more".
    /// `Err` means the connection is unrecoverable (cap overrun or
    /// non-UTF-8) and must be closed after the error is reported.
    pub fn next_line(&mut self) -> Result<Option<String>, String> {
        if !self.has_line() {
            return Ok(None);
        }
        // has_line stopped `scanned` either on the newline or on the
        // first byte past the cap.
        if self.buf[self.scanned] != b'\n' {
            return Err(format!("request line exceeds {MAX_LINE} bytes"));
        }
        let nl = self.scanned;
        let mut line: Vec<u8> = self.buf.drain(..=nl).collect();
        self.scanned = 0;
        line.pop(); // the \n
        if line.last() == Some(&b'\r') {
            line.pop();
        }
        match String::from_utf8(line) {
            Ok(s) => Ok(Some(s)),
            Err(_) => Err("request line is not valid UTF-8".to_string()),
        }
    }
}

/// Render a `(query, count)` batch as the canonical JSON answer document —
/// the format `mrss query` prints and the smoke jobs `diff`.
pub fn render_answers(answers: &[(String, u128)]) -> String {
    let mut out = String::from("[\n");
    for (i, (q, c)) in answers.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"query\":\"{}\",\"count\":{}}}{}\n",
            json_escape(q),
            c,
            if i + 1 == answers.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keywords_parse_case_insensitively() {
        assert_eq!(parse_request(" ping "), Request::Ping);
        assert_eq!(parse_request("STATS"), Request::Stats);
        assert_eq!(parse_request("shutdown"), Request::Shutdown);
        assert_eq!(parse_request("PONG x"), Request::Count("PONG x".into()));
    }

    #[test]
    fn bare_and_prefixed_queries_parse() {
        assert_eq!(parse_request("RA(P,S)=F"), Request::Count("RA(P,S)=F".into()));
        assert_eq!(parse_request("COUNT RA(P,S)=F"), Request::Count("RA(P,S)=F".into()));
        // COUNT lets a query spelled like a keyword through.
        assert_eq!(parse_request("count stats"), Request::Count("stats".into()));
    }

    #[test]
    fn observability_verbs_parse() {
        assert_eq!(parse_request("METRICS"), Request::Metrics);
        assert_eq!(parse_request(" metrics "), Request::Metrics);
        assert_eq!(parse_request("DUMP"), Request::Dump);
        assert_eq!(parse_request("dump"), Request::Dump);
        assert_eq!(
            parse_request("EXPLAIN RA(P,S)=F a=1"),
            Request::Explain("RA(P,S)=F a=1".into())
        );
        assert_eq!(parse_request("explain"), Request::Explain(String::new()));
        // A keyword with trailing text is a query, same as PING/STATS.
        assert_eq!(parse_request("METRICS x"), Request::Count("METRICS x".into()));
        assert_eq!(parse_request("DUMP x"), Request::Count("DUMP x".into()));
        // COUNT still escapes a query spelled like the new keywords.
        assert_eq!(parse_request("COUNT metrics"), Request::Count("metrics".into()));
    }

    #[test]
    fn top_and_history_parse_with_optional_numeric_args() {
        assert_eq!(parse_request("TOP"), Request::Top(None));
        assert_eq!(parse_request(" top "), Request::Top(None));
        assert_eq!(parse_request("TOP 5"), Request::Top(Some(5)));
        assert_eq!(parse_request("top 12"), Request::Top(Some(12)));
        assert_eq!(parse_request("HISTORY"), Request::History(None));
        assert_eq!(parse_request("history 30"), Request::History(Some(30)));
        // Non-numeric trailing text is a query, consistent with METRICS.
        assert_eq!(parse_request("TOP shelf"), Request::Count("TOP shelf".into()));
        assert_eq!(
            parse_request("HISTORY of(X)=1"),
            Request::Count("HISTORY of(X)=1".into())
        );
        // COUNT escapes a query spelled like the verbs.
        assert_eq!(parse_request("COUNT top"), Request::Count("top".into()));
    }

    #[test]
    fn profile_parses_with_optional_secs_and_renders_verbatim() {
        assert_eq!(parse_request("PROFILE"), Request::Profile(None));
        assert_eq!(parse_request(" profile "), Request::Profile(None));
        assert_eq!(parse_request("PROFILE 5"), Request::Profile(Some(5)));
        assert_eq!(parse_request("profile 2"), Request::Profile(Some(2)));
        // Non-numeric trailing text is a query, consistent with HISTORY.
        assert_eq!(
            parse_request("PROFILE it(X)=1"),
            Request::Count("PROFILE it(X)=1".into())
        );
        assert_eq!(parse_request("COUNT profile"), Request::Count("profile".into()));
        for json in [false, true] {
            let p = Response::Profile { json: "{\"secs\":2,\"folded\":[]}".into() };
            assert_eq!(p.render(json), "{\"secs\":2,\"folded\":[]}");
        }
    }

    #[test]
    fn top_and_history_responses_render_verbatim_in_both_modes() {
        for json in [false, true] {
            let t = Response::Top { json: "{\"entries\":0,\"by_count\":[]}".into() };
            assert_eq!(t.render(json), "{\"entries\":0,\"by_count\":[]}");
            let h = Response::History { json: "{\"slots\":0,\"series\":[]}".into() };
            assert_eq!(h.render(json), "{\"slots\":0,\"series\":[]}");
        }
    }

    #[test]
    fn observability_responses_render_verbatim_in_both_modes() {
        for json in [false, true] {
            let e = Response::Explain { json: "{\"count\":1,\"trace\":{}}".into() };
            assert_eq!(e.render(json), "{\"count\":1,\"trace\":{}}");
            let d = Response::Dump { json: "{\"last\":[]}".into() };
            assert_eq!(d.render(json), "{\"last\":[]}");
            let m = Response::Metrics { text: "# TYPE a counter\na 1\n# EOF\n".into() };
            let body = m.render(json);
            assert!(body.ends_with("# EOF"), "terminator must be the last line: {body:?}");
            assert!(!body.ends_with('\n'), "server appends the final newline");
        }
    }

    #[test]
    fn batch_splits_on_semicolons() {
        assert_eq!(
            parse_request("BATCH a=1 ; b=2;; c=3 "),
            Request::Batch(vec!["a=1".into(), "b=2".into(), "c=3".into()])
        );
        assert_eq!(parse_request("batch"), Request::Batch(vec![]));
    }

    #[test]
    fn responses_roundtrip_through_client_parse() {
        for json in [false, true] {
            let ok = Response::Count { query: "a=1".into(), count: 42 }.render(json);
            assert_eq!(parse_count_response(&ok), Ok(42));
            let err = Response::Error { query: "a=1".into(), msg: "no \"table\"".into() }
                .render(json);
            let e = parse_count_response(&err).unwrap_err();
            assert!(e.contains("table"), "{e}");
            let busy = Response::Busy { msg: "queue full".into() }.render(json);
            assert!(parse_count_response(&busy).is_err());
        }
        assert_eq!(Response::Pong.render(false), "PONG");
        assert_eq!(Response::Pong.render(true), "{\"pong\":true}");
        assert_eq!(Response::Bye.render(false), "BYE");
    }

    #[test]
    fn busy_detection_covers_both_wire_modes_and_nothing_else() {
        for json in [false, true] {
            let busy = Response::Busy { msg: "queue full".into() }.render(json);
            assert!(is_busy_response(&busy), "{busy}");
            let err = Response::Error { query: "a=1".into(), msg: "busy:true".into() }
                .render(json);
            assert!(!is_busy_response(&err), "{err}");
            let ok = Response::Count { query: "a=1".into(), count: 1 }.render(json);
            assert!(!is_busy_response(&ok), "{ok}");
        }
        assert!(is_busy_response("  BUSY shed\n"));
        assert!(!is_busy_response("{\"pong\":true}"));
    }

    #[test]
    fn json_field_extracts_numbers_and_strings() {
        let obj = "{\"query\":\"a \\\"b\\\"\",\"count\":17,\"qps\":1.5}";
        assert_eq!(json_field(obj, "count").as_deref(), Some("17"));
        assert_eq!(json_field(obj, "qps").as_deref(), Some("1.5"));
        assert_eq!(json_field(obj, "query").as_deref(), Some("a \"b\""));
        assert_eq!(json_field(obj, "absent"), None);
    }

    #[test]
    fn line_buffer_reassembles_split_lines() {
        let mut lb = LineBuffer::new();
        lb.push(b"PI");
        assert!(!lb.has_line());
        assert_eq!(lb.next_line(), Ok(None));
        lb.push(b"NG\nSTA");
        assert_eq!(lb.next_line(), Ok(Some("PING".to_string())));
        assert_eq!(lb.next_line(), Ok(None));
        lb.push(b"TS\r\n");
        assert_eq!(lb.next_line(), Ok(Some("STATS".to_string())));
        assert!(lb.is_empty());
    }

    #[test]
    fn line_buffer_drains_pipelined_lines_in_order() {
        let mut lb = LineBuffer::new();
        lb.push(b"a=1\nb=2\nc=3\n");
        assert_eq!(lb.next_line(), Ok(Some("a=1".to_string())));
        assert_eq!(lb.next_line(), Ok(Some("b=2".to_string())));
        assert_eq!(lb.next_line(), Ok(Some("c=3".to_string())));
        assert_eq!(lb.next_line(), Ok(None));
        assert_eq!(lb.len(), 0);
    }

    #[test]
    fn line_buffer_caps_unterminated_lines_incrementally() {
        let mut lb = LineBuffer::new();
        // Feed the overrun in chunks: the error fires once the cap is
        // passed, long before any newline.
        let chunk = vec![b'x'; 16 * 1024];
        for _ in 0..4 {
            lb.push(&chunk);
            assert_eq!(lb.next_line(), Ok(None));
        }
        lb.push(b"x"); // MAX_LINE + 1 bytes, still no newline
        assert!(lb.has_line());
        let err = lb.next_line().unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn line_buffer_accepts_a_line_exactly_at_the_cap() {
        let mut lb = LineBuffer::new();
        let mut line = vec![b'y'; MAX_LINE];
        line.push(b'\n');
        lb.push(&line);
        let got = lb.next_line().unwrap().unwrap();
        assert_eq!(got.len(), MAX_LINE);
    }

    #[test]
    fn line_buffer_total_may_exceed_cap_across_lines() {
        // Many pipelined small lines whose total passes MAX_LINE must all
        // parse: the cap is per line, not per buffer.
        let mut lb = LineBuffer::new();
        let n = MAX_LINE / 8 + 10;
        for _ in 0..n {
            lb.push(b"q=12345\n");
        }
        assert!(lb.len() > MAX_LINE);
        for _ in 0..n {
            assert_eq!(lb.next_line(), Ok(Some("q=12345".to_string())));
        }
        assert_eq!(lb.next_line(), Ok(None));
    }

    #[test]
    fn line_buffer_rejects_invalid_utf8() {
        let mut lb = LineBuffer::new();
        lb.push(&[0xff, 0xfe, b'\n']);
        let err = lb.next_line().unwrap_err();
        assert!(err.contains("UTF-8"), "{err}");
    }

    #[test]
    fn render_answers_matches_query_cli_shape() {
        let doc = render_answers(&[("a=1".into(), 3), ("b=2".into(), 0)]);
        assert!(doc.starts_with("[\n"));
        assert!(doc.contains("{\"query\":\"a=1\",\"count\":3},"));
        assert!(doc.ends_with("{\"query\":\"b=2\",\"count\":0}\n]\n"));
    }
}
