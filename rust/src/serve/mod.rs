//! `serve` — the concurrent network count-serving subsystem.
//!
//! The paper's thesis is that precomputed sufficient statistics make
//! multi-relational counts *cheap to query*; this module is where that
//! claim meets traffic. It turns the persisted ct-store
//! ([`crate::store`]) into a network service:
//!
//! * [`protocol`] — a line-delimited wire protocol: the `query` CLI
//!   grammar plus `BATCH` / `STATS` / `PING` / `SHUTDOWN`, with JSON or
//!   text responses;
//! * [`server`] — a dependency-free `std::net::TcpListener` front-end
//!   with a fixed worker pool, a bounded accept queue (full ⇒ `BUSY`),
//!   a per-connection request cap, and drain-clean shutdown — all workers
//!   sharing one concurrency-safe [`CountServer`](crate::store::CountServer)
//!   whose ADtree builds coalesce and whose tree bytes are charged to the
//!   store's `mem_bytes` budget;
//! * [`metrics`] — wait-free counters + a fixed-bucket latency histogram
//!   behind the `STATS` snapshot (qps, p50/p99, cache hit/miss/eviction,
//!   active connections), foldable into
//!   [`MjMetrics`](crate::mobius::MjMetrics);
//! * [`loadgen`] — the `bench-serve` client: N connections hammering the
//!   socket with a deterministic batch, emitting `BENCH_serve.json` and
//!   an answers document byte-comparable with `mrss query --fresh`.
//!
//! CLI: `mrss serve --store DIR --listen ADDR` starts the server;
//! `mrss bench-serve` drives it (or self-hosts one on an ephemeral port).

pub mod loadgen;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use metrics::{LatencyHistogram, ServeMetrics, ServeSnapshot};
pub use protocol::{parse_request, Request, Response};
pub use server::{serve, ServeConfig, ServeHandle};
