//! `serve` — the concurrent network count-serving subsystem.
//!
//! The paper's thesis is that precomputed sufficient statistics make
//! multi-relational counts *cheap to query*; this module is where that
//! claim meets traffic. It turns the persisted ct-store
//! ([`crate::store`]) into a network service:
//!
//! * [`protocol`] — a line-delimited wire protocol: the `query` CLI
//!   grammar plus `BATCH` / `STATS` / `PING` / `SHUTDOWN` and the
//!   observability verbs `EXPLAIN` / `METRICS` / `DUMP` / `TOP` /
//!   `HISTORY`, with JSON or
//!   text responses, and the resumable [`LineBuffer`](protocol::LineBuffer)
//!   the nonblocking server parses through;
//! * [`reactor`] — dependency-free readiness polling: raw-syscall
//!   `poll(2)` / `epoll(7)` backends, the `eventfd`/pipe wake primitive,
//!   and the `RLIMIT_NOFILE` probe — no external crates, same discipline
//!   as the rest of the tree;
//! * [`server`] — sharded reactor threads each running an event loop of
//!   nonblocking connection state machines (idle connections cost an fd,
//!   not a thread), with CPU-bound query execution handed to a fixed
//!   worker pool and `BATCH` members fanned out concurrently across it —
//!   replies stitched back in order, byte-identical to serial execution.
//!   All workers share one concurrency-safe
//!   [`CountServer`](crate::store::CountServer) whose ADtree builds
//!   coalesce and whose tree bytes are charged to the store's
//!   `mem_bytes` budget;
//! * [`metrics`] — wait-free counters + fixed-bucket histograms behind
//!   the `STATS` snapshot (qps, p50/p99, reactor gauges, connection
//!   distribution, batch fan-out peak), foldable into
//!   [`MjMetrics`](crate::mobius::MjMetrics);
//! * [`loadgen`] — the `bench-serve` client: N connections hammering the
//!   socket with a deterministic batch (uniform or `zipf:<s>`-skewed),
//!   an optional idle-connection pool (`--idle`), `BUSY`-aware retries
//!   with capped seeded backoff, emitting `BENCH_serve.json` and — in
//!   uniform mode — an answers document byte-comparable with
//!   `mrss query --fresh`.
//!
//! The serving stack is built to stay up under faults: worker panics are
//! caught and answered as terminal errors (the pool survives),
//! `--idle-timeout` / `--request-timeout` arm per-shard deadline heaps
//! that expire slow-loris connections and over-budget queries, and the
//! store underneath quarantines damaged tables and degrades via Möbius
//! derivation (see [`crate::store`]). All of it is driven in tests by the
//! [`crate::util::failpoint`] harness (`--features failpoints`).
//!
//! Observability lives in [`crate::obs`]: `--trace-sample N` span-traces
//! every Nth request (flight recorder + optional `--access-log`),
//! `EXPLAIN <query>` traces one query on demand (with its full
//! [`QueryCost`](crate::obs::QueryCost) block), `METRICS` exposes every
//! counter here in Prometheus text format, `TOP [k]` ranks heavy-hitter
//! plan signatures from an O(k) Misra-Gries sketch, and `HISTORY [secs]`
//! returns the per-second aggregation ring as a JSON series.
//!
//! CLI: `mrss serve --store DIR --listen ADDR` starts the server;
//! `mrss bench-serve` drives it (or self-hosts one on an ephemeral port).

pub mod loadgen;
pub mod metrics;
pub mod protocol;
pub mod reactor;
pub mod server;

pub use loadgen::{LoadgenConfig, LoadgenReport, Mix};
pub use metrics::{LatencyHistogram, ServeMetrics, ServeSnapshot};
pub use protocol::{parse_request, LineBuffer, Request, Response};
pub use reactor::{max_open_files, Poller, PollerKind, WakeFd};
pub use server::{serve, ServeConfig, ServeHandle};
