//! The TCP serving front-end: a dependency-free `std::net` server with a
//! fixed worker thread pool, bounded admission, and clean shutdown.
//!
//! ## Architecture
//!
//! ```text
//! acceptor thread ──try_send──▶ bounded queue ──recv──▶ N worker threads
//!      │                            (full ⇒ BUSY + close)     │
//!      └── woken by a self-connect on SHUTDOWN                └── shared
//!                                                         Arc<CountServer>
//! ```
//!
//! * One acceptor owns the listener; connections enter a bounded
//!   `sync_channel` queue. A full queue answers `BUSY` immediately and
//!   closes — load is shed at the door instead of growing an unbounded
//!   backlog (the admission-control half of the ROADMAP item).
//! * `threads` workers pop connections and speak the line protocol
//!   ([`super::protocol`]). Each connection is capped at `max_requests`
//!   queries, after which it gets `BUSY` and is closed — one chatty client
//!   cannot monopolize a worker forever.
//! * All workers share one [`CountServer`]: ADtree builds coalesce behind
//!   its per-table latch and tree bytes are charged to the store's
//!   `mem_bytes` budget, so concurrency never duplicates work or memory.
//! * `SHUTDOWN` (or [`ServeHandle::request_shutdown`]) latches a flag,
//!   wakes the acceptor with a self-connect, drops the queue sender, and
//!   lets the workers drain: in-flight connections finish, the process
//!   exits cleanly.
//!
//! Readers poll with a 100 ms read timeout so idle keep-alive connections
//! notice the shutdown flag instead of pinning a worker forever.

use crate::store::CountServer;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{parse_request, Request, Response, MAX_LINE};

use std::sync::atomic::Ordering::Relaxed;

/// Tuning knobs of one serving front-end.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7171` (`:0` picks an ephemeral port,
    /// reported by [`ServeHandle::addr`]).
    pub addr: String,
    /// Worker thread pool size.
    pub threads: usize,
    /// Bounded accept-queue depth; a connection arriving with the queue
    /// full is answered `BUSY` and closed.
    pub queue_depth: usize,
    /// Per-connection request cap (each `BATCH` member counts).
    pub max_requests: usize,
    /// Wire mode: JSON object lines (default) or compact text.
    pub json: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            queue_depth: 64,
            max_requests: 100_000,
            json: true,
        }
    }
}

struct Shared {
    count: Arc<CountServer>,
    metrics: ServeMetrics,
    cfg: ServeConfig,
    addr: SocketAddr,
    shutdown: AtomicBool,
}

impl Shared {
    fn snapshot(&self) -> ServeSnapshot {
        self.metrics.snapshot(self.count.stats(), self.count.tree_stats())
    }

    /// Latch the shutdown flag and wake the acceptor out of `accept()`.
    fn initiate_shutdown(&self) {
        if !self.shutdown.swap(true, SeqCst) {
            // The wake connection is consumed (and discarded) by the
            // acceptor itself once it sees the flag. A wildcard bind
            // (0.0.0.0 / [::]) is not a connectable destination — wake
            // through loopback on the bound port instead.
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        }
    }
}

/// A running server. Dropping the handle does *not* stop the server; call
/// [`ServeHandle::request_shutdown`] / send `SHUTDOWN`, then
/// [`ServeHandle::wait`].
pub struct ServeHandle {
    shared: Arc<Shared>,
    acceptor: JoinHandle<()>,
}

impl ServeHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Live metrics snapshot (same data as the `STATS` wire command).
    pub fn snapshot(&self) -> ServeSnapshot {
        self.shared.snapshot()
    }

    /// Ask the server to stop; returns immediately. In-flight connections
    /// drain before [`ServeHandle::wait`] returns.
    pub fn request_shutdown(&self) {
        self.shared.initiate_shutdown();
    }

    /// Block until the server has fully stopped (acceptor and all workers
    /// joined); returns the final metrics snapshot.
    pub fn wait(self) -> ServeSnapshot {
        let _ = self.acceptor.join();
        self.shared.snapshot()
    }
}

/// Bind and start serving `count` on `cfg.addr`. Returns once the listener
/// is bound and the worker pool is up — queries can be sent the moment
/// this returns.
pub fn serve(count: Arc<CountServer>, cfg: ServeConfig) -> Result<ServeHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding count server to {}", cfg.addr))?;
    let addr = listener.local_addr().context("resolving bound address")?;
    let threads = cfg.threads.max(1);
    let queue_depth = cfg.queue_depth.max(1);
    let shared = Arc::new(Shared {
        count,
        metrics: ServeMetrics::default(),
        cfg,
        addr,
        shutdown: AtomicBool::new(false),
    });

    let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(queue_depth);
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(threads);
    for i in 0..threads {
        let shared = Arc::clone(&shared);
        let rx = Arc::clone(&rx);
        workers.push(
            std::thread::Builder::new()
                .name(format!("mrss-serve-{i}"))
                .spawn(move || worker_loop(&shared, &rx))
                .context("spawning worker thread")?,
        );
    }

    let acceptor = {
        let shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("mrss-serve-accept".to_string())
            .spawn(move || accept_loop(&shared, listener, tx, workers))
            .context("spawning acceptor thread")?
    };
    Ok(ServeHandle { shared, acceptor })
}

fn accept_loop(
    shared: &Shared,
    listener: TcpListener,
    tx: SyncSender<TcpStream>,
    workers: Vec<JoinHandle<()>>,
) {
    for stream in listener.incoming() {
        if shared.shutdown.load(SeqCst) {
            // `stream` is (usually) the self-connect wake; discard it.
            break;
        }
        let Ok(stream) = stream else { continue };
        match tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(stream)) => {
                // Admission control: shed at the door with a clean answer.
                // The write is bounded so a non-reading client can never
                // stall the acceptor itself.
                shared.metrics.busy_rejects.fetch_add(1, Relaxed);
                let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
                let mut w = BufWriter::new(stream);
                let busy = Response::Busy { msg: "accept queue full, retry later".to_string() };
                let _ = writeln!(w, "{}", busy.render(shared.cfg.json));
                let _ = w.flush();
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    // Close the queue: workers finish whatever is buffered, then exit.
    drop(tx);
    drop(listener);
    for w in workers {
        let _ = w.join();
    }
}

fn worker_loop(shared: &Shared, rx: &Mutex<Receiver<TcpStream>>) {
    loop {
        // Hold the receiver lock only for the pop, not while serving.
        let next = rx.lock().unwrap().recv();
        let Ok(stream) = next else { return };
        shared.metrics.connections.fetch_add(1, Relaxed);
        shared.metrics.active.fetch_add(1, Relaxed);
        serve_conn(shared, stream);
        shared.metrics.active.fetch_sub(1, Relaxed);
    }
}

/// Speak the line protocol on one connection until EOF, error, cap, or
/// shutdown. All IO errors just end the connection — the client is gone.
fn serve_conn(shared: &Shared, stream: TcpStream) {
    let json = shared.cfg.json;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    // A client that stops reading must not pin this worker forever: once
    // the kernel send buffer fills, the blocked write errors out after the
    // timeout and the connection is dropped (any write error ends it).
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut writer = BufWriter::new(write_half);
    let mut line = String::new();
    let mut served = 0usize;

    loop {
        line.clear();
        // Poll-read so an idle connection notices shutdown: on timeout any
        // partial bytes stay appended to `line` and the next pass resumes
        // the same request. Every read is clamped by `take`, so the cap
        // check runs even against a client streaming an endless
        // unterminated line at full speed — `line` can never outgrow
        // `MAX_LINE` by more than one clamp.
        let eof = loop {
            if line.len() > MAX_LINE {
                let resp = Response::Error {
                    query: String::new(),
                    msg: format!("request line exceeds {MAX_LINE} bytes"),
                };
                let _ = writeln!(writer, "{}", resp.render(json));
                let _ = writer.flush();
                return;
            }
            let clamp = (MAX_LINE + 2 - line.len()) as u64;
            match (&mut reader).take(clamp).read_line(&mut line) {
                Ok(0) => break true, // EOF (clamp is ≥ 2 here, so not the limit)
                Ok(_) if line.ends_with('\n') => break false,
                Ok(_) => continue, // clamp hit mid-line; the cap check fires next
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e)
                    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
                        && !shared.shutdown.load(SeqCst) =>
                {
                    continue;
                }
                Err(_) => return,
            }
        };
        if eof {
            return;
        }
        if line.trim().is_empty() {
            continue;
        }

        let responses: Vec<Response> = match parse_request(&line) {
            Request::Ping => vec![Response::Pong],
            Request::Stats => vec![Response::Stats { json: shared.snapshot().to_json() }],
            Request::Shutdown => {
                let _ = writeln!(writer, "{}", Response::Bye.render(json));
                let _ = writer.flush();
                shared.initiate_shutdown();
                return;
            }
            Request::Count(q) => vec![answer_one(shared, &mut served, q)],
            Request::Batch(qs) if qs.is_empty() => vec![Response::Error {
                query: String::new(),
                msg: "empty BATCH (want `BATCH q1 ; q2 ; …`)".to_string(),
            }],
            Request::Batch(qs) => {
                qs.into_iter().map(|q| answer_one(shared, &mut served, q)).collect()
            }
        };
        for resp in &responses {
            if writeln!(writer, "{}", resp.render(json)).is_err() {
                return;
            }
        }
        if writer.flush().is_err() {
            return;
        }
        if served >= shared.cfg.max_requests {
            let busy = Response::Busy {
                msg: format!(
                    "per-connection request cap ({}) reached, reconnect",
                    shared.cfg.max_requests
                ),
            };
            let _ = writeln!(writer, "{}", busy.render(json));
            let _ = writer.flush();
            shared.metrics.busy_rejects.fetch_add(1, Relaxed);
            return;
        }
    }
}

/// Answer one counted query, with latency recorded bucket-exact.
fn answer_one(shared: &Shared, served: &mut usize, query: String) -> Response {
    *served += 1;
    shared.metrics.queries.fetch_add(1, Relaxed);
    let t0 = Instant::now();
    let out = shared.count.count_query(&query);
    shared.metrics.latency.record(t0.elapsed());
    match out {
        Ok(count) => Response::Count { query, count },
        Err(e) => {
            shared.metrics.errors.fetch_add(1, Relaxed);
            Response::Error { query, msg: e.to_string() }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::mobius::MobiusJoin;
    use crate::store::{CtStore, PersistConfig, StoreSink};
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mrss_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn start_uwcse(tag: &str, cfg: ServeConfig) -> (PathBuf, ServeHandle) {
        let dir = tmpdir(tag);
        let db = datagen::generate("uwcse", 0.1, 7).unwrap();
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        {
            let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
            MobiusJoin::new(&db).sink(&sink).run();
            sink.take_error().unwrap();
        }
        drop(store);
        let count = Arc::new(crate::store::CountServer::open(&dir).unwrap());
        let handle = serve(count, cfg).unwrap();
        (dir, handle)
    }

    fn roundtrip(addr: SocketAddr, lines: &[&str]) -> Vec<String> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(w, "{l}").unwrap();
            w.flush().unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            out.push(resp.trim().to_string());
        }
        out
    }

    #[test]
    fn ping_stats_count_and_shutdown_roundtrip() {
        let (dir, handle) = start_uwcse("basic", ServeConfig::default());
        let addr = handle.addr();
        let out = roundtrip(addr, &["PING", "position(P1)=faculty", "STATS"]);
        assert_eq!(out[0], "{\"pong\":true}");
        assert!(out[1].contains("\"count\":"), "{}", out[1]);
        assert!(out[2].contains("\"qps\":"), "{}", out[2]);
        // Bad query answers an error line but keeps the connection usable.
        let out = roundtrip(addr, &["nope(X)=1", "PING"]);
        assert!(out[0].contains("\"error\":"), "{}", out[0]);
        assert_eq!(out[1], "{\"pong\":true}");
        let out = roundtrip(addr, &["SHUTDOWN"]);
        assert_eq!(out[0], "{\"bye\":true}");
        let snap = handle.wait();
        assert!(snap.queries >= 2);
        assert_eq!(snap.active, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_answers_one_line_per_query_in_order() {
        let (dir, handle) = start_uwcse("batch", ServeConfig::default());
        let stream = TcpStream::connect(handle.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "BATCH position(P1)=faculty ; nope=1 ; student(P1)=yes").unwrap();
        w.flush().unwrap();
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            lines.push(l);
        }
        assert!(lines[0].contains("position(P1)=faculty"));
        assert!(lines[0].contains("\"count\":"));
        assert!(lines[1].contains("\"error\":"));
        assert!(lines[2].contains("student(P1)=yes"));
        handle.request_shutdown();
        handle.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn text_wire_mode_and_request_cap() {
        let cfg = ServeConfig { json: false, max_requests: 2, ..Default::default() };
        let (dir, handle) = start_uwcse("cap", cfg);
        let stream = TcpStream::connect(handle.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        for _ in 0..2 {
            writeln!(w, "position(P1)=faculty").unwrap();
        }
        w.flush().unwrap();
        let mut lines = Vec::new();
        // 2 answers, then the cap's BUSY, then EOF.
        for _ in 0..3 {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            lines.push(l.trim().to_string());
        }
        assert!(lines[0].starts_with("COUNT "), "{lines:?}");
        assert!(lines[1].starts_with("COUNT "), "{lines:?}");
        assert!(lines[2].starts_with("BUSY "), "{lines:?}");
        let mut l = String::new();
        assert_eq!(r.read_line(&mut l).unwrap(), 0, "server must close after BUSY");
        assert!(handle.snapshot().busy_rejects >= 1);
        handle.request_shutdown();
        handle.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_shutdown_unblocks_an_idle_server() {
        let (dir, handle) = start_uwcse("idle", ServeConfig::default());
        // One idle connected client must not block the drain.
        let _idle = TcpStream::connect(handle.addr()).unwrap();
        handle.request_shutdown();
        let snap = handle.wait(); // must return despite the idle client
        assert_eq!(snap.active, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
