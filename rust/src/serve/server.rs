//! The TCP serving front-end: a dependency-free readiness event loop with
//! sharded accept, nonblocking connection state machines, and CPU-bound
//! query execution handed to a fixed worker pool.
//!
//! ## Architecture
//!
//! ```text
//!            ┌── shard 0: poller { listener, wake fd, conns… } ──┐
//! listener ──┤                                                   ├─ jobs ─▶ bounded
//!  (shared   └── shard S: poller { listener, wake fd, conns… } ──┘  queue ──▶ N workers
//!   dup'd                ▲                                                      │
//!   fds)                 └───────────── completions + wake ◀────────────────────┘
//! ```
//!
//! * `shards` reactor threads each own a [`Poller`] (`epoll` on Linux,
//!   `poll` elsewhere — see [`super::reactor`]), a clone of the listener
//!   (the kernel load-balances `accept` across them), and the state
//!   machines of the connections they accepted. Idle connections cost one
//!   registered fd, not a parked thread — connections ≫ threads.
//! * Each connection is a small state machine: nonblocking reads append to
//!   a resumable [`LineBuffer`] (64 KiB per-line cap enforced
//!   incrementally), parsed requests dispatch to the worker pool, replies
//!   queue into an output buffer flushed under write-readiness. While a
//!   request executes the connection's read interest is dropped, so a
//!   pipelining client is backpressured by TCP instead of a server buffer.
//! * `BATCH` fans out: every member becomes its own pool job, executing
//!   concurrently across workers; replies are stitched back **in member
//!   order** before a byte is written, so answers stay byte-identical to
//!   serial execution.
//! * Workers push completions onto the owning shard's mailbox and wake its
//!   poller through an `eventfd`/pipe ([`WakeFd`]) — the same primitive
//!   that replaced the old SHUTDOWN self-connect hack.
//! * Admission control is two-tier: `max_conns` sheds at accept time
//!   (`BUSY` + close), a full execution queue sheds at read time (`BUSY`,
//!   connection stays open). `max_requests` caps one connection's lifetime
//!   queries (`BUSY` + close), so a chatty client cannot monopolize the
//!   pool forever.
//! * Shutdown latches a flag and wakes every shard: listeners deregister,
//!   idle connections close, in-flight queries drain (bounded by a grace
//!   period), and [`ServeHandle::wait`] asserts the drain left
//!   `active == 0`.
//! * Observability rides the same paths: the reactor stamps parse and
//!   enqueue times on each job; workers trace sampled requests
//!   ([`crate::obs::trace`]), publish finished traces to the flight
//!   recorder behind `DUMP`, and answer `EXPLAIN` with the span tree.
//!   `METRICS` renders every counter here in Prometheus text format.

use crate::obs::history::{HistoryRing, Slot};
use crate::obs::sketch::TopSketch;
use crate::obs::{cost, proc, profile, recorder, trace};
use crate::store::CountServer;
use crate::util::error::{Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::atomic::{AtomicBool, AtomicU64};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{json_escape, parse_request, LineBuffer, Request, Response};
use super::reactor::{fd_of, Event, Interest, Poller, PollerKind, WakeFd};

/// Poller token of the shard's listener clone.
const TOKEN_LISTENER: usize = usize::MAX;
/// Poller token of the shard's wake fd.
const TOKEN_WAKE: usize = usize::MAX - 1;
/// How many connections one readiness event will accept before yielding
/// back to the event loop (fairness under an accept storm).
const ACCEPT_BURST: usize = 64;
/// How long shutdown waits for in-flight queries / unflushed replies
/// before force-closing what remains.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(5);

/// Tuning knobs of one serving front-end.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7171` (`:0` picks an ephemeral port,
    /// reported by [`ServeHandle::addr`]).
    pub addr: String,
    /// Worker thread pool size (CPU-bound query execution).
    pub threads: usize,
    /// Reactor shard count (acceptor/event-loop threads).
    pub shards: usize,
    /// Bounded execution-queue depth; a request arriving with the queue
    /// full is answered `BUSY` (the connection stays open).
    pub queue_depth: usize,
    /// Connection limit across all shards; past it, new connections are
    /// answered `BUSY` at accept time and closed.
    pub max_conns: usize,
    /// Per-connection request cap (each `BATCH` member counts).
    pub max_requests: usize,
    /// Wire mode: JSON object lines (default) or compact text.
    pub json: bool,
    /// Readiness backend (`epoll` on Linux by default, `poll` elsewhere).
    pub poller: PollerKind,
    /// Close connections that have not completed a request (or sit parked
    /// on a partial line) for this long. `None` = never. Counted in
    /// `conn_timeouts`.
    pub idle_timeout: Option<Duration>,
    /// Abandon in-flight requests executing longer than this: the client
    /// gets `ERR deadline exceeded`, the late completion is discarded by
    /// the conn-id guard. `None` = never. Counted in `request_timeouts`.
    pub request_timeout: Option<Duration>,
    /// Test hook: workers sleep this long before executing each query so
    /// fan-out concurrency is observable deterministically. Zero (and
    /// meant to stay zero) in production.
    pub exec_delay: Duration,
    /// Trace every `N`th request (1 = all, 0 = off). Sampled requests
    /// record a full span trace — flight recorder, access log — while
    /// unsampled ones pay one relaxed counter bump and a relaxed load
    /// per span site (the overhead gate in CI holds this). `EXPLAIN`
    /// always traces its own query regardless of this setting.
    pub trace_sample: u64,
    /// Append one JSON line per *sampled* request to this file (wide
    /// events: conn id, query, queue-wait vs exec split, bytes,
    /// outcome). `None` = off; needs `trace_sample > 0` to emit.
    pub access_log: Option<String>,
    /// Sampling-profiler frequency in Hz behind the `PROFILE` verb.
    /// `0` disables the sampler thread entirely: worker/shard threads
    /// then register no publish slots and every span site's frame push
    /// short-circuits on one thread-local check (the overhead A/B gate
    /// in CI compares exactly this against the default).
    pub profile_hz: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            shards: 2,
            queue_depth: 64,
            max_conns: 16_384,
            max_requests: 100_000,
            json: true,
            poller: PollerKind::os_default(),
            idle_timeout: None,
            request_timeout: None,
            exec_delay: Duration::ZERO,
            trace_sample: 0,
            access_log: None,
            profile_hz: 99,
        }
    }
}

// ---------------------------------------------------------------------------
// execution handoff: shard → worker pool → shard
// ---------------------------------------------------------------------------

/// One query headed for the worker pool, tagged with enough provenance to
/// route its completion back to the right connection.
struct Job {
    shard: usize,
    slot: usize,
    conn_id: u64,
    member: usize,
    batch: usize,
    query: String,
    /// `EXPLAIN`: answer with the span trace instead of a bare count.
    explain: bool,
    /// When the reactor submitted the job — queue wait is measured from
    /// here to worker pickup, split from exec time in STATS/METRICS.
    enqueued: Instant,
    /// Wire-parse time measured reactor-side (0 unless sampling is on),
    /// injected into the trace as the `parse` span.
    parse_us: u64,
}

/// A finished query on its way back to the owning shard.
struct Completion {
    slot: usize,
    conn_id: u64,
    member: usize,
    resp: Response,
}

struct ExecState {
    q: VecDeque<Job>,
    closed: bool,
}

/// The bounded work queue between reactors and the worker pool.
///
/// Submission is all-or-nothing per request: a `BATCH` either gets every
/// member enqueued or none, so the queue can overshoot `threshold` by at
/// most one batch — but a large batch can never be half-started or
/// starved by the depth limit.
struct Executor {
    st: Mutex<ExecState>,
    cv: Condvar,
    threshold: usize,
}

impl Executor {
    fn new(threshold: usize) -> Executor {
        Executor {
            st: Mutex::new(ExecState { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            threshold,
        }
    }

    /// Enqueue all `jobs`, or none if the queue is at depth (or closed).
    fn try_submit(&self, jobs: Vec<Job>) -> bool {
        let n = jobs.len();
        {
            let mut st = self.st.lock().unwrap();
            if st.closed || st.q.len() >= self.threshold {
                return false;
            }
            st.q.extend(jobs);
        }
        if n == 1 {
            self.cv.notify_one();
        } else {
            self.cv.notify_all();
        }
        true
    }

    /// Block for the next job; `None` once closed and drained.
    fn pop(&self) -> Option<Job> {
        let mut st = self.st.lock().unwrap();
        loop {
            if let Some(j) = st.q.pop_front() {
                return Some(j);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.st.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Jobs currently queued (the `HISTORY` queue-depth gauge).
    fn len(&self) -> usize {
        self.st.lock().unwrap().q.len()
    }
}

/// Per-shard mailbox: workers push completions here and wake the poller.
struct ShardShared {
    completions: Mutex<Vec<Completion>>,
    wake: WakeFd,
}

struct Shared {
    count: Arc<CountServer>,
    metrics: ServeMetrics,
    cfg: ServeConfig,
    addr: SocketAddr,
    shutdown: AtomicBool,
    exec: Executor,
    shards: Vec<Arc<ShardShared>>,
    /// Round-robin counter behind `--trace-sample N`: job `i` is traced
    /// when `i % N == 0`.
    trace_tick: AtomicU64,
    /// Open `--access-log` file; workers append whole lines under the
    /// lock so concurrent sampled requests never interleave bytes.
    access_log: Option<Mutex<std::fs::File>>,
    /// Heavy-hitter summary over plan signatures: workers feed it one
    /// observation per answered count query, `TOP` and `DUMP` read it.
    /// O(capacity) memory regardless of distinct query shapes.
    top: Mutex<TopSketch>,
    /// Per-second metrics ring behind `HISTORY`, flushed by shard 0's
    /// once-a-second tick.
    history: Mutex<HistoryRing>,
}

impl Shared {
    fn snapshot(&self) -> ServeSnapshot {
        self.metrics.snapshot(
            self.count.stats(),
            self.count.tree_stats(),
            &self.count.store().dataset,
        )
    }

    /// The `METRICS` response body: every serving/store/tree/mj counter
    /// in Prometheus text exposition format.
    fn metrics_text(&self) -> String {
        let snap = self.snapshot();
        let mut mj = crate::mobius::MjMetrics::default();
        snap.merge_into(&mut mj);
        crate::obs::prom::render(&self.metrics, &snap, &mj)
    }

    /// Latch the shutdown flag and wake every shard out of its wait.
    fn initiate_shutdown(&self) {
        if !self.shutdown.swap(true, SeqCst) {
            for s in &self.shards {
                s.wake.wake();
            }
        }
    }
}

/// A running server. Dropping the handle does *not* stop the server; call
/// [`ServeHandle::request_shutdown`] / send `SHUTDOWN`, then
/// [`ServeHandle::wait`].
pub struct ServeHandle {
    shared: Arc<Shared>,
    shards: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// The span-stack sampler thread (`None` with `--profile-hz 0`).
    sampler: Option<profile::Sampler>,
}

impl ServeHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Live metrics snapshot (same data as the `STATS` wire command).
    pub fn snapshot(&self) -> ServeSnapshot {
        self.shared.snapshot()
    }

    /// Ask the server to stop; returns immediately. In-flight connections
    /// drain before [`ServeHandle::wait`] returns.
    pub fn request_shutdown(&self) {
        self.shared.initiate_shutdown();
    }

    /// Block until the server has fully stopped (shards and workers
    /// joined); returns the final metrics snapshot.
    ///
    /// Shards only exit after every connection they own is closed, so the
    /// drain-clean invariant is asserted here rather than hoped for.
    pub fn wait(self) -> ServeSnapshot {
        for s in self.shards {
            let _ = s.join();
        }
        // Shards are gone, nothing can submit: release the worker pool.
        self.shared.exec.close();
        for w in self.workers {
            let _ = w.join();
        }
        // Stop the sampler after every publisher is gone, so the final
        // snapshot below carries the complete CPU split.
        if let Some(mut s) = self.sampler {
            s.stop();
        }
        let snap = self.shared.snapshot();
        assert_eq!(snap.active, 0, "shutdown drain must close every connection");
        snap
    }
}

/// Bind and start serving `count` on `cfg.addr`. Returns once the listener
/// is bound and all shard/worker threads are up — queries can be sent the
/// moment this returns.
pub fn serve(count: Arc<CountServer>, cfg: ServeConfig) -> Result<ServeHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding count server to {}", cfg.addr))?;
    // One nonblocking flag covers every shard clone: `try_clone` dups the
    // fd, and O_NONBLOCK lives on the shared open file description.
    listener.set_nonblocking(true).context("setting listener nonblocking")?;
    let addr = listener.local_addr().context("resolving bound address")?;
    let threads = cfg.threads.max(1);
    let n_shards = cfg.shards.max(1);
    let queue_depth = cfg.queue_depth.max(1);
    let kind = cfg.poller;
    // Open the access log up front so a bad path fails `serve()` rather
    // than the first sampled request.
    let access_log = match &cfg.access_log {
        Some(p) => Some(Mutex::new(
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(p)
                .with_context(|| format!("opening access log {p}"))?,
        )),
        None => None,
    };

    // Build every shard's poller before spawning anything, so setup
    // errors (no epoll, fd limits) surface as a clean `Err` from here.
    let mut mailboxes = Vec::with_capacity(n_shards);
    let mut parts = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let ss = Arc::new(ShardShared {
            completions: Mutex::new(Vec::new()),
            wake: WakeFd::new()?,
        });
        let lst = listener.try_clone().context("cloning listener for shard")?;
        let mut poller = Poller::new(kind)?;
        poller.register(fd_of(&lst), TOKEN_LISTENER, Interest::READ)?;
        poller.register(ss.wake.fd(), TOKEN_WAKE, Interest::READ)?;
        mailboxes.push(Arc::clone(&ss));
        parts.push((poller, ss, lst));
    }
    drop(listener); // shards own their clones

    let shared = Arc::new(Shared {
        count,
        metrics: ServeMetrics::default(),
        cfg,
        addr,
        shutdown: AtomicBool::new(false),
        exec: Executor::new(queue_depth),
        shards: mailboxes,
        trace_tick: AtomicU64::new(0),
        access_log,
        top: Mutex::new(TopSketch::new(64)),
        history: Mutex::new(HistoryRing::default()),
    });

    // Start the sampler *before* any worker or shard spawns: thread
    // registration claims a publish slot only while a sampler is active,
    // so ordering decides whether span stacks are observable at all.
    let sampler = profile::start(shared.cfg.profile_hz);

    let mut workers = Vec::with_capacity(threads);
    for i in 0..threads {
        let shared = Arc::clone(&shared);
        workers.push(
            std::thread::Builder::new()
                .name(format!("mrss-serve-worker-{i}"))
                .spawn(move || worker_loop(&shared))
                .context("spawning worker thread")?,
        );
    }
    let mut shards = Vec::with_capacity(n_shards);
    for (idx, (poller, ss, lst)) in parts.into_iter().enumerate() {
        let shared = Arc::clone(&shared);
        shards.push(
            std::thread::Builder::new()
                .name(format!("mrss-serve-shard-{idx}"))
                .spawn(move || ShardCtx::new(shared, ss, idx, poller).run(lst))
                .context("spawning shard thread")?,
        );
    }
    Ok(ServeHandle { shared, shards, workers, sampler })
}

/// One worker: pop jobs, count, push the completion back to the owning
/// shard and wake it. `BATCH` members arrive as independent jobs, so a
/// multi-member batch really does execute concurrently across the pool —
/// `batch_peak` in STATS records the high-water mark.
fn worker_loop(shared: &Shared) {
    // Arms this thread's CPU clock and — when `serve()` started a
    // sampler — claims a span-stack publish slot for the profiler.
    let _reg = profile::register(profile::Role::Worker);
    while let Some(job) = shared.exec.pop() {
        // Time blocked on the queue since the last boundary ⇒ idle.
        profile::note_cpu();
        let Job { shard, slot, conn_id, member, batch, query, explain, enqueued, parse_us } = job;
        // Root profiler frame: every stack this worker publishes while
        // executing hangs under `serve.exec` in the folded output.
        let _exec_span = trace::span("serve.exec");
        let queue_wait = enqueued.elapsed();
        shared.metrics.queue_wait.record(queue_wait);
        let fanout = batch > 1;
        if fanout {
            let cur = shared.metrics.batch_inflight.fetch_add(1, Relaxed) + 1;
            shared.metrics.batch_peak.fetch_max(cur, Relaxed);
        }
        // Both injected stalls publish a profiler frame, so tests (and a
        // profile taken against a degraded server) see the stall as the
        // hot leaf rather than an anonymous gap under `serve.exec`.
        if !shared.cfg.exec_delay.is_zero() {
            let _sp = trace::span("worker.exec.delay");
            std::thread::sleep(shared.cfg.exec_delay);
        }
        if let Some(ms) = crate::util::failpoint::fire_arg("worker.exec.delay") {
            let _sp = trace::span("worker.exec.delay");
            std::thread::sleep(Duration::from_millis(ms));
        }
        // Sampling decision: `EXPLAIN` always traces its own query; with
        // `--trace-sample N` every Nth job across the pool does too. An
        // unsampled request pays this one relaxed fetch_add here and a
        // relaxed load per span site — the overhead the CI gate holds.
        let sample = shared.cfg.trace_sample;
        let traced =
            explain || (sample > 0 && shared.trace_tick.fetch_add(1, Relaxed) % sample == 0);
        if traced {
            trace::begin(&query);
            trace::event_us("parse", parse_us);
        }
        // `EXPLAIN` is an admin verb: it runs its query for the trace but
        // stays out of `queries`/qps and the latency histograms so the
        // traffic metrics describe real count load only.
        if explain {
            shared.metrics.admin_requests.fetch_add(1, Relaxed);
        } else {
            shared.metrics.queries.fetch_add(1, Relaxed);
        }
        // Arm per-query cost accounting: the planner/store/ADtree taps
        // accumulate into this thread's slot while the count executes.
        cost::begin();
        let t0 = Instant::now();
        // Panic isolation: a panicking count (bug or the armed
        // `worker.exec.panic` failpoint) must neither kill this worker nor
        // strand the connection in `Executing` — it becomes an ERR
        // completion like any other failed query.
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if crate::util::failpoint::fire("worker.exec.panic") {
                panic!("injected panic (failpoint worker.exec.panic)");
            }
            shared.count.count_query(&query)
        }));
        let exec = t0.elapsed();
        // Harvest the cost even on panic (take() also clears the slot so a
        // poisoned query cannot leak spend into the next one).
        let qcost = cost::take().unwrap_or_default();
        qcost.charge_totals();
        if traced {
            trace::set_cost(qcost);
        }
        if !explain {
            shared.metrics.latency.record(exec);
            let sig = shared.count.plan_signature(&query);
            shared.top.lock().unwrap().observe(&sig, qcost.units(), exec.as_micros() as u64);
        }
        if fanout {
            shared.metrics.batch_inflight.fetch_sub(1, Relaxed);
        }
        // Outcome for the trace/recorder/access log. The reactor arms the
        // request deadline at dispatch (`enqueued`), so that is the clock
        // to compare — queue wait and injected stalls count, exactly as
        // the client experienced them. A completion that outlived the
        // deadline was already answered `ERR deadline exceeded` by the
        // reactor and will be discarded by the conn-id guard — the flight
        // recorder is the only place it shows up.
        let outcome = match &out {
            Err(_) => "panic",
            _ if shared.cfg.request_timeout.is_some_and(|t| enqueued.elapsed() > t) => {
                "deadline_exceeded"
            }
            Ok(Ok(_)) => "ok",
            Ok(Err(_)) => "error",
        };
        let resp = match out {
            Ok(Ok(count)) => Response::Count { query, count },
            Ok(Err(e)) => {
                shared.metrics.errors.fetch_add(1, Relaxed);
                Response::Error { query, msg: e.to_string() }
            }
            Err(payload) => {
                shared.metrics.errors.fetch_add(1, Relaxed);
                shared.metrics.worker_panics.fetch_add(1, Relaxed);
                Response::Error {
                    query,
                    msg: format!("worker panicked: {}", panic_message(payload.as_ref())),
                }
            }
        };
        let resp = finish_trace(shared, resp, Obs {
            traced,
            explain,
            outcome,
            conn_id,
            batch,
            member,
            queue_wait,
            exec,
        });
        let ss = &shared.shards[shard];
        ss.completions.lock().unwrap().push(Completion { slot, conn_id, member, resp });
        ss.wake.wake();
        // Job boundary: the execution interval splits into on-CPU time
        // (busy) and injected sleeps / page waits (idle).
        profile::note_cpu();
    }
}

/// Per-job observability context handed from the hot loop to
/// [`finish_trace`].
struct Obs {
    traced: bool,
    explain: bool,
    outcome: &'static str,
    conn_id: u64,
    batch: usize,
    member: usize,
    queue_wait: Duration,
    exec: Duration,
}

/// Close out one job's trace: record the render span, publish to the
/// flight recorder (sampled traces always; panics and blown deadlines
/// even unsampled, as span-less shapes), append the access-log line, and
/// swap in the `EXPLAIN` response when asked. Untraced, healthy requests
/// take the first early return and touch nothing.
fn finish_trace(shared: &Shared, resp: Response, obs: Obs) -> Response {
    let notable = matches!(obs.outcome, "panic" | "deadline_exceeded");
    if !obs.traced && !notable {
        return resp;
    }
    let query_of = |r: &Response| -> String {
        match r {
            Response::Count { query, .. } | Response::Error { query, .. } => query.clone(),
            _ => String::new(),
        }
    };
    let mut bytes = 0u64;
    let finished = if obs.traced {
        if !obs.explain {
            // Render once worker-side so the trace carries reply size and
            // render cost; the reactor's own render is the one written.
            let _sp = trace::span("render");
            bytes = resp.render(shared.cfg.json).len() as u64 + 1;
        }
        trace::end(obs.outcome)
    } else {
        // Unsampled, but the recorder keeps abnormal outcomes anyway.
        Some(trace::Trace::minimal(
            &query_of(&resp),
            obs.outcome,
            obs.exec.as_micros() as u64,
        ))
    };
    let Some(t) = finished else { return resp };
    if obs.traced {
        if let Some(log) = &shared.access_log {
            let line = format!(
                "{{\"conn\":{},\"query\":\"{}\",\"outcome\":\"{}\",\"queue_us\":{},\
                 \"exec_us\":{},\"bytes\":{},\"batch\":{},\"member\":{}}}\n",
                obs.conn_id,
                json_escape(&t.query),
                t.outcome,
                obs.queue_wait.as_micros(),
                obs.exec.as_micros(),
                bytes,
                obs.batch,
                obs.member,
            );
            if let Ok(mut f) = log.lock() {
                let _ = f.write_all(line.as_bytes());
            }
        }
    }
    let resp = if obs.explain {
        let body = match &resp {
            Response::Count { count, .. } => format!("\"count\":{count}"),
            Response::Error { msg, .. } => format!("\"error\":\"{}\"", json_escape(msg)),
            _ => String::from("\"error\":\"unexpected response\""),
        };
        Response::Explain {
            json: format!(
                "{{\"query\":\"{}\",{body},\"trace\":{}}}",
                json_escape(&t.query),
                t.to_json()
            ),
        }
    } else {
        resp
    };
    recorder::record(t);
    if notable {
        recorder::auto_dump(obs.outcome);
    }
    resp
}

/// Best-effort text of a panic payload (panics carry `&str` or `String`
/// in practice; anything else gets a placeholder).
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

// ---------------------------------------------------------------------------
// connection state machine
// ---------------------------------------------------------------------------

enum ConnState {
    /// Reading/parsing; the next complete line may dispatch.
    Idle,
    /// One request (possibly a fanned-out `BATCH`) is in the pool.
    /// Replies accumulate by member index; nothing is written until all
    /// members land, so reply bytes and order match serial execution.
    Executing { pending: Vec<Option<Response>>, remaining: usize },
}

struct Conn {
    /// Monotonic per-shard id; completions carry it so a late result can
    /// never be attributed to a recycled slot.
    id: u64,
    /// `None` after close while completions are still draining.
    stream: Option<TcpStream>,
    buf: LineBuffer,
    out: Vec<u8>,
    out_pos: usize,
    served: usize,
    state: ConnState,
    interest: Interest,
    /// Flush what is queued, then close (cap hit, SHUTDOWN ack, protocol
    /// error).
    close_after_flush: bool,
    /// The request cap fired at dispatch; append `BUSY` + close once the
    /// in-flight request's replies render.
    cap_pending: bool,
    eof: bool,
    dead: bool,
    /// Idle-timeout clock: when the connection last completed a line
    /// (accept, parsed request, or a request finishing). Deliberately NOT
    /// advanced by raw bytes, so a slow-loris drip-feeding a partial line
    /// still expires.
    last_activity: Instant,
    /// Request-timeout clock: when the in-flight request was dispatched.
    exec_start: Option<Instant>,
}

/// Append one rendered response line to the connection's output buffer.
fn queue(conn: &mut Conn, json: bool, resp: &Response) {
    conn.out.extend_from_slice(resp.render(json).as_bytes());
    conn.out.push(b'\n');
}

/// Shard 0's once-a-second history flush: snapshots of the cumulative
/// counters at the previous flush, so each [`Slot`] stores true deltas
/// and windowed (not lifetime) latency quantiles.
struct TickState {
    next: Instant,
    epoch_s: u64,
    prev_queries: u64,
    prev_errors: u64,
    prev_admin: u64,
    /// Per-bucket latency counts at the previous flush (bounds are fixed).
    prev_latency: Vec<u64>,
    prev_cost_units: u64,
    prev_bytes: u64,
    /// `/proc/self` snapshot at the previous flush, so each slot's CPU %
    /// and ctx-switch figures are that second's delta, not process
    /// lifetime. `None` off Linux (the fields then stay zero).
    prev_proc: Option<proc::ProcessStats>,
}

impl TickState {
    fn new() -> TickState {
        // Cost totals are process-global (CLI queries and earlier servers
        // charge them too): snapshot at construction so the first slot
        // holds this server's first second, not the process's lifetime.
        let totals = cost::totals();
        TickState {
            next: Instant::now() + Duration::from_secs(1),
            epoch_s: 1,
            prev_queries: 0,
            prev_errors: 0,
            prev_admin: 0,
            prev_latency: Vec::new(),
            prev_cost_units: totals.units(),
            prev_bytes: totals.bytes_scanned,
            prev_proc: proc::read(),
        }
    }
}

/// Quantile upper bound over one window's per-bucket count deltas — the
/// same log₂ bounds as [`super::metrics::LatencyHistogram`], but computed
/// from a difference of two snapshots so each history slot reports *that
/// second's* p50/p99 rather than a lifetime aggregate.
fn quantile_from_deltas(deltas: &[(u64, u64)], q: f64) -> u64 {
    let total: u64 = deltas.iter().map(|&(_, c)| c).sum();
    if total == 0 {
        return 0;
    }
    let rank = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for &(bound, c) in deltas {
        seen += c;
        if seen >= rank {
            return bound;
        }
    }
    deltas.last().map_or(0, |&(b, _)| b)
}

struct ShardCtx {
    shared: Arc<Shared>,
    me: Arc<ShardShared>,
    idx: usize,
    poller: Poller,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Slots still owned (stream open, or completions outstanding).
    live: usize,
    next_id: u64,
    /// `Some` on shard 0 only: drives the per-second history flush.
    tick: Option<TickState>,
    /// Min-heap of `(deadline, slot, conn_id)` feeding the poller timeout.
    /// Entries are lazily validated at expiry: a stale one (recycled slot,
    /// bumped id, state change, clock pushed forward by activity) is
    /// dropped or re-pushed at the connection's *actual* deadline — so
    /// activity never has to rebuild the heap on the hot path.
    timers: BinaryHeap<Reverse<(Instant, usize, u64)>>,
}

impl ShardCtx {
    fn new(shared: Arc<Shared>, me: Arc<ShardShared>, idx: usize, poller: Poller) -> ShardCtx {
        ShardCtx {
            shared,
            me,
            idx,
            poller,
            conns: Vec::new(),
            free: Vec::new(),
            live: 0,
            next_id: 0,
            tick: if idx == 0 { Some(TickState::new()) } else { None },
            timers: BinaryHeap::new(),
        }
    }

    fn run(mut self, listener: TcpListener) {
        // CPU accounting for the reactor: poller waits show up as idle,
        // event handling as busy. Shards publish no span stacks of their
        // own — their samples fold into the `shard.idle` bucket.
        let _reg = profile::register(profile::Role::Shard);
        let mut events: Vec<Event> = Vec::new();
        let mut listener_open = true;
        let mut grace: Option<Instant> = None;
        loop {
            let shutting = self.shared.shutdown.load(SeqCst);
            let mut timeout = if shutting { Some(Duration::from_millis(100)) } else { None };
            // The earliest armed deadline bounds the wait, so timeouts
            // fire without any event traffic. A stale heap head only costs
            // one early wake-up; the expiry sweep re-files it.
            if let Some(Reverse((d, _, _))) = self.timers.peek() {
                let until = d.saturating_duration_since(Instant::now());
                timeout = Some(match timeout {
                    Some(t) => t.min(until),
                    None => until,
                });
            }
            // Shard 0 additionally wakes for the per-second history flush,
            // so the ring advances even on a completely idle server.
            if let Some(t) = &self.tick {
                let until = t.next.saturating_duration_since(Instant::now());
                timeout = Some(match timeout {
                    Some(x) => x.min(until),
                    None => until,
                });
            }
            let n = match self.poller.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(_) => {
                    // Poller is broken: close everything so `active`
                    // still drains to zero, then bail.
                    self.force_close_all();
                    break;
                }
            };
            // One boundary per wake-up keeps the accounting off the
            // per-event path; the poller block just ended, so the split
            // lands correctly without any extra bookkeeping.
            profile::note_cpu();
            if n > 0 {
                self.shared.metrics.wakeups.fetch_add(1, Relaxed);
            }
            for ev in &events {
                match ev.token {
                    TOKEN_WAKE => self.me.wake.drain(),
                    TOKEN_LISTENER => {
                        if listener_open && !self.shared.shutdown.load(SeqCst) {
                            self.accept_burst(&listener);
                        }
                    }
                    slot => self.on_event(slot, ev.readable, ev.writable),
                }
            }
            // Reap completions strictly AFTER draining the wake fd: a
            // completion pushed before its wake write is then always
            // visible to this take, so none can be stranded behind a
            // consumed wake.
            let completions = std::mem::take(&mut *self.me.completions.lock().unwrap());
            let depth = (n + completions.len()) as u64;
            if depth > 0 {
                self.shared.metrics.run_queue_peak.fetch_max(depth, Relaxed);
            }
            for c in completions {
                self.on_completion(c);
            }
            self.expire_timers();
            self.maybe_tick();
            if self.shared.shutdown.load(SeqCst) {
                if listener_open {
                    let _ = self.poller.deregister(fd_of(&listener));
                    listener_open = false;
                }
                let deadline = *grace.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
                self.drain_idle();
                if self.live == 0 {
                    break;
                }
                if Instant::now() >= deadline {
                    // Streams close now; Executing slots stay live until
                    // their completions arrive, which frees them above.
                    self.force_close_all();
                }
            }
        }
    }

    /// Shard 0 only: if a second has elapsed, flush one history slot with
    /// this window's counter deltas. A stalled reactor flushes one wide
    /// slot instead of a burst of empties, so window sums stay exact.
    fn maybe_tick(&mut self) {
        let Some(tick) = self.tick.as_mut() else { return };
        let now = Instant::now();
        if now < tick.next {
            return;
        }
        let m = &self.shared.metrics;
        let queries = m.queries.load(Relaxed);
        let errors = m.errors.load(Relaxed);
        let admin = m.admin_requests.load(Relaxed);
        let latency = m.latency.buckets();
        let totals = cost::totals();
        // `units` is linear in the cost fields, so the delta of totals is
        // the sum of this window's per-query units.
        let units = totals.units();
        let bytes = totals.bytes_scanned;
        let deltas: Vec<(u64, u64)> = latency
            .iter()
            .enumerate()
            .map(|(i, &(bound, c))| {
                (bound, c.saturating_sub(tick.prev_latency.get(i).copied().unwrap_or(0)))
            })
            .collect();
        let trees = self.shared.count.tree_stats();
        let probes = trees.hits + trees.builds;
        // Process resources, sampled at flush time. Point-in-time gauges
        // (RSS, fds) come straight from the current reading; CPU % and
        // ctx switches are deltas against the previous flush — 10 000 µs
        // of CPU over a one-second window is one percent of one core.
        let ps = proc::read();
        let (rss_bytes, cpu_user_pct, cpu_sys_pct, open_fds, ctx_switches) =
            match (&ps, &tick.prev_proc) {
                (Some(cur), Some(prev)) => (
                    cur.rss_bytes,
                    cur.utime_us.saturating_sub(prev.utime_us) / 10_000,
                    cur.stime_us.saturating_sub(prev.stime_us) / 10_000,
                    cur.open_fds,
                    (cur.voluntary_ctxt_switches + cur.nonvoluntary_ctxt_switches)
                        .saturating_sub(
                            prev.voluntary_ctxt_switches + prev.nonvoluntary_ctxt_switches,
                        ),
                ),
                (Some(cur), None) => (cur.rss_bytes, 0, 0, cur.open_fds, 0),
                _ => (0, 0, 0, 0, 0),
            };
        let slot = Slot {
            epoch_s: tick.epoch_s,
            queries: queries.saturating_sub(tick.prev_queries),
            errors: errors.saturating_sub(tick.prev_errors),
            admin: admin.saturating_sub(tick.prev_admin),
            p50_us: quantile_from_deltas(&deltas, 0.50),
            p99_us: quantile_from_deltas(&deltas, 0.99),
            queue_depth: self.shared.exec.len() as u64,
            cache_hit_pct: if probes == 0 { 0 } else { trees.hits * 100 / probes },
            cost_units: units.saturating_sub(tick.prev_cost_units),
            bytes_scanned: bytes.saturating_sub(tick.prev_bytes),
            rss_bytes,
            cpu_user_pct,
            cpu_sys_pct,
            open_fds,
            ctx_switches,
        };
        self.shared.history.lock().unwrap().push(slot);
        tick.prev_proc = ps;
        tick.epoch_s += 1;
        tick.prev_queries = queries;
        tick.prev_errors = errors;
        tick.prev_admin = admin;
        tick.prev_latency = latency.iter().map(|&(_, c)| c).collect();
        tick.prev_cost_units = units;
        tick.prev_bytes = bytes;
        while tick.next <= now {
            tick.next += Duration::from_secs(1);
        }
    }

    fn accept_burst(&mut self, listener: &TcpListener) {
        for _ in 0..ACCEPT_BURST {
            // `net.accept.err` simulates a transient accept failure
            // (EMFILE and friends): same back-off as the real Err arm.
            if crate::util::failpoint::fire("net.accept.err") {
                std::thread::sleep(Duration::from_millis(1));
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // EMFILE and friends: back off briefly instead of
                    // spinning on a level-triggered listener we cannot
                    // drain.
                    std::thread::sleep(Duration::from_millis(5));
                    break;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        let m = &self.shared.metrics;
        if m.active.load(Relaxed) as usize >= self.shared.cfg.max_conns {
            // Accept-time shedding: best-effort nonblocking reject. The
            // reactor thread must never block on a victim socket — if the
            // single write doesn't fit (unwritable peer), the close alone
            // is the answer.
            m.busy_rejects.fetch_add(1, Relaxed);
            let _ = stream.set_nonblocking(true);
            let busy = Response::Busy { msg: "connection limit reached, retry later".to_string() };
            let mut line = busy.render(self.shared.cfg.json);
            line.push('\n');
            let mut s = stream;
            let _ = s.write(line.as_bytes());
            return;
        }
        // Accepted sockets do not inherit the listener's O_NONBLOCK.
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        m.connections.fetch_add(1, Relaxed);
        let newly = m.active.fetch_add(1, Relaxed) + 1;
        m.conns.record_value(newly);
        let fd = fd_of(&stream);
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        };
        if self.poller.register(fd, slot, Interest::READ).is_err() {
            m.active.fetch_sub(1, Relaxed);
            self.free.push(slot);
            return;
        }
        m.registered_fds.fetch_add(1, Relaxed);
        let id = self.next_id;
        self.next_id += 1;
        self.conns[slot] = Some(Conn {
            id,
            stream: Some(stream),
            buf: LineBuffer::new(),
            out: Vec::new(),
            out_pos: 0,
            served: 0,
            state: ConnState::Idle,
            interest: Interest::READ,
            close_after_flush: false,
            cap_pending: false,
            eof: false,
            dead: false,
            last_activity: Instant::now(),
            exec_start: None,
        });
        self.live += 1;
        self.arm_timer(slot);
    }

    /// The connection's current deadline under the configured timeouts,
    /// if any applies to its state.
    fn conn_deadline(&self, conn: &Conn) -> Option<Instant> {
        if conn.stream.is_none() {
            return None;
        }
        match conn.state {
            ConnState::Executing { .. } => self
                .shared
                .cfg
                .request_timeout
                .and_then(|t| conn.exec_start.map(|s| s + t)),
            ConnState::Idle => self.shared.cfg.idle_timeout.map(|t| conn.last_activity + t),
        }
    }

    /// File the connection's current deadline (if any) in the heap.
    fn arm_timer(&mut self, slot: usize) {
        let entry = match self.conns.get(slot) {
            Some(Some(conn)) => self.conn_deadline(conn).map(|d| (d, conn.id)),
            _ => None,
        };
        if let Some((d, id)) = entry {
            self.timers.push(Reverse((d, slot, id)));
        }
    }

    /// Pop every due heap entry: stale ones are dropped or re-filed at the
    /// connection's actual deadline; genuinely expired ones fire.
    fn expire_timers(&mut self) {
        let now = Instant::now();
        loop {
            match self.timers.peek() {
                Some(Reverse((d, _, _))) if *d <= now => {}
                _ => break,
            }
            let Some(Reverse((_, slot, id))) = self.timers.pop() else { break };
            let actual = match self.conns.get(slot) {
                Some(Some(conn)) if conn.id == id => self.conn_deadline(conn),
                _ => continue, // slot freed or recycled since filing
            };
            match actual {
                // Activity (or a state change) pushed the deadline out.
                Some(d) if d > now => self.timers.push(Reverse((d, slot, id))),
                Some(_) => self.fire_timeout(slot, now),
                // No timeout applies to the connection's current state.
                None => {}
            }
        }
    }

    /// One connection blew its deadline. Idle: close it (`conn_timeouts`).
    /// Executing: abandon the in-flight request — reply `ERR deadline
    /// exceeded`, bump the conn id so the guard in [`ShardCtx::on_completion`]
    /// discards the late result, and return the connection to `Idle`
    /// (`request_timeouts`).
    fn fire_timeout(&mut self, slot: usize, now: Instant) {
        let json = self.shared.cfg.json;
        let max_requests = self.shared.cfg.max_requests;
        let executing = match self.conns.get(slot) {
            Some(Some(conn)) => matches!(conn.state, ConnState::Executing { .. }),
            _ => return,
        };
        if !executing {
            self.shared.metrics.conn_timeouts.fetch_add(1, Relaxed);
            self.close(slot);
            return;
        }
        self.shared.metrics.request_timeouts.fetch_add(1, Relaxed);
        self.shared.metrics.errors.fetch_add(1, Relaxed);
        let new_id = self.next_id;
        self.next_id += 1;
        let mut cap_busy = false;
        {
            let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
            conn.id = new_id;
            conn.state = ConnState::Idle;
            conn.exec_start = None;
            conn.last_activity = now;
            queue(
                conn,
                json,
                &Response::Error { query: String::new(), msg: "deadline exceeded".to_string() },
            );
            // The request cap would have closed on completion; the timeout
            // replaces that completion, so it honors the cap itself.
            if conn.cap_pending {
                conn.cap_pending = false;
                conn.close_after_flush = true;
                queue(
                    conn,
                    json,
                    &Response::Busy {
                        msg: format!(
                            "per-connection request cap ({max_requests}) reached, reconnect"
                        ),
                    },
                );
                cap_busy = true;
            }
        }
        if cap_busy {
            self.shared.metrics.busy_rejects.fetch_add(1, Relaxed);
        }
        self.arm_timer(slot);
        self.finish(slot);
    }

    fn on_event(&mut self, slot: usize, readable: bool, writable: bool) {
        match self.conns.get(slot) {
            Some(Some(_)) => {}
            _ => return,
        }
        if writable {
            self.flush(slot);
        }
        if readable {
            self.on_readable(slot);
        }
        self.finish(slot);
    }

    /// Pull bytes until the buffer holds a complete line, the socket runs
    /// dry, or the peer goes away. Stopping at the first complete line
    /// means a pipelining firehose is processed a request at a time —
    /// TCP's receive window is the backpressure.
    fn on_readable(&mut self, slot: usize) {
        let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
        if conn.dead || conn.eof || !matches!(conn.state, ConnState::Idle) {
            return;
        }
        let Some(stream) = conn.stream.as_mut() else { return };
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if conn.buf.has_line() {
                break;
            }
            match stream.read(&mut chunk) {
                Ok(0) => {
                    conn.eof = true;
                    break;
                }
                Ok(n) => conn.buf.push(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
    }

    /// The common tail of every stimulus: parse/dispatch what is buffered,
    /// flush what is queued, retune poller interest, close if terminal.
    fn finish(&mut self, slot: usize) {
        self.pump(slot);
        self.flush(slot);
        self.update_interest(slot);
        self.maybe_close(slot);
    }

    /// Parse and act on buffered lines until the buffer runs dry or the
    /// connection enters `Executing` (one request in flight at a time).
    fn pump(&mut self, slot: usize) {
        let json = self.shared.cfg.json;
        loop {
            let line = {
                let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
                if conn.dead || conn.close_after_flush || conn.stream.is_none() {
                    return;
                }
                if !matches!(conn.state, ConnState::Idle) {
                    return;
                }
                match conn.buf.next_line() {
                    Err(msg) => {
                        queue(conn, json, &Response::Error { query: String::new(), msg });
                        conn.close_after_flush = true;
                        return;
                    }
                    Ok(None) => return,
                    Ok(Some(l)) => {
                        // Only a *complete* line resets the idle clock —
                        // raw bytes don't, so drip-fed partial lines
                        // (slow-loris) still expire.
                        conn.last_activity = Instant::now();
                        l
                    }
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            // Parse time rides the job into the worker-side trace as the
            // `parse` span; the clock is touched only when sampling is on.
            let parse_t0 =
                if self.shared.cfg.trace_sample > 0 { Some(Instant::now()) } else { None };
            let req = parse_request(&line);
            let parse_us = parse_t0.map_or(0, |t| t.elapsed().as_micros() as u64);
            match req {
                Request::Ping => self.queue_to(slot, &Response::Pong),
                Request::Stats => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    let s = self.shared.snapshot().to_json();
                    self.queue_to(slot, &Response::Stats { json: s });
                }
                Request::Metrics => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    let text = self.shared.metrics_text();
                    self.queue_to(slot, &Response::Metrics { text });
                }
                Request::Dump => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    // Fold the heavy-hitter summary into the flight-record
                    // dump: splice `"top"` in before the closing brace.
                    let mut json = recorder::dump_json();
                    let top = self.shared.top.lock().unwrap().to_json(5);
                    json.truncate(json.len() - 1);
                    json.push_str(",\"top\":");
                    json.push_str(&top);
                    json.push('}');
                    self.queue_to(slot, &Response::Dump { json });
                }
                Request::Top(k) => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    let json = self.shared.top.lock().unwrap().to_json(k.unwrap_or(10));
                    self.queue_to(slot, &Response::Top { json });
                }
                Request::History(secs) => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    let json = self
                        .shared
                        .history
                        .lock()
                        .unwrap()
                        .series_json(secs.unwrap_or(60) as usize);
                    self.queue_to(slot, &Response::History { json });
                }
                Request::Profile(secs) => {
                    self.shared.metrics.admin_requests.fetch_add(1, Relaxed);
                    self.start_profile(slot, secs.unwrap_or(2).clamp(1, 60));
                }
                Request::Shutdown => {
                    self.queue_to(slot, &Response::Bye);
                    if let Some(Some(conn)) = self.conns.get_mut(slot) {
                        conn.close_after_flush = true;
                    }
                    self.shared.initiate_shutdown();
                    return;
                }
                Request::Batch(qs) if qs.is_empty() => self.queue_to(
                    slot,
                    &Response::Error {
                        query: String::new(),
                        msg: "empty BATCH (want `BATCH q1 ; q2 ; …`)".to_string(),
                    },
                ),
                Request::Explain(q) if q.is_empty() => self.queue_to(
                    slot,
                    &Response::Error {
                        query: String::new(),
                        msg: "EXPLAIN wants a query (`EXPLAIN <query>`)".to_string(),
                    },
                ),
                Request::Count(q) => self.dispatch(slot, vec![q], false, parse_us),
                Request::Explain(q) => self.dispatch(slot, vec![q], true, parse_us),
                Request::Batch(qs) => self.dispatch(slot, qs, false, parse_us),
            }
        }
    }

    /// `PROFILE [secs]`: the capture blocks for the whole window, so it
    /// runs on a one-shot helper thread and delivers its result through
    /// the ordinary completion path (mailbox + wake). The connection
    /// sits in `Executing` meanwhile — read interest drops, exactly the
    /// backpressure a count query gets — and the reactor never blocks.
    fn start_profile(&mut self, slot: usize, secs: u64) {
        let json = self.shared.cfg.json;
        let conn_id = match self.conns.get(slot) {
            Some(Some(c)) => c.id,
            _ => return,
        };
        let me = Arc::clone(&self.me);
        let spawned = std::thread::Builder::new()
            .name("mrss-profile-capture".to_string())
            .spawn(move || {
                let resp = Response::Profile { json: profile::capture(secs) };
                me.completions.lock().unwrap().push(Completion {
                    slot,
                    conn_id,
                    member: 0,
                    resp,
                });
                me.wake.wake();
            });
        let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
        match spawned {
            Ok(_) => {
                conn.state = ConnState::Executing { pending: vec![None], remaining: 1 };
                conn.exec_start = Some(Instant::now());
            }
            Err(_) => queue(
                conn,
                json,
                &Response::Error {
                    query: String::new(),
                    msg: "spawning profile capture thread failed".to_string(),
                },
            ),
        }
        self.arm_timer(slot);
    }

    fn queue_to(&mut self, slot: usize, resp: &Response) {
        let json = self.shared.cfg.json;
        if let Some(Some(conn)) = self.conns.get_mut(slot) {
            queue(conn, json, resp);
        }
    }

    /// Hand one request (1 query, or a BATCH's k members) to the pool.
    fn dispatch(&mut self, slot: usize, qs: Vec<String>, explain: bool, parse_us: u64) {
        let k = qs.len();
        let conn_id = match self.conns.get(slot) {
            Some(Some(c)) => c.id,
            _ => return,
        };
        let enqueued = Instant::now();
        let jobs: Vec<Job> = qs
            .into_iter()
            .enumerate()
            .map(|(member, query)| Job {
                shard: self.idx,
                slot,
                conn_id,
                member,
                batch: k,
                query,
                explain,
                enqueued,
                parse_us,
            })
            .collect();
        if self.shared.exec.try_submit(jobs) {
            if let Some(Some(conn)) = self.conns.get_mut(slot) {
                conn.state = ConnState::Executing { pending: vec![None; k], remaining: k };
                conn.exec_start = Some(Instant::now());
                conn.served += k;
                if conn.served >= self.shared.cfg.max_requests {
                    conn.cap_pending = true;
                }
            }
            self.arm_timer(slot);
        } else {
            // Read-time shedding: the queue is full but the connection is
            // healthy — answer BUSY and keep it open for a retry.
            self.shared.metrics.busy_rejects.fetch_add(1, Relaxed);
            self.queue_to(
                slot,
                &Response::Busy { msg: "execution queue full, retry later".to_string() },
            );
        }
    }

    /// A worker finished one member. Stitch it in; when the whole request
    /// has landed, render every reply in member order.
    fn on_completion(&mut self, c: Completion) {
        let json = self.shared.cfg.json;
        let max_requests = self.shared.cfg.max_requests;
        let mut busy_inc = false;
        {
            let Some(Some(conn)) = self.conns.get_mut(c.slot) else { return };
            if conn.id != c.conn_id {
                return; // stale completion for a recycled slot
            }
            let ConnState::Executing { pending, remaining } = &mut conn.state else { return };
            if pending[c.member].is_none() {
                *remaining -= 1;
            }
            pending[c.member] = Some(c.resp);
            if *remaining != 0 {
                return;
            }
            let ConnState::Executing { pending, .. } =
                std::mem::replace(&mut conn.state, ConnState::Idle)
            else {
                unreachable!()
            };
            conn.exec_start = None;
            conn.last_activity = Instant::now();
            for resp in pending.into_iter().flatten() {
                queue(conn, json, &resp);
            }
            if conn.cap_pending {
                conn.cap_pending = false;
                conn.close_after_flush = true;
                queue(
                    conn,
                    json,
                    &Response::Busy {
                        msg: format!(
                            "per-connection request cap ({max_requests}) reached, reconnect"
                        ),
                    },
                );
                busy_inc = true;
            }
        }
        if busy_inc {
            self.shared.metrics.busy_rejects.fetch_add(1, Relaxed);
        }
        self.arm_timer(c.slot);
        self.finish(c.slot);
    }

    /// Nonblocking write of whatever is queued; leftover bytes wait for
    /// write readiness.
    fn flush(&mut self, slot: usize) {
        let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
        let Some(stream) = conn.stream.as_mut() else {
            // Stream already force-closed: drop the buffered bytes.
            conn.out.clear();
            conn.out_pos = 0;
            return;
        };
        while conn.out_pos < conn.out.len() {
            match stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => conn.out_pos += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if conn.out_pos >= conn.out.len() {
            conn.out.clear();
            conn.out_pos = 0;
        }
    }

    /// Keep the poller's view in sync with the state machine: read only
    /// when Idle (drops read interest during execution = backpressure),
    /// write only while output is queued.
    fn update_interest(&mut self, slot: usize) {
        let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
        let Some(stream) = conn.stream.as_ref() else { return };
        let want = Interest {
            read: matches!(conn.state, ConnState::Idle)
                && !conn.close_after_flush
                && !conn.eof
                && !conn.dead,
            write: conn.out_pos < conn.out.len(),
        };
        if want != conn.interest {
            let fd = fd_of(stream);
            if self.poller.modify(fd, slot, want).is_ok() {
                conn.interest = want;
            }
        }
    }

    fn maybe_close(&mut self, slot: usize) {
        enum Act {
            Nothing,
            Free,
            Close,
        }
        let act = {
            let Some(Some(conn)) = self.conns.get_mut(slot) else { return };
            let idle = matches!(conn.state, ConnState::Idle);
            let drained = conn.out_pos >= conn.out.len();
            if conn.stream.is_none() {
                // Force-closed earlier; free once completions drained.
                if idle {
                    Act::Free
                } else {
                    Act::Nothing
                }
            } else if conn.dead
                || (conn.close_after_flush && idle && drained)
                || (conn.eof && idle && drained && !conn.buf.has_line())
            {
                Act::Close
            } else {
                Act::Nothing
            }
        };
        match act {
            Act::Nothing => {}
            Act::Free => self.free_slot(slot),
            Act::Close => self.close(slot),
        }
    }

    /// Close the socket (deregister + drop). The slot itself is freed only
    /// once no completions are outstanding for it.
    fn close(&mut self, slot: usize) {
        let stream = match self.conns.get_mut(slot) {
            Some(Some(conn)) => conn.stream.take(),
            _ => return,
        };
        if let Some(stream) = stream {
            let _ = self.poller.deregister(fd_of(&stream));
            self.shared.metrics.registered_fds.fetch_sub(1, Relaxed);
            self.shared.metrics.active.fetch_sub(1, Relaxed);
            drop(stream);
        }
        let idle = match self.conns.get(slot) {
            Some(Some(conn)) => matches!(conn.state, ConnState::Idle),
            _ => return,
        };
        if idle {
            self.free_slot(slot);
        }
    }

    fn free_slot(&mut self, slot: usize) {
        if let Some(entry) = self.conns.get_mut(slot) {
            if entry.take().is_some() {
                self.free.push(slot);
                self.live -= 1;
            }
        }
    }

    /// Shutdown sweep: close every connection with nothing in flight and
    /// nothing left to flush.
    fn drain_idle(&mut self) {
        for slot in 0..self.conns.len() {
            let close = match &self.conns[slot] {
                Some(conn) => {
                    conn.stream.is_some()
                        && matches!(conn.state, ConnState::Idle)
                        && conn.out_pos >= conn.out.len()
                }
                None => false,
            };
            if close {
                self.close(slot);
            }
        }
    }

    /// Grace expired (or the poller died): close every stream now.
    fn force_close_all(&mut self) {
        for slot in 0..self.conns.len() {
            self.close(slot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::mobius::MobiusJoin;
    use crate::store::{CtStore, PersistConfig, StoreSink};
    use std::io::{BufRead, BufReader, BufWriter};
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mrss_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn start_uwcse(tag: &str, cfg: ServeConfig) -> (PathBuf, ServeHandle) {
        let dir = tmpdir(tag);
        let db = datagen::generate("uwcse", 0.1, 7).unwrap();
        let store = CtStore::create(&dir, "uwcse", 0.1, 7).unwrap();
        {
            let sink = StoreSink::new(&store, &db.schema, PersistConfig::default());
            MobiusJoin::new(&db).sink(&sink).run();
            sink.take_error().unwrap();
        }
        drop(store);
        let count = Arc::new(crate::store::CountServer::open(&dir).unwrap());
        let handle = serve(count, cfg).unwrap();
        (dir, handle)
    }

    fn roundtrip(addr: SocketAddr, lines: &[&str]) -> Vec<String> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(w, "{l}").unwrap();
            w.flush().unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            out.push(resp.trim().to_string());
        }
        out
    }

    #[test]
    fn ping_stats_count_and_shutdown_roundtrip() {
        let (dir, handle) = start_uwcse("basic", ServeConfig::default());
        let addr = handle.addr();
        let out = roundtrip(addr, &["PING", "position(P1)=faculty", "STATS"]);
        assert_eq!(out[0], "{\"pong\":true}");
        assert!(out[1].contains("\"count\":"), "{}", out[1]);
        assert!(out[2].contains("\"qps\":"), "{}", out[2]);
        // Bad query answers an error line but keeps the connection usable.
        let out = roundtrip(addr, &["nope(X)=1", "PING"]);
        assert!(out[0].contains("\"error\":"), "{}", out[0]);
        assert_eq!(out[1], "{\"pong\":true}");
        let out = roundtrip(addr, &["SHUTDOWN"]);
        assert_eq!(out[0], "{\"bye\":true}");
        let snap = handle.wait();
        assert!(snap.queries >= 2);
        assert_eq!(snap.active, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_answers_one_line_per_query_in_order() {
        let (dir, handle) = start_uwcse("batch", ServeConfig::default());
        let stream = TcpStream::connect(handle.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "BATCH position(P1)=faculty ; nope=1 ; student(P1)=yes").unwrap();
        w.flush().unwrap();
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            lines.push(l);
        }
        assert!(lines[0].contains("position(P1)=faculty"));
        assert!(lines[0].contains("\"count\":"));
        assert!(lines[1].contains("\"error\":"));
        assert!(lines[2].contains("student(P1)=yes"));
        handle.request_shutdown();
        handle.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn text_wire_mode_and_request_cap() {
        let cfg = ServeConfig { json: false, max_requests: 2, ..Default::default() };
        let (dir, handle) = start_uwcse("cap", cfg);
        let stream = TcpStream::connect(handle.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        for _ in 0..2 {
            writeln!(w, "position(P1)=faculty").unwrap();
        }
        w.flush().unwrap();
        let mut lines = Vec::new();
        // 2 answers, then the cap's BUSY, then EOF.
        for _ in 0..3 {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            lines.push(l.trim().to_string());
        }
        assert!(lines[0].starts_with("COUNT "), "{lines:?}");
        assert!(lines[1].starts_with("COUNT "), "{lines:?}");
        assert!(lines[2].starts_with("BUSY "), "{lines:?}");
        let mut l = String::new();
        assert_eq!(r.read_line(&mut l).unwrap(), 0, "server must close after BUSY");
        assert!(handle.snapshot().busy_rejects >= 1);
        handle.request_shutdown();
        handle.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn idle_timeout_closes_parked_connections() {
        let cfg = ServeConfig {
            idle_timeout: Some(Duration::from_millis(150)),
            ..Default::default()
        };
        let (dir, handle) = start_uwcse("idletimeout", cfg);
        // One fully idle client, one parked mid-line (slow-loris shape):
        // both must be closed by the reactor, no reads required.
        let idle = TcpStream::connect(handle.addr()).unwrap();
        let mut loris = TcpStream::connect(handle.addr()).unwrap();
        loris.write_all(b"PIN").unwrap(); // no newline, never completes
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let snap = handle.snapshot();
            if snap.conn_timeouts >= 2 && snap.active == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "idle timeout never fired: {snap:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
        // The sockets are really closed: reads see EOF.
        let mut buf = [0u8; 16];
        let mut r = idle.try_clone().unwrap();
        r.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        assert_eq!(r.read(&mut buf).unwrap(), 0, "idle socket must be closed");
        handle.request_shutdown();
        handle.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_timeout_answers_deadline_exceeded_and_conn_survives() {
        let cfg = ServeConfig {
            // Workers sleep 400 ms per query; the deadline fires at 50 ms.
            exec_delay: Duration::from_millis(400),
            request_timeout: Some(Duration::from_millis(50)),
            ..Default::default()
        };
        let (dir, handle) = start_uwcse("reqtimeout", cfg);
        let stream = TcpStream::connect(handle.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "position(P1)=faculty").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("deadline exceeded"), "{line}");
        // The connection is back to Idle and usable; the late completion
        // (arriving ~350 ms later) must be discarded by the conn-id guard,
        // not written to us.
        writeln!(w, "PING").unwrap();
        w.flush().unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "{\"pong\":true}");
        std::thread::sleep(Duration::from_millis(500));
        let snap = handle.snapshot();
        assert_eq!(snap.request_timeouts, 1, "{snap:?}");
        // Nothing extra may have been written after the late completion.
        handle.request_shutdown();
        let snap = handle.wait();
        assert_eq!(snap.active, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_shutdown_unblocks_an_idle_server() {
        let (dir, handle) = start_uwcse("idle", ServeConfig::default());
        // One idle connected client must not block the drain.
        let _idle = TcpStream::connect(handle.addr()).unwrap();
        handle.request_shutdown();
        let snap = handle.wait(); // must return despite the idle client
        assert_eq!(snap.active, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
