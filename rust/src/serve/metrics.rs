//! Live serving metrics: lock-free counters + a fixed-bucket latency
//! histogram, snapshotted by the `STATS` wire command and folded into
//! [`MjMetrics`](crate::mobius::MjMetrics) for the run reports.
//!
//! The histogram buckets latencies by `ceil(log2(micros))` — 40 buckets
//! cover 1 µs to 2^38 µs (~3 days) with ≤2× relative error, far beyond
//! any real latency, which is plenty for p50/p99 on a count service whose
//! fast path is microseconds. All counters are relaxed atomics: recording
//! must never contend with the queries it measures.

use crate::mobius::MjMetrics;
use crate::obs::cost::{self, QueryCost};
use crate::obs::profile;
use crate::serve::protocol::json_escape;
use crate::store::{StoreStats, TreeStats};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::{Duration, Instant};

/// Number of power-of-two latency buckets: bucket `i` holds latencies in
/// `(2^(i-1), 2^i]` µs, so buckets 0..=38 span 1 µs .. 2^38 µs (~3 days)
/// and bucket 39 is the catch-all above.
const BUCKETS: usize = 40;

/// A fixed-bucket log-scale latency histogram (thread-safe, wait-free).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    /// Exact sum of recorded values — the buckets alone only bound it,
    /// and Prometheus exposition wants a true `_sum`.
    sum: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    fn bucket_of(micros: u128) -> usize {
        // Bucket i holds latencies in (2^(i-1), 2^i] µs; bucket 0 is ≤1 µs.
        (128 - micros.max(1).leading_zeros() as usize - 1
            + usize::from(!micros.max(1).is_power_of_two()))
        .min(BUCKETS - 1)
    }

    pub fn record(&self, d: Duration) {
        self.record_value(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Record a raw value instead of a duration — the same log₂ buckets
    /// serve any positive magnitude (e.g. concurrent-connection counts),
    /// with `quantile_upper_us` then reading as a plain value bound.
    pub fn record_value(&self, v: u64) {
        self.buckets[Self::bucket_of(v as u128)].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Relaxed)).sum()
    }

    /// Exact sum of every recorded value (µs for durations).
    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// `(upper_bound, count)` per bucket, in ascending bound order —
    /// the raw material for Prometheus cumulative-`le` rendering.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        (0..BUCKETS).map(|i| (1u64 << i, self.buckets[i].load(Relaxed))).collect()
    }

    /// Upper bound (µs) of the bucket containing quantile `q` (0..=1).
    /// Zero when nothing was recorded.
    pub fn quantile_upper_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return 1u64 << i;
            }
        }
        1u64 << (BUCKETS - 1)
    }
}

/// Shared live counters of one serving front-end.
#[derive(Debug)]
pub struct ServeMetrics {
    start: Instant,
    /// Queries answered (errors included; each BATCH member counts).
    /// Admin verbs are counted in `admin_requests` instead so qps and
    /// the latency histograms describe real count traffic only.
    pub queries: AtomicU64,
    /// Admin verbs served (STATS/METRICS/DUMP/TOP/HISTORY/EXPLAIN) —
    /// excluded from `queries` and from the latency histograms.
    pub admin_requests: AtomicU64,
    /// Queries that answered with an error line.
    pub errors: AtomicU64,
    /// Connections turned away or cut short by admission control.
    pub busy_rejects: AtomicU64,
    /// Connections accepted over the lifetime of the server.
    pub connections: AtomicU64,
    /// Connections currently being served.
    pub active: AtomicU64,
    /// Worker-pool execution time per query (dispatch excluded).
    pub latency: LatencyHistogram,
    /// Time a job sat in the worker queue before a thread picked it
    /// up — split from `latency` so `STATS` shows *where* latency
    /// lives: a saturated pool grows this, slow planning grows that.
    pub queue_wait: LatencyHistogram,
    /// Reactor wake-ups: poller waits that returned with ≥1 event.
    pub wakeups: AtomicU64,
    /// Fds currently registered across all reactor shards (gauge).
    pub registered_fds: AtomicU64,
    /// Deepest per-wakeup work batch any shard has processed (events +
    /// drained completions) — the run-queue high-water mark.
    pub run_queue_peak: AtomicU64,
    /// BATCH members currently executing on the worker pool (gauge).
    pub batch_inflight: AtomicU64,
    /// Most BATCH members ever observed in flight at once: > 1 proves
    /// fan-out executes members concurrently, not serially.
    pub batch_peak: AtomicU64,
    /// Distribution of `active + 1` sampled at every accept — how many
    /// connections were open each time one more arrived.
    pub conns: LatencyHistogram,
    /// Worker jobs that panicked and were converted to `ERR` replies (the
    /// worker thread survives; the connection returns to `Idle`).
    pub worker_panics: AtomicU64,
    /// Connections closed for sitting idle (or parked mid-line) past
    /// `--idle-timeout`.
    pub conn_timeouts: AtomicU64,
    /// In-flight requests answered `ERR deadline exceeded` after
    /// `--request-timeout`; their late completions are discarded.
    pub request_timeouts: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            start: Instant::now(),
            queries: AtomicU64::new(0),
            admin_requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            busy_rejects: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            active: AtomicU64::new(0),
            latency: LatencyHistogram::default(),
            queue_wait: LatencyHistogram::default(),
            wakeups: AtomicU64::new(0),
            registered_fds: AtomicU64::new(0),
            run_queue_peak: AtomicU64::new(0),
            batch_inflight: AtomicU64::new(0),
            batch_peak: AtomicU64::new(0),
            conns: LatencyHistogram::default(),
            worker_panics: AtomicU64::new(0),
            conn_timeouts: AtomicU64::new(0),
            request_timeouts: AtomicU64::new(0),
        }
    }
}

impl ServeMetrics {
    /// Point-in-time snapshot, joined with the store/tree cache counters
    /// and the serving dataset's name (an arbitrary string on the wire —
    /// `to_json` escapes it like every other string field).
    pub fn snapshot(&self, store: StoreStats, trees: TreeStats, dataset: &str) -> ServeSnapshot {
        let uptime = self.start.elapsed();
        let queries = self.queries.load(Relaxed);
        let wakeups = self.wakeups.load(Relaxed);
        ServeSnapshot {
            dataset: dataset.to_string(),
            uptime_secs: uptime.as_secs_f64(),
            queries,
            admin_requests: self.admin_requests.load(Relaxed),
            errors: self.errors.load(Relaxed),
            busy_rejects: self.busy_rejects.load(Relaxed),
            connections: self.connections.load(Relaxed),
            active: self.active.load(Relaxed),
            qps: queries as f64 / uptime.as_secs_f64().max(1e-9),
            p50_us: self.latency.quantile_upper_us(0.50),
            p99_us: self.latency.quantile_upper_us(0.99),
            queue_p50_us: self.queue_wait.quantile_upper_us(0.50),
            queue_p99_us: self.queue_wait.quantile_upper_us(0.99),
            wakeups,
            wakeups_per_sec: wakeups as f64 / uptime.as_secs_f64().max(1e-9),
            registered_fds: self.registered_fds.load(Relaxed),
            run_queue_peak: self.run_queue_peak.load(Relaxed),
            batch_peak: self.batch_peak.load(Relaxed),
            conns_p50: self.conns.quantile_upper_us(0.50),
            conns_p99: self.conns.quantile_upper_us(0.99),
            worker_panics: self.worker_panics.load(Relaxed),
            conn_timeouts: self.conn_timeouts.load(Relaxed),
            request_timeouts: self.request_timeouts.load(Relaxed),
            cost: cost::totals(),
            threads: profile::cpu_snapshot(),
            store,
            trees,
        }
    }
}

/// What `STATS` returns: one consistent view of traffic, latency, and both
/// caches.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSnapshot {
    /// Dataset the store serves (manifest string, escaped on render).
    pub dataset: String,
    pub uptime_secs: f64,
    pub queries: u64,
    /// Admin verbs served (excluded from `queries`/qps/latency).
    pub admin_requests: u64,
    pub errors: u64,
    pub busy_rejects: u64,
    pub connections: u64,
    pub active: u64,
    pub qps: f64,
    /// Execution-latency bucket upper bounds, µs (≤2× relative error
    /// by design).
    pub p50_us: u64,
    pub p99_us: u64,
    /// Queue-wait bucket upper bounds, µs — dispatch to pickup.
    pub queue_p50_us: u64,
    pub queue_p99_us: u64,
    /// Reactor wake-ups with ≥1 event, total and per second.
    pub wakeups: u64,
    pub wakeups_per_sec: f64,
    /// Fds registered across all reactor shards right now.
    pub registered_fds: u64,
    /// Deepest per-wakeup work batch any shard processed.
    pub run_queue_peak: u64,
    /// Most BATCH members in flight at once (> 1 ⇒ concurrent fan-out).
    pub batch_peak: u64,
    /// Connections-open distribution sampled at accept (bucket bounds).
    pub conns_p50: u64,
    pub conns_p99: u64,
    /// Panicked worker jobs converted to `ERR` replies.
    pub worker_panics: u64,
    /// Connections closed by `--idle-timeout`.
    pub conn_timeouts: u64,
    /// Requests answered `ERR deadline exceeded` by `--request-timeout`.
    pub request_timeouts: u64,
    /// Process-wide query-cost totals (see [`cost::totals`]).
    pub cost: QueryCost,
    /// Per-role thread-CPU split (worker/shard/sampler busy vs idle),
    /// indexed like [`profile::ALL_ROLES`].
    pub threads: [profile::RoleCpu; 3],
    pub store: StoreStats,
    pub trees: TreeStats,
}

impl ServeSnapshot {
    /// Render as a single-line JSON object (the `STATS` wire response).
    /// Every string field — here the dataset name — goes through
    /// [`json_escape`]; numbers render bare.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"dataset\":\"{}\",\
             \"uptime_secs\":{:.3},\"queries\":{},\"admin_requests\":{},\"errors\":{},\
             \"busy_rejects\":{},\
             \"connections\":{},\"active\":{},\"qps\":{:.1},\"p50_us\":{},\"p99_us\":{},\
             \"queue\":{{\"p50_us\":{},\"p99_us\":{}}},\
             \"batch_peak\":{},\
             \"worker_panics\":{},\"conn_timeouts\":{},\"request_timeouts\":{},\
             \"reactor\":{{\"registered_fds\":{},\"run_queue_peak\":{},\"wakeups\":{},\
             \"wakeups_per_sec\":{:.1}}},\
             \"conns\":{{\"p50\":{},\"p99\":{}}},\
             \"cost\":{},\
             \"threads\":{},\
             \"store\":{{\"hits\":{},\"misses\":{},\"evictions\":{},\"bytes_read\":{},\
             \"quarantined_tables\":{}}},\
             \"adtree\":{{\"hits\":{},\"builds\":{},\"building\":{},\"coalesced_waits\":{},\
             \"evictions\":{},\"bytes\":{}}}}}",
            json_escape(&self.dataset),
            self.uptime_secs,
            self.queries,
            self.admin_requests,
            self.errors,
            self.busy_rejects,
            self.connections,
            self.active,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.queue_p50_us,
            self.queue_p99_us,
            self.batch_peak,
            self.worker_panics,
            self.conn_timeouts,
            self.request_timeouts,
            self.registered_fds,
            self.run_queue_peak,
            self.wakeups,
            self.wakeups_per_sec,
            self.conns_p50,
            self.conns_p99,
            self.cost.to_json(),
            profile::threads_json(&self.threads),
            self.store.hits,
            self.store.misses,
            self.store.evictions,
            self.store.bytes_read,
            self.store.quarantined_tables,
            self.trees.hits,
            self.trees.builds,
            self.trees.building,
            self.trees.coalesced_waits,
            self.trees.evictions,
            self.trees.bytes,
        )
    }

    /// Fold the serving counters into a run-level [`MjMetrics`] record —
    /// how the serving path joins the same reports as the Möbius Join.
    pub fn merge_into(&self, m: &mut MjMetrics) {
        m.store_hits += self.store.hits;
        m.store_misses += self.store.misses;
        m.store_evictions += self.store.evictions;
        m.adtree_builds += self.trees.builds;
        m.adtree_coalesced += self.trees.coalesced_waits;
        m.adtree_evictions += self.trees.evictions;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_log2_micros() {
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 0);
        assert_eq!(LatencyHistogram::bucket_of(2), 1);
        assert_eq!(LatencyHistogram::bucket_of(3), 2);
        assert_eq!(LatencyHistogram::bucket_of(4), 2);
        assert_eq!(LatencyHistogram::bucket_of(1024), 10);
        assert_eq!(LatencyHistogram::bucket_of(u128::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_bound_recorded_latencies() {
        let h = LatencyHistogram::default();
        // 98 fast (≤ 8 µs) + 2 slow (~1 ms): p50 stays in the fast bucket,
        // p99 must reach the slow one.
        for _ in 0..98 {
            h.record(Duration::from_micros(7));
        }
        for _ in 0..2 {
            h.record(Duration::from_micros(900));
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_upper_us(0.50), 8);
        assert_eq!(h.quantile_upper_us(0.99), 1024);
        assert_eq!(h.sum(), 98 * 7 + 2 * 900);
        assert_eq!(LatencyHistogram::default().quantile_upper_us(0.5), 0);
    }

    #[test]
    fn quantile_edge_cases_stay_in_bounds() {
        // Empty histogram: every quantile reports 0, not the top bound.
        let empty = LatencyHistogram::default();
        for q in [0.0, 0.5, 1.0, 2.0, -1.0] {
            assert_eq!(empty.quantile_upper_us(q), 0, "q={q}");
        }
        // q = 1.0 (and out-of-range q, clamped) must land on the last
        // *occupied* bucket, never index past the array.
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(7));
        h.record(Duration::from_micros(900));
        assert_eq!(h.quantile_upper_us(1.0), 1024);
        assert_eq!(h.quantile_upper_us(5.0), 1024);
        assert_eq!(h.quantile_upper_us(0.0), 8);
        assert_eq!(h.quantile_upper_us(-3.0), 8);
        // A value in the catch-all bucket resolves to its bound.
        let top = LatencyHistogram::default();
        top.record_value(u64::MAX);
        assert_eq!(top.quantile_upper_us(1.0), 1u64 << (BUCKETS - 1));
    }

    #[test]
    fn buckets_accessor_matches_recorded_counts() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_micros(3)); // (2,4] ⇒ bucket 2
        h.record(Duration::from_micros(4));
        let buckets = h.buckets();
        assert_eq!(buckets.len(), BUCKETS);
        assert_eq!(buckets[2], (4, 2));
        assert_eq!(buckets.iter().map(|(_, c)| c).sum::<u64>(), h.count());
        assert!(buckets.windows(2).all(|w| w[0].0 < w[1].0), "bounds not ascending");
    }

    #[test]
    fn snapshot_json_has_the_key_fields() {
        let m = ServeMetrics::default();
        m.queries.fetch_add(3, Relaxed);
        m.admin_requests.fetch_add(2, Relaxed);
        m.latency.record(Duration::from_micros(5));
        m.wakeups.fetch_add(10, Relaxed);
        m.registered_fds.fetch_add(4, Relaxed);
        m.run_queue_peak.fetch_max(9, Relaxed);
        m.batch_peak.fetch_max(2, Relaxed);
        m.conns.record_value(3);
        m.worker_panics.fetch_add(1, Relaxed);
        m.conn_timeouts.fetch_add(5, Relaxed);
        m.request_timeouts.fetch_add(6, Relaxed);
        m.queue_wait.record(Duration::from_micros(3));
        let store = StoreStats { quarantined_tables: 7, ..Default::default() };
        // A dataset name with JSON metacharacters must come out escaped —
        // the audit that every string field routes through json_escape.
        let snap = m.snapshot(store, TreeStats::default(), "uw\"cse\\");
        let j = snap.to_json();
        for key in [
            "\"dataset\":\"uw\\\"cse\\\\\"",
            "\"queue\":{\"p50_us\":4,\"p99_us\":4}",
            "\"queries\":3",
            "\"admin_requests\":2",
            "\"cost\":{\"tables_loaded\":",
            "\"threads\":{\"worker\":{\"busy_us\":",
            "\"qps\":",
            "\"p99_us\":",
            "\"adtree\"",
            "\"store\"",
            "\"reactor\":{\"registered_fds\":4",
            "\"run_queue_peak\":9",
            "\"wakeups\":10",
            "\"batch_peak\":2",
            "\"conns\":{\"p50\":4,\"p99\":4}",
            "\"building\":0",
            "\"worker_panics\":1",
            "\"conn_timeouts\":5",
            "\"request_timeouts\":6",
            "\"quarantined_tables\":7",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // Round-trips through the flat-JSON field extractor; `builds`
        // must keep resolving to the adtree counter, not `building`.
        let f = |k| super::super::protocol::json_field(&j, k);
        assert_eq!(f("queries").as_deref(), Some("3"));
        assert_eq!(f("builds").as_deref(), Some("0"));
        assert_eq!(f("registered_fds").as_deref(), Some("4"));
        assert_eq!(f("batch_peak").as_deref(), Some("2"));
        assert_eq!(f("worker_panics").as_deref(), Some("1"));
        assert_eq!(f("conn_timeouts").as_deref(), Some("5"));
        assert_eq!(f("request_timeouts").as_deref(), Some("6"));
        assert_eq!(f("quarantined_tables").as_deref(), Some("7"));
    }

    #[test]
    fn record_value_buckets_connection_counts() {
        let h = LatencyHistogram::default();
        for _ in 0..9 {
            h.record_value(100); // (64,128] bucket ⇒ upper bound 128
        }
        h.record_value(10_000);
        assert_eq!(h.count(), 10);
        assert_eq!(h.quantile_upper_us(0.50), 128);
        assert_eq!(h.quantile_upper_us(0.99), 16_384);
    }

    #[test]
    fn snapshot_merges_into_mj_metrics() {
        let store = StoreStats { hits: 2, misses: 1, ..Default::default() };
        let trees =
            TreeStats { builds: 4, coalesced_waits: 3, evictions: 1, ..Default::default() };
        let snap = ServeMetrics::default().snapshot(store, trees, "uwcse");
        let mut m = MjMetrics::default();
        snap.merge_into(&mut m);
        assert_eq!((m.store_hits, m.store_misses), (2, 1));
        assert_eq!(
            (m.adtree_builds, m.adtree_coalesced, m.adtree_evictions),
            (4, 3, 1)
        );
    }
}
