//! Concurrent load generator for the count server (`mrss bench-serve`).
//!
//! Drives one socket per client thread with a deterministic query batch
//! ([`gen_queries`]), records client-side latency in the same fixed-bucket
//! histogram the server uses, and emits `BENCH_serve.json` — the serving
//! path's entry in the repo's measured perf trajectory. Answers come back
//! tagged with their original batch index, so the report renders the
//! canonical answers document byte-comparable with `mrss query --fresh`
//! (what the `serve-smoke` CI job diffs).

use crate::schema::Schema;
use crate::store::gen_queries;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::LatencyHistogram;
use super::protocol::{json_field, parse_count_response, render_answers};

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7171`.
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Total queries across all clients.
    pub queries: usize,
    /// Seed for the deterministic query batch (matches `query --gen`).
    pub seed: u64,
    /// Fetch a final `STATS` snapshot after the run.
    pub stats: bool,
    /// Send `SHUTDOWN` after the run and require the `BYE` ack.
    pub shutdown: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            clients: 8,
            queries: 200,
            seed: 7,
            stats: true,
            shutdown: false,
        }
    }
}

/// Outcome of one load-generation run.
#[derive(Debug)]
pub struct LoadgenReport {
    /// Successful `(query, count)` answers in original batch order.
    pub answers: Vec<(String, u128)>,
    /// `(query, error)` responses in original batch order.
    pub errors: Vec<(String, String)>,
    pub clients: usize,
    pub wall: Duration,
    /// Client-observed throughput (answers + errors per second).
    pub qps: f64,
    /// Client-side latency bucket upper bounds, µs.
    pub p50_us: u64,
    pub p99_us: u64,
    /// The server's final `STATS` JSON object, when requested.
    pub server_stats: Option<String>,
}

impl LoadgenReport {
    /// The canonical answers document (`mrss query` shape) — only valid
    /// for diffing when `errors` is empty, which the caller must check.
    pub fn answers_json(&self) -> String {
        render_answers(&self.answers)
    }

    /// Render `BENCH_serve.json`.
    pub fn bench_json(&self, dataset: &str) -> String {
        let server = self.server_stats.as_deref().unwrap_or("null");
        format!(
            "{{\n  \"bench\": \"serve\",\n  \"dataset\": \"{dataset}\",\n  \"clients\": {},\n  \
             \"queries\": {},\n  \"errors\": {},\n  \"wall_secs\": {:.4},\n  \"qps\": {:.1},\n  \
             \"client_p50_us\": {},\n  \"client_p99_us\": {},\n  \"server\": {server}\n}}\n",
            self.clients,
            self.answers.len() + self.errors.len(),
            self.errors.len(),
            self.wall.as_secs_f64(),
            self.qps,
            self.p50_us,
            self.p99_us,
        )
    }

    /// Did the server report zero duplicate ADtree builds? (Builds may at
    /// most equal the number of distinct stored tables; coalesced waits
    /// prove contention existed without duplicating work.) `None` when no
    /// server stats were fetched.
    pub fn zero_duplicate_builds(&self, stored_tables: u64) -> Option<bool> {
        let stats = self.server_stats.as_deref()?;
        let builds: u64 = json_field(stats, "builds")?.parse().ok()?;
        Some(builds <= stored_tables)
    }
}

/// One client's share of the batch: every `clients`-th query, interleaved
/// so all connections stay busy for the whole run.
fn shard(queries: &[String], client: usize, clients: usize) -> Vec<(usize, String)> {
    queries
        .iter()
        .enumerate()
        .skip(client)
        .step_by(clients)
        .map(|(i, q)| (i, q.clone()))
        .collect()
}

/// Run the load: `clients` threads, `queries` total, against `addr`.
/// Connection-level failures abort the run; per-query error responses are
/// recorded and reported, not fatal.
pub fn run(schema: &Schema, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let clients = cfg.clients.max(1);
    let queries = gen_queries(schema, cfg.queries, cfg.seed);
    let hist = Arc::new(LatencyHistogram::default());

    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        let mine = shard(&queries, c, clients);
        let addr = cfg.addr.clone();
        let hist = Arc::clone(&hist);
        handles.push(std::thread::spawn(
            move || -> Result<Vec<(usize, Result<u128, String>)>> {
                let stream = TcpStream::connect(&addr)
                    .with_context(|| format!("client {c}: connecting to {addr}"))?;
                stream.set_nodelay(true).ok();
                let mut w = BufWriter::new(stream.try_clone().context("cloning stream")?);
                let mut r = BufReader::new(stream);
                let mut out = Vec::with_capacity(mine.len());
                let mut line = String::new();
                for (idx, q) in mine {
                    let t = Instant::now();
                    writeln!(w, "{q}").with_context(|| format!("client {c}: send"))?;
                    w.flush().with_context(|| format!("client {c}: flush"))?;
                    line.clear();
                    let n = r.read_line(&mut line).with_context(|| format!("client {c}: recv"))?;
                    if n == 0 {
                        crate::bail!("client {c}: server closed the connection mid-run");
                    }
                    hist.record(t.elapsed());
                    out.push((idx, parse_count_response(&line)));
                }
                Ok(out)
            },
        ));
    }

    let mut tagged: Vec<(usize, Result<u128, String>)> = Vec::with_capacity(queries.len());
    for h in handles {
        tagged.extend(h.join().map_err(|_| crate::anyhow!("client thread panicked"))??);
    }
    let wall = t0.elapsed();
    tagged.sort_by_key(|&(i, _)| i);

    let mut answers = Vec::new();
    let mut errors = Vec::new();
    for (i, outcome) in tagged {
        match outcome {
            Ok(c) => answers.push((queries[i].clone(), c)),
            Err(e) => errors.push((queries[i].clone(), e)),
        }
    }

    let server_stats = if cfg.stats { Some(control(&cfg.addr, "STATS")?) } else { None };
    if cfg.shutdown {
        let bye = control(&cfg.addr, "SHUTDOWN")?;
        if !(bye == "BYE" || bye.contains("\"bye\"")) {
            crate::bail!("expected BYE ack to SHUTDOWN, got `{bye}`");
        }
    }

    let n = queries.len();
    Ok(LoadgenReport {
        answers,
        errors,
        clients,
        wall,
        qps: n as f64 / wall.as_secs_f64().max(1e-9),
        p50_us: hist.quantile_upper_us(0.50),
        p99_us: hist.quantile_upper_us(0.99),
        server_stats,
    })
}

/// One request/response exchange on a fresh control connection.
fn control(addr: &str, line: &str) -> Result<String> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("control: connecting to {addr}"))?;
    let mut w = BufWriter::new(stream.try_clone().context("control: cloning stream")?);
    let mut r = BufReader::new(stream);
    writeln!(w, "{line}").context("control: send")?;
    w.flush().context("control: flush")?;
    let mut resp = String::new();
    r.read_line(&mut resp).context("control: recv")?;
    Ok(resp.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_partitions_the_batch_exactly() {
        let qs: Vec<String> = (0..10).map(|i| format!("q{i}")).collect();
        let mut seen = vec![false; qs.len()];
        for c in 0..3 {
            for (i, q) in shard(&qs, c, 3) {
                assert_eq!(q, format!("q{i}"));
                assert!(!seen[i], "query {i} sharded twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every query must be assigned");
    }

    #[test]
    fn bench_json_and_duplicate_build_check() {
        let rep = LoadgenReport {
            answers: vec![("a=1".into(), 5)],
            errors: vec![],
            clients: 8,
            wall: Duration::from_millis(500),
            qps: 2.0,
            p50_us: 64,
            p99_us: 512,
            server_stats: Some(
                "{\"queries\":1,\"adtree\":{\"hits\":9,\"builds\":3,\"coalesced_waits\":2,\
                 \"evictions\":0,\"bytes\":10}}"
                    .to_string(),
            ),
        };
        let j = rep.bench_json("uwcse");
        for key in ["\"bench\": \"serve\"", "\"clients\": 8", "\"client_p99_us\": 512"] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(rep.zero_duplicate_builds(12), Some(true));
        assert_eq!(rep.zero_duplicate_builds(2), Some(false));
        assert_eq!(
            LoadgenReport { server_stats: None, ..rep }.zero_duplicate_builds(12),
            None
        );
    }
}
