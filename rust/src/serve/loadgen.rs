//! Concurrent load generator for the count server (`mrss bench-serve`).
//!
//! Drives one socket per client thread with a deterministic query batch
//! ([`gen_queries`]), records client-side latency in the same fixed-bucket
//! histogram the server uses, and emits `BENCH_serve.json` — the serving
//! path's entry in the repo's measured perf trajectory. Answers come back
//! tagged with their original batch index, so the report renders the
//! canonical answers document byte-comparable with `mrss query --fresh`
//! (what the `serve-smoke` CI job diffs).

use crate::schema::Schema;
use crate::store::gen_queries;
use crate::util::error::{Context, Result};
use crate::util::rng::Pcg64;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::LatencyHistogram;
use super::protocol::{is_busy_response, json_field, parse_count_response, render_answers};
use super::reactor::max_open_files;

/// How the hot clients pick queries from the generated batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mix {
    /// Every generated query exactly once, round-robin across clients —
    /// answers stay byte-diffable against `mrss query --fresh`.
    Uniform,
    /// Zipf-skewed sampling with exponent `s`: hot queries repeat, the
    /// tail is rare — the shape a structure-search workload actually has.
    /// Repeats make the answers document non-diffable; the run reports
    /// throughput/latency instead.
    Zipf(f64),
}

impl Mix {
    /// Parse a `--mix` flag value: `uniform` or `zipf:<s>`.
    pub fn parse(s: &str) -> Result<Mix> {
        if s == "uniform" {
            return Ok(Mix::Uniform);
        }
        if let Some(rest) = s.strip_prefix("zipf:") {
            let exp: f64 =
                rest.parse().map_err(|_| crate::anyhow!("bad zipf exponent `{rest}`"))?;
            if !(exp > 0.0 && exp.is_finite()) {
                crate::bail!("zipf exponent must be finite and > 0, got {exp}");
            }
            return Ok(Mix::Zipf(exp));
        }
        crate::bail!("unknown mix `{s}` (uniform|zipf:<s>)")
    }

    pub fn name(&self) -> String {
        match self {
            Mix::Uniform => "uniform".to_string(),
            Mix::Zipf(s) => format!("zipf:{s}"),
        }
    }

    pub fn is_uniform(&self) -> bool {
        matches!(self, Mix::Uniform)
    }
}

/// Zipf-distributed index sampler over `0..n`: `P(i) ∝ 1/(i+1)^s`.
/// Cumulative weights are precomputed once; each draw is one uniform
/// variate plus a binary search.
struct ZipfSampler {
    cum: Vec<f64>,
    total: f64,
}

impl ZipfSampler {
    fn new(n: usize, s: f64) -> ZipfSampler {
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(s);
            cum.push(total);
        }
        ZipfSampler { cum, total }
    }

    fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64() * self.total;
        self.cum.partition_point(|&c| c < u).min(self.cum.len().saturating_sub(1))
    }
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7171`.
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Total queries across all clients.
    pub queries: usize,
    /// Seed for the deterministic query batch (matches `query --gen`).
    pub seed: u64,
    /// Query selection: uniform round-robin or zipf-skewed.
    pub mix: Mix,
    /// Idle connections to open before the hot run and hold through the
    /// final `STATS` — the 10k-connections claim, reproduced on demand.
    pub idle: usize,
    /// Fetch a final `STATS` snapshot after the run.
    pub stats: bool,
    /// Send `SHUTDOWN` after the run and require the `BYE` ack.
    pub shutdown: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            clients: 8,
            queries: 200,
            seed: 7,
            mix: Mix::Uniform,
            idle: 0,
            stats: true,
            shutdown: false,
        }
    }
}

/// Outcome of one load-generation run.
#[derive(Debug)]
pub struct LoadgenReport {
    /// Successful `(query, count)` answers in original batch order.
    pub answers: Vec<(String, u128)>,
    /// `(query, error)` responses in original batch order.
    pub errors: Vec<(String, String)>,
    pub clients: usize,
    pub wall: Duration,
    /// Client-observed throughput (answers + errors per second).
    pub qps: f64,
    /// Client-side latency bucket upper bounds, µs.
    pub p50_us: u64,
    pub p99_us: u64,
    /// Query mix the run used (`uniform` / `zipf:<s>`).
    pub mix: String,
    /// Idle connections actually held open during the hot run (may be
    /// below the requested `--idle` when the fd limit clamps the pool).
    pub idle_open: usize,
    /// `BUSY` responses the clients absorbed by backing off and resending
    /// instead of recording an error — admission-control pressure made
    /// visible without failing the run.
    pub busy_retries: u64,
    /// The server's final `STATS` JSON object, when requested.
    pub server_stats: Option<String>,
    /// The server's final `TOP 5` heavy-hitter JSON, when stats were
    /// requested — which plan shapes dominated this run's load.
    pub server_top: Option<String>,
    /// The server's closing `HISTORY 60` window, when stats were requested
    /// — the per-second series covering the run's tail.
    pub server_history: Option<String>,
    /// A closing `PROFILE 2` capture (folded span stacks + self-time
    /// table), when stats were requested — where the server spent the
    /// run's final seconds, attached to the perf artifact.
    pub server_profile: Option<String>,
    /// The `"process"` block of that capture (RSS, CPU, fds, ctx
    /// switches), split out so dashboards can read it without parsing
    /// the folded stacks.
    pub server_process: Option<String>,
}

impl LoadgenReport {
    /// The canonical answers document (`mrss query` shape) — only valid
    /// for diffing when `errors` is empty, which the caller must check.
    pub fn answers_json(&self) -> String {
        render_answers(&self.answers)
    }

    /// Render `BENCH_serve.json`.
    pub fn bench_json(&self, dataset: &str) -> String {
        let server = self.server_stats.as_deref().unwrap_or("null");
        let top = self.server_top.as_deref().unwrap_or("null");
        let history = self.server_history.as_deref().unwrap_or("null");
        let profile = self.server_profile.as_deref().unwrap_or("null");
        let process = self.server_process.as_deref().unwrap_or("null");
        format!(
            "{{\n  \"bench\": \"serve\",\n  \"dataset\": \"{dataset}\",\n  \"clients\": {},\n  \
             \"mix\": \"{}\",\n  \"idle\": {},\n  \
             \"queries\": {},\n  \"errors\": {},\n  \"busy_retries\": {},\n  \
             \"wall_secs\": {:.4},\n  \"qps\": {:.1},\n  \
             \"client_p50_us\": {},\n  \"client_p99_us\": {},\n  \"server\": {server},\n  \
             \"server_top\": {top},\n  \"server_history\": {history},\n  \
             \"server_profile\": {profile},\n  \"server_process\": {process}\n}}\n",
            self.clients,
            self.mix,
            self.idle_open,
            self.answers.len() + self.errors.len(),
            self.errors.len(),
            self.busy_retries,
            self.wall.as_secs_f64(),
            self.qps,
            self.p50_us,
            self.p99_us,
        )
    }

    /// Did the server report zero duplicate ADtree builds? (Builds may at
    /// most equal the number of distinct stored tables; coalesced waits
    /// prove contention existed without duplicating work.) `None` when no
    /// server stats were fetched.
    pub fn zero_duplicate_builds(&self, stored_tables: u64) -> Option<bool> {
        let stats = self.server_stats.as_deref()?;
        let builds: u64 = json_field(stats, "builds")?.parse().ok()?;
        Some(builds <= stored_tables)
    }
}

/// Give up on a query after this many consecutive `BUSY` replies: the last
/// one is recorded as the query's error, so a saturated server still
/// terminates the run with an honest report instead of spinning.
const MAX_BUSY_RETRIES: u32 = 8;

/// Backoff before the `attempt`-th resend of a shed query: exponential
/// from 2 ms, capped at 200 ms, plus up-to-one-step seeded jitter so the
/// shed clients don't resynchronize into another thundering herd.
fn busy_backoff(attempt: u32, rng: &mut Pcg64) -> Duration {
    let base_ms = (2u64 << attempt.min(16)).min(200);
    Duration::from_millis(base_ms + rng.below(base_ms))
}

/// One client's share of the batch: every `clients`-th query, interleaved
/// so all connections stay busy for the whole run.
fn shard(queries: &[String], client: usize, clients: usize) -> Vec<(usize, String)> {
    queries
        .iter()
        .enumerate()
        .skip(client)
        .step_by(clients)
        .map(|(i, q)| (i, q.clone()))
        .collect()
}

/// One client's zipf-skewed selection: the same index set as [`shard`]
/// (so tags stay unique across clients), but each tag carries a query
/// sampled from the skewed distribution instead of the round-robin one.
fn skewed(queries: &[String], client: usize, clients: usize, s: f64, seed: u64) -> Vec<(usize, String)> {
    let sampler = ZipfSampler::new(queries.len(), s);
    let mut rng = Pcg64::new(seed, client as u64 + 1);
    let n = queries.len();
    let count = n / clients + usize::from(client < n % clients);
    (0..count)
        .map(|k| (client + k * clients, queries[sampler.sample(&mut rng)].clone()))
        .collect()
}

/// Open up to `want` idle connections, clamped well below the process's
/// open-file limit so the hot clients and control connections always fit.
fn open_idle_pool(addr: &str, want: usize, clients: usize) -> Vec<TcpStream> {
    if want == 0 {
        return Vec::new();
    }
    let budget = max_open_files()
        .map(|lim| (lim as usize).saturating_sub(clients + 64))
        .unwrap_or(want);
    let target = want.min(budget);
    let mut pool = Vec::with_capacity(target);
    for _ in 0..target {
        match TcpStream::connect(addr) {
            Ok(s) => pool.push(s),
            Err(_) => break, // local fd limit or server shed: hold what we got
        }
    }
    pool
}

/// Run the load: `clients` threads, `queries` total, against `addr`,
/// with `cfg.idle` idle connections held open for the whole run.
/// Connection-level failures abort the run; per-query error responses are
/// recorded and reported, not fatal.
pub fn run(schema: &Schema, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let clients = cfg.clients.max(1);
    let queries = gen_queries(schema, cfg.queries, cfg.seed);
    let hist = Arc::new(LatencyHistogram::default());
    let busy_retries = Arc::new(AtomicU64::new(0));

    // The idle pool goes up first so the hot run (and its p50/p99) is
    // measured with every idle connection registered server-side.
    let idle_pool = open_idle_pool(&cfg.addr, cfg.idle, clients);
    let idle_open = idle_pool.len();

    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        let mine = match cfg.mix {
            Mix::Uniform => shard(&queries, c, clients),
            Mix::Zipf(s) => skewed(&queries, c, clients, s, cfg.seed),
        };
        let addr = cfg.addr.clone();
        let hist = Arc::clone(&hist);
        let retries = Arc::clone(&busy_retries);
        let seed = cfg.seed;
        handles.push(std::thread::spawn(
            move || -> Result<Vec<(usize, String, Result<u128, String>)>> {
                let stream = TcpStream::connect(&addr)
                    .with_context(|| format!("client {c}: connecting to {addr}"))?;
                stream.set_nodelay(true).ok();
                let mut w = BufWriter::new(stream.try_clone().context("cloning stream")?);
                let mut r = BufReader::new(stream);
                let mut out = Vec::with_capacity(mine.len());
                let mut line = String::new();
                // Jitter stream for BUSY backoff: seeded per client so a
                // contended run replays identically.
                let mut rng = Pcg64::new(seed, 0x6u64 << 32 | c as u64);
                for (idx, q) in mine {
                    let t = Instant::now();
                    let mut attempt = 0u32;
                    loop {
                        writeln!(w, "{q}").with_context(|| format!("client {c}: send"))?;
                        w.flush().with_context(|| format!("client {c}: flush"))?;
                        line.clear();
                        let n = r
                            .read_line(&mut line)
                            .with_context(|| format!("client {c}: recv"))?;
                        if n == 0 {
                            crate::bail!("client {c}: server closed the connection mid-run");
                        }
                        if is_busy_response(&line) && attempt < MAX_BUSY_RETRIES {
                            retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(busy_backoff(attempt, &mut rng));
                            attempt += 1;
                            continue;
                        }
                        break;
                    }
                    // Latency includes the retries: that is what this
                    // client actually waited for the answer.
                    hist.record(t.elapsed());
                    out.push((idx, q, parse_count_response(&line)));
                }
                Ok(out)
            },
        ));
    }

    let mut tagged: Vec<(usize, String, Result<u128, String>)> =
        Vec::with_capacity(queries.len());
    for h in handles {
        tagged.extend(h.join().map_err(|_| crate::anyhow!("client thread panicked"))??);
    }
    let wall = t0.elapsed();
    tagged.sort_by(|a, b| a.0.cmp(&b.0));

    let mut answers = Vec::new();
    let mut errors = Vec::new();
    for (_, q, outcome) in tagged {
        match outcome {
            Ok(c) => answers.push((q, c)),
            Err(e) => errors.push((q, e)),
        }
    }

    // STATS is fetched while the idle pool is still open, so the reported
    // `active` / `conns` distribution reflects the loaded server. TOP,
    // HISTORY, and the closing PROFILE ride on the same control path: the
    // heavy-hitter table, per-second window, and folded span stacks all
    // belong to the loaded server. PROFILE blocks for its 2 s capture
    // window (tolerated: the hot run is over, only the report waits).
    let (server_stats, server_top, server_history, server_profile) = if cfg.stats {
        (
            Some(control(&cfg.addr, "STATS")?),
            Some(control(&cfg.addr, "TOP 5")?),
            Some(control(&cfg.addr, "HISTORY 60")?),
            Some(control(&cfg.addr, "PROFILE 2")?),
        )
    } else {
        (None, None, None, None)
    };
    let server_process =
        server_profile.as_deref().and_then(|p| extract_flat_object(p, "process"));
    drop(idle_pool);
    if cfg.shutdown {
        let bye = control(&cfg.addr, "SHUTDOWN")?;
        if !(bye == "BYE" || bye.contains("\"bye\"")) {
            crate::bail!("expected BYE ack to SHUTDOWN, got `{bye}`");
        }
    }

    let n = queries.len();
    Ok(LoadgenReport {
        answers,
        errors,
        clients,
        wall,
        qps: n as f64 / wall.as_secs_f64().max(1e-9),
        p50_us: hist.quantile_upper_us(0.50),
        p99_us: hist.quantile_upper_us(0.99),
        mix: cfg.mix.name(),
        idle_open,
        busy_retries: busy_retries.load(Ordering::Relaxed),
        server_stats,
        server_top,
        server_history,
        server_profile,
        server_process,
    })
}

/// Pull one `"key":{…}` sub-object out of a JSON line. Only valid for
/// *flat* objects (no nested braces) — exactly the shape of the
/// `"process"` block in a `PROFILE` response.
fn extract_flat_object(json: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":{{");
    let start = json.find(&pat)? + pat.len() - 1;
    let end = start + json[start..].find('}')?;
    Some(json[start..=end].to_string())
}

/// One request/response exchange on a fresh control connection.
fn control(addr: &str, line: &str) -> Result<String> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("control: connecting to {addr}"))?;
    let mut w = BufWriter::new(stream.try_clone().context("control: cloning stream")?);
    let mut r = BufReader::new(stream);
    writeln!(w, "{line}").context("control: send")?;
    w.flush().context("control: flush")?;
    let mut resp = String::new();
    r.read_line(&mut resp).context("control: recv")?;
    Ok(resp.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_partitions_the_batch_exactly() {
        let qs: Vec<String> = (0..10).map(|i| format!("q{i}")).collect();
        let mut seen = vec![false; qs.len()];
        for c in 0..3 {
            for (i, q) in shard(&qs, c, 3) {
                assert_eq!(q, format!("q{i}"));
                assert!(!seen[i], "query {i} sharded twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every query must be assigned");
    }

    #[test]
    fn bench_json_and_duplicate_build_check() {
        let rep = LoadgenReport {
            answers: vec![("a=1".into(), 5)],
            errors: vec![],
            clients: 8,
            wall: Duration::from_millis(500),
            qps: 2.0,
            p50_us: 64,
            p99_us: 512,
            mix: "uniform".to_string(),
            idle_open: 0,
            busy_retries: 3,
            server_stats: Some(
                "{\"queries\":1,\"adtree\":{\"hits\":9,\"builds\":3,\"coalesced_waits\":2,\
                 \"evictions\":0,\"bytes\":10}}"
                    .to_string(),
            ),
            server_top: Some("{\"entries\":1,\"capacity\":64}".to_string()),
            server_history: None,
            server_profile: Some("{\"secs\":2,\"ticks\":12,\"folded\":[]}".to_string()),
            server_process: Some("{\"rss_bytes\":1048576,\"open_fds\":20}".to_string()),
        };
        let j = rep.bench_json("uwcse");
        for key in [
            "\"bench\": \"serve\"",
            "\"clients\": 8",
            "\"client_p99_us\": 512",
            "\"mix\": \"uniform\"",
            "\"idle\": 0",
            "\"busy_retries\": 3",
            "\"server_top\": {\"entries\":1,\"capacity\":64}",
            "\"server_history\": null",
            "\"server_profile\": {\"secs\":2,\"ticks\":12,\"folded\":[]}",
            "\"server_process\": {\"rss_bytes\":1048576,\"open_fds\":20}",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(rep.zero_duplicate_builds(12), Some(true));
        assert_eq!(rep.zero_duplicate_builds(2), Some(false));
        assert_eq!(
            LoadgenReport { server_stats: None, ..rep }.zero_duplicate_builds(12),
            None
        );
    }

    #[test]
    fn extract_flat_object_pulls_the_process_block() {
        let resp = "{\"secs\":2,\"folded\":[{\"stack\":\"a;b\",\"samples\":3}],\
                    \"process\":{\"rss_bytes\":42,\"open_fds\":7}}";
        assert_eq!(
            extract_flat_object(resp, "process").as_deref(),
            Some("{\"rss_bytes\":42,\"open_fds\":7}")
        );
        assert_eq!(extract_flat_object(resp, "missing"), None);
        assert_eq!(extract_flat_object("{\"error\":\"disabled\"}", "process"), None);
    }

    #[test]
    fn busy_backoff_grows_caps_and_jitters_deterministically() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 1);
        let mut prev_base = 0;
        for attempt in 0..MAX_BUSY_RETRIES {
            let da = busy_backoff(attempt, &mut a);
            let db = busy_backoff(attempt, &mut b);
            assert_eq!(da, db, "same seed must jitter identically");
            let base_ms = (2u64 << attempt).min(200);
            assert!(da >= Duration::from_millis(base_ms), "below base at {attempt}");
            assert!(da < Duration::from_millis(2 * base_ms), "over 2x base at {attempt}");
            assert!(base_ms >= prev_base, "backoff must be monotone");
            prev_base = base_ms;
        }
        // Far past the cap: stays bounded, no shift overflow.
        assert!(busy_backoff(60, &mut a) < Duration::from_millis(400));
    }

    #[test]
    fn mix_parses_uniform_and_zipf() {
        assert_eq!(Mix::parse("uniform").unwrap(), Mix::Uniform);
        assert_eq!(Mix::parse("zipf:1.1").unwrap(), Mix::Zipf(1.1));
        assert_eq!(Mix::parse("zipf:0.5").unwrap().name(), "zipf:0.5");
        assert!(Mix::parse("zipf:").is_err());
        assert!(Mix::parse("zipf:-1").is_err());
        assert!(Mix::parse("zipf:nope").is_err());
        assert!(Mix::parse("gauss").is_err());
        assert!(Mix::Uniform.is_uniform());
        assert!(!Mix::Zipf(1.0).is_uniform());
    }

    #[test]
    fn zipf_sampler_is_skewed_and_deterministic() {
        let sampler = ZipfSampler::new(100, 1.2);
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            let i = sampler.sample(&mut a);
            assert_eq!(i, sampler.sample(&mut b), "same seed must sample identically");
            assert!(i < 100);
            counts[i] += 1;
        }
        // Head beats tail by a wide margin under s=1.2.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[90..].iter().sum();
        assert!(head > 10 * tail.max(1), "zipf head {head} vs tail {tail} not skewed");
        assert!(counts[0] > counts[50], "rank 0 must dominate rank 50");
    }

    #[test]
    fn skewed_selection_keeps_tags_unique_and_total_constant() {
        let qs: Vec<String> = (0..10).map(|i| format!("q{i}")).collect();
        let mut seen = vec![false; qs.len()];
        let mut total = 0;
        for c in 0..3 {
            for (tag, q) in skewed(&qs, c, 3, 1.0, 7) {
                assert!(!seen[tag], "tag {tag} assigned twice");
                seen[tag] = true;
                assert!(qs.contains(&q));
                total += 1;
            }
        }
        assert_eq!(total, qs.len(), "skewed mix must issue the same total load");
        assert!(seen.iter().all(|&s| s));
    }
}
