//! Dependency-free readiness polling: a thin raw-syscall wrapper over
//! `poll(2)` (every unix) and `epoll(7)` (Linux), plus the wake primitive
//! (`eventfd(2)` / nonblocking pipe) the reactor shards block on.
//!
//! The crate deliberately has no external dependencies, so the syscalls
//! are declared directly against the libc that `std` already links — the
//! same discipline as the hand-rolled `anyhow`/`rand` shims under
//! `util/`. Only the level-triggered subset the server needs is wrapped:
//! register / modify / deregister an fd with a `usize` token, and wait
//! for readiness events with an optional timeout.
//!
//! Everything here is unix-only at runtime; on other platforms the
//! constructors return a clean error so `serve --listen` fails with a
//! message instead of a compile break.

use crate::util::error::Result;
use std::time::Duration;

/// Raw file descriptor (mirrors `std::os::unix::io::RawFd`; aliased here
/// so `server.rs` stays free of platform `cfg`s).
pub type RawFd = i32;

/// Extract the raw fd of a socket/listener without importing unix traits
/// at the call site.
#[cfg(unix)]
pub fn fd_of<T: std::os::unix::io::AsRawFd>(t: &T) -> RawFd {
    t.as_raw_fd()
}

#[cfg(not(unix))]
pub fn fd_of<T>(_t: &T) -> RawFd {
    -1
}

/// What a registration wants to hear about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const READ: Interest = Interest { read: true, write: false };
    pub const WRITE: Interest = Interest { read: false, write: true };
    pub const BOTH: Interest = Interest { read: true, write: true };
}

/// One readiness event. `readable` includes hangup/error conditions so a
/// dead peer always surfaces as a (zero-byte / errored) read.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// Which readiness backend a [`Poller`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollerKind {
    /// `poll(2)` — portable across unix, O(registered fds) per wait.
    Poll,
    /// `epoll(7)` — Linux, O(ready fds) per wait.
    #[cfg(target_os = "linux")]
    Epoll,
}

impl PollerKind {
    /// The best backend this OS offers (epoll on Linux, poll elsewhere).
    pub fn os_default() -> PollerKind {
        #[cfg(target_os = "linux")]
        {
            PollerKind::Epoll
        }
        #[cfg(not(target_os = "linux"))]
        {
            PollerKind::Poll
        }
    }

    /// Parse a `--poller` flag value.
    pub fn parse(s: &str) -> Result<PollerKind> {
        match s {
            "poll" => Ok(PollerKind::Poll),
            #[cfg(target_os = "linux")]
            "epoll" => Ok(PollerKind::Epoll),
            other => crate::bail!("unknown poller `{other}` (poll|epoll, epoll is Linux-only)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PollerKind::Poll => "poll",
            #[cfg(target_os = "linux")]
            PollerKind::Epoll => "epoll",
        }
    }
}

/// A level-triggered readiness poller over one backend.
pub enum Poller {
    Poll(PollPoller),
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
}

impl Poller {
    pub fn new(kind: PollerKind) -> Result<Poller> {
        match kind {
            PollerKind::Poll => Ok(Poller::Poll(PollPoller::new())),
            #[cfg(target_os = "linux")]
            PollerKind::Epoll => Ok(Poller::Epoll(EpollPoller::new()?)),
        }
    }

    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        match self {
            Poller::Poll(p) => p.register(fd, token, interest),
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        match self {
            Poller::Poll(p) => p.modify(fd, token, interest),
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.modify(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        match self {
            Poller::Poll(p) => p.deregister(fd),
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.deregister(fd),
        }
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// elapses (`None` = forever). Ready events are appended to `events`
    /// (cleared first); returns how many were delivered.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> Result<usize> {
        match self {
            Poller::Poll(p) => p.wait(events, timeout),
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(events, timeout),
        }
    }
}

/// Milliseconds for `poll`/`epoll_wait`: `None` ⇒ -1 (forever), rounded
/// up so a 1 ns timeout never busy-spins as 0 ms.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => d.as_millis().min(i32::MAX as u128).max(1) as i32,
    }
}

// ---------------------------------------------------------------------------
// unix syscall layer
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short, c_uint, c_void};

    // `nfds_t` is `unsigned long` on Linux, `unsigned int` on the BSDs.
    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = c_uint;

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    /// `struct pollfd` — identical layout on every unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    /// `struct rlimit`: `rlim_t` is 64-bit on every 64-bit unix.
    #[repr(C)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    #[cfg(target_os = "linux")]
    pub const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(target_os = "linux"))]
    pub const RLIMIT_NOFILE: c_int = 8;

    pub const F_SETFL: c_int = 4;
    pub const F_GETFL: c_int = 3;
    #[cfg(target_os = "linux")]
    pub const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    pub const O_NONBLOCK: c_int = 0x0004;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
        #[cfg(not(target_os = "linux"))]
        pub fn pipe(fds: *mut c_int) -> c_int;
    }

    #[cfg(target_os = "linux")]
    pub mod epoll {
        use std::os::raw::c_int;

        pub const EPOLLIN: u32 = 0x001;
        pub const EPOLLOUT: u32 = 0x004;
        pub const EPOLLERR: u32 = 0x008;
        pub const EPOLLHUP: u32 = 0x010;
        pub const EPOLL_CTL_ADD: c_int = 1;
        pub const EPOLL_CTL_DEL: c_int = 2;
        pub const EPOLL_CTL_MOD: c_int = 3;
        pub const EPOLL_CLOEXEC: c_int = 0o2000000;

        /// `struct epoll_event` — packed on x86-64 (kernel ABI), naturally
        /// aligned everywhere else.
        #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
        #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
        #[derive(Clone, Copy)]
        pub struct EpollEvent {
            pub events: u32,
            pub data: u64,
        }

        extern "C" {
            pub fn epoll_create1(flags: c_int) -> c_int;
            pub fn epoll_ctl(
                epfd: c_int,
                op: c_int,
                fd: c_int,
                event: *mut EpollEvent,
            ) -> c_int;
            pub fn epoll_wait(
                epfd: c_int,
                events: *mut EpollEvent,
                maxevents: c_int,
                timeout: c_int,
            ) -> c_int;
        }

        #[cfg(target_os = "linux")]
        pub const EFD_CLOEXEC: c_int = 0o2000000;
        #[cfg(target_os = "linux")]
        pub const EFD_NONBLOCK: c_int = 0o4000;

        extern "C" {
            pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        }
    }
}

/// Last OS error as the crate's error type, with context.
#[cfg(unix)]
fn os_err(what: &str) -> crate::util::error::Error {
    crate::anyhow!("{what}: {}", std::io::Error::last_os_error())
}

#[cfg(unix)]
fn last_kind() -> std::io::ErrorKind {
    std::io::Error::last_os_error().kind()
}

// ---------------------------------------------------------------------------
// poll(2) backend
// ---------------------------------------------------------------------------

/// The portable backend: one `pollfd` per registration, rebuilt revents
/// every wait. Linear modify/deregister — fine for the per-shard fd
/// counts this serves (thousands), and the fallback when epoll is absent.
pub struct PollPoller {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
    tokens: Vec<usize>,
}

impl PollPoller {
    pub fn new() -> PollPoller {
        PollPoller {
            #[cfg(unix)]
            fds: Vec::new(),
            tokens: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    #[cfg(unix)]
    fn events_mask(interest: Interest) -> std::os::raw::c_short {
        let mut m = 0;
        if interest.read {
            m |= sys::POLLIN;
        }
        if interest.write {
            m |= sys::POLLOUT;
        }
        m
    }

    #[cfg(unix)]
    fn position(&self, fd: RawFd) -> Result<usize> {
        self.fds
            .iter()
            .position(|p| p.fd == fd)
            .ok_or_else(|| crate::anyhow!("poll backend: fd {fd} is not registered"))
    }

    #[cfg(unix)]
    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        if self.fds.iter().any(|p| p.fd == fd) {
            crate::bail!("poll backend: fd {fd} registered twice");
        }
        self.fds.push(sys::PollFd { fd, events: Self::events_mask(interest), revents: 0 });
        self.tokens.push(token);
        Ok(())
    }

    #[cfg(unix)]
    pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        let i = self.position(fd)?;
        self.fds[i].events = Self::events_mask(interest);
        self.tokens[i] = token;
        Ok(())
    }

    #[cfg(unix)]
    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        let i = self.position(fd)?;
        self.fds.swap_remove(i);
        self.tokens.swap_remove(i);
        Ok(())
    }

    #[cfg(unix)]
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> Result<usize> {
        events.clear();
        if self.fds.is_empty() {
            // Nothing registered: sleep out the timeout instead of asking
            // the kernel to poll an empty set.
            if let Some(d) = timeout {
                std::thread::sleep(d);
                return Ok(0);
            }
            crate::bail!("poll backend: wait forever on an empty fd set");
        }
        let n = loop {
            let rc = unsafe {
                sys::poll(
                    self.fds.as_mut_ptr(),
                    self.fds.len() as sys::NfdsT,
                    timeout_ms(timeout),
                )
            };
            if rc >= 0 {
                break rc as usize;
            }
            if last_kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(os_err("poll"));
        };
        if n > 0 {
            for (p, &token) in self.fds.iter().zip(&self.tokens) {
                let r = p.revents;
                if r == 0 {
                    continue;
                }
                events.push(Event {
                    token,
                    readable: r & (sys::POLLIN | sys::POLLHUP | sys::POLLERR | sys::POLLNVAL)
                        != 0,
                    writable: r & (sys::POLLOUT | sys::POLLERR) != 0,
                });
            }
        }
        Ok(events.len())
    }

    #[cfg(not(unix))]
    pub fn register(&mut self, _fd: RawFd, _token: usize, _interest: Interest) -> Result<()> {
        crate::bail!("readiness polling requires a unix platform")
    }

    #[cfg(not(unix))]
    pub fn modify(&mut self, _fd: RawFd, _token: usize, _interest: Interest) -> Result<()> {
        crate::bail!("readiness polling requires a unix platform")
    }

    #[cfg(not(unix))]
    pub fn deregister(&mut self, _fd: RawFd) -> Result<()> {
        crate::bail!("readiness polling requires a unix platform")
    }

    #[cfg(not(unix))]
    pub fn wait(&mut self, _events: &mut Vec<Event>, _t: Option<Duration>) -> Result<usize> {
        crate::bail!("readiness polling requires a unix platform")
    }
}

impl Default for PollPoller {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// epoll(7) backend (Linux)
// ---------------------------------------------------------------------------

/// The Linux backend: O(ready) waits, kernel-held registration table.
#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: RawFd,
    buf: Vec<sys::epoll::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    /// How many events one `epoll_wait` can deliver; more simply arrive
    /// on the next wait (level-triggered, nothing is lost).
    const WAIT_BATCH: usize = 512;

    pub fn new() -> Result<EpollPoller> {
        let epfd = unsafe { sys::epoll::epoll_create1(sys::epoll::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(os_err("epoll_create1"));
        }
        Ok(EpollPoller {
            epfd,
            buf: vec![sys::epoll::EpollEvent { events: 0, data: 0 }; Self::WAIT_BATCH],
        })
    }

    fn events_mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.read {
            m |= sys::epoll::EPOLLIN;
        }
        if interest.write {
            m |= sys::epoll::EPOLLOUT;
        }
        m
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        let mut ev = sys::epoll::EpollEvent {
            events: Self::events_mask(interest),
            data: token as u64,
        };
        let rc = unsafe { sys::epoll::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(os_err("epoll_ctl"));
        }
        Ok(())
    }

    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        self.ctl(sys::epoll::EPOLL_CTL_ADD, fd, token, interest)
    }

    pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        self.ctl(sys::epoll::EPOLL_CTL_MOD, fd, token, interest)
    }

    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        // The event argument is ignored for DEL but must be non-null on
        // pre-2.6.9 kernels; pass a dummy either way.
        self.ctl(sys::epoll::EPOLL_CTL_DEL, fd, 0, Interest { read: false, write: false })
    }

    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> Result<usize> {
        events.clear();
        let n = loop {
            let rc = unsafe {
                sys::epoll::epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as std::os::raw::c_int,
                    timeout_ms(timeout),
                )
            };
            if rc >= 0 {
                break rc as usize;
            }
            if last_kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(os_err("epoll_wait"));
        };
        for ev in &self.buf[..n] {
            // Copy out of the (possibly packed) struct before using.
            let mask = ev.events;
            let token = ev.data as usize;
            events.push(Event {
                token,
                readable: mask
                    & (sys::epoll::EPOLLIN | sys::epoll::EPOLLHUP | sys::epoll::EPOLLERR)
                    != 0,
                writable: mask & (sys::epoll::EPOLLOUT | sys::epoll::EPOLLERR) != 0,
            });
        }
        Ok(events.len())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.epfd);
        }
    }
}

// ---------------------------------------------------------------------------
// WakeFd: how another thread interrupts a blocked wait()
// ---------------------------------------------------------------------------

/// A self-wake primitive the reactor registers like any other fd: writing
/// to it makes a blocked [`Poller::wait`] return. `eventfd(2)` on Linux
/// (one fd, counter semantics), a nonblocking pipe elsewhere. This is
/// what replaced the old SHUTDOWN self-connect hack: shutdown and
/// completion delivery both wake the shard through here.
pub struct WakeFd {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakeFd {
    #[cfg(target_os = "linux")]
    pub fn new() -> Result<WakeFd> {
        let fd = unsafe {
            sys::epoll::eventfd(0, sys::epoll::EFD_CLOEXEC | sys::epoll::EFD_NONBLOCK)
        };
        if fd < 0 {
            return Err(os_err("eventfd"));
        }
        Ok(WakeFd { read_fd: fd, write_fd: fd })
    }

    #[cfg(all(unix, not(target_os = "linux")))]
    pub fn new() -> Result<WakeFd> {
        let mut fds = [0 as std::os::raw::c_int; 2];
        if unsafe { sys::pipe(fds.as_mut_ptr()) } < 0 {
            return Err(os_err("pipe"));
        }
        for fd in fds {
            let flags = unsafe { sys::fcntl(fd, sys::F_GETFL, 0) };
            if flags < 0
                || unsafe { sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) } < 0
            {
                let e = os_err("fcntl(O_NONBLOCK) on wake pipe");
                unsafe {
                    sys::close(fds[0]);
                    sys::close(fds[1]);
                }
                return Err(e);
            }
        }
        Ok(WakeFd { read_fd: fds[0], write_fd: fds[1] })
    }

    #[cfg(not(unix))]
    pub fn new() -> Result<WakeFd> {
        crate::bail!("wake fd requires a unix platform")
    }

    /// The fd to register for read interest.
    pub fn fd(&self) -> RawFd {
        self.read_fd
    }

    /// Wake the owning poller. Best-effort and signal-safe: a full pipe /
    /// saturated counter means a wake is already pending, which is all
    /// that matters.
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            let one: u64 = 1;
            unsafe {
                sys::write(self.write_fd, (&one as *const u64).cast(), 8);
            }
        }
    }

    /// Consume all pending wakes so level-triggered polling goes quiet.
    pub fn drain(&self) {
        #[cfg(unix)]
        {
            let mut buf = [0u8; 64];
            loop {
                let n = unsafe { sys::read(self.read_fd, buf.as_mut_ptr().cast(), buf.len()) };
                if n <= 0 {
                    break;
                }
            }
        }
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::close(self.read_fd);
            if self.write_fd != self.read_fd {
                sys::close(self.write_fd);
            }
        }
    }
}

// WakeFd is written from other threads by design.
unsafe impl Send for WakeFd {}
unsafe impl Sync for WakeFd {}

/// The process's open-file limit (`RLIMIT_NOFILE` soft limit), used to
/// clamp idle-connection pools so tests and `loadgen --idle` never trip
/// EMFILE. `None` when the platform can't say.
pub fn max_open_files() -> Option<u64> {
    #[cfg(unix)]
    {
        let mut lim = sys::RLimit { cur: 0, max: 0 };
        if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut lim) } == 0 {
            return Some(lim.cur);
        }
        None
    }
    #[cfg(not(unix))]
    {
        None
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    fn kinds() -> Vec<PollerKind> {
        #[cfg(target_os = "linux")]
        {
            vec![PollerKind::Poll, PollerKind::Epoll]
        }
        #[cfg(not(target_os = "linux"))]
        {
            vec![PollerKind::Poll]
        }
    }

    #[test]
    fn wake_fd_wakes_and_drains() {
        for kind in kinds() {
            let wake = WakeFd::new().unwrap();
            let mut poller = Poller::new(kind).unwrap();
            poller.register(wake.fd(), 7, Interest::READ).unwrap();
            let mut events = Vec::new();
            // Nothing pending: times out empty.
            let n = poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert_eq!(n, 0, "{}: spurious event", kind.name());
            wake.wake();
            wake.wake(); // coalesces
            let n = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(n, 1, "{}", kind.name());
            assert_eq!(events[0].token, 7);
            assert!(events[0].readable);
            wake.drain();
            let n = poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert_eq!(n, 0, "{}: drain must clear readiness", kind.name());
        }
    }

    #[test]
    fn socket_readiness_and_modify_roundtrip() {
        for kind in kinds() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut client = TcpStream::connect(addr).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();

            let mut poller = Poller::new(kind).unwrap();
            let fd = fd_of(&server_side);
            poller.register(fd, 3, Interest::READ).unwrap();
            let mut events = Vec::new();
            // Idle socket: no events.
            let n = poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert_eq!(n, 0, "{}", kind.name());
            client.write_all(b"hi\n").unwrap();
            client.flush().unwrap();
            let n = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(n, 1, "{}", kind.name());
            assert!(events[0].readable && events[0].token == 3);
            // Write interest on a socket with buffer space fires at once.
            poller.modify(fd, 4, Interest::WRITE).unwrap();
            let n = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(n, 1, "{}", kind.name());
            assert!(events[0].writable && events[0].token == 4);
            poller.deregister(fd).unwrap();
            drop(client);
        }
    }

    #[test]
    fn hangup_reports_readable() {
        for kind in kinds() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let client = TcpStream::connect(addr).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();
            let mut poller = Poller::new(kind).unwrap();
            poller.register(fd_of(&server_side), 9, Interest::READ).unwrap();
            drop(client); // peer goes away
            let mut events = Vec::new();
            let n = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert!(n >= 1, "{}", kind.name());
            assert!(events[0].readable, "{}: hangup must surface as readable", kind.name());
        }
    }

    #[test]
    fn poller_kind_parses() {
        assert_eq!(PollerKind::parse("poll").unwrap(), PollerKind::Poll);
        assert!(PollerKind::parse("kqueue").is_err());
        #[cfg(target_os = "linux")]
        {
            assert_eq!(PollerKind::parse("epoll").unwrap(), PollerKind::Epoll);
            assert_eq!(PollerKind::os_default(), PollerKind::Epoll);
        }
        assert!(!PollerKind::os_default().name().is_empty());
    }

    #[test]
    fn nofile_limit_is_sane() {
        let lim = max_open_files().expect("unix must report RLIMIT_NOFILE");
        assert!(lim >= 64, "soft nofile limit {lim} is implausibly low");
    }
}
