//! The cross-product (CP) baseline of paper §5.2: materialize the Cartesian
//! product of the entity populations (one factor per FO variable), classify
//! every tuple against the relationship tables, and GROUP BY everything.
//!
//! This is the approach the Möbius Join makes obsolete — it is implemented
//! both as the correctness oracle (its output must equal the MJ joint table
//! exactly) and as the Table 3 comparison baseline, including the paper's
//! "N.T." (non-termination) behaviour via a time/size budget.

use crate::ct::CtTable;
use crate::db::Database;
use crate::schema::{RandomVar, VarId, NA};
use crate::util::fxhash::FxHashMap;
use std::time::{Duration, Instant};

/// Resource budget for the CP enumeration.
#[derive(Debug, Clone, Copy)]
pub struct CpBudget {
    /// Give up after this much wall time (the paper's runs crashed after
    /// hours; we cut off deterministically).
    pub max_time: Duration,
    /// Give up immediately if the cross product has more tuples than this.
    pub max_tuples: u128,
}

impl Default for CpBudget {
    fn default() -> Self {
        CpBudget { max_time: Duration::from_secs(120), max_tuples: 200_000_000 }
    }
}

/// Outcome of a CP run.
#[derive(Debug)]
pub enum CpOutcome {
    Done { ct: CtTable, cp_tuples: u128, elapsed: Duration },
    /// The paper's "N.T.": budget exhausted.
    NonTermination { cp_tuples: u128, elapsed: Duration },
}

impl CpOutcome {
    pub fn ct(&self) -> Option<&CtTable> {
        match self {
            CpOutcome::Done { ct, .. } => Some(ct),
            CpOutcome::NonTermination { .. } => None,
        }
    }

    /// Cross-product size (number of tuples the CP approach materializes),
    /// reported even on non-termination (Table 3 "CP-#tuples").
    pub fn cp_tuples(&self) -> u128 {
        match self {
            CpOutcome::Done { cp_tuples, .. } | CpOutcome::NonTermination { cp_tuples, .. } => {
                *cp_tuples
            }
        }
    }
}

/// Size of the full entity cross product: ∏ over FO variables of the
/// population size.
pub fn cross_product_size(db: &Database) -> u128 {
    db.schema
        .fo_vars
        .iter()
        .map(|f| db.entity_counts[f.pop] as u128)
        .product()
}

/// Materialize the cross product and compute the joint contingency table by
/// brute force.
pub fn cross_product_ct(db: &Database, budget: CpBudget) -> CpOutcome {
    let t0 = Instant::now();
    let cp_tuples = cross_product_size(db);
    if cp_tuples > budget.max_tuples {
        return CpOutcome::NonTermination { cp_tuples, elapsed: t0.elapsed() };
    }
    let schema = &db.schema;
    let nfo = schema.fo_vars.len();
    let vars: Vec<VarId> = (0..schema.random_vars.len()).collect();

    // Column plan.
    enum Src {
        Ent { fo: usize, pop: usize, attr_idx: usize },
        Ind { rel: usize },
        RAttr { rel: usize, attr_idx: usize },
    }
    let sources: Vec<Src> = vars
        .iter()
        .map(|&v| match schema.random_vars[v] {
            RandomVar::EntityAttr { fo, attr } => {
                let pop = schema.fo_vars[fo].pop;
                Src::Ent { fo, pop, attr_idx: db.attr_pos_in_pop(pop, attr) }
            }
            RandomVar::RelInd { rel } => Src::Ind { rel },
            RandomVar::RelAttr { rel, attr } => {
                Src::RAttr { rel, attr_idx: db.attr_pos_in_rel(rel, attr) }
            }
        })
        .collect();

    let mut groups: FxHashMap<Vec<u16>, u64> = FxHashMap::default();
    let mut binding = vec![0u32; nfo];
    let mut key = vec![0u16; vars.len()];
    let mut checked: u64 = 0;

    // Odometer enumeration over all entity combinations.
    let sizes: Vec<u32> = schema.fo_vars.iter().map(|f| db.entity_counts[f.pop]).collect();
    if sizes.iter().any(|&n| n == 0) {
        return CpOutcome::Done { ct: CtTable::empty(vars), cp_tuples, elapsed: t0.elapsed() };
    }
    'outer: loop {
        // Emit current combination.
        for (slot, src) in sources.iter().enumerate() {
            key[slot] = match *src {
                Src::Ent { fo, pop, attr_idx } => db.entity_attr(pop, attr_idx, binding[fo]),
                Src::Ind { rel } => {
                    let r = &schema.relationships[rel];
                    let a = binding[schema_fo_slot(schema, r.fo_vars[0])];
                    let b = binding[schema_fo_slot(schema, r.fo_vars[1])];
                    db.rels[rel].tuple_of_pair(a, b).map(|_| 1).unwrap_or(0)
                }
                Src::RAttr { rel, attr_idx } => {
                    let r = &schema.relationships[rel];
                    let a = binding[schema_fo_slot(schema, r.fo_vars[0])];
                    let b = binding[schema_fo_slot(schema, r.fo_vars[1])];
                    match db.rels[rel].tuple_of_pair(a, b) {
                        Some(t) => db.rels[rel].attrs[attr_idx][t as usize],
                        None => NA,
                    }
                }
            };
        }
        if let Some(c) = groups.get_mut(key.as_slice()) {
            *c += 1;
        } else {
            groups.insert(key.clone(), 1);
        }
        checked += 1;
        if checked % 65536 == 0 && t0.elapsed() > budget.max_time {
            return CpOutcome::NonTermination { cp_tuples, elapsed: t0.elapsed() };
        }
        // Advance odometer.
        let mut slot = 0;
        loop {
            binding[slot] += 1;
            if binding[slot] < sizes[slot] {
                break;
            }
            binding[slot] = 0;
            slot += 1;
            if slot == nfo {
                break 'outer;
            }
        }
    }

    let mut rows = Vec::with_capacity(groups.len() * vars.len());
    let mut counts = Vec::with_capacity(groups.len());
    for (k, c) in groups {
        rows.extend_from_slice(&k);
        counts.push(c);
    }
    CpOutcome::Done {
        ct: CtTable::from_raw(vars, rows, counts),
        cp_tuples,
        elapsed: t0.elapsed(),
    }
}

/// FO variables are globally indexed; binding slots use the same index.
#[inline]
fn schema_fo_slot(_schema: &crate::schema::Schema, fo: usize) -> usize {
    fo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::university_db;

    #[test]
    fn cp_total_is_population_product() {
        let db = university_db();
        let out = cross_product_ct(&db, CpBudget::default());
        let ct = out.ct().expect("small db terminates");
        assert_eq!(ct.total(), 27); // 3 students x 3 courses x 3 profs
        assert_eq!(out.cp_tuples(), 27);
        ct.check_invariants().unwrap();
    }

    #[test]
    fn cp_respects_tuple_budget() {
        let db = university_db();
        let out =
            cross_product_ct(&db, CpBudget { max_time: Duration::from_secs(5), max_tuples: 10 });
        assert!(matches!(out, CpOutcome::NonTermination { cp_tuples: 27, .. }));
    }

    #[test]
    fn cp_all_true_rows_match_join_count() {
        let db = university_db();
        let out = cross_product_ct(&db, CpBudget::default());
        let ct = out.ct().unwrap();
        let s = &db.schema;
        let sel = ct.select(&[(s.rel_ind_var(0), 1), (s.rel_ind_var(1), 1)]);
        // (s,c,p) with s registered in c and p RA s: jack 2*1 + kim 1*2 + paul 1*1
        assert_eq!(sel.total(), 5);
    }
}
