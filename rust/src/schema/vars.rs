//! Random-variable registry types.

use super::{AttrId, FoVarId, RelId};

/// Index into `Schema::random_vars`. Contingency-table columns are always
/// kept sorted by `VarId`, which gives every variable set a canonical
/// column order.
pub type VarId = usize;

/// A parametrized random variable (PRV) in the statistical view of the
/// schema (paper §2.1).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum RandomVar {
    /// Entity attribute variable, e.g. `intelligence(S)` (a 1Att).
    EntityAttr { fo: FoVarId, attr: AttrId },
    /// Relationship attribute variable, e.g. `capability(P,S)` (a 2Att).
    /// Takes the reserved value `n/a` when the relationship is false.
    RelAttr { rel: RelId, attr: AttrId },
    /// Boolean relationship indicator, e.g. `RA(P,S)`; codes 0 = F, 1 = T.
    RelInd { rel: RelId },
}

/// Coarse kind tag, useful for filtering variable sets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    EntityAttr,
    RelAttr,
    RelInd,
}

impl RandomVar {
    pub fn kind(&self) -> VarKind {
        match self {
            RandomVar::EntityAttr { .. } => VarKind::EntityAttr,
            RandomVar::RelAttr { .. } => VarKind::RelAttr,
            RandomVar::RelInd { .. } => VarKind::RelInd,
        }
    }

    /// The relationship this variable belongs to, if any.
    pub fn rel(&self) -> Option<RelId> {
        match self {
            RandomVar::RelAttr { rel, .. } | RandomVar::RelInd { rel } => Some(*rel),
            RandomVar::EntityAttr { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tags() {
        assert_eq!(RandomVar::EntityAttr { fo: 0, attr: 0 }.kind(), VarKind::EntityAttr);
        assert_eq!(RandomVar::RelAttr { rel: 1, attr: 2 }.kind(), VarKind::RelAttr);
        assert_eq!(RandomVar::RelInd { rel: 1 }.kind(), VarKind::RelInd);
    }

    #[test]
    fn rel_accessor() {
        assert_eq!(RandomVar::EntityAttr { fo: 0, attr: 0 }.rel(), None);
        assert_eq!(RandomVar::RelAttr { rel: 3, attr: 2 }.rel(), Some(3));
        assert_eq!(RandomVar::RelInd { rel: 5 }.rel(), Some(5));
    }
}
