//! Relational schema and the functor/random-variable view of it.
//!
//! Mirrors Section 2 of the paper: a schema derived from an ER model has
//! *entity tables* (populations with descriptive attributes) and *binary
//! relationship tables* (with their own descriptive attributes). The
//! statistical view instantiates each population with first-order (FO)
//! variables and each relationship with a relationship variable; descriptive
//! attributes become attribute random variables:
//!
//! * `1Atts` — entity attribute variables, e.g. `intelligence(S)`;
//! * `2Atts` — relationship attribute variables, e.g. `capability(P,S)`;
//! * relationship indicator variables, e.g. `RA(P,S) ∈ {F,T}`.
//!
//! Self-relationships (e.g. `Borders(Country,Country)`) instantiate two FO
//! variables over the same population, which duplicates that population's
//! 1Atts in the statistical view — exactly as in the paper's Mondial/UW-CSE
//! benchmarks.

pub mod builder;
mod vars;

pub use builder::{university_schema, SchemaBuilder};
pub use vars::{RandomVar, VarId, VarKind};

/// Index types into the schema registries.
pub type PopId = usize;
pub type AttrId = usize;
pub type RelId = usize;
pub type FoVarId = usize;

/// Value code reserved for "n/a" on relationship attributes: the value of a
/// 2Att is undefined when the relationship does not hold (paper §2.2). The
/// code equals the attribute's arity, so codes are `0..arity` for real
/// values and `arity` for n/a.
pub const NA: u16 = u16::MAX;

/// A descriptive attribute with a finite categorical domain.
#[derive(Debug, Clone)]
pub struct Attribute {
    pub name: String,
    pub values: Vec<String>,
}

impl Attribute {
    /// Number of real (non-n/a) values.
    pub fn arity(&self) -> usize {
        self.values.len()
    }
}

/// An entity type ("population") with its descriptive attributes.
#[derive(Debug, Clone)]
pub struct Population {
    pub name: String,
    pub attrs: Vec<AttrId>,
    /// FO variables instantiated over this population (1, or 2 when the
    /// population participates in a self-relationship).
    pub fo_vars: Vec<FoVarId>,
}

/// A binary relationship type between two populations (possibly the same
/// population — a self-relationship).
#[derive(Debug, Clone)]
pub struct RelationshipType {
    pub name: String,
    pub pops: [PopId; 2],
    pub attrs: Vec<AttrId>,
    /// The FO variables this relationship's canonical relationship variable
    /// is instantiated with, e.g. `RA(P, S)` or `Borders(C1, C2)`.
    pub fo_vars: [FoVarId; 2],
}

impl RelationshipType {
    pub fn is_self(&self) -> bool {
        self.pops[0] == self.pops[1]
    }
}

/// A first-order variable, e.g. `S` ranging over students.
#[derive(Debug, Clone)]
pub struct FoVar {
    pub name: String,
    pub pop: PopId,
}

/// A complete relational schema plus its statistical (random-variable) view.
#[derive(Debug, Clone)]
pub struct Schema {
    pub name: String,
    pub populations: Vec<Population>,
    pub attributes: Vec<Attribute>,
    pub relationships: Vec<RelationshipType>,
    pub fo_vars: Vec<FoVar>,
    /// Canonical ordered registry of all random variables. `VarId` indexes
    /// into this; contingency-table columns are always sorted by `VarId`.
    pub random_vars: Vec<RandomVar>,
}

impl Schema {
    /// Number of relationship variables (the paper's parameter `m`).
    pub fn num_rel_vars(&self) -> usize {
        self.relationships.len()
    }

    /// Arity (number of distinct value codes, incl. n/a for 2Atts) of a
    /// random variable.
    pub fn var_arity(&self, v: VarId) -> usize {
        match self.random_vars[v] {
            RandomVar::EntityAttr { attr, .. } => self.attributes[attr].arity(),
            RandomVar::RelAttr { attr, .. } => self.attributes[attr].arity() + 1, // + n/a
            RandomVar::RelInd { .. } => 2,
        }
    }

    /// The `VarId` of a relationship indicator variable.
    pub fn rel_ind_var(&self, rel: RelId) -> VarId {
        self.random_vars
            .iter()
            .position(|rv| matches!(rv, RandomVar::RelInd { rel: r } if *r == rel))
            .expect("every relationship has an indicator variable")
    }

    /// 1Atts(fo): entity attribute variables of one FO variable.
    pub fn one_atts_of_fo(&self, fo: FoVarId) -> Vec<VarId> {
        self.random_vars
            .iter()
            .enumerate()
            .filter(|(_, rv)| matches!(rv, RandomVar::EntityAttr { fo: f, .. } if *f == fo))
            .map(|(i, _)| i)
            .collect()
    }

    /// 2Atts(rel): relationship attribute variables of one relationship.
    pub fn two_atts_of_rel(&self, rel: RelId) -> Vec<VarId> {
        self.random_vars
            .iter()
            .enumerate()
            .filter(|(_, rv)| matches!(rv, RandomVar::RelAttr { rel: r, .. } if *r == rel))
            .map(|(i, _)| i)
            .collect()
    }

    /// The FO variables appearing in a set of relationships.
    pub fn fo_vars_of_rels(&self, rels: &[RelId]) -> Vec<FoVarId> {
        let mut out: Vec<FoVarId> = rels
            .iter()
            .flat_map(|&r| self.relationships[r].fo_vars.iter().copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// 1Atts(R-set) ∪ 2Atts(R-set): all attribute variables of a
    /// relationship set (paper's `Atts(R)`).
    pub fn atts_of_rels(&self, rels: &[RelId]) -> Vec<VarId> {
        let mut out = Vec::new();
        for fo in self.fo_vars_of_rels(rels) {
            out.extend(self.one_atts_of_fo(fo));
        }
        for &r in rels {
            out.extend(self.two_atts_of_rel(r));
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// All variables of the ct-table for a relationship chain:
    /// indicators ∪ Atts (paper's `R ∪ Atts(R)`).
    pub fn ct_vars_of_rels(&self, rels: &[RelId]) -> Vec<VarId> {
        let mut out = self.atts_of_rels(rels);
        out.extend(rels.iter().map(|&r| self.rel_ind_var(r)));
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Human-readable name of a random variable, e.g. `intelligence(S)`,
    /// `capability(P,S)`, `RA(P,S)`.
    pub fn var_name(&self, v: VarId) -> String {
        match &self.random_vars[v] {
            RandomVar::EntityAttr { fo, attr } => {
                format!("{}({})", self.attributes[*attr].name, self.fo_vars[*fo].name)
            }
            RandomVar::RelAttr { rel, attr } => {
                let r = &self.relationships[*rel];
                format!(
                    "{}({},{})",
                    self.attributes[*attr].name,
                    self.fo_vars[r.fo_vars[0]].name,
                    self.fo_vars[r.fo_vars[1]].name
                )
            }
            RandomVar::RelInd { rel } => {
                let r = &self.relationships[*rel];
                format!(
                    "{}({},{})",
                    r.name, self.fo_vars[r.fo_vars[0]].name, self.fo_vars[r.fo_vars[1]].name
                )
            }
        }
    }

    /// Human-readable value of a random variable code (handles T/F and n/a).
    pub fn value_name(&self, v: VarId, code: u16) -> String {
        match &self.random_vars[v] {
            RandomVar::EntityAttr { attr, .. } => self.attributes[*attr].values[code as usize].clone(),
            RandomVar::RelAttr { attr, .. } => {
                if code == NA {
                    "n/a".to_string()
                } else {
                    self.attributes[*attr].values[code as usize].clone()
                }
            }
            RandomVar::RelInd { .. } => if code == 1 { "T" } else { "F" }.to_string(),
        }
    }

    /// Find a random variable by display name (used by the CLI/config layer).
    pub fn var_by_name(&self, name: &str) -> Option<VarId> {
        (0..self.random_vars.len()).find(|&v| self.var_name(v) == name)
    }

    /// Number of value codes for a variable *as stored in ct-tables*:
    /// arity for 1Atts, arity+1 (n/a) for 2Atts, 2 for indicators. The n/a
    /// code itself is `NA`, not `arity`, so this is only used for
    /// cardinality estimates, not code enumeration.
    pub fn ct_cardinality(&self, v: VarId) -> usize {
        self.var_arity(v)
    }

    /// Enumerate the valid ct codes for a variable (n/a encoded as `NA`).
    pub fn var_codes(&self, v: VarId) -> Vec<u16> {
        match self.random_vars[v] {
            RandomVar::EntityAttr { attr, .. } => {
                (0..self.attributes[attr].arity() as u16).collect()
            }
            RandomVar::RelAttr { attr, .. } => {
                let mut c: Vec<u16> = (0..self.attributes[attr].arity() as u16).collect();
                c.push(NA);
                c
            }
            RandomVar::RelInd { .. } => vec![0, 1],
        }
    }

    /// Total number of attributes (paper Table 2 "#Attributes" column):
    /// descriptive attributes of entity and relationship tables.
    pub fn num_attributes(&self) -> usize {
        self.attributes.len()
    }

    /// Total number of tables (entity + relationship).
    pub fn num_tables(&self) -> usize {
        self.populations.len() + self.relationships.len()
    }

    /// Number of self-relationships.
    pub fn num_self_rels(&self) -> usize {
        self.relationships.iter().filter(|r| r.is_self()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn university() -> Schema {
        crate::schema::builder::university_schema()
    }

    #[test]
    fn university_shape() {
        let s = university();
        assert_eq!(s.populations.len(), 3);
        assert_eq!(s.relationships.len(), 2);
        assert_eq!(s.num_tables(), 5);
        assert_eq!(s.num_self_rels(), 0);
        // 6 entity attrs + 4 rel attrs
        assert_eq!(s.num_attributes(), 10);
        // random vars: 6 entity-attr vars + 4 rel-attr vars + 2 indicators
        assert_eq!(s.random_vars.len(), 12);
    }

    #[test]
    fn var_names_and_values() {
        let s = university();
        let names: Vec<String> = (0..s.random_vars.len()).map(|v| s.var_name(v)).collect();
        assert!(names.contains(&"intelligence(S)".to_string()));
        assert!(names.contains(&"RA(P,S)".to_string()));
        assert!(names.contains(&"capability(P,S)".to_string()));
        let ra = s.var_by_name("RA(P,S)").unwrap();
        assert_eq!(s.value_name(ra, 0), "F");
        assert_eq!(s.value_name(ra, 1), "T");
        let cap = s.var_by_name("capability(P,S)").unwrap();
        assert_eq!(s.value_name(cap, NA), "n/a");
    }

    #[test]
    fn atts_partition() {
        let s = university();
        let ra: RelId = s.relationships.iter().position(|r| r.name == "RA").unwrap();
        let atts = s.atts_of_rels(&[ra]);
        // RA(P,S): 2 prof attrs + 2 student attrs + 2 rel attrs
        assert_eq!(atts.len(), 6);
        let ct_vars = s.ct_vars_of_rels(&[ra]);
        assert_eq!(ct_vars.len(), 7); // + indicator
        assert!(ct_vars.contains(&s.rel_ind_var(ra)));
    }

    #[test]
    fn self_relationship_duplicates_one_atts() {
        let mut b = SchemaBuilder::new("toy");
        let c = b.population("Country");
        b.attr(c, "size", &["small", "big"]);
        let _borders = b.relationship("Borders", c, c);
        let s = b.finish();
        assert_eq!(s.populations[c].fo_vars.len(), 2);
        assert_eq!(s.num_self_rels(), 1);
        // size(Country1) and size(Country2) are distinct random variables
        let ea: Vec<VarId> = (0..s.random_vars.len())
            .filter(|&v| matches!(s.random_vars[v], RandomVar::EntityAttr { .. }))
            .collect();
        assert_eq!(ea.len(), 2);
        assert_ne!(s.var_name(ea[0]), s.var_name(ea[1]));
    }

    #[test]
    fn var_codes_include_na_for_two_atts() {
        let s = university();
        let cap = s.var_by_name("capability(P,S)").unwrap();
        let codes = s.var_codes(cap);
        assert_eq!(*codes.last().unwrap(), NA);
        assert_eq!(codes.len(), s.var_arity(cap));
        let intel = s.var_by_name("intelligence(S)").unwrap();
        assert!(!s.var_codes(intel).contains(&NA));
    }

    #[test]
    fn fo_vars_of_rels_dedup() {
        let s = university();
        let all: Vec<RelId> = (0..s.relationships.len()).collect();
        let fos = s.fo_vars_of_rels(&all);
        // Reg(S,C) and RA(P,S) share S: {S, C, P}
        assert_eq!(fos.len(), 3);
    }
}
