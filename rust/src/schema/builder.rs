//! Fluent construction of schemas, including the FO-variable instantiation
//! policy and the canonical random-variable registry.

use super::{
    AttrId, Attribute, FoVar, FoVarId, PopId, Population, RandomVar, RelId, RelationshipType,
    Schema,
};

/// Builder for [`Schema`]. Populations and attributes are declared first,
/// then relationships; `finish()` freezes the random-variable registry.
///
/// FO-variable policy (matches the paper's benchmark setup, cf. Table 1):
/// each population gets one canonical FO variable on first use; a
/// self-relationship upgrades the population to two FO variables (`X1`,
/// `X2`) and uses both, while non-self relationships always bind the first.
#[derive(Debug)]
pub struct SchemaBuilder {
    name: String,
    populations: Vec<Population>,
    attributes: Vec<Attribute>,
    relationships: Vec<RelationshipType>,
    fo_vars: Vec<FoVar>,
    rel_attr_owner: Vec<Vec<AttrId>>, // parallel to relationships
}

impl SchemaBuilder {
    pub fn new(name: &str) -> Self {
        SchemaBuilder {
            name: name.to_string(),
            populations: Vec::new(),
            attributes: Vec::new(),
            relationships: Vec::new(),
            fo_vars: Vec::new(),
            rel_attr_owner: Vec::new(),
        }
    }

    /// Declare an entity type.
    pub fn population(&mut self, name: &str) -> PopId {
        self.populations.push(Population {
            name: name.to_string(),
            attrs: Vec::new(),
            fo_vars: Vec::new(),
        });
        self.populations.len() - 1
    }

    /// Declare a descriptive attribute on an entity type.
    pub fn attr(&mut self, pop: PopId, name: &str, values: &[&str]) -> AttrId {
        assert!(values.len() >= 2, "attribute {name} needs >= 2 values");
        let id = self.push_attr(name, values);
        self.populations[pop].attrs.push(id);
        id
    }

    /// Declare a binary relationship between two entity types.
    pub fn relationship(&mut self, name: &str, p1: PopId, p2: PopId) -> RelId {
        let fo1 = self.fo_var_for(p1, 0);
        let fo2 = if p1 == p2 { self.fo_var_for(p2, 1) } else { self.fo_var_for(p2, 0) };
        self.relationships.push(RelationshipType {
            name: name.to_string(),
            pops: [p1, p2],
            attrs: Vec::new(),
            fo_vars: [fo1, fo2],
        });
        self.rel_attr_owner.push(Vec::new());
        self.relationships.len() - 1
    }

    /// Declare a descriptive attribute on a relationship.
    pub fn rel_attr(&mut self, rel: RelId, name: &str, values: &[&str]) -> AttrId {
        assert!(values.len() >= 2, "attribute {name} needs >= 2 values");
        let id = self.push_attr(name, values);
        self.relationships[rel].attrs.push(id);
        self.rel_attr_owner[rel].push(id);
        id
    }

    fn push_attr(&mut self, name: &str, values: &[&str]) -> AttrId {
        self.attributes.push(Attribute {
            name: name.to_string(),
            values: values.iter().map(|s| s.to_string()).collect(),
        });
        self.attributes.len() - 1
    }

    /// Get or create the `idx`-th FO variable of a population (idx 0 or 1).
    fn fo_var_for(&mut self, pop: PopId, idx: usize) -> FoVarId {
        assert!(idx < 2);
        while self.populations[pop].fo_vars.len() <= idx {
            let n = self.populations[pop].fo_vars.len();
            let base = short_var_name(&self.populations[pop].name);
            // A second variable forces numbering on both ("C1", "C2").
            let name = if idx == 0 && n == 0 { base.clone() } else { format!("{base}{}", n + 1) };
            self.fo_vars.push(FoVar { name, pop });
            let id = self.fo_vars.len() - 1;
            self.populations[pop].fo_vars.push(id);
        }
        // When the second variable is created lazily, rename the first for
        // display consistency ("C" -> "C1").
        if idx == 1 {
            let first = self.populations[pop].fo_vars[0];
            let base = short_var_name(&self.populations[pop].name);
            self.fo_vars[first].name = format!("{base}1");
        }
        self.populations[pop].fo_vars[idx]
    }

    /// Freeze the schema: build the canonical random-variable registry.
    /// Order: all entity-attribute variables (by FO var, then attribute),
    /// then per relationship its indicator followed by its 2Atts.
    pub fn finish(mut self) -> Schema {
        // Populations outside every relationship still get one FO variable:
        // their 1Atts join the statistical space via cross product (e.g.
        // UW-CSE's isolated Course table).
        for pop in 0..self.populations.len() {
            if self.populations[pop].fo_vars.is_empty() {
                self.fo_var_for(pop, 0);
            }
        }
        self.finish_inner()
    }

    fn finish_inner(self) -> Schema {
        let mut random_vars = Vec::new();
        for (fo_id, fo) in self.fo_vars.iter().enumerate() {
            for &attr in &self.populations[fo.pop].attrs {
                random_vars.push(RandomVar::EntityAttr { fo: fo_id, attr });
            }
        }
        for (rel_id, rel) in self.relationships.iter().enumerate() {
            random_vars.push(RandomVar::RelInd { rel: rel_id });
            for &attr in &rel.attrs {
                random_vars.push(RandomVar::RelAttr { rel: rel_id, attr });
            }
        }
        Schema {
            name: self.name,
            populations: self.populations,
            attributes: self.attributes,
            relationships: self.relationships,
            fo_vars: self.fo_vars,
            random_vars,
        }
    }
}

/// Short FO-variable name from a population name: first letter, uppercased
/// (e.g. "Student" -> "S"); falls back to the full name on collision.
fn short_var_name(pop_name: &str) -> String {
    pop_name.chars().take(1).collect::<String>().to_uppercase()
}

/// The paper's running example (Figures 1-2): Student, Course, Professor;
/// Registration(S,C) with grade/satisfaction; RA(P,S) with capability/salary.
pub fn university_schema() -> Schema {
    let mut b = SchemaBuilder::new("university");
    let s = b.population("Student");
    b.attr(s, "intelligence", &["1", "2", "3"]);
    b.attr(s, "ranking", &["1", "2"]);
    let c = b.population("Course");
    b.attr(c, "rating", &["1", "2", "3"]);
    b.attr(c, "difficulty", &["1", "2"]);
    let p = b.population("Professor");
    b.attr(p, "popularity", &["1", "2", "3"]);
    b.attr(p, "teachingability", &["1", "2"]);
    let reg = b.relationship("Registration", s, c);
    b.rel_attr(reg, "grade", &["1", "2", "3"]);
    b.rel_attr(reg, "satisfaction", &["1", "2"]);
    let ra = b.relationship("RA", p, s);
    b.rel_attr(ra, "capability", &["1", "2", "3"]);
    b.rel_attr(ra, "salary", &["Low", "Med", "High"]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::VarKind;

    #[test]
    fn registry_order_is_stable() {
        let s = university_schema();
        // Entity-attr vars come first, then rel blocks in declaration order.
        let kinds: Vec<VarKind> = s.random_vars.iter().map(|v| v.kind()).collect();
        let first_rel = kinds.iter().position(|k| *k != VarKind::EntityAttr).unwrap();
        assert!(kinds[..first_rel].iter().all(|k| *k == VarKind::EntityAttr));
        assert_eq!(kinds[first_rel], VarKind::RelInd);
    }

    #[test]
    fn fo_var_naming_non_self() {
        let s = university_schema();
        let names: Vec<&str> = s.fo_vars.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["S", "C", "P"]);
    }

    #[test]
    fn fo_var_naming_self_rel() {
        let mut b = SchemaBuilder::new("toy");
        let c = b.population("Country");
        b.attr(c, "size", &["s", "b"]);
        b.relationship("Borders", c, c);
        let s = b.finish();
        let names: Vec<&str> = s.fo_vars.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["C1", "C2"]);
    }

    #[test]
    fn mixed_self_and_normal_share_first_var() {
        let mut b = SchemaBuilder::new("uwcse");
        let person = b.population("Person");
        b.attr(person, "pos", &["fac", "stu"]);
        let course = b.population("Course");
        b.attr(course, "level", &["ug", "grad"]);
        let adv = b.relationship("AdvisedBy", person, person);
        let taught = b.relationship("TaughtBy", course, person);
        let s = b.finish();
        // AdvisedBy uses (P1, P2); TaughtBy binds P1.
        assert_eq!(s.relationships[adv].fo_vars[0], s.relationships[taught].fo_vars[1]);
        assert_ne!(s.relationships[adv].fo_vars[0], s.relationships[adv].fo_vars[1]);
    }

    #[test]
    #[should_panic(expected = "needs >= 2 values")]
    fn attr_arity_checked() {
        let mut b = SchemaBuilder::new("bad");
        let p = b.population("P");
        b.attr(p, "x", &["only"]);
    }
}
