//! # mrss — Multi-Relational Sufficient Statistics
//!
//! A reproduction of *"Computing Multi-Relational Sufficient Statistics for
//! Large Databases"* (Qian, Schulte & Sun, CIKM 2014): the **Möbius Join**
//! virtual-join algorithm that computes contingency tables over a relational
//! database covering any combination of **positive and negative
//! relationships**, without materializing entity cross products.
//!
//! ## Layout
//!
//! * [`schema`] — relational schemas + the random-variable (functor) view;
//! * [`db`] — in-memory relational engine (tables, indexes, join counting);
//! * [`ct`] — contingency tables and the ct-algebra (σ, π, χ, ×, +, −);
//! * [`lattice`] — the relationship-chain lattice;
//! * [`mobius`] — the Möbius Join dynamic program (Algorithms 1 and 2);
//! * [`baseline`] — cross-product enumeration baseline (the paper's CP);
//! * [`datagen`] — synthetic generators mirroring the seven benchmarks;
//! * [`store`] — persisted statistics repository (binary ct codec,
//!   directory store with LRU cache) + the count-query service;
//! * [`serve`] — concurrent TCP count-serving front-end over the store
//!   (wire protocol, worker pool, admission control, load generator);
//! * [`obs`] — observability: structured span tracing, the flight
//!   recorder behind `DUMP`, Prometheus text exposition for `METRICS`;
//! * [`apps`] — feature selection, association rules, Bayesian networks;
//! * [`runtime`] — AOT-compiled XLA kernels via PJRT, with native fallback;
//! * [`coordinator`] — pipeline orchestration, metrics, configs;
//! * [`util`] — RNG, timing, text tables, property-testing harness.

pub mod util;
pub mod schema;
pub mod ct;
pub mod db;
pub mod lattice;
pub mod mobius;
pub mod baseline;
pub mod datagen;
pub mod store;
pub mod serve;
pub mod obs;
pub mod runtime;
pub mod apps;
pub mod coordinator;
pub mod config;

/// Crate version string (used by the CLI banner).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
