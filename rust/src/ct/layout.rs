//! `CtLayout` — the schema-driven bit-packing codec behind [`CtTable`].
//!
//! Every contingency-table column gets a fixed-width bit field sized from
//! its value cardinality; a whole row then packs into a single integer key
//! — a `u64` for layouts up to 64 bits, a two-word `u128` for layouts up
//! to 128 bits (the [`RowKey`] abstraction), spilling to the row-major
//! wide path only past 128 bits. Fields are assigned most-significant-first
//! in canonical column order, so **unsigned integer order of packed keys
//! equals lexicographic row order** — the property every sort-merge
//! operator relies on, at either key width.
//!
//! The `n/a` code of relationship attributes (stored as `NA = u16::MAX` in
//! unpacked rows, paper §2.2) is re-mapped inside the field to `cap` (one
//! past the largest real code). Since every real code is `< cap`, the
//! remap preserves the seed's ordering convention that n/a sorts after all
//! real values, which keeps packed tables bit-identical to the historical
//! row-major semantics once decoded.
//!
//! [`CtTable`]: super::CtTable

use crate::schema::{RandomVar, Schema, VarId, NA};

/// An unsigned integer wide enough to hold one packed row.
///
/// The ct-algebra kernels are generic over this trait and monomorphized at
/// two widths: `u64` (the one-word tier, layouts ≤ 64 bits) and `u128`
/// (the two-word tier, layouts ≤ 128 bits — the hepatitis/imdb-scale joint
/// tables). Individual fields are always narrow (≤ 17 bits, a `u16` code
/// plus the n/a slot), so field values travel as `u64` and only whole keys
/// need the generic width.
pub trait RowKey:
    Copy
    + Ord
    + Eq
    + std::hash::Hash
    + std::fmt::Debug
    + Send
    + Sync
    + std::ops::BitOr<Output = Self>
    + std::ops::BitAnd<Output = Self>
    + std::ops::Shl<u32, Output = Self>
    + std::ops::Shr<u32, Output = Self>
    + 'static
{
    /// Key width in bits.
    const BITS: u32;
    /// The all-zero key.
    const ZERO: Self;
    /// Widen a (narrow) field value into a key.
    fn from_u64(v: u64) -> Self;
    /// The low 64 bits (lossless for masked fields ≤ 64 bits wide).
    fn low_u64(self) -> u64;
    /// A mask of the `bits` lowest bits (`bits` may equal `BITS`).
    fn ones(bits: u32) -> Self;
}

impl RowKey for u64 {
    const BITS: u32 = 64;
    const ZERO: Self = 0;

    #[inline]
    fn from_u64(v: u64) -> Self {
        v
    }

    #[inline]
    fn low_u64(self) -> u64 {
        self
    }

    #[inline]
    fn ones(bits: u32) -> Self {
        if bits >= 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        }
    }
}

impl RowKey for u128 {
    const BITS: u32 = 128;
    const ZERO: Self = 0;

    #[inline]
    fn from_u64(v: u64) -> Self {
        v as u128
    }

    #[inline]
    fn low_u64(self) -> u64 {
        self as u64
    }

    #[inline]
    fn ones(bits: u32) -> Self {
        if bits >= 128 {
            u128::MAX
        } else {
            (1u128 << bits) - 1
        }
    }
}

/// One column's slot in the packed key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColLayout {
    /// Exclusive upper bound on *real* codes (real codes are `0..cap`).
    pub cap: u16,
    /// Whether the column can hold the `NA` sentinel (encoded as `cap`).
    pub na: bool,
    /// Field width in bits (≥ 1).
    pub bits: u32,
    /// Left shift of the field within the key (MSB-first assignment).
    pub shift: u32,
}

impl ColLayout {
    /// Largest encoded field value this column can produce.
    fn enc_max(cap: u16, na: bool) -> u32 {
        if na {
            cap as u32
        } else {
            (cap as u32).saturating_sub(1)
        }
    }
}

/// Packing layout for one canonical column order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CtLayout {
    cols: Vec<ColLayout>,
    total_bits: u32,
}

impl CtLayout {
    /// Build from `(cap, na)` specs in column order.
    pub fn from_specs(specs: &[(u16, bool)]) -> CtLayout {
        let mut cols: Vec<ColLayout> = specs
            .iter()
            .map(|&(cap, na)| {
                let cap = cap.max(1);
                let bits = (32 - ColLayout::enc_max(cap, na).leading_zeros()).max(1);
                ColLayout { cap, na, bits, shift: 0 }
            })
            .collect();
        let total_bits: u32 = cols.iter().map(|c| c.bits).sum();
        // MSB-first: column 0 occupies the highest bits.
        let mut acc = total_bits;
        for c in cols.iter_mut() {
            acc -= c.bits;
            c.shift = acc;
        }
        CtLayout { cols, total_bits }
    }

    /// Schema-driven layout for a canonical (sorted) variable set: caps come
    /// from attribute cardinalities, so tables built anywhere in the system
    /// over the same variables share one layout and merge without
    /// re-encoding.
    pub fn for_vars(schema: &Schema, vars: &[VarId]) -> CtLayout {
        let specs: Vec<(u16, bool)> = vars
            .iter()
            .map(|&v| match schema.random_vars[v] {
                RandomVar::EntityAttr { attr, .. } => {
                    (schema.attributes[attr].arity() as u16, false)
                }
                RandomVar::RelAttr { attr, .. } => (schema.attributes[attr].arity() as u16, true),
                RandomVar::RelInd { .. } => (2, false),
            })
            .collect();
        CtLayout::from_specs(&specs)
    }

    /// Observe `(cap, na)` specs from row-major data, reading input column
    /// `col_of(out_col)` for each output column (identity for pre-permuted
    /// data). Used by the schema-less [`CtTable::from_raw`] constructor.
    ///
    /// [`CtTable::from_raw`]: super::CtTable::from_raw
    pub fn observe(
        width: usize,
        n_rows: usize,
        rows: &[u16],
        col_of: impl Fn(usize) -> usize,
    ) -> CtLayout {
        let mut specs = vec![(1u16, false); width];
        for r in 0..n_rows {
            let row = &rows[r * width..(r + 1) * width];
            for (out_col, spec) in specs.iter_mut().enumerate() {
                let code = row[col_of(out_col)];
                if code == NA {
                    spec.1 = true;
                } else if code >= spec.0 {
                    spec.0 = code + 1;
                }
            }
        }
        CtLayout::from_specs(&specs)
    }

    pub fn width(&self) -> usize {
        self.cols.len()
    }

    /// Heap bytes held by this layout (its column vector) — part of the
    /// exact [`CtTable::mem_bytes`](super::CtTable::mem_bytes) accounting.
    pub(crate) fn heap_bytes(&self) -> usize {
        self.cols.capacity() * std::mem::size_of::<ColLayout>()
    }

    pub fn total_bits(&self) -> u32 {
        self.total_bits
    }

    /// Whether a whole row fits one `u64` key (the one-word packed tier).
    pub fn fits(&self) -> bool {
        self.total_bits <= 64
    }

    /// Whether a whole row fits one `u128` key (either packed tier).
    pub fn fits2(&self) -> bool {
        self.total_bits <= 128
    }

    /// Whether a whole row fits the key type `K`.
    pub fn fits_key<K: RowKey>(&self) -> bool {
        self.total_bits <= K::BITS
    }

    pub fn col(&self, c: usize) -> &ColLayout {
        &self.cols[c]
    }

    /// `(cap, na)` spec of one column.
    pub fn spec(&self, c: usize) -> (u16, bool) {
        (self.cols[c].cap, self.cols[c].na)
    }

    /// Mask of one column's field at key width `K` (before shifting).
    #[inline]
    pub fn field_mask_k<K: RowKey>(&self, c: usize) -> K {
        K::ones(self.cols[c].bits)
    }

    /// Encode one code into its field value. Caller guarantees validity
    /// (checked in debug builds).
    #[inline]
    pub fn encode(&self, c: usize, code: u16) -> u64 {
        let col = &self.cols[c];
        if code == NA {
            debug_assert!(col.na, "NA code in a column without n/a support");
            col.cap as u64
        } else {
            debug_assert!(code < col.cap, "code {code} out of range (cap {})", col.cap);
            code as u64
        }
    }

    /// Encode a code if it is representable; `None` means no stored row can
    /// match it (selection conditions use this to answer "empty").
    #[inline]
    pub fn try_encode(&self, c: usize, code: u16) -> Option<u64> {
        let col = &self.cols[c];
        if code == NA {
            col.na.then_some(col.cap as u64)
        } else if code < col.cap {
            Some(code as u64)
        } else {
            None
        }
    }

    /// Decode one raw field value back to a `u16` code.
    #[inline]
    pub fn decode(&self, c: usize, field: u64) -> u16 {
        let col = &self.cols[c];
        if col.na && field == col.cap as u64 {
            NA
        } else {
            field as u16
        }
    }

    /// Extract the raw field value of column `c` from a key of width `K`.
    /// Fields are ≤ 17 bits, so the value comes back as a plain `u64`.
    #[inline]
    pub fn extract_k<K: RowKey>(&self, c: usize, key: K) -> u64 {
        ((key >> self.cols[c].shift) & self.field_mask_k::<K>(c)).low_u64()
    }

    /// Decode column `c` of a key of width `K` to its `u16` code.
    #[inline]
    pub fn decode_field_k<K: RowKey>(&self, c: usize, key: K) -> u16 {
        self.decode(c, self.extract_k::<K>(c, key))
    }

    /// Pack a full row (codes in layout column order).
    #[inline]
    pub fn pack(&self, row: &[u16]) -> u64 {
        self.pack_k::<u64>(row)
    }

    /// Pack a full row into a key of width `K`.
    #[inline]
    pub fn pack_k<K: RowKey>(&self, row: &[u16]) -> K {
        debug_assert_eq!(row.len(), self.cols.len());
        debug_assert!(self.fits_key::<K>());
        let mut key = K::ZERO;
        for (c, &code) in row.iter().enumerate() {
            key = key | (K::from_u64(self.encode(c, code)) << self.cols[c].shift);
        }
        key
    }

    /// Pack a row if every code is representable.
    pub fn try_pack(&self, row: &[u16]) -> Option<u64> {
        self.try_pack_k::<u64>(row)
    }

    /// Pack a row into a key of width `K` if every code is representable.
    pub fn try_pack_k<K: RowKey>(&self, row: &[u16]) -> Option<K> {
        debug_assert_eq!(row.len(), self.cols.len());
        let mut key = K::ZERO;
        for (c, &code) in row.iter().enumerate() {
            key = key | (K::from_u64(self.try_encode(c, code)?) << self.cols[c].shift);
        }
        Some(key)
    }

    /// Append the decoded row of `key` to `out`.
    pub fn unpack_into(&self, key: u64, out: &mut Vec<u16>) {
        self.unpack_into_k::<u64>(key, out)
    }

    /// Append the decoded row of a width-`K` key to `out`.
    pub fn unpack_into_k<K: RowKey>(&self, key: K, out: &mut Vec<u16>) {
        for c in 0..self.cols.len() {
            out.push(self.decode_field_k::<K>(c, key));
        }
    }

    /// Decoded row of `key` as a fresh vector.
    pub fn unpack(&self, key: u64) -> Vec<u16> {
        self.unpack_k::<u64>(key)
    }

    /// Decoded row of a width-`K` key as a fresh vector.
    pub fn unpack_k<K: RowKey>(&self, key: K) -> Vec<u16> {
        let mut out = Vec::with_capacity(self.cols.len());
        self.unpack_into_k::<K>(key, &mut out);
        out
    }

    /// Column-wise least upper bound of two layouts over the same variable
    /// set: both sides' keys re-encode into the result losslessly and
    /// order-preservingly.
    pub fn union_with(&self, other: &CtLayout) -> CtLayout {
        debug_assert_eq!(self.width(), other.width());
        let specs: Vec<(u16, bool)> = self
            .cols
            .iter()
            .zip(&other.cols)
            .map(|(a, b)| (a.cap.max(b.cap), a.na || b.na))
            .collect();
        CtLayout::from_specs(&specs)
    }

    /// Sub-layout over a subset of columns (indices ascending).
    pub fn sub(&self, keep: &[usize]) -> CtLayout {
        let specs: Vec<(u16, bool)> = keep.iter().map(|&c| self.spec(c)).collect();
        CtLayout::from_specs(&specs)
    }

    /// Shift-compress plan mapping source columns `cols` (ascending) onto
    /// `target` (whose column `i` is `cols[i]`): one
    /// `(source shift, field mask, destination shift)` triple per kept
    /// column, at key width `K`. Specs must match pairwise so raw field
    /// values carry over without decode — true for [`sub`]-derived targets.
    ///
    /// [`sub`]: CtLayout::sub
    pub fn compress_plan_k<K: RowKey>(
        &self,
        cols: &[usize],
        target: &CtLayout,
    ) -> Vec<(u32, K, u32)> {
        debug_assert_eq!(cols.len(), target.width());
        cols.iter()
            .enumerate()
            .map(|(out_c, &src_c)| {
                debug_assert_eq!(self.spec(src_c), target.spec(out_c));
                (self.cols[src_c].shift, self.field_mask_k::<K>(src_c), target.cols[out_c].shift)
            })
            .collect()
    }

    /// Apply a [`compress_plan_k`]: extract each planned field from `key`
    /// and place it at its destination shift. The single shift-compress
    /// kernel shared by π projection and fused χ conditioning; source and
    /// destination keys share the width (compression never widens).
    ///
    /// [`compress_plan_k`]: CtLayout::compress_plan_k
    #[inline]
    pub fn apply_plan_k<K: RowKey>(key: K, plans: &[(u32, K, u32)]) -> K {
        let mut out = K::ZERO;
        for &(ss, m, ds) in plans {
            out = out | (((key >> ss) & m) << ds);
        }
        out
    }

    /// Translate a key of `self` into `target`'s encoding (same variable
    /// set; `target` must cover `self`, e.g. come from [`union_with`]).
    ///
    /// [`union_with`]: CtLayout::union_with
    #[inline]
    pub fn reencode(&self, target: &CtLayout, key: u64) -> u64 {
        self.reencode_k::<u64, u64>(target, key)
    }

    /// [`reencode`](CtLayout::reencode) across key widths: a `KS` key of
    /// `self` becomes a `KT` key of `target` (e.g. a one-word key widening
    /// into a two-word union layout).
    #[inline]
    pub fn reencode_k<KS: RowKey, KT: RowKey>(&self, target: &CtLayout, key: KS) -> KT {
        debug_assert_eq!(self.width(), target.width());
        debug_assert!(target.fits_key::<KT>());
        let mut out = KT::ZERO;
        for c in 0..self.cols.len() {
            let code = self.decode_field_k::<KS>(c, key);
            out = out | (KT::from_u64(target.encode(c, code)) << target.cols[c].shift);
        }
        out
    }
}

/// LSD radix sort of `(key, payload)` pairs by key, base 256, touching only
/// the bytes that `key_bits` covers. Equal keys keep their relative input
/// order (stable), which the group-by fold after projection relies on not
/// at all — but stability comes free with counting sort.
pub fn radix_sort_pairs(data: &mut Vec<(u64, u64)>, key_bits: u32) {
    radix_sort_pairs_k::<u64>(data, key_bits)
}

/// [`radix_sort_pairs`] at key width `K`: the same byte-wise counting sort
/// over one- or two-word keys. Wide keys with few populated high bytes pay
/// almost nothing for the extra passes (an all-equal byte is skipped after
/// one counting scan).
pub fn radix_sort_pairs_k<K: RowKey>(data: &mut Vec<(K, u64)>, key_bits: u32) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    // Small inputs: comparison sort beats the bucket passes.
    if n < 64 {
        data.sort_unstable_by_key(|&(k, _)| k);
        return;
    }
    let passes = ((key_bits + 7) / 8).max(1).min(K::BITS / 8);
    let mut scratch: Vec<(K, u64)> = vec![(K::ZERO, 0); n];
    for pass in 0..passes {
        let shift = pass * 8;
        let mut counts = [0usize; 256];
        for &(k, _) in data.iter() {
            counts[((k >> shift).low_u64() & 0xFF) as usize] += 1;
        }
        // All keys share this byte: nothing to move.
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        let mut starts = [0usize; 256];
        let mut acc = 0;
        for (b, &c) in counts.iter().enumerate() {
            starts[b] = acc;
            acc += c;
        }
        for &(k, p) in data.iter() {
            let b = ((k >> shift).low_u64() & 0xFF) as usize;
            scratch[starts[b]] = (k, p);
            starts[b] += 1;
        }
        std::mem::swap(data, &mut scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn pack_unpack_roundtrip_with_na() {
        let l = CtLayout::from_specs(&[(3, false), (4, true), (2, false)]);
        assert_eq!(l.width(), 3);
        // bits: 2 (max 2), 3 (max 4 = NA), 1 (max 1)
        assert_eq!(l.total_bits(), 6);
        assert!(l.fits());
        for row in [[0u16, 0, 0], [2, 3, 1], [1, NA, 0]] {
            assert_eq!(l.unpack(l.pack(&row)), row.to_vec());
        }
    }

    #[test]
    fn packed_order_is_lexicographic() {
        let l = CtLayout::from_specs(&[(3, true), (5, false)]);
        let rows: Vec<Vec<u16>> = vec![
            vec![0, 0],
            vec![0, 4],
            vec![1, 0],
            vec![2, 4],
            vec![NA, 0], // NA sorts after every real code
            vec![NA, 4],
        ];
        let keys: Vec<u64> = rows.iter().map(|r| l.pack(r)).collect();
        for w in keys.windows(2) {
            assert!(w[0] < w[1], "packed order broke: {keys:?}");
        }
    }

    #[test]
    fn try_encode_rejects_unrepresentable() {
        let l = CtLayout::from_specs(&[(3, false)]);
        assert_eq!(l.try_encode(0, 2), Some(2));
        assert_eq!(l.try_encode(0, 3), None);
        assert_eq!(l.try_encode(0, NA), None);
        let lna = CtLayout::from_specs(&[(3, true)]);
        assert_eq!(lna.try_encode(0, NA), Some(3));
    }

    #[test]
    fn observe_matches_data() {
        let rows: Vec<u16> = vec![0, 5, 2, NA, 1, 3];
        let l = CtLayout::observe(2, 3, &rows, |c| c);
        assert_eq!(l.spec(0), (3, false));
        assert_eq!(l.spec(1), (6, true));
    }

    #[test]
    fn union_covers_both_and_reencode_preserves_order() {
        let a = CtLayout::from_specs(&[(2, false), (3, false)]);
        let b = CtLayout::from_specs(&[(4, false), (2, true)]);
        let u = a.union_with(&b);
        assert_eq!(u.spec(0), (4, false));
        assert_eq!(u.spec(1), (3, true));
        let mut rng = Pcg64::seeded(5);
        let mut rows: Vec<Vec<u16>> = (0..50)
            .map(|_| vec![rng.below(2) as u16, rng.below(3) as u16])
            .collect();
        rows.sort_unstable();
        let re: Vec<u64> = rows.iter().map(|r| a.reencode(&u, a.pack(r))).collect();
        for w in re.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for (r, &k) in rows.iter().zip(&re) {
            assert_eq!(&u.unpack(k), r);
        }
    }

    #[test]
    fn sub_layout_decodes_kept_columns() {
        let l = CtLayout::from_specs(&[(3, false), (4, true), (5, false)]);
        let s = l.sub(&[0, 2]);
        assert_eq!(s.width(), 2);
        assert_eq!(s.spec(0), (3, false));
        assert_eq!(s.spec(1), (5, false));
    }

    #[test]
    fn radix_sort_matches_std_sort() {
        let mut rng = Pcg64::seeded(11);
        for n in [0usize, 1, 2, 63, 64, 1000] {
            for bits in [8u32, 24, 64] {
                let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
                let mut a: Vec<(u64, u64)> =
                    (0..n).map(|i| (rng.next_u64() & mask, i as u64)).collect();
                let mut b = a.clone();
                radix_sort_pairs(&mut a, bits);
                b.sort_by_key(|&(k, _)| k);
                let ka: Vec<u64> = a.iter().map(|&(k, _)| k).collect();
                let kb: Vec<u64> = b.iter().map(|&(k, _)| k).collect();
                assert_eq!(ka, kb, "n={n} bits={bits}");
            }
        }
    }

    #[test]
    fn wide_layout_reports_not_fitting() {
        let specs: Vec<(u16, bool)> = (0..40).map(|_| (4u16, false)).collect();
        let l = CtLayout::from_specs(&specs);
        assert_eq!(l.total_bits(), 80);
        assert!(!l.fits());
        assert!(l.fits2());
        assert!(!l.fits_key::<u64>());
        assert!(l.fits_key::<u128>());
        let specs: Vec<(u16, bool)> = (0..70).map(|_| (4u16, false)).collect();
        let l = CtLayout::from_specs(&specs);
        assert_eq!(l.total_bits(), 140);
        assert!(!l.fits2());
    }

    #[test]
    fn two_word_pack_unpack_roundtrip_with_na() {
        // 30 columns, mixed widths with NA on odd columns: 65..=128 bits.
        let specs: Vec<(u16, bool)> = (0..30).map(|c| (4u16, c % 2 == 1)).collect();
        let l = CtLayout::from_specs(&specs);
        assert!(!l.fits() && l.fits2(), "total_bits = {}", l.total_bits());
        let mut rng = Pcg64::seeded(21);
        let mut rows: Vec<Vec<u16>> = (0..200)
            .map(|_| {
                (0..30)
                    .map(|c| {
                        if c % 2 == 1 && rng.chance(0.25) {
                            NA
                        } else {
                            rng.below(4) as u16
                        }
                    })
                    .collect()
            })
            .collect();
        for r in &rows {
            assert_eq!(l.unpack_k::<u128>(l.pack_k::<u128>(r)), *r);
            assert_eq!(l.try_pack_k::<u128>(r), Some(l.pack_k::<u128>(r)));
        }
        // Integer order of two-word keys == lexicographic row order (with
        // NA comparing after every real code, as the remap guarantees).
        let na_last = |a: &[u16], b: &[u16]| {
            let rank = |x: u16| if x == NA { u32::MAX } else { x as u32 };
            a.iter().map(|&x| rank(x)).cmp(b.iter().map(|&x| rank(x)))
        };
        rows.sort_unstable_by(|a, b| na_last(a, b));
        let keys: Vec<u128> = rows.iter().map(|r| l.pack_k::<u128>(r)).collect();
        for w in keys.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn reencode_widens_across_key_widths() {
        // A 60-bit layout re-encoded into an 80-bit union target.
        let a = CtLayout::from_specs(&vec![(8u16, false); 20]);
        let b = CtLayout::from_specs(&vec![(16u16, false); 20]);
        assert!(a.fits());
        let u = a.union_with(&b);
        assert!(!u.fits() && u.fits2());
        let row: Vec<u16> = (0..20).map(|c| (c % 8) as u16).collect();
        let k64 = a.pack(&row);
        let k128: u128 = a.reencode_k::<u64, u128>(&u, k64);
        assert_eq!(u.unpack_k::<u128>(k128), row);
    }

    #[test]
    fn radix_sort_u128_matches_std_sort() {
        let mut rng = Pcg64::seeded(13);
        for n in [0usize, 1, 2, 63, 64, 1000] {
            for bits in [8u32, 72, 128] {
                let mask = if bits == 128 { u128::MAX } else { (1u128 << bits) - 1 };
                let mut a: Vec<(u128, u64)> = (0..n)
                    .map(|i| {
                        let k = ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
                        (k & mask, i as u64)
                    })
                    .collect();
                let mut b = a.clone();
                radix_sort_pairs_k::<u128>(&mut a, bits);
                b.sort_by_key(|&(k, _)| k);
                let ka: Vec<u128> = a.iter().map(|&(k, _)| k).collect();
                let kb: Vec<u128> = b.iter().map(|&(k, _)| k).collect();
                assert_eq!(ka, kb, "n={n} bits={bits}");
            }
        }
    }
}
