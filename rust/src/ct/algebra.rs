//! The ct-algebra operators (paper §4.1): σ selection, π projection,
//! χ conditioning, × cross product, + addition, − subtraction, plus the
//! `extend`/`union` helpers Algorithm 1 needs.
//!
//! All operators preserve the [`CtTable`] invariants (sorted unique rows,
//! positive counts). Binary merge operators are single-pass scans over the
//! sorted inputs, matching the sort-merge cost model of §4.1.3.

use super::CtTable;
use crate::schema::VarId;

/// Error from [`CtTable::subtract`]: the paper defines `ct1 − ct2` only when
/// ct2's rows are a subset of ct1's with pointwise smaller-or-equal counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubtractError {
    /// A row of `ct2` is missing from `ct1`.
    MissingRow(Vec<u16>),
    /// A shared row has a larger count in `ct2` than in `ct1`.
    CountUnderflow { row: Vec<u16>, have: u64, sub: u64 },
    /// The two tables have different column sets.
    VarMismatch,
}

impl std::fmt::Display for SubtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubtractError::MissingRow(r) => write!(f, "subtract: row {r:?} missing from minuend"),
            SubtractError::CountUnderflow { row, have, sub } => {
                write!(f, "subtract: row {row:?} has {have} < {sub}")
            }
            SubtractError::VarMismatch => write!(f, "subtract: variable sets differ"),
        }
    }
}

impl std::error::Error for SubtractError {}

impl CtTable {
    /// σ_φ: keep rows matching all `(var, value)` conditions. Columns are
    /// unchanged. Conditions on absent variables panic (caller bug).
    pub fn select(&self, cond: &[(VarId, u16)]) -> CtTable {
        let cols: Vec<(usize, u16)> = cond
            .iter()
            .map(|&(v, val)| (self.col_of(v).expect("select: unknown var"), val))
            .collect();
        let w = self.width();
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let r = &self.rows[i * w..(i + 1) * w];
            if cols.iter().all(|&(ci, val)| r[ci] == val) {
                rows.extend_from_slice(r);
                counts.push(c);
            }
        }
        // Selection preserves sortedness and uniqueness.
        CtTable { vars: self.vars.clone(), rows, counts }
    }

    /// π_keep: project onto a subset of columns, summing counts of rows that
    /// collapse together (SQL GROUP BY, §4.1.1).
    pub fn project(&self, keep: &[VarId]) -> CtTable {
        let mut keep_sorted: Vec<VarId> = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let cols: Vec<usize> = keep_sorted
            .iter()
            .map(|&v| self.col_of(v).expect("project: unknown var"))
            .collect();
        if cols.len() == self.width() {
            return self.clone();
        }
        let w = self.width();
        let nw = cols.len();
        if nw == 0 {
            let total: u128 = self.total();
            return if total == 0 {
                CtTable::empty(Vec::new())
            } else {
                CtTable::scalar(u64::try_from(total).expect("count overflow"))
            };
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = &self.rows[i * w..(i + 1) * w];
            rows.extend(cols.iter().map(|&c| r[c]));
        }
        // `cols` is increasing, so projected rows keep relative order only
        // per-prefix; re-sort + fold via from_raw.
        CtTable::from_raw(keep_sorted, rows, self.counts.clone())
    }

    /// χ_φ: conditioning = select then drop the conditioned columns
    /// (§4.1.1: `χ_φ ct = π_rest (σ_φ ct)`).
    pub fn condition(&self, cond: &[(VarId, u16)]) -> CtTable {
        let sel = self.select(cond);
        let drop: Vec<VarId> = cond.iter().map(|&(v, _)| v).collect();
        let rest: Vec<VarId> = self.vars.iter().copied().filter(|v| !drop.contains(v)).collect();
        // After fixing the dropped columns to constants, remaining rows are
        // still unique and sorted; project() handles the general case anyway.
        sel.project(&rest)
    }

    /// ×: cross product; counts multiply (§4.1.2). Variable sets must be
    /// disjoint.
    pub fn cross(&self, other: &CtTable) -> CtTable {
        for v in &other.vars {
            assert!(self.col_of(*v).is_none(), "cross: overlapping var {v}");
        }
        // Nullary fast paths (scalar multiplication).
        if self.width() == 0 {
            let k = if self.is_empty() { 0 } else { self.counts[0] };
            return other.scale(k);
        }
        if other.width() == 0 {
            let k = if other.is_empty() { 0 } else { other.counts[0] };
            return self.scale(k);
        }
        if let Some(out) = self.cross_packed(other) {
            return out;
        }
        let mut vars = Vec::with_capacity(self.width() + other.width());
        vars.extend_from_slice(&self.vars);
        vars.extend_from_slice(&other.vars);
        let mut rows = Vec::with_capacity((self.len() * other.len()) * vars.len());
        let mut counts = Vec::with_capacity(self.len() * other.len());
        for (ra, ca) in self.iter() {
            for (rb, cb) in other.iter() {
                rows.extend_from_slice(ra);
                rows.extend_from_slice(rb);
                counts.push(ca.checked_mul(cb).expect("count overflow in cross"));
            }
        }
        CtTable::from_raw(vars, rows, counts)
    }

    /// Packed cross product (§Perf): when the merged row fits 128 bits,
    /// precompute each operand row's bit contribution at its final column
    /// positions, so each output row is a single `pa | pb` — no u16 row
    /// materialization, and the output is produced in sorted order by
    /// iterating the (pre-sorted) key lists nested. Returns None when the
    /// packed width overflows.
    fn cross_packed(&self, other: &CtTable) -> Option<CtTable> {
        let wa = self.width();
        let wb = other.width();
        let width = wa + wb;
        // Merged column layout.
        let mut vars: Vec<(VarId, bool, usize)> = Vec::with_capacity(width); // (var, from_a, src col)
        for (c, &v) in self.vars.iter().enumerate() {
            vars.push((v, true, c));
        }
        for (c, &v) in other.vars.iter().enumerate() {
            vars.push((v, false, c));
        }
        vars.sort_unstable_by_key(|&(v, _, _)| v);
        // Bits per merged column from observed max codes.
        let max_of = |t: &CtTable, c: usize| {
            (0..t.len()).map(|i| t.row(i)[c]).max().unwrap_or(0)
        };
        let mut bits = Vec::with_capacity(width);
        for &(_, from_a, c) in &vars {
            let m = if from_a { max_of(self, c) } else { max_of(other, c) };
            bits.push(16 - (m.max(1)).leading_zeros());
        }
        let total_bits: u32 = bits.iter().sum();
        if total_bits > 128 {
            return None;
        }
        let mut shifts = vec![0u32; width];
        let mut acc = 0u32;
        for col in (0..width).rev() {
            shifts[col] = acc;
            acc += bits[col];
        }
        // Partial keys per operand row.
        let partial = |t: &CtTable, from_a: bool| -> Vec<u128> {
            (0..t.len())
                .map(|i| {
                    let row = t.row(i);
                    let mut k = 0u128;
                    for (col, &(_, fa, c)) in vars.iter().enumerate() {
                        if fa == from_a {
                            k |= (row[c] as u128) << shifts[col];
                        }
                    }
                    k
                })
                .collect()
        };
        let pa = partial(self, true);
        let pb = partial(other, false);
        // Keys ordered by (a-part, b-part); that is NOT globally sorted when
        // columns interleave, so sort the combined keys. Rows are unique by
        // construction (operands are unique), so no fold needed.
        let mut keyed: Vec<(u128, u64)> = Vec::with_capacity(pa.len() * pb.len());
        for (ka, &ca) in pa.iter().zip(&self.counts) {
            for (kb, &cb) in pb.iter().zip(&other.counts) {
                keyed.push((ka | kb, ca.checked_mul(cb).expect("count overflow in cross")));
            }
        }
        keyed.sort_unstable_by_key(|&(k, _)| k);
        let mut rows = Vec::with_capacity(keyed.len() * width);
        let mut counts = Vec::with_capacity(keyed.len());
        for (k, c) in keyed {
            for col in 0..width {
                let mask = (1u128 << bits[col]) - 1;
                rows.push(((k >> shifts[col]) & mask) as u16);
            }
            counts.push(c);
        }
        Some(CtTable { vars: vars.iter().map(|&(v, _, _)| v).collect(), rows, counts })
    }

    /// Multiply every count by `k` (k = 0 empties the table).
    pub fn scale(&self, k: u64) -> CtTable {
        if k == 0 {
            return CtTable::empty(self.vars.clone());
        }
        let counts = self
            .counts
            .iter()
            .map(|&c| c.checked_mul(k).expect("count overflow in scale"))
            .collect();
        CtTable { vars: self.vars.clone(), rows: self.rows.clone(), counts }
    }

    /// +: count addition over identical variable sets; rows present in only
    /// one operand keep that operand's count (§4.1.2). Sort-merge.
    pub fn add(&self, other: &CtTable) -> CtTable {
        assert_eq!(self.vars, other.vars, "add: variable sets differ");
        let w = self.width();
        if w == 0 {
            let t = self.total() + other.total();
            return CtTable::scalar(u64::try_from(t).expect("count overflow"));
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let ord = if i == self.len() {
                std::cmp::Ordering::Greater
            } else if j == other.len() {
                std::cmp::Ordering::Less
            } else {
                self.row(i).cmp(other.row(j))
            };
            match ord {
                std::cmp::Ordering::Less => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    rows.extend_from_slice(other.row(j));
                    counts.push(other.counts[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i].checked_add(other.counts[j]).expect("overflow"));
                    i += 1;
                    j += 1;
                }
            }
        }
        CtTable { vars: self.vars.clone(), rows, counts }
    }

    /// −: count subtraction (§4.1.2). Defined only when `other`'s rows ⊆
    /// `self`'s rows with pointwise `count_other <= count_self`; rows whose
    /// difference is zero are omitted from the result. Sort-merge.
    pub fn subtract(&self, other: &CtTable) -> Result<CtTable, SubtractError> {
        if self.vars != other.vars {
            return Err(SubtractError::VarMismatch);
        }
        let w = self.width();
        if w == 0 {
            let (a, b) = (self.total(), other.total());
            if b > a {
                return Err(SubtractError::CountUnderflow {
                    row: vec![],
                    have: a as u64,
                    sub: b as u64,
                });
            }
            let d = (a - b) as u64;
            return Ok(if d == 0 { CtTable::empty(vec![]) } else { CtTable::scalar(d) });
        }
        let mut rows = Vec::with_capacity(self.rows.len());
        let mut counts = Vec::with_capacity(self.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() {
            if j < other.len() {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => {
                        rows.extend_from_slice(self.row(i));
                        counts.push(self.counts[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        return Err(SubtractError::MissingRow(other.row(j).to_vec()));
                    }
                    std::cmp::Ordering::Equal => {
                        let (a, b) = (self.counts[i], other.counts[j]);
                        if b > a {
                            return Err(SubtractError::CountUnderflow {
                                row: self.row(i).to_vec(),
                                have: a,
                                sub: b,
                            });
                        }
                        if a > b {
                            rows.extend_from_slice(self.row(i));
                            counts.push(a - b);
                        }
                        i += 1;
                        j += 1;
                    }
                }
            } else {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            }
        }
        if j < other.len() {
            return Err(SubtractError::MissingRow(other.row(j).to_vec()));
        }
        Ok(CtTable { vars: self.vars.clone(), rows, counts })
    }

    /// Extend with constant columns (Algorithm 1 lines 2-3: tag a partial
    /// table with `R = T/F` and `2Atts = n/a`). New vars must not already be
    /// present. Inserting constant columns preserves row order.
    pub fn extend_const(&self, consts: &[(VarId, u16)]) -> CtTable {
        if consts.is_empty() {
            return self.clone();
        }
        let mut merged: Vec<(VarId, Option<u16>)> =
            self.vars.iter().map(|&v| (v, None)).collect();
        for &(v, val) in consts {
            assert!(self.col_of(v).is_none(), "extend_const: var {v} already present");
            merged.push((v, Some(val)));
        }
        merged.sort_unstable_by_key(|&(v, _)| v);
        let vars: Vec<VarId> = merged.iter().map(|&(v, _)| v).collect();
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]));
        let w = self.width();
        let nw = vars.len();
        // Special case: extending an *empty-width* table (scalar) — each
        // count row becomes the constant row.
        if w == 0 {
            if self.is_empty() {
                return CtTable::empty(vars);
            }
            let rows: Vec<u16> = merged.iter().map(|&(_, c)| c.unwrap()).collect();
            return CtTable { vars, rows, counts: self.counts.clone() };
        }
        // §Perf: copy contiguous source segments between constant inserts
        // instead of a per-column match (the pivot extends multi-million-row
        // tables twice per chain).
        #[derive(Clone, Copy)]
        enum Piece {
            Src { start: usize, len: usize },
            Const(u16),
        }
        let mut pieces: Vec<Piece> = Vec::new();
        let mut src = 0usize;
        for &(_, c) in &merged {
            match c {
                Some(val) => pieces.push(Piece::Const(val)),
                None => {
                    if let Some(Piece::Src { len, .. }) = pieces.last_mut() {
                        *len += 1;
                    } else {
                        pieces.push(Piece::Src { start: src, len: 1 });
                    }
                    src += 1;
                }
            }
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = self.row(i);
            for &p in &pieces {
                match p {
                    Piece::Const(val) => rows.push(val),
                    Piece::Src { start, len } => rows.extend_from_slice(&r[start..start + len]),
                }
            }
        }
        CtTable { vars, rows, counts: self.counts.clone() }
    }

    /// ∪ of two tables over the same variables whose row sets are disjoint
    /// (Algorithm 1 line 4: `ct_F^+ ∪ ct_T^+`, disjoint because the pivot
    /// column differs). Single merge pass; panics on a shared row.
    pub fn union_disjoint(&self, other: &CtTable) -> CtTable {
        assert_eq!(self.vars, other.vars, "union: variable sets differ");
        let w = self.width();
        if w == 0 {
            assert!(
                self.is_empty() || other.is_empty(),
                "union_disjoint: two nullary rows always collide"
            );
            let t = self.total() + other.total();
            return if t == 0 {
                CtTable::empty(vec![])
            } else {
                CtTable::scalar(u64::try_from(t).unwrap())
            };
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let take_left = if i == self.len() {
                false
            } else if j == other.len() {
                true
            } else {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => panic!("union_disjoint: shared row"),
                }
            };
            if take_left {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            } else {
                rows.extend_from_slice(other.row(j));
                counts.push(other.counts[j]);
                j += 1;
            }
        }
        CtTable { vars: self.vars.clone(), rows, counts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;
    use crate::util::Pcg64;

    /// Random small ct-table for property tests.
    fn random_ct(rng: &mut Pcg64, vars: &[VarId], arities: &[u16]) -> CtTable {
        let n = rng.index(12) + 1;
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..n {
            for &a in arities {
                rows.push(rng.below(a as u64) as u16);
            }
            counts.push(rng.below(20) + 1);
        }
        CtTable::from_raw(vars.to_vec(), rows, counts)
    }

    #[test]
    fn select_matches_condition() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let s = t.select(&[(3, 1)]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.count_of(&[0, 1]), 11);
        assert_eq!(s.count_of(&[1, 1]), 13);
        s.check_invariants().unwrap();
    }

    #[test]
    fn project_sums_groups() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let p = t.project(&[1]);
        assert_eq!(p.len(), 2);
        assert_eq!(p.count_of(&[0]), 21);
        assert_eq!(p.count_of(&[1]), 25);
        assert_eq!(p.total(), t.total());
    }

    #[test]
    fn project_to_nothing_gives_scalar_total() {
        let t = CtTable::from_raw(vec![2], vec![0, 1], vec![4, 6]);
        let p = t.project(&[]);
        assert_eq!(p.width(), 0);
        assert_eq!(p.total(), 10);
    }

    #[test]
    fn condition_drops_columns() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let c = t.condition(&[(3, 0)]);
        assert_eq!(c.vars, vec![1]);
        assert_eq!(c.count_of(&[0]), 10);
        assert_eq!(c.count_of(&[1]), 12);
    }

    #[test]
    fn cross_multiplies_counts() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let b = CtTable::from_raw(vec![4], vec![0, 1], vec![5, 7]);
        let x = a.cross(&b);
        assert_eq!(x.len(), 4);
        assert_eq!(x.count_of(&[0, 0]), 10);
        assert_eq!(x.count_of(&[1, 1]), 21);
        assert_eq!(x.total(), a.total() * b.total());
        // column order canonical even when crossing (higher, lower)
        let y = b.cross(&a);
        assert_eq!(x, y);
    }

    #[test]
    fn cross_with_scalar_scales() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let s = CtTable::scalar(4);
        let x = a.cross(&s);
        assert_eq!(x.count_of(&[0]), 8);
        assert_eq!(x.count_of(&[1]), 12);
    }

    #[test]
    fn add_merges_disjoint_and_shared() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let b = CtTable::from_raw(vec![1], vec![1, 2], vec![10, 20]);
        let s = a.add(&b);
        assert_eq!(s.count_of(&[0]), 2);
        assert_eq!(s.count_of(&[1]), 13);
        assert_eq!(s.count_of(&[2]), 20);
        s.check_invariants().unwrap();
    }

    #[test]
    fn subtract_exact_and_errors() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![5, 3]);
        let b = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let d = a.subtract(&b).unwrap();
        assert_eq!(d.len(), 1); // the (1) row hit zero and was dropped
        assert_eq!(d.count_of(&[0]), 3);
        // underflow
        let c = CtTable::from_raw(vec![1], vec![0], vec![6]);
        assert!(matches!(a.subtract(&c), Err(SubtractError::CountUnderflow { .. })));
        // missing row
        let m = CtTable::from_raw(vec![1], vec![2], vec![1]);
        assert!(matches!(a.subtract(&m), Err(SubtractError::MissingRow(_))));
        // var mismatch
        let v = CtTable::from_raw(vec![2], vec![0], vec![1]);
        assert_eq!(a.subtract(&v), Err(SubtractError::VarMismatch));
    }

    #[test]
    fn extend_const_inserts_sorted() {
        let t = CtTable::from_raw(vec![2], vec![0, 1], vec![4, 6]);
        let e = t.extend_const(&[(0, 9), (5, 1)]);
        assert_eq!(e.vars, vec![0, 2, 5]);
        assert_eq!(e.count_of(&[9, 0, 1]), 4);
        assert_eq!(e.count_of(&[9, 1, 1]), 6);
        e.check_invariants().unwrap();
    }

    #[test]
    fn extend_const_on_scalar() {
        let s = CtTable::scalar(3);
        let e = s.extend_const(&[(1, 0), (2, 7)]);
        assert_eq!(e.len(), 1);
        assert_eq!(e.count_of(&[0, 7]), 3);
    }

    #[test]
    fn union_disjoint_merges() {
        let a = CtTable::from_raw(vec![1, 2], vec![0, 0, 1, 1], vec![1, 2]);
        let b = CtTable::from_raw(vec![1, 2], vec![0, 1, 1, 0], vec![3, 4]);
        let u = a.union_disjoint(&b);
        assert_eq!(u.len(), 4);
        assert_eq!(u.total(), 10);
        u.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "shared row")]
    fn union_rejects_overlap() {
        let a = CtTable::from_raw(vec![1], vec![0], vec![1]);
        let b = CtTable::from_raw(vec![1], vec![0], vec![1]);
        a.union_disjoint(&b);
    }

    // ---------- property tests ----------

    #[test]
    fn prop_projection_preserves_total() {
        run_prop(
            "projection_total",
            200,
            0xC0FFEE,
            |r| random_ct(r, &[1, 4, 7], &[3, 2, 4]),
            |t| {
                for keep in [vec![1], vec![4, 7], vec![1, 7], vec![]] {
                    let p = t.project(&keep);
                    if p.total() != t.total() {
                        return Err(format!("total changed for keep={keep:?}"));
                    }
                    p.check_invariants()?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_add_then_subtract_roundtrip() {
        run_prop(
            "add_sub_roundtrip",
            200,
            0xBEEF,
            |r| (random_ct(r, &[0, 2], &[3, 3]), random_ct(r, &[0, 2], &[3, 3])),
            |(a, b)| {
                let sum = a.add(b);
                let back = sum.subtract(b).map_err(|e| e.to_string())?;
                if &back != a {
                    return Err("a + b - b != a".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_select_project_commute() {
        // σ on a kept column commutes with π.
        run_prop(
            "select_project_commute",
            200,
            0xABCD,
            |r| random_ct(r, &[0, 3, 5], &[2, 3, 2]),
            |t| {
                let a = t.select(&[(0, 1)]).project(&[0, 3]);
                let b = t.project(&[0, 3]).select(&[(0, 1)]);
                if a != b {
                    return Err("σπ != πσ".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cross_total_is_product() {
        run_prop(
            "cross_total",
            100,
            0x1234,
            |r| (random_ct(r, &[0], &[4]), random_ct(r, &[2, 3], &[2, 2])),
            |(a, b)| {
                let x = a.cross(b);
                x.check_invariants()?;
                if x.total() != a.total() * b.total() {
                    return Err("cross total mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_add_commutative_associative() {
        run_prop(
            "add_comm_assoc",
            150,
            0x7777,
            |r| {
                (
                    random_ct(r, &[1], &[4]),
                    random_ct(r, &[1], &[4]),
                    random_ct(r, &[1], &[4]),
                )
            },
            |(a, b, c)| {
                if a.add(b) != b.add(a) {
                    return Err("not commutative".into());
                }
                if a.add(b).add(c) != a.add(&b.add(c)) {
                    return Err("not associative".into());
                }
                Ok(())
            },
        );
    }
}
