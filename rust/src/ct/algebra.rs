//! The ct-algebra operators (paper §4.1): σ selection, π projection,
//! χ conditioning, × cross product, + addition, − subtraction, plus the
//! `extend`/`union` helpers Algorithm 1 needs — implemented as **integer
//! kernels generic over the packed key width** ([`RowKey`], monomorphized
//! at `u64` for ≤ 64-bit layouts and `u128` for 65–128-bit layouts; see
//! [`CtLayout`](super::CtLayout)):
//!
//! * σ / χ — one mask-AND + compare per row;
//! * π — shift-compress each key into the kept columns' sub-layout, then a
//!   radix-sort group-by;
//! * × — OR of precomputed per-operand partial keys under the merged
//!   (disjoint) layout;
//! * + / − / ∪ — single-pass sort-merge scans over scalar keys,
//!   matching the sort-merge cost model of §4.1.3.
//!
//! Operands whose layouts differ are re-encoded into the column-wise union
//! layout first (order-preserving, linear), widening one-word keys into a
//! two-word union when needed; results land in the narrowest tier their
//! layout allows. Only operands on the row-major wide store (> 128-bit
//! layouts) — or results past 128 bits — route through the retained
//! row-major implementation in [`reference`](super::reference); every such
//! routing bumps [`reference_op_fallbacks`] so the integration tests can
//! assert paper-scale schemas never leave the packed path. The property
//! tests here and in `reference.rs` assert all paths are bit-identical.
//!
//! All operators preserve the [`CtTable`] invariants (sorted unique rows,
//! positive counts, canonical column order).
//!
//! Every dispatch point carries a [`ticks`] hot-spot timer: when the
//! relaxed gate is on (the serving stack and the ct-ops bench enable
//! it), each operator call ticks a per-(kernel, tier) counter and
//! charges its wall time, so `METRICS` / `MjMetrics::breakdown()` can
//! name the most expensive kernel before anyone vectorizes it.
//!
//! [`RowKey`]: super::RowKey
//! [`reference_op_fallbacks`]: super::reference::reference_op_fallbacks

use super::layout::{radix_sort_pairs_k, RowKey};
use super::reference::{note_op_fallback, RefTable};
use super::{CtLayout, CtTable, KeyStore, RowStore};
use crate::schema::{VarId, NA};
use std::borrow::Cow;

pub mod ticks {
    //! Hot-spot timers for the ct-algebra kernels: cumulative tick and
    //! nanosecond counters per (operator, key-width tier), the
    //! measurement the SIMD roadmap item starts from. Behind the same
    //! relaxed-load gate idiom as span tracing: while [`enabled`] is
    //! false (the default — the Möbius build hot loop runs untimed)
    //! every operator pays one relaxed bool load; the serving stack and
    //! the ct-ops bench turn the gate on so `METRICS`,
    //! `MjMetrics::breakdown()`, and `BENCH_ctops_micro.json` can name
    //! the most expensive kernel. Counters are per-*operator-call* (one
    //! tick per dispatch, not per row), so the gate sits outside the
    //! row loops.

    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
    use std::time::Instant;

    /// The instrumented ct-algebra operators.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Kernel {
        Select,
        Project,
        Condition,
        Cross,
        Add,
        Subtract,
        Extend,
        Union,
    }

    /// Every instrumented kernel, in display order.
    pub const ALL_KERNELS: [Kernel; 8] = [
        Kernel::Select,
        Kernel::Project,
        Kernel::Condition,
        Kernel::Cross,
        Kernel::Add,
        Kernel::Subtract,
        Kernel::Extend,
        Kernel::Union,
    ];

    /// Key-width tier an operator call ran at: the one-word `u64`
    /// kernel, the two-word `u128` kernel, or the row-major wide
    /// fallback (`reference.rs`).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Tier {
        U64,
        U128,
        Wide,
    }

    /// Every tier, in display order.
    pub const ALL_TIERS: [Tier; 3] = [Tier::U64, Tier::U128, Tier::Wide];

    impl Kernel {
        fn idx(self) -> usize {
            match self {
                Kernel::Select => 0,
                Kernel::Project => 1,
                Kernel::Condition => 2,
                Kernel::Cross => 3,
                Kernel::Add => 4,
                Kernel::Subtract => 5,
                Kernel::Extend => 6,
                Kernel::Union => 7,
            }
        }

        /// Lower-case operator name, as used in metric labels.
        pub fn name(self) -> &'static str {
            match self {
                Kernel::Select => "select",
                Kernel::Project => "project",
                Kernel::Condition => "condition",
                Kernel::Cross => "cross",
                Kernel::Add => "add",
                Kernel::Subtract => "subtract",
                Kernel::Extend => "extend",
                Kernel::Union => "union",
            }
        }
    }

    impl Tier {
        fn idx(self) -> usize {
            match self {
                Tier::U64 => 0,
                Tier::U128 => 1,
                Tier::Wide => 2,
            }
        }

        /// Tier suffix, as used in metric labels (`select_u64`).
        pub fn name(self) -> &'static str {
            match self {
                Tier::U64 => "u64",
                Tier::U128 => "u128",
                Tier::Wide => "wide",
            }
        }
    }

    /// Number of (kernel, tier) counter slots.
    pub const SLOTS: usize = 24;

    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static ENABLED: AtomicBool = AtomicBool::new(false);
    static TICKS: [AtomicU64; SLOTS] = [ZERO; SLOTS];
    static NANOS: [AtomicU64; SLOTS] = [ZERO; SLOTS];

    /// Is kernel timing on? One relaxed load — the whole cost of an
    /// operator dispatch while profiling is off.
    #[inline]
    pub fn enabled() -> bool {
        ENABLED.load(Relaxed)
    }

    /// Turn kernel timing on/off process-wide (serve() and the ct-ops
    /// bench enable it; library users default to off).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Relaxed);
    }

    fn slot(k: Kernel, t: Tier) -> usize {
        k.idx() * ALL_TIERS.len() + t.idx()
    }

    /// RAII guard: created at an operator's dispatch point, charges
    /// elapsed wall nanos to the (kernel, tier) slot on drop. A no-op
    /// shell when the gate is off.
    pub struct KernelTimer {
        slot: usize,
        start: Option<Instant>,
    }

    /// Start timing one operator call (ticks the call counter
    /// immediately; nanos land on drop). Free when [`enabled`] is off.
    #[inline]
    pub fn timer(k: Kernel, t: Tier) -> KernelTimer {
        if !enabled() {
            return KernelTimer { slot: 0, start: None };
        }
        let s = slot(k, t);
        TICKS[s].fetch_add(1, Relaxed);
        KernelTimer { slot: s, start: Some(Instant::now()) }
    }

    impl Drop for KernelTimer {
        fn drop(&mut self) {
            if let Some(t0) = self.start {
                NANOS[self.slot].fetch_add(t0.elapsed().as_nanos() as u64, Relaxed);
            }
        }
    }

    /// Cumulative (calls, nanos) for one (kernel, tier) slot.
    pub fn counter(k: Kernel, t: Tier) -> (u64, u64) {
        let s = slot(k, t);
        (TICKS[s].load(Relaxed), NANOS[s].load(Relaxed))
    }

    /// Every slot as `(kernel, tier, calls, nanos)`, zero rows included
    /// (Prometheus rendering wants stable families).
    pub fn snapshot() -> Vec<(&'static str, &'static str, u64, u64)> {
        let mut out = Vec::with_capacity(SLOTS);
        for k in ALL_KERNELS {
            for t in ALL_TIERS {
                let (c, n) = counter(k, t);
                out.push((k.name(), t.name(), c, n));
            }
        }
        out
    }

    /// Serializes tests that toggle the process-global gate, so an
    /// exact "gated-off calls do not count" assertion cannot race a
    /// concurrent test enabling the gate.
    #[cfg(test)]
    pub fn gate_lock() -> std::sync::MutexGuard<'static, ()> {
        use std::sync::{Mutex, OnceLock};
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|p| p.into_inner())
    }

    /// The slot with the most cumulative time, as
    /// `(label, calls, nanos)` with label like `subtract_u64` — `None`
    /// until any timed call has landed.
    pub fn hottest() -> Option<(String, u64, u64)> {
        snapshot()
            .into_iter()
            .filter(|&(_, _, c, n)| c > 0 && n > 0)
            .max_by_key(|&(_, _, _, n)| n)
            .map(|(k, t, c, n)| (format!("{k}_{t}"), c, n))
    }
}

/// Error from [`CtTable::subtract`]: the paper defines `ct1 − ct2` only when
/// ct2's rows are a subset of ct1's with pointwise smaller-or-equal counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubtractError {
    /// A row of `ct2` is missing from `ct1`.
    MissingRow(Vec<u16>),
    /// A shared row has a larger count in `ct2` than in `ct1`.
    CountUnderflow { row: Vec<u16>, have: u64, sub: u64 },
    /// The two tables have different column sets.
    VarMismatch,
}

impl std::fmt::Display for SubtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubtractError::MissingRow(r) => write!(f, "subtract: row {r:?} missing from minuend"),
            SubtractError::CountUnderflow { row, have, sub } => {
                write!(f, "subtract: row {row:?} has {have} < {sub}")
            }
            SubtractError::VarMismatch => write!(f, "subtract: variable sets differ"),
        }
    }
}

impl std::error::Error for SubtractError {}

/// Mask/value pair for a packed selection filter at key width `K`, or the
/// reason none can match.
enum Filter<K> {
    /// `key & mask == want` selects the row.
    MaskCompare { mask: K, want: K },
    /// A condition value is unrepresentable or contradictory: no row matches.
    Never,
}

/// One output column of `extend_const`: copied from a source column or
/// filled with a constant value.
#[derive(Clone, Copy)]
enum Entry {
    Src(usize),
    Const(u16),
}

/// Two merge operands aligned onto one layout at a common key width,
/// borrowing the key slices when the layouts already agree.
enum Aligned<'a> {
    K1(CtLayout, Cow<'a, [u64]>, Cow<'a, [u64]>),
    K2(CtLayout, Cow<'a, [u128]>, Cow<'a, [u128]>),
}

/// Re-encode one operand's keys (stored at width `KS`) into the union
/// layout `u` at two-word width — the shared body of every widening arm of
/// [`CtTable::aligned_keys`].
fn widen_into<KS: RowKey>(layout: &CtLayout, keys: &[KS], u: &CtLayout) -> Vec<u128> {
    keys.iter().map(|&k| layout.reencode_k::<KS, u128>(u, k)).collect()
}

/// Align one operand's keys to a same-width union layout: borrow when its
/// layout already equals the union (common when only the other operand
/// needed re-encoding, e.g. the wider side of a mixed-width merge), else
/// pay one re-encode pass.
fn align<'a, K: RowKey>(layout: &CtLayout, keys: &'a [K], u: &CtLayout) -> Cow<'a, [K]> {
    if layout == u {
        Cow::Borrowed(keys)
    } else {
        Cow::Owned(keys.iter().map(|&k| layout.reencode_k::<K, K>(u, k)).collect())
    }
}

impl CtTable {
    /// Build the mask-compare filter for `(column, value)` conditions.
    fn filter_for<K: RowKey>(&self, cols: &[(usize, u16)]) -> Filter<K> {
        let mut mask = K::ZERO;
        let mut want = K::ZERO;
        for &(c, val) in cols {
            let Some(enc) = self.layout.try_encode(c, val) else {
                return Filter::Never;
            };
            let shift = self.layout.col(c).shift;
            let fmask = self.layout.field_mask_k::<K>(c) << shift;
            let fwant = K::from_u64(enc) << shift;
            if mask & fmask != K::ZERO && want & fmask != fwant {
                return Filter::Never; // two different values for one column
            }
            mask = mask | fmask;
            want = want | fwant;
        }
        Filter::MaskCompare { mask, want }
    }

    /// σ_φ: keep rows matching all `(var, value)` conditions. Columns are
    /// unchanged. Conditions on absent variables panic (caller bug).
    pub fn select(&self, cond: &[(VarId, u16)]) -> CtTable {
        let cols: Vec<(usize, u16)> = cond
            .iter()
            .map(|&(v, val)| (self.col_of(v).expect("select: unknown var"), val))
            .collect();
        if cols.is_empty() {
            return self.clone();
        }
        match &self.store {
            RowStore::Packed(keys) => {
                let _t = ticks::timer(ticks::Kernel::Select, ticks::Tier::U64);
                self.select_packed::<u64>(keys, &cols)
            }
            RowStore::Packed2(keys) => {
                let _t = ticks::timer(ticks::Kernel::Select, ticks::Tier::U128);
                self.select_packed::<u128>(keys, &cols)
            }
            RowStore::Wide(_) => {
                let _t = ticks::timer(ticks::Kernel::Select, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).select(cond).to_ct()
            }
        }
    }

    /// σ kernel at key width `K`: one mask-AND + compare per row.
    fn select_packed<K: KeyStore>(&self, keys: &[K], cols: &[(usize, u16)]) -> CtTable {
        let (mask, want) = match self.filter_for::<K>(cols) {
            Filter::MaskCompare { mask, want } => (mask, want),
            Filter::Never => {
                return CtTable::empty_with_layout(self.vars.clone(), self.layout.clone())
            }
        };
        let mut out_keys = Vec::new();
        let mut out_counts = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            if k & mask == want {
                out_keys.push(k);
                out_counts.push(self.counts[i]);
            }
        }
        // Selection preserves sortedness, uniqueness, and the layout, so the
        // result stays in the operand's tier.
        CtTable {
            vars: self.vars.clone(),
            counts: out_counts,
            layout: self.layout.clone(),
            store: K::store(out_keys),
        }
    }

    /// π_keep: project onto a subset of columns, summing counts of rows that
    /// collapse together (SQL GROUP BY, §4.1.1). Packed path: shift-compress
    /// every key into the kept sub-layout, radix sort, fold equal keys.
    pub fn project(&self, keep: &[VarId]) -> CtTable {
        let mut keep_sorted: Vec<VarId> = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let cols: Vec<usize> = keep_sorted
            .iter()
            .map(|&v| self.col_of(v).expect("project: unknown var"))
            .collect();
        if cols.len() == self.width() {
            return self.clone();
        }
        if cols.is_empty() {
            let total: u128 = self.total();
            return if total == 0 {
                CtTable::empty(Vec::new())
            } else {
                CtTable::scalar(u64::try_from(total).expect("count overflow"))
            };
        }
        match &self.store {
            RowStore::Packed(keys) => {
                let _t = ticks::timer(ticks::Kernel::Project, ticks::Tier::U64);
                self.project_packed::<u64>(keys, &cols, keep_sorted)
            }
            RowStore::Packed2(keys) => {
                let _t = ticks::timer(ticks::Kernel::Project, ticks::Tier::U128);
                self.project_packed::<u128>(keys, &cols, keep_sorted)
            }
            RowStore::Wide(_) => {
                let _t = ticks::timer(ticks::Kernel::Project, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).project(keep).to_ct()
            }
        }
    }

    /// π kernel at key width `K`. The result narrows to the one-word store
    /// whenever the kept columns fit 64 bits (via [`KeyStore::finish`]).
    fn project_packed<K: KeyStore>(
        &self,
        keys: &[K],
        cols: &[usize],
        keep_sorted: Vec<VarId>,
    ) -> CtTable {
        let sub = self.layout.sub(cols);
        let plans = self.layout.compress_plan_k::<K>(cols, &sub);
        let mut keyed: Vec<(K, u64)> = Vec::with_capacity(self.len());
        for (i, &k) in keys.iter().enumerate() {
            keyed.push((CtLayout::apply_plan_k::<K>(k, &plans), self.counts[i]));
        }
        radix_sort_pairs_k::<K>(&mut keyed, sub.total_bits());
        let mut out_keys: Vec<K> = Vec::with_capacity(keyed.len());
        let mut out_counts: Vec<u64> = Vec::with_capacity(keyed.len());
        for (k, c) in keyed {
            if out_keys.last() == Some(&k) {
                let li = out_counts.len() - 1;
                out_counts[li] = out_counts[li].checked_add(c).expect("count overflow");
            } else {
                out_keys.push(k);
                out_counts.push(c);
            }
        }
        K::finish(keep_sorted, sub, out_keys, out_counts)
    }

    /// χ_φ: conditioning = select then drop the conditioned columns
    /// (§4.1.1: `χ_φ ct = π_rest (σ_φ ct)`). Packed path fuses both: one
    /// mask-compare filter plus a shift-compress — no re-sort is needed
    /// because the dropped fields are constant across the surviving rows.
    pub fn condition(&self, cond: &[(VarId, u16)]) -> CtTable {
        let cols: Vec<(usize, u16)> = cond
            .iter()
            .map(|&(v, val)| (self.col_of(v).expect("condition: unknown var"), val))
            .collect();
        if cols.is_empty() {
            return self.clone();
        }
        match &self.store {
            RowStore::Packed(keys) => {
                let _t = ticks::timer(ticks::Kernel::Condition, ticks::Tier::U64);
                self.condition_packed::<u64>(keys, &cols)
            }
            RowStore::Packed2(keys) => {
                let _t = ticks::timer(ticks::Kernel::Condition, ticks::Tier::U128);
                self.condition_packed::<u128>(keys, &cols)
            }
            RowStore::Wide(_) => {
                let _t = ticks::timer(ticks::Kernel::Condition, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).condition(cond).to_ct()
            }
        }
    }

    /// χ kernel at key width `K`: fused filter + shift-compress. Narrows to
    /// the one-word store when the remaining columns fit 64 bits.
    fn condition_packed<K: KeyStore>(&self, keys: &[K], cols: &[(usize, u16)]) -> CtTable {
        let mut drop: Vec<usize> = cols.iter().map(|&(c, _)| c).collect();
        drop.sort_unstable();
        drop.dedup();
        let rest_cols: Vec<usize> = (0..self.width()).filter(|c| !drop.contains(c)).collect();
        let rest_vars: Vec<VarId> = rest_cols.iter().map(|&c| self.vars[c]).collect();

        let filter = self.filter_for::<K>(cols);
        if rest_cols.is_empty() {
            // Conditioned on every column: the result is nullary.
            let total: u128 = match filter {
                Filter::Never => 0,
                Filter::MaskCompare { mask, want } => keys
                    .iter()
                    .zip(&self.counts)
                    .filter(|(&k, _)| k & mask == want)
                    .map(|(_, &c)| c as u128)
                    .sum(),
            };
            return if total == 0 {
                CtTable::empty(Vec::new())
            } else {
                CtTable::scalar(u64::try_from(total).expect("count overflow"))
            };
        }
        let sub = self.layout.sub(&rest_cols);
        let (mask, want) = match filter {
            Filter::MaskCompare { mask, want } => (mask, want),
            Filter::Never => return CtTable::empty_with_layout(rest_vars, sub),
        };
        let plans = self.layout.compress_plan_k::<K>(&rest_cols, &sub);
        let mut out_keys = Vec::new();
        let mut out_counts = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            if k & mask != want {
                continue;
            }
            out_keys.push(CtLayout::apply_plan_k::<K>(k, &plans));
            out_counts.push(self.counts[i]);
        }
        // Dropped fields are fixed constants over the survivors, so the
        // compressed keys stay sorted and unique.
        K::finish(rest_vars, sub, out_keys, out_counts)
    }

    /// ×: cross product; counts multiply (§4.1.2). Variable sets must be
    /// disjoint. Packed path: each operand row contributes a precomputed
    /// partial key at its final column positions, so every output row is a
    /// single `pa | pb` (no u16 materialization), then one radix sort puts
    /// the interleaved columns in canonical order. Runs at whichever key
    /// width the merged layout needs (either operand may be one- or
    /// two-word).
    pub fn cross(&self, other: &CtTable) -> CtTable {
        for v in &other.vars {
            assert!(self.col_of(*v).is_none(), "cross: overlapping var {v}");
        }
        // Nullary fast paths (scalar multiplication).
        if self.width() == 0 {
            let k = if self.is_empty() { 0 } else { self.counts[0] };
            return other.scale(k);
        }
        if other.width() == 0 {
            let k = if other.is_empty() { 0 } else { other.counts[0] };
            return self.scale(k);
        }
        if self.is_packed() && other.is_packed() {
            // Merged column plan: (var, from_self, source column).
            let mut merged: Vec<(VarId, bool, usize)> =
                Vec::with_capacity(self.width() + other.width());
            for (c, &v) in self.vars.iter().enumerate() {
                merged.push((v, true, c));
            }
            for (c, &v) in other.vars.iter().enumerate() {
                merged.push((v, false, c));
            }
            merged.sort_unstable_by_key(|&(v, _, _)| v);
            let specs: Vec<(u16, bool)> = merged
                .iter()
                .map(|&(_, fa, c)| if fa { self.layout.spec(c) } else { other.layout.spec(c) })
                .collect();
            let ml = CtLayout::from_specs(&specs);
            if ml.fits() {
                let _t = ticks::timer(ticks::Kernel::Cross, ticks::Tier::U64);
                return cross_packed::<u64>(self, other, &merged, ml);
            }
            if ml.fits2() {
                let _t = ticks::timer(ticks::Kernel::Cross, ticks::Tier::U128);
                return cross_packed::<u128>(self, other, &merged, ml);
            }
        }
        let _t = ticks::timer(ticks::Kernel::Cross, ticks::Tier::Wide);
        note_op_fallback();
        RefTable::from(self).cross(&RefTable::from(other)).to_ct()
    }

    /// Multiply every count by `k` (k = 0 empties the table).
    pub fn scale(&self, k: u64) -> CtTable {
        if k == 0 {
            return CtTable::empty_with_layout(self.vars.clone(), self.layout.clone());
        }
        let counts = self
            .counts
            .iter()
            .map(|&c| c.checked_mul(k).expect("count overflow in scale"))
            .collect();
        CtTable {
            vars: self.vars.clone(),
            counts,
            layout: self.layout.clone(),
            store: self.store.clone(),
        }
    }

    /// Align two packed operands onto one layout. The common case — equal
    /// (schema-derived) layouts — borrows the key slices directly; only
    /// differing layouts pay a re-encode pass, widening into a two-word
    /// union when the unified layout exceeds 64 bits. Returns `None` when
    /// either operand is on the wide store or the unified layout does not
    /// fit 128 bits (callers fall back to the row-major reference path).
    fn aligned_keys<'a>(&'a self, other: &'a CtTable) -> Option<Aligned<'a>> {
        match (&self.store, &other.store) {
            (RowStore::Packed(ka), RowStore::Packed(kb)) => {
                if self.layout == other.layout {
                    return Some(Aligned::K1(
                        self.layout.clone(),
                        Cow::Borrowed(ka.as_slice()),
                        Cow::Borrowed(kb.as_slice()),
                    ));
                }
                let u = self.layout.union_with(&other.layout);
                if u.fits() {
                    let ra = align::<u64>(&self.layout, ka, &u);
                    let rb = align::<u64>(&other.layout, kb, &u);
                    Some(Aligned::K1(u, ra, rb))
                } else if u.fits2() {
                    let ra = widen_into::<u64>(&self.layout, ka, &u);
                    let rb = widen_into::<u64>(&other.layout, kb, &u);
                    Some(Aligned::K2(u, Cow::Owned(ra), Cow::Owned(rb)))
                } else {
                    None
                }
            }
            (RowStore::Packed2(ka), RowStore::Packed2(kb)) => {
                if self.layout == other.layout {
                    return Some(Aligned::K2(
                        self.layout.clone(),
                        Cow::Borrowed(ka.as_slice()),
                        Cow::Borrowed(kb.as_slice()),
                    ));
                }
                // The union covers each operand column-wise, so it is at
                // least as wide as the wider operand: never back under 65
                // bits here.
                let u = self.layout.union_with(&other.layout);
                if !u.fits2() {
                    return None;
                }
                let ra = align::<u128>(&self.layout, ka, &u);
                let rb = align::<u128>(&other.layout, kb, &u);
                Some(Aligned::K2(u, ra, rb))
            }
            (RowStore::Packed(ka), RowStore::Packed2(kb)) => {
                // The one-word side always widens; the two-word side often
                // already IS the union (its layout dominates column-wise)
                // and then borrows.
                let u = self.layout.union_with(&other.layout);
                if !u.fits2() {
                    return None;
                }
                let ra = widen_into::<u64>(&self.layout, ka, &u);
                let rb = align::<u128>(&other.layout, kb, &u);
                Some(Aligned::K2(u, Cow::Owned(ra), rb))
            }
            (RowStore::Packed2(ka), RowStore::Packed(kb)) => {
                let u = self.layout.union_with(&other.layout);
                if !u.fits2() {
                    return None;
                }
                let ra = align::<u128>(&self.layout, ka, &u);
                let rb = widen_into::<u64>(&other.layout, kb, &u);
                Some(Aligned::K2(u, ra, Cow::Owned(rb)))
            }
            _ => None,
        }
    }

    /// +: count addition over identical variable sets; rows present in only
    /// one operand keep that operand's count (§4.1.2). Sort-merge on scalar
    /// keys at the aligned width.
    pub fn add(&self, other: &CtTable) -> CtTable {
        assert_eq!(self.vars, other.vars, "add: variable sets differ");
        if self.width() == 0 {
            let t = self.total() + other.total();
            return if t == 0 {
                CtTable::empty(Vec::new())
            } else {
                CtTable::scalar(u64::try_from(t).expect("count overflow"))
            };
        }
        match self.aligned_keys(other) {
            Some(Aligned::K1(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Add, ticks::Tier::U64);
                merge_add::<u64>(self, other, layout, &ka, &kb)
            }
            Some(Aligned::K2(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Add, ticks::Tier::U128);
                merge_add::<u128>(self, other, layout, &ka, &kb)
            }
            None => {
                let _t = ticks::timer(ticks::Kernel::Add, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).add(&RefTable::from(other)).to_ct()
            }
        }
    }

    /// −: count subtraction (§4.1.2). Defined only when `other`'s rows ⊆
    /// `self`'s rows with pointwise `count_other <= count_self`; rows whose
    /// difference is zero are omitted from the result. Sort-merge on scalar
    /// keys at the aligned width.
    pub fn subtract(&self, other: &CtTable) -> Result<CtTable, SubtractError> {
        if self.vars != other.vars {
            return Err(SubtractError::VarMismatch);
        }
        if self.width() == 0 {
            let (a, b) = (self.total(), other.total());
            if b > a {
                return Err(SubtractError::CountUnderflow {
                    row: vec![],
                    have: a as u64,
                    sub: b as u64,
                });
            }
            let d = (a - b) as u64;
            return Ok(if d == 0 { CtTable::empty(vec![]) } else { CtTable::scalar(d) });
        }
        match self.aligned_keys(other) {
            Some(Aligned::K1(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Subtract, ticks::Tier::U64);
                merge_subtract::<u64>(self, other, layout, &ka, &kb)
            }
            Some(Aligned::K2(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Subtract, ticks::Tier::U128);
                merge_subtract::<u128>(self, other, layout, &ka, &kb)
            }
            None => {
                let _t = ticks::timer(ticks::Kernel::Subtract, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self)
                    .subtract(&RefTable::from(other))
                    .map(|r| r.to_ct())
            }
        }
    }

    /// Extend with constant columns (Algorithm 1 lines 2-3: tag a partial
    /// table with `R = T/F` and `2Atts = n/a`). New vars must not already be
    /// present. Packed path: every key gains the same constant fields, so
    /// row order is preserved and the rewrite is one shift-OR pass — the
    /// result widens to the two-word tier when the constants push the
    /// layout past 64 bits.
    pub fn extend_const(&self, consts: &[(VarId, u16)]) -> CtTable {
        if consts.is_empty() {
            return self.clone();
        }
        for &(v, _) in consts {
            assert!(self.col_of(v).is_none(), "extend_const: var {v} already present");
        }
        // Merged column plan (key-width independent): source column or
        // constant value per output column.
        let mut merged: Vec<(VarId, Entry)> =
            self.vars.iter().enumerate().map(|(c, &v)| (v, Entry::Src(c))).collect();
        for &(v, val) in consts {
            merged.push((v, Entry::Const(val)));
        }
        merged.sort_unstable_by_key(|&(v, _)| v);
        let vars: Vec<VarId> = merged.iter().map(|&(v, _)| v).collect();
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]));
        let specs: Vec<(u16, bool)> = merged
            .iter()
            .map(|&(_, e)| match e {
                Entry::Src(c) => self.layout.spec(c),
                Entry::Const(val) => {
                    if val == NA {
                        (1, true)
                    } else {
                        (val + 1, false)
                    }
                }
            })
            .collect();
        let nl = CtLayout::from_specs(&specs);
        match (&self.store, nl.total_bits()) {
            (RowStore::Packed(keys), 0..=64) => {
                let _t = ticks::timer(ticks::Kernel::Extend, ticks::Tier::U64);
                extend_packed::<u64, u64>(self, keys, &merged, vars, nl)
            }
            (RowStore::Packed(keys), 65..=128) => {
                let _t = ticks::timer(ticks::Kernel::Extend, ticks::Tier::U128);
                extend_packed::<u64, u128>(self, keys, &merged, vars, nl)
            }
            (RowStore::Packed2(keys), 65..=128) => {
                let _t = ticks::timer(ticks::Kernel::Extend, ticks::Tier::U128);
                extend_packed::<u128, u128>(self, keys, &merged, vars, nl)
            }
            _ => {
                let _t = ticks::timer(ticks::Kernel::Extend, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).extend_const(consts).to_ct()
            }
        }
    }

    /// ∪ of two tables over the same variables whose row sets are disjoint
    /// (Algorithm 1 line 4: `ct_F^+ ∪ ct_T^+`, disjoint because the pivot
    /// column differs). Single merge pass at the aligned key width; panics
    /// on a shared row.
    pub fn union_disjoint(&self, other: &CtTable) -> CtTable {
        assert_eq!(self.vars, other.vars, "union: variable sets differ");
        if self.width() == 0 {
            assert!(
                self.is_empty() || other.is_empty(),
                "union_disjoint: two nullary rows always collide"
            );
            let t = self.total() + other.total();
            return if t == 0 {
                CtTable::empty(vec![])
            } else {
                CtTable::scalar(u64::try_from(t).unwrap())
            };
        }
        match self.aligned_keys(other) {
            Some(Aligned::K1(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Union, ticks::Tier::U64);
                merge_union::<u64>(self, other, layout, &ka, &kb)
            }
            Some(Aligned::K2(layout, ka, kb)) => {
                let _t = ticks::timer(ticks::Kernel::Union, ticks::Tier::U128);
                merge_union::<u128>(self, other, layout, &ka, &kb)
            }
            None => {
                let _t = ticks::timer(ticks::Kernel::Union, ticks::Tier::Wide);
                note_op_fallback();
                RefTable::from(self).union_disjoint(&RefTable::from(other)).to_ct()
            }
        }
    }
}

/// × kernel at merged key width `KM`. Each operand's partial keys are built
/// from its own store width (`u64` or `u128`), widened into `KM` fields.
fn cross_packed<KM: KeyStore>(
    a: &CtTable,
    b: &CtTable,
    merged: &[(VarId, bool, usize)],
    ml: CtLayout,
) -> CtTable {
    fn partials<KO: RowKey, KM: RowKey>(
        t: &CtTable,
        keys: &[KO],
        merged: &[(VarId, bool, usize)],
        ml: &CtLayout,
        from_self: bool,
    ) -> Vec<KM> {
        keys.iter()
            .map(|&k| {
                let mut out = KM::ZERO;
                for (mc, &(_, fa, c)) in merged.iter().enumerate() {
                    if fa == from_self {
                        let field = t.layout.extract_k::<KO>(c, k);
                        out = out | (KM::from_u64(field) << ml.col(mc).shift);
                    }
                }
                out
            })
            .collect()
    }
    let side = |t: &CtTable, from_self: bool| -> Vec<KM> {
        match &t.store {
            RowStore::Packed(keys) => partials::<u64, KM>(t, keys, merged, &ml, from_self),
            RowStore::Packed2(keys) => partials::<u128, KM>(t, keys, merged, &ml, from_self),
            RowStore::Wide(_) => unreachable!("cross_packed requires packed operands"),
        }
    };
    let pa = side(a, true);
    let pb = side(b, false);
    let mut keyed: Vec<(KM, u64)> = Vec::with_capacity(pa.len() * pb.len());
    for (x, &ca) in pa.iter().zip(&a.counts) {
        for (y, &cb) in pb.iter().zip(&b.counts) {
            keyed.push((*x | *y, ca.checked_mul(cb).expect("count overflow in cross")));
        }
    }
    // Interleaved columns break the nested-loop order; one radix sort
    // restores it. Keys are unique by construction (operands are unique and
    // fields partition), so no fold.
    radix_sort_pairs_k::<KM>(&mut keyed, ml.total_bits());
    let mut keys = Vec::with_capacity(keyed.len());
    let mut counts = Vec::with_capacity(keyed.len());
    for (k, c) in keyed {
        keys.push(k);
        counts.push(c);
    }
    let vars: Vec<VarId> = merged.iter().map(|&(v, _, _)| v).collect();
    KM::finish(vars, ml, keys, counts)
}

/// + kernel: single-pass sort-merge at key width `K`.
fn merge_add<K: KeyStore>(
    a: &CtTable,
    b: &CtTable,
    layout: CtLayout,
    ka: &[K],
    kb: &[K],
) -> CtTable {
    let mut keys = Vec::with_capacity(ka.len() + kb.len());
    let mut counts = Vec::with_capacity(ka.len() + kb.len());
    let (mut i, mut j) = (0, 0);
    while i < ka.len() || j < kb.len() {
        let ord = if i == ka.len() {
            std::cmp::Ordering::Greater
        } else if j == kb.len() {
            std::cmp::Ordering::Less
        } else {
            ka[i].cmp(&kb[j])
        };
        match ord {
            std::cmp::Ordering::Less => {
                keys.push(ka[i]);
                counts.push(a.counts[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                keys.push(kb[j]);
                counts.push(b.counts[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                keys.push(ka[i]);
                counts.push(a.counts[i].checked_add(b.counts[j]).expect("overflow"));
                i += 1;
                j += 1;
            }
        }
    }
    K::finish(a.vars.clone(), layout, keys, counts)
}

/// − kernel: single-pass sort-merge at key width `K`; error rows decode
/// through the aligned layout.
fn merge_subtract<K: KeyStore>(
    a: &CtTable,
    b: &CtTable,
    layout: CtLayout,
    ka: &[K],
    kb: &[K],
) -> Result<CtTable, SubtractError> {
    let mut keys = Vec::with_capacity(ka.len());
    let mut counts = Vec::with_capacity(ka.len());
    let (mut i, mut j) = (0, 0);
    while i < ka.len() {
        if j < kb.len() {
            match ka[i].cmp(&kb[j]) {
                std::cmp::Ordering::Less => {
                    keys.push(ka[i]);
                    counts.push(a.counts[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    return Err(SubtractError::MissingRow(layout.unpack_k::<K>(kb[j])));
                }
                std::cmp::Ordering::Equal => {
                    let (ca, cb) = (a.counts[i], b.counts[j]);
                    if cb > ca {
                        return Err(SubtractError::CountUnderflow {
                            row: layout.unpack_k::<K>(ka[i]),
                            have: ca,
                            sub: cb,
                        });
                    }
                    if ca > cb {
                        keys.push(ka[i]);
                        counts.push(ca - cb);
                    }
                    i += 1;
                    j += 1;
                }
            }
        } else {
            keys.push(ka[i]);
            counts.push(a.counts[i]);
            i += 1;
        }
    }
    if j < kb.len() {
        return Err(SubtractError::MissingRow(layout.unpack_k::<K>(kb[j])));
    }
    Ok(K::finish(a.vars.clone(), layout, keys, counts))
}

/// ∪ kernel: single-pass disjoint merge at key width `K`.
fn merge_union<K: KeyStore>(
    a: &CtTable,
    b: &CtTable,
    layout: CtLayout,
    ka: &[K],
    kb: &[K],
) -> CtTable {
    let mut keys = Vec::with_capacity(ka.len() + kb.len());
    let mut counts = Vec::with_capacity(ka.len() + kb.len());
    let (mut i, mut j) = (0, 0);
    while i < ka.len() || j < kb.len() {
        let take_left = if i == ka.len() {
            false
        } else if j == kb.len() {
            true
        } else {
            match ka[i].cmp(&kb[j]) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => panic!("union_disjoint: shared row"),
            }
        };
        if take_left {
            keys.push(ka[i]);
            counts.push(a.counts[i]);
            i += 1;
        } else {
            keys.push(kb[j]);
            counts.push(b.counts[j]);
            j += 1;
        }
    }
    K::finish(a.vars.clone(), layout, keys, counts)
}

/// `extend_const` kernel from source key width `KS` to destination width
/// `KD` (extension only widens, so `KD` covers `KS`): every key gains the
/// same constant fields in one shift-OR pass, preserving row order.
fn extend_packed<KS: RowKey, KD: KeyStore>(
    t: &CtTable,
    keys: &[KS],
    merged: &[(VarId, Entry)],
    vars: Vec<VarId>,
    nl: CtLayout,
) -> CtTable {
    let mut const_bits = KD::ZERO;
    // (source column, destination shift) per copied column.
    let mut plans: Vec<(usize, u32)> = Vec::new();
    for (out_c, &(_, e)) in merged.iter().enumerate() {
        match e {
            Entry::Const(val) => {
                const_bits =
                    const_bits | (KD::from_u64(nl.encode(out_c, val)) << nl.col(out_c).shift);
            }
            Entry::Src(c) => plans.push((c, nl.col(out_c).shift)),
        }
    }
    if t.width() == 0 {
        // Extending a scalar: each count row becomes the constant row.
        if t.is_empty() {
            return CtTable::empty_with_layout(vars, nl);
        }
        return KD::finish(vars, nl, vec![const_bits], t.counts.clone());
    }
    let out_keys: Vec<KD> = keys
        .iter()
        .map(|&k| {
            let mut out = const_bits;
            for &(c, ds) in &plans {
                out = out | (KD::from_u64(t.layout.extract_k::<KS>(c, k)) << ds);
            }
            out
        })
        .collect();
    KD::finish(vars, nl, out_keys, t.counts.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::NA;
    use crate::util::proptest::run_prop;
    use crate::util::Pcg64;

    /// Random small ct-table for property tests.
    fn random_ct(rng: &mut Pcg64, vars: &[VarId], arities: &[u16]) -> CtTable {
        let n = rng.index(12) + 1;
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..n {
            for &a in arities {
                rows.push(rng.below(a as u64) as u16);
            }
            counts.push(rng.below(20) + 1);
        }
        CtTable::from_raw(vars.to_vec(), rows, counts)
    }

    /// Random ct-table that also draws the NA sentinel on some columns
    /// (odd column indices), exercising the n/a remap inside the codec.
    fn random_ct_na(rng: &mut Pcg64, vars: &[VarId], arities: &[u16]) -> CtTable {
        let n = rng.index(12) + 1;
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..n {
            for (c, &a) in arities.iter().enumerate() {
                if c % 2 == 1 && rng.chance(0.3) {
                    rows.push(NA);
                } else {
                    rows.push(rng.below(a as u64) as u16);
                }
            }
            counts.push(rng.below(20) + 1);
        }
        CtTable::from_raw(vars.to_vec(), rows, counts)
    }

    #[test]
    fn select_matches_condition() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let s = t.select(&[(3, 1)]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.count_of(&[0, 1]), 11);
        assert_eq!(s.count_of(&[1, 1]), 13);
        s.check_invariants().unwrap();
    }

    #[test]
    fn select_unrepresentable_value_matches_nothing() {
        let t = CtTable::from_raw(vec![1, 3], vec![0, 0, 1, 1], vec![1, 2]);
        assert!(t.select(&[(1, 9)]).is_empty());
        assert!(t.select(&[(1, NA)]).is_empty());
        // Contradictory conditions on one column match nothing.
        assert!(t.select(&[(1, 0), (1, 1)]).is_empty());
        // ... but a repeated identical condition is fine.
        assert_eq!(t.select(&[(1, 0), (1, 0)]).len(), 1);
    }

    #[test]
    fn project_sums_groups() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let p = t.project(&[1]);
        assert_eq!(p.len(), 2);
        assert_eq!(p.count_of(&[0]), 21);
        assert_eq!(p.count_of(&[1]), 25);
        assert_eq!(p.total(), t.total());
    }

    #[test]
    fn project_to_nothing_gives_scalar_total() {
        let t = CtTable::from_raw(vec![2], vec![0, 1], vec![4, 6]);
        let p = t.project(&[]);
        assert_eq!(p.width(), 0);
        assert_eq!(p.total(), 10);
    }

    #[test]
    fn condition_drops_columns() {
        let t = CtTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let c = t.condition(&[(3, 0)]);
        assert_eq!(c.vars, vec![1]);
        assert_eq!(c.count_of(&[0]), 10);
        assert_eq!(c.count_of(&[1]), 12);
    }

    #[test]
    fn condition_on_all_columns_gives_scalar() {
        let t = CtTable::from_raw(vec![1, 3], vec![0, 0, 1, 1], vec![4, 5]);
        let c = t.condition(&[(1, 1), (3, 1)]);
        assert_eq!(c.width(), 0);
        assert_eq!(c.total(), 5);
        let miss = t.condition(&[(1, 0), (3, 1)]);
        assert!(miss.is_empty());
    }

    #[test]
    fn cross_multiplies_counts() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let b = CtTable::from_raw(vec![4], vec![0, 1], vec![5, 7]);
        let x = a.cross(&b);
        assert_eq!(x.len(), 4);
        assert_eq!(x.count_of(&[0, 0]), 10);
        assert_eq!(x.count_of(&[1, 1]), 21);
        assert_eq!(x.total(), a.total() * b.total());
        // column order canonical even when crossing (higher, lower)
        let y = b.cross(&a);
        assert_eq!(x, y);
    }

    #[test]
    fn cross_with_scalar_scales() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let s = CtTable::scalar(4);
        let x = a.cross(&s);
        assert_eq!(x.count_of(&[0]), 8);
        assert_eq!(x.count_of(&[1]), 12);
    }

    #[test]
    fn add_merges_disjoint_and_shared() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let b = CtTable::from_raw(vec![1], vec![1, 2], vec![10, 20]);
        let s = a.add(&b);
        assert_eq!(s.count_of(&[0]), 2);
        assert_eq!(s.count_of(&[1]), 13);
        assert_eq!(s.count_of(&[2]), 20);
        s.check_invariants().unwrap();
    }

    #[test]
    fn subtract_exact_and_errors() {
        let a = CtTable::from_raw(vec![1], vec![0, 1], vec![5, 3]);
        let b = CtTable::from_raw(vec![1], vec![0, 1], vec![2, 3]);
        let d = a.subtract(&b).unwrap();
        assert_eq!(d.len(), 1); // the (1) row hit zero and was dropped
        assert_eq!(d.count_of(&[0]), 3);
        // underflow
        let c = CtTable::from_raw(vec![1], vec![0], vec![6]);
        assert!(matches!(a.subtract(&c), Err(SubtractError::CountUnderflow { .. })));
        // missing row
        let m = CtTable::from_raw(vec![1], vec![2], vec![1]);
        assert!(matches!(a.subtract(&m), Err(SubtractError::MissingRow(_))));
        // var mismatch
        let v = CtTable::from_raw(vec![2], vec![0], vec![1]);
        assert_eq!(a.subtract(&v), Err(SubtractError::VarMismatch));
    }

    #[test]
    fn subtract_error_rows_decode() {
        // The row carried inside the error must be decoded codes (incl. NA),
        // not raw packed fields.
        let a = CtTable::from_raw(vec![1], vec![0], vec![5]);
        let m = CtTable::from_raw(vec![1], vec![NA], vec![1]);
        match a.subtract(&m) {
            Err(SubtractError::MissingRow(r)) => assert_eq!(r, vec![NA]),
            other => panic!("expected MissingRow, got {other:?}"),
        }
    }

    #[test]
    fn extend_const_inserts_sorted() {
        let t = CtTable::from_raw(vec![2], vec![0, 1], vec![4, 6]);
        let e = t.extend_const(&[(0, 9), (5, 1)]);
        assert_eq!(e.vars, vec![0, 2, 5]);
        assert_eq!(e.count_of(&[9, 0, 1]), 4);
        assert_eq!(e.count_of(&[9, 1, 1]), 6);
        e.check_invariants().unwrap();
    }

    #[test]
    fn extend_const_with_na() {
        let t = CtTable::from_raw(vec![2], vec![0, 1], vec![4, 6]);
        let e = t.extend_const(&[(3, NA)]);
        assert_eq!(e.count_of(&[0, NA]), 4);
        assert_eq!(e.count_of(&[1, NA]), 6);
        e.check_invariants().unwrap();
    }

    #[test]
    fn extend_const_on_scalar() {
        let s = CtTable::scalar(3);
        let e = s.extend_const(&[(1, 0), (2, 7)]);
        assert_eq!(e.len(), 1);
        assert_eq!(e.count_of(&[0, 7]), 3);
    }

    #[test]
    fn extend_const_widens_into_two_word_tier() {
        // A 64-bit table plus one constant column crosses the word
        // boundary: the result must stay packed, on the u128 store.
        let width = 32usize;
        let vars: Vec<VarId> = (0..width).collect();
        let mut rows = Vec::new();
        for r in 0..3u16 {
            rows.extend(std::iter::repeat(r).take(width));
        }
        let t = CtTable::from_raw(vars, rows, vec![1, 2, 3]);
        assert!(t.is_packed() && !t.is_packed2());
        assert_eq!(t.layout().total_bits(), 64); // 32 cols x 2 bits
        let e = t.extend_const(&[(100, 1), (101, NA)]);
        assert!(e.is_packed2(), "widened extension left the packed path");
        assert_eq!(e.len(), 3);
        let mut q = vec![1u16; width];
        q.push(1);
        q.push(NA);
        assert_eq!(e.count_of(&q), 2);
        e.check_invariants().unwrap();
    }

    #[test]
    fn union_disjoint_merges() {
        let a = CtTable::from_raw(vec![1, 2], vec![0, 0, 1, 1], vec![1, 2]);
        let b = CtTable::from_raw(vec![1, 2], vec![0, 1, 1, 0], vec![3, 4]);
        let u = a.union_disjoint(&b);
        assert_eq!(u.len(), 4);
        assert_eq!(u.total(), 10);
        u.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "shared row")]
    fn union_rejects_overlap() {
        let a = CtTable::from_raw(vec![1], vec![0], vec![1]);
        let b = CtTable::from_raw(vec![1], vec![0], vec![1]);
        a.union_disjoint(&b);
    }

    // ---------- property tests ----------

    #[test]
    fn prop_projection_preserves_total() {
        run_prop(
            "projection_total",
            200,
            0xC0FFEE,
            |r| random_ct(r, &[1, 4, 7], &[3, 2, 4]),
            |t| {
                for keep in [vec![1], vec![4, 7], vec![1, 7], vec![]] {
                    let p = t.project(&keep);
                    if p.total() != t.total() {
                        return Err(format!("total changed for keep={keep:?}"));
                    }
                    p.check_invariants()?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_add_then_subtract_roundtrip() {
        run_prop(
            "add_sub_roundtrip",
            200,
            0xBEEF,
            |r| (random_ct(r, &[0, 2], &[3, 3]), random_ct(r, &[0, 2], &[3, 3])),
            |(a, b)| {
                let sum = a.add(b);
                let back = sum.subtract(b).map_err(|e| e.to_string())?;
                if &back != a {
                    return Err("a + b - b != a".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_select_project_commute() {
        // σ on a kept column commutes with π.
        run_prop(
            "select_project_commute",
            200,
            0xABCD,
            |r| random_ct(r, &[0, 3, 5], &[2, 3, 2]),
            |t| {
                let a = t.select(&[(0, 1)]).project(&[0, 3]);
                let b = t.project(&[0, 3]).select(&[(0, 1)]);
                if a != b {
                    return Err("σπ != πσ".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cross_total_is_product() {
        run_prop(
            "cross_total",
            100,
            0x1234,
            |r| (random_ct(r, &[0], &[4]), random_ct(r, &[2, 3], &[2, 2])),
            |(a, b)| {
                let x = a.cross(b);
                x.check_invariants()?;
                if x.total() != a.total() * b.total() {
                    return Err("cross total mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_add_commutative_associative() {
        run_prop(
            "add_comm_assoc",
            150,
            0x7777,
            |r| {
                (
                    random_ct(r, &[1], &[4]),
                    random_ct(r, &[1], &[4]),
                    random_ct(r, &[1], &[4]),
                )
            },
            |(a, b, c)| {
                if a.add(b) != b.add(a) {
                    return Err("not commutative".into());
                }
                if a.add(b).add(c) != a.add(&b.add(c)) {
                    return Err("not associative".into());
                }
                Ok(())
            },
        );
    }

    // ---------- packed vs row-major reference equivalence ----------

    /// Compare a packed-path result against the reference row-major result;
    /// also check every invariant on the packed side.
    fn expect_same(got: &CtTable, want: &RefTable, what: &str) -> Result<(), String> {
        got.check_invariants().map_err(|e| format!("{what}: invariant broken: {e}"))?;
        if got != &want.to_ct() {
            return Err(format!("{what}: packed != reference\n got {got:?}\nwant {want:?}"));
        }
        Ok(())
    }

    #[test]
    fn prop_unary_ops_match_reference() {
        run_prop(
            "unary_ops_match_reference",
            250,
            0x5EED_01,
            |r| random_ct_na(r, &[0, 2, 5], &[3, 4, 2]),
            |t| {
                let rt = RefTable::from(t);
                expect_same(&t.select(&[(2, 1)]), &rt.select(&[(2, 1)]), "select")?;
                expect_same(&t.select(&[(2, NA)]), &rt.select(&[(2, NA)]), "select NA")?;
                for keep in [vec![0], vec![2], vec![0, 5], vec![2, 5], vec![]] {
                    expect_same(&t.project(&keep), &rt.project(&keep), "project")?;
                }
                for cond in [vec![(2usize, 0u16)], vec![(0, 1), (5, 1)], vec![(2, NA)]] {
                    expect_same(&t.condition(&cond), &rt.condition(&cond), "condition")?;
                }
                expect_same(
                    &t.extend_const(&[(1, 3), (7, NA)]),
                    &rt.extend_const(&[(1, 3), (7, NA)]),
                    "extend_const",
                )?;
                expect_same(&t.scale(3), &rt.scale(3), "scale")?;
                Ok(())
            },
        );
    }

    #[test]
    fn prop_binary_ops_match_reference() {
        run_prop(
            "binary_ops_match_reference",
            250,
            0x5EED_02,
            |r| {
                (
                    random_ct_na(r, &[1, 4], &[3, 3]),
                    random_ct_na(r, &[1, 4], &[3, 3]),
                )
            },
            |(a, b)| {
                let (ra, rb) = (RefTable::from(a), RefTable::from(b));
                expect_same(&a.add(b), &ra.add(&rb), "add")?;
                let sum = a.add(b);
                let rsum = ra.add(&rb);
                expect_same(
                    &sum.subtract(b).map_err(|e| e.to_string())?,
                    &rsum.subtract(&rb).map_err(|e| e.to_string())?,
                    "subtract",
                )?;
                // cross needs disjoint vars; shift b's projection's VarIds.
                let b_shifted = rename_vars(&b.project(&[4]), 100);
                let got = a.cross(&b_shifted);
                let want = ra.cross(&RefTable::from(&b_shifted));
                expect_same(&got, &want, "cross")?;
                Ok(())
            },
        );
    }

    /// Test helper: shift vars to make two tables disjoint for cross.
    fn rename_vars(t: &CtTable, by: usize) -> CtTable {
        let mut t = t.clone();
        t.vars = t.vars.iter().map(|v| v + by).collect();
        t
    }

    #[test]
    fn prop_union_disjoint_matches_reference() {
        run_prop(
            "union_matches_reference",
            200,
            0x5EED_03,
            |r| random_ct_na(r, &[1, 4], &[3, 4]),
            |t| {
                if t.len() < 2 {
                    return Ok(());
                }
                // Split rows into two disjoint halves by index.
                let rt = RefTable::from(t);
                let (mut ar, mut ac, mut br, mut bc) = (vec![], vec![], vec![], vec![]);
                for i in 0..rt.len() {
                    if i % 2 == 0 {
                        ar.extend_from_slice(rt.row(i));
                        ac.push(rt.counts[i]);
                    } else {
                        br.extend_from_slice(rt.row(i));
                        bc.push(rt.counts[i]);
                    }
                }
                let ra = RefTable { vars: rt.vars.clone(), rows: ar, counts: ac };
                let rb = RefTable { vars: rt.vars.clone(), rows: br, counts: bc };
                let got = ra.to_ct().union_disjoint(&rb.to_ct());
                expect_same(&got, &ra.union_disjoint(&rb), "union_disjoint")?;
                if &got != t {
                    return Err("union of halves != whole".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_wide_storage_matches_packed() {
        // The same logical table, forced onto the wide store, must give the
        // same operator results (wide ops run the reference path).
        run_prop(
            "wide_matches_packed",
            150,
            0x5EED_04,
            |r| random_ct_na(r, &[0, 3, 6], &[4, 3, 2]),
            |t| {
                let rt = RefTable::from(t);
                let wide = CtTable::from_parts_wide_unchecked(
                    rt.vars.clone(),
                    rt.rows.clone(),
                    rt.counts.clone(),
                );
                if t.is_packed() == wide.is_packed() {
                    return Err("expected differing storage".into());
                }
                for keep in [vec![0], vec![3, 6]] {
                    if t.project(&keep) != wide.project(&keep) {
                        return Err("project differs across storage".into());
                    }
                }
                if t.select(&[(3, 1)]) != wide.select(&[(3, 1)]) {
                    return Err("select differs across storage".into());
                }
                // Mixed-storage merge falls back to the reference path.
                if t.add(&wide) != t.add(t) {
                    return Err("mixed-storage add differs".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn packed_ops_on_wide_tables_fall_back() {
        // 70 two-bit columns: a 140-bit layout is past both packed tiers,
        // so the wide store and the reference operators take over.
        let width = 70usize;
        let vars: Vec<VarId> = (0..width).collect();
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        let mut rng = Pcg64::seeded(77);
        for _ in 0..20 {
            for _ in 0..width {
                rows.push(rng.below(3) as u16);
            }
            counts.push(rng.below(9) + 1);
        }
        let t = CtTable::from_raw(vars.clone(), rows, counts);
        assert!(!t.is_packed());
        let before = super::super::reference::reference_op_fallbacks();
        let p = t.project(&vars[..2]);
        assert_eq!(p.total(), t.total());
        p.check_invariants().unwrap();
        let s = t.select(&[(0, 1)]);
        s.check_invariants().unwrap();
        let sum = t.add(&t);
        assert_eq!(sum.total(), 2 * t.total());
        assert_eq!(sum.subtract(&t).unwrap(), t);
        let e = t.extend_const(&[(100, 1)]);
        assert_eq!(e.width(), width + 1);
        e.check_invariants().unwrap();
        // Each routed operator bumped the fallback counter at least once
        // (other tests run concurrently, so only a lower bound is safe).
        assert!(super::super::reference::reference_op_fallbacks() >= before + 5);
    }

    #[test]
    fn kernel_ticks_fire_per_operator_and_respect_the_gate() {
        use super::ticks::{self, Kernel, Tier};
        // Wide-store operands keep every op in this test on the Wide
        // tier — and no other test in this binary runs a wide-store
        // union, so that slot is safe for an exact gated-off check even
        // though tests share the process-global counters.
        let a = CtTable::from_parts_wide_unchecked(vec![1, 2], vec![0, 0], vec![1]);
        let b = CtTable::from_parts_wide_unchecked(vec![1, 2], vec![1, 1], vec![2]);
        let c = CtTable::from_parts_wide_unchecked(vec![5], vec![0], vec![3]);

        let _gate = ticks::gate_lock();
        let prev = ticks::enabled();
        ticks::set_enabled(false);
        let off = ticks::counter(Kernel::Union, Tier::Wide);
        a.union_disjoint(&b).check_invariants().unwrap();
        assert_eq!(
            ticks::counter(Kernel::Union, Tier::Wide),
            off,
            "disabled gate must not count"
        );

        ticks::set_enabled(true);
        let before: Vec<(u64, u64)> = [
            Kernel::Union,
            Kernel::Select,
            Kernel::Project,
            Kernel::Condition,
            Kernel::Cross,
            Kernel::Add,
            Kernel::Subtract,
            Kernel::Extend,
        ]
        .iter()
        .map(|&k| ticks::counter(k, Tier::Wide))
        .collect();
        a.union_disjoint(&b);
        a.select(&[(1, 0)]);
        a.project(&[1]);
        a.condition(&[(2, 0)]);
        a.cross(&c);
        let sum = a.add(&b);
        sum.subtract(&b).unwrap();
        a.extend_const(&[(9, 1)]);
        for (i, &k) in [
            Kernel::Union,
            Kernel::Select,
            Kernel::Project,
            Kernel::Condition,
            Kernel::Cross,
            Kernel::Add,
            Kernel::Subtract,
            Kernel::Extend,
        ]
        .iter()
        .enumerate()
        {
            let (t0, n0) = before[i];
            let (t1, n1) = ticks::counter(k, Tier::Wide);
            assert!(t1 >= t0 + 1, "{} wide tick did not fire: {t0} -> {t1}", k.name());
            assert!(n1 >= n0, "{} wide nanos went backwards", k.name());
        }
        assert!(
            ticks::hottest().is_some(),
            "hottest() must name a kernel once timed calls landed"
        );
        assert_eq!(ticks::snapshot().len(), ticks::SLOTS);
        ticks::set_enabled(prev);
    }
}
