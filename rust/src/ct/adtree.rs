//! ADtree (All-Dimensions tree, Moore & Lee 1998) over a contingency table.
//!
//! The paper's related-work section positions ADtrees as the complementary
//! *memory-efficient* representation of sufficient statistics and names
//! "build an ADtree for the contingency table once it has been computed"
//! as future work — this module implements exactly that: an ADtree built
//! from a [`CtTable`], answering arbitrary conjunctive count queries with
//! the classic most-common-value (MCV) elision that gives the structure its
//! sub-table-size footprint.
//!
//! Structure: an ADNode stores the count of its query prefix and one Vary
//! node per remaining variable; a Vary node stores child ADNodes for every
//! value *except* the most common one (reconstructed by subtraction at
//! query time). A leaf-list cutoff (`min_count`) stops expansion for rare
//! prefixes, falling back to scanning the rows of the sub-table.

use super::CtTable;
use crate::obs::{cost, trace};
use crate::schema::VarId;

/// Configuration for ADtree construction.
#[derive(Debug, Clone, Copy)]
pub struct AdTreeConfig {
    /// Prefixes with count below this become leaf lists (scanned on query).
    pub min_count: u64,
}

impl Default for AdTreeConfig {
    fn default() -> Self {
        AdTreeConfig { min_count: 16 }
    }
}

/// An ADtree over the variable set of one contingency table.
#[derive(Debug)]
pub struct AdTree {
    vars: Vec<VarId>,
    /// Distinct observed codes per column (MCV first).
    codes: Vec<Vec<u16>>,
    root: Node,
    nodes: usize,
}

#[derive(Debug)]
enum Node {
    /// Expanded node: total count + Vary structure per remaining column.
    Ad { count: u64, vary: Vec<Vary> },
    /// Leaf list: row indices into the source table (kept inline).
    Leaf { rows: Vec<u16>, counts: Vec<u64>, width: usize },
}

#[derive(Debug)]
struct Vary {
    /// Index of the most common value within `codes[col]` (elided child).
    mcv: usize,
    /// Children for each non-MCV observed value (parallel to
    /// `codes[col]` minus the MCV slot); `None` = zero count.
    children: Vec<Option<Box<Node>>>,
}

impl AdTree {
    /// Build an ADtree from a contingency table. The (possibly packed)
    /// table is decoded to a row-major code matrix once up front — tree
    /// construction indexes rows many times per node.
    pub fn build(ct: &CtTable, cfg: AdTreeConfig) -> AdTree {
        let _sp = trace::span_detailed("adtree.build", || format!("rows={}", ct.len()));
        let width = ct.width();
        let matrix = ct.decode_rows();
        // Observed codes per column with counts, MCV first.
        let mut codes: Vec<Vec<u16>> = Vec::with_capacity(width);
        for c in 0..width {
            let mut tally: std::collections::BTreeMap<u16, u64> = Default::default();
            for (r, &n) in ct.counts.iter().enumerate() {
                *tally.entry(matrix[r * width + c]).or_insert(0) += n;
            }
            let mut pairs: Vec<(u16, u64)> = tally.into_iter().collect();
            pairs.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            codes.push(pairs.into_iter().map(|(v, _)| v).collect());
        }
        let idx: Vec<usize> = (0..ct.len()).collect();
        let mut nodes = 0usize;
        let root = Self::build_node(&matrix, &ct.counts, width, &codes, &idx, 0, &cfg, &mut nodes);
        AdTree { vars: ct.vars.clone(), codes, root, nodes }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_node(
        matrix: &[u16],
        row_counts: &[u64],
        width: usize,
        codes: &[Vec<u16>],
        rows: &[usize],
        depth: usize,
        cfg: &AdTreeConfig,
        nodes: &mut usize,
    ) -> Node {
        *nodes += 1;
        let count: u64 = rows.iter().map(|&r| row_counts[r]).sum();
        if count < cfg.min_count && depth > 0 {
            // Leaf list: copy the sub-table rows.
            let mut data = Vec::with_capacity(rows.len() * width);
            let mut counts = Vec::with_capacity(rows.len());
            for &r in rows {
                data.extend_from_slice(&matrix[r * width..(r + 1) * width]);
                counts.push(row_counts[r]);
            }
            return Node::Leaf { rows: data, counts, width };
        }
        let mut vary = Vec::with_capacity(width.saturating_sub(depth));
        for col in depth..width {
            // Partition rows by value of `col`.
            let mut by_val: Vec<Vec<usize>> = vec![Vec::new(); codes[col].len()];
            for &r in rows {
                let v = matrix[r * width + col];
                let slot = codes[col].iter().position(|&c| c == v).unwrap();
                by_val[slot].push(r);
            }
            // MCV within this node = heaviest slot (not necessarily the
            // global MCV; classic ADtrees use per-node MCV).
            let mcv = (0..by_val.len())
                .max_by_key(|&s| by_val[s].iter().map(|&r| row_counts[r]).sum::<u64>())
                .unwrap_or(0);
            let mut children: Vec<Option<Box<Node>>> = Vec::with_capacity(by_val.len());
            for (slot, sub) in by_val.iter().enumerate() {
                if slot == mcv || sub.is_empty() {
                    children.push(None);
                } else {
                    children.push(Some(Box::new(Self::build_node(
                        matrix,
                        row_counts,
                        width,
                        codes,
                        sub,
                        col + 1,
                        cfg,
                        nodes,
                    ))));
                }
            }
            vary.push(Vary { mcv, children });
        }
        Node::Ad { count, vary }
    }

    /// Count of a conjunctive query `(var, code)*` — the same semantics as
    /// filtering the source ct-table (vars must belong to the tree).
    pub fn count(&self, query: &[(VarId, u16)]) -> u64 {
        let _sp = trace::span("adtree.probe");
        // Normalize to (column, code), sorted by column.
        let mut q: Vec<(usize, u16)> = query
            .iter()
            .map(|&(v, code)| {
                (self.vars.binary_search(&v).expect("query var not in ADtree"), code)
            })
            .collect();
        q.sort_unstable();
        let mut probed = 0u64;
        let total = self.count_node(&self.root, 0, &q, &mut probed);
        cost::add_nodes_probed(probed);
        total
    }

    fn count_node(
        &self,
        node: &Node,
        depth: usize,
        query: &[(usize, u16)],
        probed: &mut u64,
    ) -> u64 {
        *probed += 1;
        match node {
            Node::Leaf { rows, counts, width } => {
                let mut total = 0;
                for (i, &c) in counts.iter().enumerate() {
                    let row = &rows[i * width..(i + 1) * width];
                    if query.iter().all(|&(col, code)| row[col] == code) {
                        total += c;
                    }
                }
                total
            }
            Node::Ad { count, vary } => {
                let Some(&(col, code)) = query.first() else {
                    return *count;
                };
                let v = &vary[col - depth];
                let Some(slot) = self.codes[col].iter().position(|&c| c == code) else {
                    return 0; // never-observed value
                };
                if slot == v.mcv {
                    // MCV elision: count(mcv) = count(node) − Σ others,
                    // each conditioned on the rest of the query.
                    let rest = &query[1..];
                    let all = self.count_node_skip(node, depth, col, rest, probed);
                    let mut others = 0;
                    for (s, child) in v.children.iter().enumerate() {
                        if s == v.mcv {
                            continue;
                        }
                        if let Some(ch) = child {
                            others += self.count_node(ch, col + 1, rest, probed);
                        }
                    }
                    all - others
                } else {
                    match &v.children[slot] {
                        Some(ch) => self.count_node(ch, col + 1, &query[1..], probed),
                        None => 0,
                    }
                }
            }
        }
    }

    /// Count of `query` under `node` ignoring variable `skip_col`
    /// (marginalized over it) — the "parent count" of the MCV subtraction.
    fn count_node_skip(
        &self,
        node: &Node,
        depth: usize,
        _skip_col: usize,
        query: &[(usize, u16)],
        probed: &mut u64,
    ) -> u64 {
        self.count_node(node, depth, query, probed)
    }

    /// Number of tree nodes (the memory-efficiency metric vs ct rows).
    pub fn num_nodes(&self) -> usize {
        self.nodes
    }

    pub fn vars(&self) -> &[VarId] {
        &self.vars
    }

    /// Exact heap footprint of the tree in bytes — what a cache must
    /// charge against a shared `mem_bytes` budget (mirrors
    /// [`CtTable::mem_bytes`](super::CtTable::mem_bytes)): struct size plus
    /// every owned allocation, walked recursively.
    pub fn mem_bytes(&self) -> usize {
        let mut total = std::mem::size_of::<AdTree>();
        total += self.vars.capacity() * std::mem::size_of::<VarId>();
        total += self.codes.capacity() * std::mem::size_of::<Vec<u16>>();
        for c in &self.codes {
            total += c.capacity() * std::mem::size_of::<u16>();
        }
        total += node_bytes(&self.root);
        total
    }
}

/// Heap bytes of one node subtree, excluding the `Box` pointer that holds
/// it (charged at the owning `children` slot).
fn node_bytes(node: &Node) -> usize {
    match node {
        Node::Leaf { rows, counts, .. } => {
            rows.capacity() * std::mem::size_of::<u16>()
                + counts.capacity() * std::mem::size_of::<u64>()
        }
        Node::Ad { vary, .. } => {
            let mut total = vary.capacity() * std::mem::size_of::<Vary>();
            for v in vary {
                total += v.children.capacity() * std::mem::size_of::<Option<Box<Node>>>();
                for child in v.children.iter().flatten() {
                    total += std::mem::size_of::<Node>() + node_bytes(child);
                }
            }
            total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_ct(seed: u64, n: usize, arities: &[u16]) -> CtTable {
        let mut rng = Pcg64::seeded(seed);
        let vars: Vec<VarId> = (0..arities.len()).collect();
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..n {
            for &a in arities {
                rows.push(rng.below(a as u64) as u16);
            }
            counts.push(rng.below(30) + 1);
        }
        CtTable::from_raw(vars, rows, counts)
    }

    /// Oracle: count by selection on the source table.
    fn oracle(ct: &CtTable, q: &[(VarId, u16)]) -> u64 {
        u64::try_from(ct.select(q).total()).unwrap()
    }

    #[test]
    fn counts_match_selection_oracle() {
        let ct = random_ct(3, 200, &[3, 2, 4, 3]);
        let tree = AdTree::build(&ct, AdTreeConfig::default());
        let mut rng = Pcg64::seeded(9);
        for _ in 0..300 {
            // Random query over a random var subset.
            let nv = rng.index(4) + 1;
            let mut q: Vec<(VarId, u16)> = Vec::new();
            let picks = rng.sample_indices(4, nv);
            for v in picks {
                let arity = [3u16, 2, 4, 3][v];
                q.push((v, rng.below(arity as u64 + 1) as u16)); // may be unobserved
            }
            q.sort_unstable();
            q.dedup_by_key(|p| p.0);
            assert_eq!(tree.count(&q), oracle(&ct, &q), "query {q:?}");
        }
    }

    #[test]
    fn empty_query_returns_total() {
        let ct = random_ct(5, 100, &[2, 3]);
        let tree = AdTree::build(&ct, AdTreeConfig::default());
        assert_eq!(tree.count(&[]) as u128, ct.total());
    }

    #[test]
    fn leaf_cutoff_still_correct() {
        let ct = random_ct(7, 150, &[4, 4, 2]);
        for min_count in [1, 8, 1_000_000] {
            let tree = AdTree::build(&ct, AdTreeConfig { min_count });
            for v0 in 0..4u16 {
                for v2 in 0..2u16 {
                    let q = vec![(0usize, v0), (2usize, v2)];
                    assert_eq!(tree.count(&q), oracle(&ct, &q));
                }
            }
        }
    }

    #[test]
    fn wide_tier_table_builds_and_counts() {
        // A 65–128-bit (two-word packed) source table: the tree decodes the
        // u128 keys once up front and must answer exactly like selection.
        let ct = random_ct(11, 120, &[6u16; 24]);
        assert!(ct.is_packed2(), "expected the two-word tier, got {}", ct.tier());
        let tree = AdTree::build(&ct, AdTreeConfig { min_count: 8 });
        assert_eq!(tree.count(&[]) as u128, ct.total());
        let mut rng = Pcg64::seeded(17);
        for _ in 0..100 {
            let nv = rng.index(3) + 1;
            let mut q: Vec<(VarId, u16)> = Vec::new();
            for v in rng.sample_indices(24, nv) {
                q.push((v, rng.below(7) as u16)); // may be unobserved
            }
            q.sort_unstable();
            q.dedup_by_key(|p| p.0);
            assert_eq!(tree.count(&q), oracle(&ct, &q), "query {q:?}");
        }
    }

    #[test]
    fn probe_charges_nodes_to_the_active_query_cost() {
        let ct = random_ct(3, 200, &[3, 2, 4, 3]);
        let tree = AdTree::build(&ct, AdTreeConfig::default());
        cost::begin();
        let n = tree.count(&[(0, 1), (2, 2)]);
        assert_eq!(n, oracle(&ct, &[(0, 1), (2, 2)]));
        let c = cost::take().expect("cost accounting was begun");
        assert!(c.adtree_nodes_probed >= 1, "{c:?}");
        // A broader probe (empty query hits only the root) charges less.
        cost::begin();
        tree.count(&[]);
        let root_only = cost::take().unwrap();
        assert_eq!(root_only.adtree_nodes_probed, 1);
        assert!(c.adtree_nodes_probed >= root_only.adtree_nodes_probed);
    }

    #[test]
    fn mem_bytes_scales_with_tree_size() {
        let small = AdTree::build(&random_ct(3, 20, &[2, 2]), AdTreeConfig::default());
        let big = AdTree::build(&random_ct(3, 400, &[4, 4, 4, 3]), AdTreeConfig::default());
        // Every tree owns at least its struct; a bigger tree charges more.
        assert!(small.mem_bytes() >= std::mem::size_of::<AdTree>());
        assert!(big.mem_bytes() > small.mem_bytes());
        // More nodes ⇒ at least one Node-struct worth of bytes per extra node.
        assert!(big.mem_bytes() >= big.num_nodes() * std::mem::size_of::<u64>());
    }

    #[test]
    fn compression_smaller_than_rows_on_skewed_data() {
        // Heavily skewed data: MCV elision should keep the tree small.
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for i in 0..400u64 {
            let dominant = i % 10 != 0;
            rows.extend_from_slice(&[
                if dominant { 0 } else { (i % 3) as u16 + 1 },
                if dominant { 0 } else { (i % 2) as u16 },
                (i % 2) as u16,
            ]);
            counts.push(1 + (dominant as u64) * 50);
        }
        let ct = CtTable::from_raw(vec![0, 1, 2], rows, counts);
        let tree = AdTree::build(&ct, AdTreeConfig { min_count: 4 });
        assert!(tree.num_nodes() < ct.len() * 4, "{} nodes vs {} rows", tree.num_nodes(), ct.len());
        // spot-check correctness on the dominant cell
        assert_eq!(tree.count(&[(0, 0), (1, 0)]), oracle(&ct, &[(0, 0), (1, 0)]));
    }
}
