//! Contingency tables and the ct-algebra (paper §2.2, §4.1).
//!
//! A contingency table `ct(V)` over a variable set `V = {V1..Vn}` has one
//! row per value assignment with a positive count, with three invariants
//! that every operation preserves:
//!
//! 1. `vars` is strictly increasing (canonical column order by `VarId`);
//! 2. rows are sorted lexicographically and unique;
//! 3. all counts are positive (zero-count rows are omitted, paper §2.2).
//!
//! ## Storage: three tiers of packed row keys (`CtLayout`)
//!
//! Rows are not stored as `u16` code slices. Each table carries a
//! [`CtLayout`] — per-column bit widths derived from value cardinalities
//! (schema arities where available, observed maxima otherwise) — and
//! chooses one of **three storage tiers** by the layout's total width:
//!
//! 1. **one-word packed** (≤ 64 bits): one `u64` key per row;
//! 2. **two-word packed** (65–128 bits): one `u128` key per row — the
//!    regime of the paper's large hepatitis/imdb-style joint tables;
//! 3. **row-major wide** (> 128 bits): the historical `u16`-slice store,
//!    kept as the escape hatch and the property-test oracle.
//!
//! In both packed tiers the key's unsigned order equals the lexicographic
//! row order, and the ct-algebra operators are **integer kernels generic
//! over the key width** ([`RowKey`], monomorphized at `u64` and `u128`):
//!
//! * σ `select` / χ `condition` — mask-compare filters (one AND + compare
//!   per row instead of a `width`-cell scan);
//! * π `project` — shift-compress into a sub-layout + radix-sort group-by;
//! * × `cross` — a single `OR` of precomputed partial keys per output row;
//! * `+` / `−` / `∪` — single-pass sort-merge scans over scalar keys,
//!   exactly the cost model §4.1.3 assumes.
//!
//! Results always land in the narrowest tier their layout allows (a
//! projection of a two-word table whose kept columns fit 64 bits comes
//! back one-word packed). Only tables on the wide store route operators
//! through the retained row-major reference path ([`reference`]) — results
//! are bit-identical either way (asserted by the property tests in
//! `algebra.rs` and `reference.rs`), and every such routing bumps the
//! [`reference::reference_op_fallbacks`] counter so scale tests can assert
//! the fast path was never left.
//!
//! The `n/a` sentinel (`NA = u16::MAX`) packs as `cap` (one past the
//! largest real code) per column, preserving the convention that n/a sorts
//! after all real values; keys decode back to `NA` losslessly.

mod algebra;
mod display;
mod layout;
pub mod adtree;
pub mod reference;

pub use adtree::{AdTree, AdTreeConfig};
pub use algebra::{ticks, SubtractError};
pub use display::render_ct;
pub use layout::{radix_sort_pairs, radix_sort_pairs_k, ColLayout, CtLayout, RowKey};

use crate::schema::VarId;

/// Physical row storage: one- or two-word packed scalar keys, or the
/// row-major wide fallback when the layout exceeds 128 bits.
#[derive(Debug, Clone)]
pub(crate) enum RowStore {
    /// One `u64` key per row, sorted ascending (== lexicographic rows).
    /// Used whenever the layout fits 64 bits.
    Packed(Vec<u64>),
    /// One `u128` key per row, sorted ascending. Used for 65–128-bit
    /// layouts (never for layouts that fit 64 bits — constructors narrow).
    Packed2(Vec<u128>),
    /// Row-major `u16` codes (`NA = u16::MAX`), sorted lexicographically.
    Wide(Vec<u16>),
}

/// Crate-internal bridge between a [`RowKey`] width and the [`RowStore`]
/// variant that holds it: lets one generic kernel read and build tables at
/// either packed width.
pub(crate) trait KeyStore: RowKey {
    /// Wrap sorted-unique keys in the matching store variant.
    fn store(keys: Vec<Self>) -> RowStore;

    /// Build a table from sorted-unique keys under `layout`, narrowing to
    /// the one-word store when the layout allows it (keys produced at
    /// `u128` width whose layout fits 64 bits truncate losslessly and
    /// order-preservingly).
    fn finish(vars: Vec<VarId>, layout: CtLayout, keys: Vec<Self>, counts: Vec<u64>) -> CtTable;
}

impl KeyStore for u64 {
    fn store(keys: Vec<Self>) -> RowStore {
        RowStore::Packed(keys)
    }

    fn finish(vars: Vec<VarId>, layout: CtLayout, keys: Vec<Self>, counts: Vec<u64>) -> CtTable {
        CtTable::from_sorted_packed(vars, layout, keys, counts)
    }
}

impl KeyStore for u128 {
    fn store(keys: Vec<Self>) -> RowStore {
        RowStore::Packed2(keys)
    }

    fn finish(vars: Vec<VarId>, layout: CtLayout, keys: Vec<Self>, counts: Vec<u64>) -> CtTable {
        if layout.fits() {
            let narrow: Vec<u64> = keys.iter().map(|&k| k as u64).collect();
            return CtTable::from_sorted_packed(vars, layout, narrow, counts);
        }
        CtTable::from_sorted_packed2(vars, layout, keys, counts)
    }
}

/// The packed half of [`CtTable::from_raw`], generic over the key width:
/// pack every positive-count row under the column permutation `perm`,
/// radix sort, and fold duplicate keys. Returns `(keys, counts)` ready for
/// the matching store variant.
fn pack_raw_keyed<K: KeyStore>(
    layout: &CtLayout,
    perm: &[usize],
    width: usize,
    rows: &[u16],
    counts: &[u64],
) -> (Vec<K>, Vec<u64>) {
    let n = counts.len();
    let mut keyed: Vec<(K, u64)> = Vec::with_capacity(n);
    for r in 0..n {
        if counts[r] == 0 {
            continue;
        }
        let row = &rows[r * width..(r + 1) * width];
        let mut key = K::ZERO;
        for (out_col, &p) in perm.iter().enumerate() {
            key = key | (K::from_u64(layout.encode(out_col, row[p])) << layout.col(out_col).shift);
        }
        keyed.push((key, counts[r]));
    }
    radix_sort_pairs_k::<K>(&mut keyed, layout.total_bits());
    let mut keys: Vec<K> = Vec::with_capacity(keyed.len());
    let mut folded: Vec<u64> = Vec::with_capacity(keyed.len());
    for (k, c) in keyed {
        if keys.last() == Some(&k) {
            let li = folded.len() - 1;
            folded[li] = folded[li].checked_add(c).expect("count overflow");
        } else {
            keys.push(k);
            folded.push(c);
        }
    }
    (keys, folded)
}

/// A contingency table: sufficient statistics for one variable set.
#[derive(Clone)]
pub struct CtTable {
    /// Column headers, strictly increasing.
    pub vars: Vec<VarId>,
    /// Per-row query counts, parallel to the rows.
    pub counts: Vec<u64>,
    pub(crate) layout: CtLayout,
    pub(crate) store: RowStore,
}

impl CtTable {
    /// An empty table over a variable set.
    pub fn empty(vars: Vec<VarId>) -> Self {
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]), "vars must be sorted+unique");
        let layout = CtLayout::from_specs(&vec![(1u16, false); vars.len()]);
        // Store choice must follow the layout: 1 bit per column still
        // exceeds 64 bits for very wide variable sets.
        Self::empty_with_layout(vars, layout)
    }

    /// An empty table that keeps a caller-chosen layout (so later merges
    /// with sibling tables stay re-encode-free).
    pub(crate) fn empty_with_layout(vars: Vec<VarId>, layout: CtLayout) -> Self {
        debug_assert_eq!(vars.len(), layout.width());
        let store = if layout.fits() {
            RowStore::Packed(Vec::new())
        } else if layout.fits2() {
            RowStore::Packed2(Vec::new())
        } else {
            RowStore::Wide(Vec::new())
        };
        CtTable { vars, counts: Vec::new(), layout, store }
    }

    /// The nullary table with a single row of count `n` (identity for ×).
    pub fn scalar(n: u64) -> Self {
        CtTable {
            vars: Vec::new(),
            counts: vec![n],
            layout: CtLayout::from_specs(&[]),
            store: RowStore::Packed(Vec::new()),
        }
    }

    /// Trusted constructor: `keys` already sorted ascending and unique,
    /// `counts` positive, `vars` canonical, `layout.fits()`.
    pub(crate) fn from_sorted_packed(
        vars: Vec<VarId>,
        layout: CtLayout,
        keys: Vec<u64>,
        counts: Vec<u64>,
    ) -> Self {
        debug_assert!(layout.fits());
        debug_assert_eq!(keys.len(), counts.len());
        debug_assert!(keys.windows(2).all(|w| w[0] < w[1]), "keys must be sorted+unique");
        CtTable { vars, counts, layout, store: RowStore::Packed(keys) }
    }

    /// Trusted constructor for the two-word tier: `keys` already sorted
    /// ascending and unique, `counts` positive, `vars` canonical, and the
    /// layout strictly wider than 64 bits but within 128 (narrower layouts
    /// must use [`from_sorted_packed`](CtTable::from_sorted_packed)).
    pub(crate) fn from_sorted_packed2(
        vars: Vec<VarId>,
        layout: CtLayout,
        keys: Vec<u128>,
        counts: Vec<u64>,
    ) -> Self {
        debug_assert!(!layout.fits() && layout.fits2());
        debug_assert_eq!(keys.len(), counts.len());
        debug_assert!(keys.windows(2).all(|w| w[0] < w[1]), "keys must be sorted+unique");
        CtTable { vars, counts, layout, store: RowStore::Packed2(keys) }
    }

    /// Trusted constructor from sorted-unique row-major codes: packs them
    /// at the narrowest width the observed layout allows, keeping the wide
    /// store only past 128 bits.
    pub(crate) fn from_sorted_rows(vars: Vec<VarId>, rows: Vec<u16>, counts: Vec<u64>) -> Self {
        let width = vars.len();
        debug_assert!(width > 0);
        debug_assert_eq!(rows.len(), counts.len() * width);
        let layout = CtLayout::observe(width, counts.len(), &rows, |c| c);
        if layout.fits() {
            // Per-column encoding is monotone, so packing preserves order.
            let keys: Vec<u64> =
                (0..counts.len()).map(|r| layout.pack(&rows[r * width..(r + 1) * width])).collect();
            CtTable { vars, counts, layout, store: RowStore::Packed(keys) }
        } else if layout.fits2() {
            let keys: Vec<u128> = (0..counts.len())
                .map(|r| layout.pack_k::<u128>(&rows[r * width..(r + 1) * width]))
                .collect();
            CtTable { vars, counts, layout, store: RowStore::Packed2(keys) }
        } else {
            CtTable { vars, counts, layout, store: RowStore::Wide(rows) }
        }
    }

    /// Test-only escape hatch: store arbitrary (possibly invalid) wide rows
    /// so the invariant checker has something to catch.
    #[cfg(test)]
    pub(crate) fn from_parts_wide_unchecked(
        vars: Vec<VarId>,
        rows: Vec<u16>,
        counts: Vec<u64>,
    ) -> Self {
        let layout = CtLayout::observe(vars.len(), counts.len(), &rows, |c| c);
        CtTable { vars, counts, layout, store: RowStore::Wide(rows) }
    }

    /// Build from unsorted (row, count) pairs over possibly-unsorted
    /// columns: sorts columns, permutes codes, sorts rows, folds duplicates,
    /// drops zero counts. The general-purpose normalizing constructor.
    ///
    /// Hot path (§Perf): with the observed layout fitting 64 bits, rows are
    /// packed once and radix-sorted as scalar keys — no comparator
    /// indirection, no index permutation.
    pub fn from_raw(vars: Vec<VarId>, rows: Vec<u16>, counts: Vec<u64>) -> Self {
        let width = vars.len();
        if width == 0 {
            let total: u64 = counts.iter().sum();
            return if total == 0 { CtTable::empty(vars) } else { CtTable::scalar(total) };
        }
        assert_eq!(rows.len(), counts.len() * width, "rows/counts shape mismatch");
        // Sort columns into canonical order, tracking the permutation.
        let mut perm: Vec<usize> = (0..width).collect();
        perm.sort_by_key(|&i| vars[i]);
        let svars: Vec<VarId> = perm.iter().map(|&i| vars[i]).collect();
        assert!(svars.windows(2).all(|w| w[0] != w[1]), "duplicate column vars");

        let n = counts.len();
        let layout = CtLayout::observe(width, n, &rows, |out_col| perm[out_col]);
        // Packed tiers: pack each row under the column permutation, radix
        // sort, fold duplicates — the keys ARE the stored rows at either
        // width (the 65..128-bit tier used to sort as transient u128 keys
        // and spill to the wide store).
        if layout.fits() {
            let (keys, folded) = pack_raw_keyed::<u64>(&layout, &perm, width, &rows, &counts);
            return CtTable { vars: svars, counts: folded, layout, store: RowStore::Packed(keys) };
        }
        if layout.fits2() {
            let (keys, folded) = pack_raw_keyed::<u128>(&layout, &perm, width, &rows, &counts);
            return CtTable { vars: svars, counts: folded, layout, store: RowStore::Packed2(keys) };
        }

        // Wide path: comparator sort over an index permutation.
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let key = |r: usize| &rows[r * width..(r + 1) * width];
        let permuted_cmp = |a: usize, b: usize| {
            let (ka, kb) = (key(a), key(b));
            for &p in &perm {
                match ka[p].cmp(&kb[p]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        };
        idx.sort_unstable_by(|&a, &b| permuted_cmp(a as usize, b as usize));

        let mut out_rows: Vec<u16> = Vec::with_capacity(rows.len());
        let mut out_counts: Vec<u64> = Vec::with_capacity(n);
        for &i in &idx {
            let i = i as usize;
            if counts[i] == 0 {
                continue;
            }
            // Out rows are stored already permuted: compare in output order.
            let is_dup = !out_counts.is_empty() && {
                let last = &out_rows[out_rows.len() - width..];
                (0..width).all(|c| last[c] == key(i)[perm[c]])
            };
            if is_dup {
                let li = out_counts.len() - 1;
                out_counts[li] += counts[i];
            } else {
                out_rows.extend(perm.iter().map(|&p| key(i)[p]));
                out_counts.push(counts[i]);
            }
        }
        CtTable { vars: svars, counts: out_counts, layout, store: RowStore::Wide(out_rows) }
    }

    /// Number of rows (sufficient statistics) in the table.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Number of columns.
    pub fn width(&self) -> usize {
        self.vars.len()
    }

    /// The packing layout of this table.
    pub fn layout(&self) -> &CtLayout {
        &self.layout
    }

    /// The one-word packed keys, when this table uses the `u64` store.
    pub fn keys(&self) -> Option<&[u64]> {
        match &self.store {
            RowStore::Packed(k) => Some(k),
            _ => None,
        }
    }

    /// The two-word packed keys, when this table uses the `u128` store.
    pub fn keys2(&self) -> Option<&[u128]> {
        match &self.store {
            RowStore::Packed2(k) => Some(k),
            _ => None,
        }
    }

    /// Whether rows are stored as packed integer keys at either width (vs
    /// the row-major wide fallback).
    pub fn is_packed(&self) -> bool {
        matches!(self.store, RowStore::Packed(_) | RowStore::Packed2(_))
    }

    /// Whether rows are stored as two-word (`u128`) packed keys.
    pub fn is_packed2(&self) -> bool {
        matches!(self.store, RowStore::Packed2(_))
    }

    /// Storage tier name, for metrics and bench labels.
    pub fn tier(&self) -> &'static str {
        match self.store {
            RowStore::Packed(_) => "packed64",
            RowStore::Packed2(_) => "packed128",
            RowStore::Wide(_) => "rowmajor",
        }
    }

    /// The `i`-th row, decoded to value codes.
    pub fn row(&self, i: usize) -> Vec<u16> {
        let w = self.width();
        if w == 0 {
            return Vec::new();
        }
        match &self.store {
            RowStore::Packed(keys) => self.layout.unpack(keys[i]),
            RowStore::Packed2(keys) => self.layout.unpack_k::<u128>(keys[i]),
            RowStore::Wide(rows) => rows[i * w..(i + 1) * w].to_vec(),
        }
    }

    /// All rows decoded to a row-major code matrix (`len() * width()`).
    pub fn decode_rows(&self) -> Vec<u16> {
        match &self.store {
            RowStore::Wide(rows) => rows.clone(),
            RowStore::Packed(keys) => {
                let mut out = Vec::with_capacity(self.len() * self.width());
                for &k in keys {
                    self.layout.unpack_into(k, &mut out);
                }
                out
            }
            RowStore::Packed2(keys) => {
                let mut out = Vec::with_capacity(self.len() * self.width());
                for &k in keys {
                    self.layout.unpack_into_k::<u128>(k, &mut out);
                }
                out
            }
        }
    }

    /// Sum of all counts (total number of instantiations covered).
    pub fn total(&self) -> u128 {
        self.counts.iter().map(|&c| c as u128).sum()
    }

    /// Position of a variable in `vars`, if present.
    pub fn col_of(&self, v: VarId) -> Option<usize> {
        self.vars.binary_search(&v).ok()
    }

    /// The count of one exact assignment (0 if absent). Assignment must
    /// cover all columns, in column order.
    pub fn count_of(&self, assignment: &[u16]) -> u64 {
        assert_eq!(assignment.len(), self.width());
        if self.width() == 0 {
            return self.counts.first().copied().unwrap_or(0);
        }
        match &self.store {
            RowStore::Packed(keys) => match self.layout.try_pack(assignment) {
                None => 0,
                Some(k) => keys.binary_search(&k).map(|i| self.counts[i]).unwrap_or(0),
            },
            RowStore::Packed2(keys) => match self.layout.try_pack_k::<u128>(assignment) {
                None => 0,
                Some(k) => keys.binary_search(&k).map(|i| self.counts[i]).unwrap_or(0),
            },
            RowStore::Wide(rows) => {
                let w = self.width();
                let mut lo = 0usize;
                let mut hi = self.len();
                while lo < hi {
                    let mid = (lo + hi) / 2;
                    match rows[mid * w..(mid + 1) * w].cmp(assignment) {
                        std::cmp::Ordering::Less => lo = mid + 1,
                        std::cmp::Ordering::Greater => hi = mid,
                        std::cmp::Ordering::Equal => return self.counts[mid],
                    }
                }
                0
            }
        }
    }

    /// Verify all invariants (test/debug helper).
    pub fn check_invariants(&self) -> Result<(), String> {
        if !self.vars.windows(2).all(|w| w[0] < w[1]) {
            return Err("vars not strictly increasing".into());
        }
        let w = self.width();
        if self.layout.width() != w {
            return Err(format!("layout width {} != table width {w}", self.layout.width()));
        }
        if w == 0 {
            if self.counts.len() > 1 {
                return Err("nullary table with >1 row".into());
            }
        } else {
            match &self.store {
                RowStore::Packed(keys) => {
                    if keys.len() != self.counts.len() {
                        return Err(format!(
                            "shape mismatch: {} keys, {} counts",
                            keys.len(),
                            self.counts.len()
                        ));
                    }
                    if !self.layout.fits() {
                        return Err("packed store with a >64-bit layout".into());
                    }
                    for i in 1..keys.len() {
                        if keys[i - 1] >= keys[i] {
                            return Err(format!("keys not sorted/unique at {i}"));
                        }
                    }
                    if self.layout.total_bits() < 64 {
                        let mask = !((1u64 << self.layout.total_bits()) - 1);
                        if keys.iter().any(|&k| k & mask != 0) {
                            return Err("key uses bits outside the layout".into());
                        }
                    }
                }
                RowStore::Packed2(keys) => {
                    if keys.len() != self.counts.len() {
                        return Err(format!(
                            "shape mismatch: {} keys, {} counts",
                            keys.len(),
                            self.counts.len()
                        ));
                    }
                    if self.layout.fits() {
                        return Err("two-word store with a layout that fits 64 bits".into());
                    }
                    if !self.layout.fits2() {
                        return Err("two-word store with a >128-bit layout".into());
                    }
                    for i in 1..keys.len() {
                        if keys[i - 1] >= keys[i] {
                            return Err(format!("keys not sorted/unique at {i}"));
                        }
                    }
                    if self.layout.total_bits() < 128 {
                        let mask = !((1u128 << self.layout.total_bits()) - 1);
                        if keys.iter().any(|&k| k & mask != 0) {
                            return Err("key uses bits outside the layout".into());
                        }
                    }
                }
                RowStore::Wide(rows) => {
                    if rows.len() != self.counts.len() * w {
                        return Err(format!(
                            "shape mismatch: {} codes, {} counts, width {w}",
                            rows.len(),
                            self.counts.len()
                        ));
                    }
                    for i in 1..self.len() {
                        if rows[(i - 1) * w..i * w] >= rows[i * w..(i + 1) * w] {
                            return Err(format!("rows not sorted/unique at {i}"));
                        }
                    }
                }
            }
        }
        if self.counts.iter().any(|&c| c == 0) {
            return Err("zero count present".into());
        }
        Ok(())
    }

    /// Iterate `(row, count)` pairs (rows decoded per item).
    pub fn iter(&self) -> impl Iterator<Item = (Vec<u16>, u64)> + '_ {
        (0..self.len()).map(move |i| (self.row(i), self.counts[i]))
    }

    /// Exact memory footprint in bytes: the struct itself plus every heap
    /// allocation it owns, accounted per storage tier (one `u64` per
    /// one-word key, one `u128` per two-word key, one `u16` per row-major
    /// cell), using vector *capacities* — this is what the ct-store's LRU
    /// eviction budget charges against, so under-counting would let the
    /// cache blow its `mem_bytes` budget.
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        let store = match &self.store {
            RowStore::Packed(keys) => keys.capacity() * size_of::<u64>(),
            RowStore::Packed2(keys) => keys.capacity() * size_of::<u128>(),
            RowStore::Wide(rows) => rows.capacity() * size_of::<u16>(),
        };
        size_of::<Self>()
            + store
            + self.counts.capacity() * size_of::<u64>()
            + self.vars.capacity() * size_of::<VarId>()
            + self.layout.heap_bytes()
    }
}

impl PartialEq for CtTable {
    /// Logical equality: same variables, rows, and counts — independent of
    /// packed-vs-wide storage and of layout bit widths.
    fn eq(&self, other: &Self) -> bool {
        if self.vars != other.vars || self.counts != other.counts {
            return false;
        }
        match (&self.store, &other.store) {
            (RowStore::Packed(a), RowStore::Packed(b)) if self.layout == other.layout => a == b,
            (RowStore::Packed2(a), RowStore::Packed2(b)) if self.layout == other.layout => a == b,
            _ => self.decode_rows() == other.decode_rows(),
        }
    }
}

impl Eq for CtTable {}

impl std::fmt::Debug for CtTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let rows: Vec<Vec<u16>> = (0..self.len()).map(|i| self.row(i)).collect();
        f.debug_struct("CtTable")
            .field("vars", &self.vars)
            .field("rows", &rows)
            .field("counts", &self.counts)
            .field("tier", &self.tier())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_raw_sorts_and_folds() {
        // vars given out of order; rows unsorted with duplicates
        let t = CtTable::from_raw(
            vec![5, 2],
            vec![
                1, 0, // (V5=1, V2=0)
                0, 1, // (V5=0, V2=1)
                1, 0, // dup of row 0
            ],
            vec![2, 3, 4],
        );
        assert_eq!(t.vars, vec![2, 5]);
        assert_eq!(t.len(), 2);
        // canonical rows: (V2, V5): (0,1) count 6, (1,0) count 3
        assert_eq!(t.row(0), &[0, 1]);
        assert_eq!(t.counts[0], 6);
        assert_eq!(t.row(1), &[1, 0]);
        assert_eq!(t.counts[1], 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn from_raw_drops_zero_counts() {
        let t = CtTable::from_raw(vec![0], vec![0, 1, 2], vec![1, 0, 2]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.total(), 3);
    }

    #[test]
    fn scalar_and_empty() {
        let s = CtTable::scalar(7);
        assert_eq!(s.total(), 7);
        assert_eq!(s.width(), 0);
        s.check_invariants().unwrap();
        let e = CtTable::empty(vec![1, 2]);
        assert!(e.is_empty());
        e.check_invariants().unwrap();
    }

    #[test]
    fn count_of_binary_search() {
        let t = CtTable::from_raw(
            vec![0, 1],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![5, 6, 7, 8],
        );
        assert_eq!(t.count_of(&[0, 1]), 6);
        assert_eq!(t.count_of(&[1, 0]), 7);
        assert_eq!(t.count_of(&[2, 2]), 0);
    }

    #[test]
    fn nullary_from_raw_sums() {
        let t = CtTable::from_raw(vec![], vec![], vec![3, 4, 5]);
        assert_eq!(t.total(), 12);
        assert_eq!(t.len(), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate column vars")]
    fn duplicate_vars_rejected() {
        CtTable::from_raw(vec![1, 1], vec![0, 0], vec![1]);
    }

    #[test]
    fn invariant_checker_catches_unsorted() {
        let bad = CtTable::from_parts_wide_unchecked(vec![0], vec![2, 1], vec![1, 1]);
        assert!(bad.check_invariants().is_err());
    }

    #[test]
    fn small_tables_use_packed_store() {
        let t = CtTable::from_raw(vec![0, 1], vec![0, 0, 1, 1], vec![1, 2]);
        assert!(t.is_packed());
        assert_eq!(t.keys().unwrap().len(), 2);
    }

    #[test]
    fn mid_width_layout_uses_two_word_store() {
        // 40 columns x 2 bits = 80 bits: one-word packing overflows, but the
        // two-word tier keeps the rows as u128 keys.
        let width = 40usize;
        let vars: Vec<VarId> = (0..width).collect();
        let mut rows = Vec::new();
        for r in 0..3u16 {
            rows.extend(std::iter::repeat(r).take(width));
        }
        let t = CtTable::from_raw(vars, rows, vec![1, 2, 3]);
        assert!(t.is_packed() && t.is_packed2());
        assert_eq!(t.tier(), "packed128");
        assert!(t.keys().is_none());
        assert_eq!(t.keys2().unwrap().len(), 3);
        assert_eq!(t.len(), 3);
        assert_eq!(t.row(1), vec![1u16; width]);
        assert_eq!(t.count_of(&vec![2u16; width]), 3);
        assert_eq!(t.count_of(&vec![3u16; width]), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn oversized_layout_spills_to_wide() {
        // 70 columns x 2 bits = 140 bits > 128: past both packed tiers, the
        // row-major wide store takes over and still satisfies every
        // invariant.
        let width = 70usize;
        let vars: Vec<VarId> = (0..width).collect();
        let mut rows = Vec::new();
        for r in 0..3u16 {
            rows.extend(std::iter::repeat(r).take(width));
        }
        let t = CtTable::from_raw(vars, rows, vec![1, 2, 3]);
        assert!(!t.is_packed());
        assert_eq!(t.tier(), "rowmajor");
        assert_eq!(t.len(), 3);
        assert_eq!(t.row(1), vec![1u16; width]);
        assert_eq!(t.count_of(&vec![2u16; width]), 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn two_word_from_raw_sorts_and_folds() {
        // Same normalization semantics as the one-word tier: columns given
        // out of order, duplicate rows folded, zero counts dropped — but on
        // a 75-bit layout (25 columns x 3 bits).
        let width = 25usize;
        let vars: Vec<VarId> = (0..width).rev().collect(); // descending on purpose
        let mut rows = Vec::new();
        // Three logical rows; the first and third collapse after the column
        // permutation (identical code per column). Max code 4 -> 3 bits per
        // column under the observed layout.
        for r in [4u16, 1, 4] {
            rows.extend(std::iter::repeat(r).take(width));
        }
        let t = CtTable::from_raw(vars, rows, vec![4, 5, 6]);
        assert!(t.is_packed2());
        assert_eq!(t.len(), 2);
        assert_eq!(t.count_of(&vec![1u16; width]), 5);
        assert_eq!(t.count_of(&vec![4u16; width]), 10);
        t.check_invariants().unwrap();
    }

    #[test]
    fn na_codes_roundtrip_through_packing() {
        use crate::schema::NA;
        let t = CtTable::from_raw(vec![3, 9], vec![0, NA, 1, 2, 0, 0], vec![4, 5, 6]);
        assert!(t.is_packed());
        assert_eq!(t.count_of(&[0, NA]), 4);
        assert_eq!(t.count_of(&[0, 0]), 6);
        // NA sorts after real codes: rows (0,0) < (0,NA) < (1,2).
        assert_eq!(t.row(0), &[0, 0]);
        assert_eq!(t.row(1), &[0, NA]);
        assert_eq!(t.row(2), &[1, 2]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn mem_bytes_accounts_every_tier_exactly() {
        use std::mem::size_of;
        // Shared fixed overhead: struct + vars + counts + layout columns.
        let fixed = |t: &CtTable| {
            size_of::<CtTable>()
                + t.vars.capacity() * size_of::<VarId>()
                + t.counts.capacity() * size_of::<u64>()
                + t.layout.heap_bytes()
        };

        // One-word tier: 8 bytes per key slot.
        let p64 = CtTable::from_raw(vec![0, 1], vec![0, 0, 0, 1, 1, 0], vec![1, 2, 3]);
        assert_eq!(p64.tier(), "packed64");
        let keys_cap = match &p64.store {
            RowStore::Packed(k) => k.capacity(),
            _ => unreachable!(),
        };
        assert_eq!(p64.mem_bytes(), fixed(&p64) + keys_cap * 8);

        // Two-word tier: 16 bytes per key slot (a 75-bit layout).
        let width = 25usize;
        let mut rows = Vec::new();
        for r in 0..3u16 {
            rows.extend(std::iter::repeat(4 * r).take(width));
        }
        let p128 = CtTable::from_raw((0..width).collect(), rows, vec![1, 2, 3]);
        assert_eq!(p128.tier(), "packed128");
        let keys_cap = match &p128.store {
            RowStore::Packed2(k) => k.capacity(),
            _ => unreachable!(),
        };
        assert_eq!(p128.mem_bytes(), fixed(&p128) + keys_cap * 16);

        // Row-major tier: 2 bytes per cell slot (a >128-bit layout).
        let width = 70usize;
        let mut rows = Vec::new();
        for r in 0..3u16 {
            rows.extend(std::iter::repeat(r).take(width));
        }
        let wide = CtTable::from_raw((0..width).collect(), rows, vec![1, 2, 3]);
        assert_eq!(wide.tier(), "rowmajor");
        let cells_cap = match &wide.store {
            RowStore::Wide(r) => r.capacity(),
            _ => unreachable!(),
        };
        assert_eq!(wide.mem_bytes(), fixed(&wide) + cells_cap * 2);

        // Tier consistency: the same logical rows cost 2x key bytes on the
        // two-word tier vs the one-word tier — never silently equal.
        assert!(p128.mem_bytes() > p64.mem_bytes());
    }

    #[test]
    fn logical_equality_ignores_storage() {
        let packed = CtTable::from_raw(vec![0, 1], vec![0, 1, 1, 0], vec![2, 3]);
        let wide = CtTable::from_parts_wide_unchecked(vec![0, 1], vec![0, 1, 1, 0], vec![2, 3]);
        assert!(packed.is_packed());
        assert!(!wide.is_packed());
        assert_eq!(packed, wide);
    }
}
