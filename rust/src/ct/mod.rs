//! Contingency tables and the ct-algebra (paper §2.2, §4.1).
//!
//! A contingency table `ct(V)` over a variable set `V = {V1..Vn}` has one
//! row per value assignment with a positive count. We store it columnar-ish:
//! a flat row-major code matrix plus a parallel count vector, with three
//! invariants that every operation preserves:
//!
//! 1. `vars` is strictly increasing (canonical column order by `VarId`);
//! 2. rows are sorted lexicographically and unique;
//! 3. all counts are positive (zero-count rows are omitted, paper §2.2).
//!
//! Sorted order is what makes the binary operations (`add`, `subtract`,
//! `union_disjoint`) single-pass sort-merge scans, which the paper's cost
//! analysis (§4.1.3) assumes.

mod algebra;
mod display;
pub mod adtree;

pub use adtree::{AdTree, AdTreeConfig};
pub use algebra::SubtractError;
pub use display::render_ct;

use crate::schema::VarId;

/// A contingency table: sufficient statistics for one variable set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CtTable {
    /// Column headers, strictly increasing.
    pub vars: Vec<VarId>,
    /// Row-major value codes; `rows.len() == vars.len() * len()`.
    pub rows: Vec<u16>,
    /// Per-row query counts, parallel to rows.
    pub counts: Vec<u64>,
}

impl CtTable {
    /// An empty table over a variable set.
    pub fn empty(vars: Vec<VarId>) -> Self {
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]), "vars must be sorted+unique");
        CtTable { vars, rows: Vec::new(), counts: Vec::new() }
    }

    /// The nullary table with a single row of count `n` (identity for ×).
    pub fn scalar(n: u64) -> Self {
        CtTable { vars: Vec::new(), rows: Vec::new(), counts: vec![n] }
    }

    /// Build from unsorted (row, count) pairs over possibly-unsorted
    /// columns: sorts columns, permutes codes, sorts rows, folds duplicates,
    /// drops zero counts. The general-purpose normalizing constructor.
    ///
    /// Hot path (§Perf): when every column fits a small bit-width and the
    /// packed row fits 128 bits, rows are sorted as packed `u128` keys
    /// (single integer compare) instead of through an index/comparator
    /// indirection — 3-6x faster on the multi-million-row tables the
    /// Möbius Join produces.
    pub fn from_raw(vars: Vec<VarId>, rows: Vec<u16>, counts: Vec<u64>) -> Self {
        let width = vars.len();
        if width == 0 {
            let total: u64 = counts.iter().sum();
            return if total == 0 { CtTable::empty(vars) } else { CtTable::scalar(total) };
        }
        assert_eq!(rows.len(), counts.len() * width, "rows/counts shape mismatch");
        // Sort columns into canonical order, tracking the permutation.
        let mut perm: Vec<usize> = (0..width).collect();
        perm.sort_by_key(|&i| vars[i]);
        let mut svars: Vec<VarId> = perm.iter().map(|&i| vars[i]).collect();
        svars.dedup();
        assert_eq!(svars.len(), width, "duplicate column vars");

        // Packed fast path: per-column bit widths from the observed max
        // code (NA = 0xFFFF needs 16 bits and still packs).
        let n = counts.len();
        let mut max_code = vec![0u16; width];
        for r in 0..n {
            let row = &rows[r * width..(r + 1) * width];
            for (c, &v) in row.iter().enumerate() {
                if v > max_code[c] {
                    max_code[c] = v;
                }
            }
        }
        let bits: Vec<u32> = max_code
            .iter()
            .map(|&m| 16 - (m.max(1)).leading_zeros().saturating_sub(0))
            .collect();
        let total_bits: u32 = perm.iter().map(|&p| bits[p]).sum();
        if total_bits <= 128 {
            return Self::from_raw_packed(svars, &rows, &counts, &perm, &bits);
        }

        let n = counts.len();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let key = |r: usize| &rows[r * width..(r + 1) * width];
        let permuted_cmp = |a: usize, b: usize| {
            let (ka, kb) = (key(a), key(b));
            for &p in &perm {
                match ka[p].cmp(&kb[p]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        };
        idx.sort_unstable_by(|&a, &b| permuted_cmp(a as usize, b as usize));

        let mut out_rows: Vec<u16> = Vec::with_capacity(rows.len());
        let mut out_counts: Vec<u64> = Vec::with_capacity(n);
        for &i in &idx {
            let i = i as usize;
            if counts[i] == 0 {
                continue;
            }
            // Out rows are stored already permuted: compare in output order.
            let is_dup = !out_counts.is_empty() && {
                let last = &out_rows[out_rows.len() - width..];
                (0..width).all(|c| last[c] == key(i)[perm[c]])
            };
            if is_dup {
                let li = out_counts.len() - 1;
                out_counts[li] += counts[i];
            } else {
                out_rows.extend(perm.iter().map(|&p| key(i)[p]));
                out_counts.push(counts[i]);
            }
        }
        CtTable { vars: svars, rows: out_rows, counts: out_counts }
    }

    /// Packed-key constructor (see `from_raw`). `perm` maps output column
    /// -> input column; `bits` are per-input-column widths.
    fn from_raw_packed(
        svars: Vec<VarId>,
        rows: &[u16],
        counts: &[u64],
        perm: &[usize],
        bits: &[u32],
    ) -> Self {
        let width = perm.len();
        let n = counts.len();
        // Shifts per output column, most-significant first so that packed
        // integer order == lexicographic row order.
        let mut shifts = vec![0u32; width];
        let mut acc = 0u32;
        for out_col in (0..width).rev() {
            shifts[out_col] = acc;
            acc += bits[perm[out_col]];
        }
        let mut keyed: Vec<(u128, u64)> = Vec::with_capacity(n);
        for r in 0..n {
            if counts[r] == 0 {
                continue;
            }
            let row = &rows[r * width..(r + 1) * width];
            let mut key = 0u128;
            for (out_col, &p) in perm.iter().enumerate() {
                key |= (row[p] as u128) << shifts[out_col];
            }
            keyed.push((key, counts[r]));
        }
        keyed.sort_unstable_by_key(|&(k, _)| k);
        let mut out_rows: Vec<u16> = Vec::with_capacity(keyed.len() * width);
        let mut out_counts: Vec<u64> = Vec::with_capacity(keyed.len());
        let mut last_key: Option<u128> = None;
        for (key, c) in keyed {
            if last_key == Some(key) {
                *out_counts.last_mut().unwrap() += c;
            } else {
                for (out_col, &p) in perm.iter().enumerate() {
                    let mask = (1u128 << bits[p]) - 1;
                    out_rows.push(((key >> shifts[out_col]) & mask) as u16);
                }
                out_counts.push(c);
                last_key = Some(key);
            }
        }
        CtTable { vars: svars, rows: out_rows, counts: out_counts }
    }

    /// Number of rows (sufficient statistics) in the table.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Number of columns.
    pub fn width(&self) -> usize {
        self.vars.len()
    }

    /// The `i`-th row as a code slice.
    pub fn row(&self, i: usize) -> &[u16] {
        &self.rows[i * self.width()..(i + 1) * self.width()]
    }

    /// Sum of all counts (total number of instantiations covered).
    pub fn total(&self) -> u128 {
        self.counts.iter().map(|&c| c as u128).sum()
    }

    /// Position of a variable in `vars`, if present.
    pub fn col_of(&self, v: VarId) -> Option<usize> {
        self.vars.binary_search(&v).ok()
    }

    /// The count of one exact assignment (0 if absent). Assignment must
    /// cover all columns, in column order.
    pub fn count_of(&self, assignment: &[u16]) -> u64 {
        assert_eq!(assignment.len(), self.width());
        let w = self.width();
        let mut lo = 0usize;
        let mut hi = self.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            match self.rows[mid * w..(mid + 1) * w].cmp(assignment) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => return self.counts[mid],
            }
        }
        0
    }

    /// Verify all invariants (test/debug helper).
    pub fn check_invariants(&self) -> Result<(), String> {
        if !self.vars.windows(2).all(|w| w[0] < w[1]) {
            return Err("vars not strictly increasing".into());
        }
        let w = self.width();
        if w == 0 {
            if self.counts.len() > 1 {
                return Err("nullary table with >1 row".into());
            }
        } else if self.rows.len() != self.counts.len() * w {
            return Err(format!(
                "shape mismatch: {} codes, {} counts, width {w}",
                self.rows.len(),
                self.counts.len()
            ));
        }
        for i in 1..self.len() {
            if self.row(i - 1) >= self.row(i) {
                return Err(format!("rows not sorted/unique at {i}"));
            }
        }
        if self.counts.iter().any(|&c| c == 0) {
            return Err("zero count present".into());
        }
        Ok(())
    }

    /// Iterate `(row, count)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[u16], u64)> + '_ {
        (0..self.len()).map(move |i| (self.row(i), self.counts[i]))
    }

    /// Approximate heap footprint in bytes (for metrics/backpressure).
    pub fn mem_bytes(&self) -> usize {
        self.rows.len() * 2 + self.counts.len() * 8 + self.vars.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_raw_sorts_and_folds() {
        // vars given out of order; rows unsorted with duplicates
        let t = CtTable::from_raw(
            vec![5, 2],
            vec![
                1, 0, // (V5=1, V2=0)
                0, 1, // (V5=0, V2=1)
                1, 0, // dup of row 0
            ],
            vec![2, 3, 4],
        );
        assert_eq!(t.vars, vec![2, 5]);
        assert_eq!(t.len(), 2);
        // canonical rows: (V2, V5): (0,1) count 6, (1,0) count 3
        assert_eq!(t.row(0), &[0, 1]);
        assert_eq!(t.counts[0], 6);
        assert_eq!(t.row(1), &[1, 0]);
        assert_eq!(t.counts[1], 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn from_raw_drops_zero_counts() {
        let t = CtTable::from_raw(vec![0], vec![0, 1, 2], vec![1, 0, 2]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.total(), 3);
    }

    #[test]
    fn scalar_and_empty() {
        let s = CtTable::scalar(7);
        assert_eq!(s.total(), 7);
        assert_eq!(s.width(), 0);
        s.check_invariants().unwrap();
        let e = CtTable::empty(vec![1, 2]);
        assert!(e.is_empty());
        e.check_invariants().unwrap();
    }

    #[test]
    fn count_of_binary_search() {
        let t = CtTable::from_raw(
            vec![0, 1],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![5, 6, 7, 8],
        );
        assert_eq!(t.count_of(&[0, 1]), 6);
        assert_eq!(t.count_of(&[1, 0]), 7);
        assert_eq!(t.count_of(&[2, 2]), 0);
    }

    #[test]
    fn nullary_from_raw_sums() {
        let t = CtTable::from_raw(vec![], vec![], vec![3, 4, 5]);
        assert_eq!(t.total(), 12);
        assert_eq!(t.len(), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate column vars")]
    fn duplicate_vars_rejected() {
        CtTable::from_raw(vec![1, 1], vec![0, 0], vec![1]);
    }

    #[test]
    fn invariant_checker_catches_unsorted() {
        let bad = CtTable { vars: vec![0], rows: vec![2, 1], counts: vec![1, 1] };
        assert!(bad.check_invariants().is_err());
    }
}
