//! Human-readable rendering of contingency tables (paper Figure 3 style).

use super::CtTable;
use crate::schema::Schema;
use crate::util::table::TextTable;

/// Render (an excerpt of) a contingency table with named variables and
/// values, count column first, at most `limit` rows (0 = all).
pub fn render_ct(ct: &CtTable, schema: &Schema, limit: usize) -> String {
    let mut header = vec!["count".to_string()];
    header.extend(ct.vars.iter().map(|&v| schema.var_name(v)));
    let mut t = TextTable::new(header);
    let n = if limit == 0 { ct.len() } else { ct.len().min(limit) };
    for i in 0..n {
        let mut cells = vec![ct.counts[i].to_string()];
        let row = ct.row(i);
        cells.extend(row.iter().zip(&ct.vars).map(|(&code, &v)| schema.value_name(v, code)));
        t.row(cells);
    }
    let mut s = t.render();
    if n < ct.len() {
        s.push_str(&format!("... ({} more rows)\n", ct.len() - n));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::CtTable;
    use crate::schema::builder::university_schema;

    #[test]
    fn renders_named_values() {
        let s = university_schema();
        let intel = s.var_by_name("intelligence(S)").unwrap();
        let rank = s.var_by_name("ranking(S)").unwrap();
        let ct = CtTable::from_raw(
            vec![intel, rank],
            vec![0, 0, 2, 1],
            vec![5, 7],
        );
        let out = render_ct(&ct, &s, 0);
        assert!(out.contains("intelligence(S)"));
        assert!(out.contains("ranking(S)"));
        assert!(out.contains('5'));
        assert!(out.lines().count() >= 4);
    }

    #[test]
    fn truncates_with_note() {
        let s = university_schema();
        let intel = s.var_by_name("intelligence(S)").unwrap();
        let ct = CtTable::from_raw(vec![intel], vec![0, 1, 2], vec![1, 2, 3]);
        let out = render_ct(&ct, &s, 2);
        assert!(out.contains("1 more rows"));
    }
}
