//! The retained **row-major reference implementation** of the ct-algebra.
//!
//! This is the seed's `Vec<u16>`-slice semantics, kept for three jobs:
//!
//! 1. **oracle** — the property tests in `algebra.rs` assert the packed-key
//!    operators are bit-identical to these implementations;
//! 2. **wide fallback** — tables whose [`CtLayout`] exceeds 64 bits route
//!    their operators through here (decoded rows in, sorted rows out);
//! 3. **baseline** — `benches/bench_ctops_micro.rs` measures packed vs
//!    row-major on identical inputs.
//!
//! Rows here are plain `u16` code slices with `NA = u16::MAX`, compared
//! lexicographically; `NA` sorts after every real code by construction.
//!
//! [`CtLayout`]: super::CtLayout

use super::{CtTable, SubtractError};
use crate::schema::VarId;

/// A row-major contingency table (the seed's storage): sorted unique rows,
/// positive counts, canonical column order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RefTable {
    pub vars: Vec<VarId>,
    /// Row-major value codes; `rows.len() == vars.len() * counts.len()`.
    pub rows: Vec<u16>,
    pub counts: Vec<u64>,
}

impl From<&CtTable> for RefTable {
    fn from(ct: &CtTable) -> RefTable {
        RefTable { vars: ct.vars.clone(), rows: ct.decode_rows(), counts: ct.counts.clone() }
    }
}

impl RefTable {
    pub fn empty(vars: Vec<VarId>) -> RefTable {
        RefTable { vars, rows: Vec::new(), counts: Vec::new() }
    }

    pub fn scalar(n: u64) -> RefTable {
        RefTable { vars: Vec::new(), rows: Vec::new(), counts: vec![n] }
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    pub fn width(&self) -> usize {
        self.vars.len()
    }

    pub fn row(&self, i: usize) -> &[u16] {
        &self.rows[i * self.width()..(i + 1) * self.width()]
    }

    pub fn total(&self) -> u128 {
        self.counts.iter().map(|&c| c as u128).sum()
    }

    pub fn col_of(&self, v: VarId) -> Option<usize> {
        self.vars.binary_search(&v).ok()
    }

    /// Convert back to a (packed-if-possible) [`CtTable`].
    pub fn to_ct(&self) -> CtTable {
        if self.width() == 0 {
            let total: u64 = self.counts.iter().sum();
            return if total == 0 { CtTable::empty(Vec::new()) } else { CtTable::scalar(total) };
        }
        if self.is_empty() {
            return CtTable::empty(self.vars.clone());
        }
        CtTable::from_sorted_rows(self.vars.clone(), self.rows.clone(), self.counts.clone())
    }

    /// Normalize unsorted (row, count) pairs over possibly-unsorted columns
    /// (the seed's `from_raw`): sort columns, permute codes, sort rows,
    /// fold duplicates, drop zeros.
    pub fn from_raw(vars: Vec<VarId>, rows: Vec<u16>, counts: Vec<u64>) -> RefTable {
        let width = vars.len();
        if width == 0 {
            let total: u64 = counts.iter().sum();
            return if total == 0 { RefTable::empty(vars) } else { RefTable::scalar(total) };
        }
        assert_eq!(rows.len(), counts.len() * width, "rows/counts shape mismatch");
        let mut perm: Vec<usize> = (0..width).collect();
        perm.sort_by_key(|&i| vars[i]);
        let svars: Vec<VarId> = perm.iter().map(|&i| vars[i]).collect();
        assert!(svars.windows(2).all(|w| w[0] != w[1]), "duplicate column vars");

        let n = counts.len();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let key = |r: usize| &rows[r * width..(r + 1) * width];
        let permuted_cmp = |a: usize, b: usize| {
            let (ka, kb) = (key(a), key(b));
            for &p in &perm {
                match ka[p].cmp(&kb[p]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        };
        idx.sort_unstable_by(|&a, &b| permuted_cmp(a as usize, b as usize));

        let mut out_rows: Vec<u16> = Vec::with_capacity(rows.len());
        let mut out_counts: Vec<u64> = Vec::with_capacity(n);
        for &i in &idx {
            let i = i as usize;
            if counts[i] == 0 {
                continue;
            }
            let is_dup = !out_counts.is_empty() && {
                let last = &out_rows[out_rows.len() - width..];
                (0..width).all(|c| last[c] == key(i)[perm[c]])
            };
            if is_dup {
                let li = out_counts.len() - 1;
                out_counts[li] += counts[i];
            } else {
                out_rows.extend(perm.iter().map(|&p| key(i)[p]));
                out_counts.push(counts[i]);
            }
        }
        RefTable { vars: svars, rows: out_rows, counts: out_counts }
    }

    /// σ_φ: keep rows matching all `(var, value)` conditions.
    pub fn select(&self, cond: &[(VarId, u16)]) -> RefTable {
        let cols: Vec<(usize, u16)> = cond
            .iter()
            .map(|&(v, val)| (self.col_of(v).expect("select: unknown var"), val))
            .collect();
        let w = self.width();
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let r = &self.rows[i * w..(i + 1) * w];
            if cols.iter().all(|&(ci, val)| r[ci] == val) {
                rows.extend_from_slice(r);
                counts.push(c);
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// π_keep: project onto a subset of columns, summing collapsing rows.
    pub fn project(&self, keep: &[VarId]) -> RefTable {
        let mut keep_sorted: Vec<VarId> = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let cols: Vec<usize> = keep_sorted
            .iter()
            .map(|&v| self.col_of(v).expect("project: unknown var"))
            .collect();
        if cols.len() == self.width() {
            return self.clone();
        }
        let w = self.width();
        let nw = cols.len();
        if nw == 0 {
            let total: u128 = self.total();
            return if total == 0 {
                RefTable::empty(Vec::new())
            } else {
                RefTable::scalar(u64::try_from(total).expect("count overflow"))
            };
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = &self.rows[i * w..(i + 1) * w];
            rows.extend(cols.iter().map(|&c| r[c]));
        }
        RefTable::from_raw(keep_sorted, rows, self.counts.clone())
    }

    /// χ_φ: conditioning = select then drop the conditioned columns.
    pub fn condition(&self, cond: &[(VarId, u16)]) -> RefTable {
        let sel = self.select(cond);
        let drop: Vec<VarId> = cond.iter().map(|&(v, _)| v).collect();
        let rest: Vec<VarId> = self.vars.iter().copied().filter(|v| !drop.contains(v)).collect();
        sel.project(&rest)
    }

    /// ×: cross product; counts multiply. Variable sets must be disjoint.
    pub fn cross(&self, other: &RefTable) -> RefTable {
        for v in &other.vars {
            assert!(self.col_of(*v).is_none(), "cross: overlapping var {v}");
        }
        if self.width() == 0 {
            let k = if self.is_empty() { 0 } else { self.counts[0] };
            return other.scale(k);
        }
        if other.width() == 0 {
            let k = if other.is_empty() { 0 } else { other.counts[0] };
            return self.scale(k);
        }
        let mut vars = Vec::with_capacity(self.width() + other.width());
        vars.extend_from_slice(&self.vars);
        vars.extend_from_slice(&other.vars);
        let mut rows = Vec::with_capacity((self.len() * other.len()) * vars.len());
        let mut counts = Vec::with_capacity(self.len() * other.len());
        for i in 0..self.len() {
            for j in 0..other.len() {
                rows.extend_from_slice(self.row(i));
                rows.extend_from_slice(other.row(j));
                counts.push(
                    self.counts[i].checked_mul(other.counts[j]).expect("count overflow in cross"),
                );
            }
        }
        RefTable::from_raw(vars, rows, counts)
    }

    /// Multiply every count by `k` (k = 0 empties the table).
    pub fn scale(&self, k: u64) -> RefTable {
        if k == 0 {
            return RefTable::empty(self.vars.clone());
        }
        let counts = self
            .counts
            .iter()
            .map(|&c| c.checked_mul(k).expect("count overflow in scale"))
            .collect();
        RefTable { vars: self.vars.clone(), rows: self.rows.clone(), counts }
    }

    /// +: count addition over identical variable sets (sort-merge).
    pub fn add(&self, other: &RefTable) -> RefTable {
        assert_eq!(self.vars, other.vars, "add: variable sets differ");
        let w = self.width();
        if w == 0 {
            let t = self.total() + other.total();
            return RefTable::scalar(u64::try_from(t).expect("count overflow"));
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let ord = if i == self.len() {
                std::cmp::Ordering::Greater
            } else if j == other.len() {
                std::cmp::Ordering::Less
            } else {
                self.row(i).cmp(other.row(j))
            };
            match ord {
                std::cmp::Ordering::Less => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    rows.extend_from_slice(other.row(j));
                    counts.push(other.counts[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i].checked_add(other.counts[j]).expect("overflow"));
                    i += 1;
                    j += 1;
                }
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// −: count subtraction; defined only when `other ⊆ self` pointwise.
    pub fn subtract(&self, other: &RefTable) -> Result<RefTable, SubtractError> {
        if self.vars != other.vars {
            return Err(SubtractError::VarMismatch);
        }
        let w = self.width();
        if w == 0 {
            let (a, b) = (self.total(), other.total());
            if b > a {
                return Err(SubtractError::CountUnderflow {
                    row: vec![],
                    have: a as u64,
                    sub: b as u64,
                });
            }
            let d = (a - b) as u64;
            return Ok(if d == 0 { RefTable::empty(vec![]) } else { RefTable::scalar(d) });
        }
        let mut rows = Vec::with_capacity(self.rows.len());
        let mut counts = Vec::with_capacity(self.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() {
            if j < other.len() {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => {
                        rows.extend_from_slice(self.row(i));
                        counts.push(self.counts[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        return Err(SubtractError::MissingRow(other.row(j).to_vec()));
                    }
                    std::cmp::Ordering::Equal => {
                        let (a, b) = (self.counts[i], other.counts[j]);
                        if b > a {
                            return Err(SubtractError::CountUnderflow {
                                row: self.row(i).to_vec(),
                                have: a,
                                sub: b,
                            });
                        }
                        if a > b {
                            rows.extend_from_slice(self.row(i));
                            counts.push(a - b);
                        }
                        i += 1;
                        j += 1;
                    }
                }
            } else {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            }
        }
        if j < other.len() {
            return Err(SubtractError::MissingRow(other.row(j).to_vec()));
        }
        Ok(RefTable { vars: self.vars.clone(), rows, counts })
    }

    /// ∪ of two tables over the same variables with disjoint row sets.
    pub fn union_disjoint(&self, other: &RefTable) -> RefTable {
        assert_eq!(self.vars, other.vars, "union: variable sets differ");
        let w = self.width();
        if w == 0 {
            assert!(
                self.is_empty() || other.is_empty(),
                "union_disjoint: two nullary rows always collide"
            );
            let t = self.total() + other.total();
            return if t == 0 {
                RefTable::empty(vec![])
            } else {
                RefTable::scalar(u64::try_from(t).unwrap())
            };
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let take_left = if i == self.len() {
                false
            } else if j == other.len() {
                true
            } else {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => panic!("union_disjoint: shared row"),
                }
            };
            if take_left {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            } else {
                rows.extend_from_slice(other.row(j));
                counts.push(other.counts[j]);
                j += 1;
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// Extend with constant columns (Algorithm 1 lines 2-3).
    pub fn extend_const(&self, consts: &[(VarId, u16)]) -> RefTable {
        if consts.is_empty() {
            return self.clone();
        }
        let mut merged: Vec<(VarId, Option<u16>)> =
            self.vars.iter().map(|&v| (v, None)).collect();
        for &(v, val) in consts {
            assert!(self.col_of(v).is_none(), "extend_const: var {v} already present");
            merged.push((v, Some(val)));
        }
        merged.sort_unstable_by_key(|&(v, _)| v);
        let vars: Vec<VarId> = merged.iter().map(|&(v, _)| v).collect();
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]));
        let w = self.width();
        let nw = vars.len();
        if w == 0 {
            if self.is_empty() {
                return RefTable::empty(vars);
            }
            let rows: Vec<u16> = merged.iter().map(|&(_, c)| c.unwrap()).collect();
            return RefTable { vars, rows, counts: self.counts.clone() };
        }
        // Copy contiguous source segments between constant inserts.
        #[derive(Clone, Copy)]
        enum Piece {
            Src { start: usize, len: usize },
            Const(u16),
        }
        let mut pieces: Vec<Piece> = Vec::new();
        let mut src = 0usize;
        for &(_, c) in &merged {
            match c {
                Some(val) => pieces.push(Piece::Const(val)),
                None => {
                    if let Some(Piece::Src { len, .. }) = pieces.last_mut() {
                        *len += 1;
                    } else {
                        pieces.push(Piece::Src { start: src, len: 1 });
                    }
                    src += 1;
                }
            }
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = self.row(i);
            for &p in &pieces {
                match p {
                    Piece::Const(val) => rows.push(val),
                    Piece::Src { start, len } => rows.extend_from_slice(&r[start..start + len]),
                }
            }
        }
        RefTable { vars, rows, counts: self.counts.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_ct() {
        let ct = CtTable::from_raw(vec![2, 7], vec![0, 1, 1, 0, 0, 0], vec![3, 4, 5]);
        let r = RefTable::from(&ct);
        assert_eq!(r.len(), 3);
        assert_eq!(r.to_ct(), ct);
    }

    #[test]
    fn ref_ops_mirror_seed_semantics() {
        let t = RefTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let s = t.select(&[(3, 1)]);
        assert_eq!(s.len(), 2);
        let p = t.project(&[1]);
        assert_eq!(p.total(), t.total());
        let c = t.condition(&[(3, 0)]);
        assert_eq!(c.vars, vec![1]);
        let sum = t.add(&t);
        assert_eq!(sum.total(), 2 * t.total());
        let back = sum.subtract(&t).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_and_empty_to_ct() {
        assert_eq!(RefTable::scalar(4).to_ct(), CtTable::scalar(4));
        assert_eq!(RefTable::empty(vec![1]).to_ct(), CtTable::empty(vec![1]));
    }
}
