//! The retained **row-major reference implementation** of the ct-algebra.
//!
//! This is the seed's `Vec<u16>`-slice semantics, kept for three jobs:
//!
//! 1. **oracle** — the property tests in `algebra.rs` and below assert the
//!    packed-key operators (both the one-word `u64` and two-word `u128`
//!    tiers) are bit-identical to these implementations;
//! 2. **wide fallback** — tables whose [`CtLayout`] exceeds 128 bits route
//!    their operators through here (decoded rows in, sorted rows out);
//!    each routing bumps [`reference_op_fallbacks`] so scale tests can
//!    assert the packed path was never left;
//! 3. **baseline** — `benches/bench_ctops_micro.rs` measures packed vs
//!    row-major on identical inputs.
//!
//! Rows here are plain `u16` code slices with `NA = u16::MAX`, compared
//! lexicographically; `NA` sorts after every real code by construction.
//!
//! [`CtLayout`]: super::CtLayout

use super::{CtTable, SubtractError};
use crate::schema::VarId;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of ct-algebra operator calls that routed through this
/// row-major reference path instead of a packed kernel. Monotonic; read it
/// before and after a workload and compare deltas. With both packed tiers
/// in place (layouts ≤ 128 bits), a paper-scale Möbius Join should leave
/// this counter untouched — `rust/tests/wide_tier.rs` asserts exactly that.
static REF_OP_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Current value of the reference-fallback counter (see [`note_op_fallback`]).
pub fn reference_op_fallbacks() -> u64 {
    REF_OP_FALLBACKS.load(Ordering::Relaxed)
}

/// Record one operator call that left the packed fast path. Called by the
/// dispatch sites in `algebra.rs` only — constructing a [`RefTable`]
/// directly (oracle tests, benches) does not count.
pub(crate) fn note_op_fallback() {
    REF_OP_FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// A row-major contingency table (the seed's storage): sorted unique rows,
/// positive counts, canonical column order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RefTable {
    pub vars: Vec<VarId>,
    /// Row-major value codes; `rows.len() == vars.len() * counts.len()`.
    pub rows: Vec<u16>,
    pub counts: Vec<u64>,
}

impl From<&CtTable> for RefTable {
    fn from(ct: &CtTable) -> RefTable {
        RefTable { vars: ct.vars.clone(), rows: ct.decode_rows(), counts: ct.counts.clone() }
    }
}

impl RefTable {
    pub fn empty(vars: Vec<VarId>) -> RefTable {
        RefTable { vars, rows: Vec::new(), counts: Vec::new() }
    }

    pub fn scalar(n: u64) -> RefTable {
        RefTable { vars: Vec::new(), rows: Vec::new(), counts: vec![n] }
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    pub fn width(&self) -> usize {
        self.vars.len()
    }

    pub fn row(&self, i: usize) -> &[u16] {
        &self.rows[i * self.width()..(i + 1) * self.width()]
    }

    pub fn total(&self) -> u128 {
        self.counts.iter().map(|&c| c as u128).sum()
    }

    pub fn col_of(&self, v: VarId) -> Option<usize> {
        self.vars.binary_search(&v).ok()
    }

    /// Convert back to a (packed-if-possible) [`CtTable`].
    pub fn to_ct(&self) -> CtTable {
        if self.width() == 0 {
            let total: u64 = self.counts.iter().sum();
            return if total == 0 { CtTable::empty(Vec::new()) } else { CtTable::scalar(total) };
        }
        if self.is_empty() {
            return CtTable::empty(self.vars.clone());
        }
        CtTable::from_sorted_rows(self.vars.clone(), self.rows.clone(), self.counts.clone())
    }

    /// Normalize unsorted (row, count) pairs over possibly-unsorted columns
    /// (the seed's `from_raw`): sort columns, permute codes, sort rows,
    /// fold duplicates, drop zeros.
    pub fn from_raw(vars: Vec<VarId>, rows: Vec<u16>, counts: Vec<u64>) -> RefTable {
        let width = vars.len();
        if width == 0 {
            let total: u64 = counts.iter().sum();
            return if total == 0 { RefTable::empty(vars) } else { RefTable::scalar(total) };
        }
        assert_eq!(rows.len(), counts.len() * width, "rows/counts shape mismatch");
        let mut perm: Vec<usize> = (0..width).collect();
        perm.sort_by_key(|&i| vars[i]);
        let svars: Vec<VarId> = perm.iter().map(|&i| vars[i]).collect();
        assert!(svars.windows(2).all(|w| w[0] != w[1]), "duplicate column vars");

        let n = counts.len();
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let key = |r: usize| &rows[r * width..(r + 1) * width];
        let permuted_cmp = |a: usize, b: usize| {
            let (ka, kb) = (key(a), key(b));
            for &p in &perm {
                match ka[p].cmp(&kb[p]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        };
        idx.sort_unstable_by(|&a, &b| permuted_cmp(a as usize, b as usize));

        let mut out_rows: Vec<u16> = Vec::with_capacity(rows.len());
        let mut out_counts: Vec<u64> = Vec::with_capacity(n);
        for &i in &idx {
            let i = i as usize;
            if counts[i] == 0 {
                continue;
            }
            let is_dup = !out_counts.is_empty() && {
                let last = &out_rows[out_rows.len() - width..];
                (0..width).all(|c| last[c] == key(i)[perm[c]])
            };
            if is_dup {
                let li = out_counts.len() - 1;
                out_counts[li] += counts[i];
            } else {
                out_rows.extend(perm.iter().map(|&p| key(i)[p]));
                out_counts.push(counts[i]);
            }
        }
        RefTable { vars: svars, rows: out_rows, counts: out_counts }
    }

    /// σ_φ: keep rows matching all `(var, value)` conditions.
    pub fn select(&self, cond: &[(VarId, u16)]) -> RefTable {
        let cols: Vec<(usize, u16)> = cond
            .iter()
            .map(|&(v, val)| (self.col_of(v).expect("select: unknown var"), val))
            .collect();
        let w = self.width();
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let r = &self.rows[i * w..(i + 1) * w];
            if cols.iter().all(|&(ci, val)| r[ci] == val) {
                rows.extend_from_slice(r);
                counts.push(c);
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// π_keep: project onto a subset of columns, summing collapsing rows.
    pub fn project(&self, keep: &[VarId]) -> RefTable {
        let mut keep_sorted: Vec<VarId> = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let cols: Vec<usize> = keep_sorted
            .iter()
            .map(|&v| self.col_of(v).expect("project: unknown var"))
            .collect();
        if cols.len() == self.width() {
            return self.clone();
        }
        let w = self.width();
        let nw = cols.len();
        if nw == 0 {
            let total: u128 = self.total();
            return if total == 0 {
                RefTable::empty(Vec::new())
            } else {
                RefTable::scalar(u64::try_from(total).expect("count overflow"))
            };
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = &self.rows[i * w..(i + 1) * w];
            rows.extend(cols.iter().map(|&c| r[c]));
        }
        RefTable::from_raw(keep_sorted, rows, self.counts.clone())
    }

    /// χ_φ: conditioning = select then drop the conditioned columns.
    pub fn condition(&self, cond: &[(VarId, u16)]) -> RefTable {
        let sel = self.select(cond);
        let drop: Vec<VarId> = cond.iter().map(|&(v, _)| v).collect();
        let rest: Vec<VarId> = self.vars.iter().copied().filter(|v| !drop.contains(v)).collect();
        sel.project(&rest)
    }

    /// ×: cross product; counts multiply. Variable sets must be disjoint.
    pub fn cross(&self, other: &RefTable) -> RefTable {
        for v in &other.vars {
            assert!(self.col_of(*v).is_none(), "cross: overlapping var {v}");
        }
        if self.width() == 0 {
            let k = if self.is_empty() { 0 } else { self.counts[0] };
            return other.scale(k);
        }
        if other.width() == 0 {
            let k = if other.is_empty() { 0 } else { other.counts[0] };
            return self.scale(k);
        }
        let mut vars = Vec::with_capacity(self.width() + other.width());
        vars.extend_from_slice(&self.vars);
        vars.extend_from_slice(&other.vars);
        let mut rows = Vec::with_capacity((self.len() * other.len()) * vars.len());
        let mut counts = Vec::with_capacity(self.len() * other.len());
        for i in 0..self.len() {
            for j in 0..other.len() {
                rows.extend_from_slice(self.row(i));
                rows.extend_from_slice(other.row(j));
                counts.push(
                    self.counts[i].checked_mul(other.counts[j]).expect("count overflow in cross"),
                );
            }
        }
        RefTable::from_raw(vars, rows, counts)
    }

    /// Multiply every count by `k` (k = 0 empties the table).
    pub fn scale(&self, k: u64) -> RefTable {
        if k == 0 {
            return RefTable::empty(self.vars.clone());
        }
        let counts = self
            .counts
            .iter()
            .map(|&c| c.checked_mul(k).expect("count overflow in scale"))
            .collect();
        RefTable { vars: self.vars.clone(), rows: self.rows.clone(), counts }
    }

    /// +: count addition over identical variable sets (sort-merge).
    pub fn add(&self, other: &RefTable) -> RefTable {
        assert_eq!(self.vars, other.vars, "add: variable sets differ");
        let w = self.width();
        if w == 0 {
            // Two empty nullary operands sum to the empty table (a scalar
            // row of count 0 would break the positive-counts invariant) —
            // same convention as subtract and union_disjoint.
            let t = self.total() + other.total();
            return if t == 0 {
                RefTable::empty(Vec::new())
            } else {
                RefTable::scalar(u64::try_from(t).expect("count overflow"))
            };
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let ord = if i == self.len() {
                std::cmp::Ordering::Greater
            } else if j == other.len() {
                std::cmp::Ordering::Less
            } else {
                self.row(i).cmp(other.row(j))
            };
            match ord {
                std::cmp::Ordering::Less => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    rows.extend_from_slice(other.row(j));
                    counts.push(other.counts[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    rows.extend_from_slice(self.row(i));
                    counts.push(self.counts[i].checked_add(other.counts[j]).expect("overflow"));
                    i += 1;
                    j += 1;
                }
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// −: count subtraction; defined only when `other ⊆ self` pointwise.
    pub fn subtract(&self, other: &RefTable) -> Result<RefTable, SubtractError> {
        if self.vars != other.vars {
            return Err(SubtractError::VarMismatch);
        }
        let w = self.width();
        if w == 0 {
            let (a, b) = (self.total(), other.total());
            if b > a {
                return Err(SubtractError::CountUnderflow {
                    row: vec![],
                    have: a as u64,
                    sub: b as u64,
                });
            }
            let d = (a - b) as u64;
            return Ok(if d == 0 { RefTable::empty(vec![]) } else { RefTable::scalar(d) });
        }
        let mut rows = Vec::with_capacity(self.rows.len());
        let mut counts = Vec::with_capacity(self.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() {
            if j < other.len() {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => {
                        rows.extend_from_slice(self.row(i));
                        counts.push(self.counts[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        return Err(SubtractError::MissingRow(other.row(j).to_vec()));
                    }
                    std::cmp::Ordering::Equal => {
                        let (a, b) = (self.counts[i], other.counts[j]);
                        if b > a {
                            return Err(SubtractError::CountUnderflow {
                                row: self.row(i).to_vec(),
                                have: a,
                                sub: b,
                            });
                        }
                        if a > b {
                            rows.extend_from_slice(self.row(i));
                            counts.push(a - b);
                        }
                        i += 1;
                        j += 1;
                    }
                }
            } else {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            }
        }
        if j < other.len() {
            return Err(SubtractError::MissingRow(other.row(j).to_vec()));
        }
        Ok(RefTable { vars: self.vars.clone(), rows, counts })
    }

    /// ∪ of two tables over the same variables with disjoint row sets.
    pub fn union_disjoint(&self, other: &RefTable) -> RefTable {
        assert_eq!(self.vars, other.vars, "union: variable sets differ");
        let w = self.width();
        if w == 0 {
            assert!(
                self.is_empty() || other.is_empty(),
                "union_disjoint: two nullary rows always collide"
            );
            let t = self.total() + other.total();
            return if t == 0 {
                RefTable::empty(vec![])
            } else {
                RefTable::scalar(u64::try_from(t).unwrap())
            };
        }
        let mut rows = Vec::with_capacity(self.rows.len() + other.rows.len());
        let mut counts = Vec::with_capacity(self.len() + other.len());
        let (mut i, mut j) = (0, 0);
        while i < self.len() || j < other.len() {
            let take_left = if i == self.len() {
                false
            } else if j == other.len() {
                true
            } else {
                match self.row(i).cmp(other.row(j)) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => panic!("union_disjoint: shared row"),
                }
            };
            if take_left {
                rows.extend_from_slice(self.row(i));
                counts.push(self.counts[i]);
                i += 1;
            } else {
                rows.extend_from_slice(other.row(j));
                counts.push(other.counts[j]);
                j += 1;
            }
        }
        RefTable { vars: self.vars.clone(), rows, counts }
    }

    /// Extend with constant columns (Algorithm 1 lines 2-3).
    pub fn extend_const(&self, consts: &[(VarId, u16)]) -> RefTable {
        if consts.is_empty() {
            return self.clone();
        }
        let mut merged: Vec<(VarId, Option<u16>)> =
            self.vars.iter().map(|&v| (v, None)).collect();
        for &(v, val) in consts {
            assert!(self.col_of(v).is_none(), "extend_const: var {v} already present");
            merged.push((v, Some(val)));
        }
        merged.sort_unstable_by_key(|&(v, _)| v);
        let vars: Vec<VarId> = merged.iter().map(|&(v, _)| v).collect();
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]));
        let w = self.width();
        let nw = vars.len();
        if w == 0 {
            if self.is_empty() {
                return RefTable::empty(vars);
            }
            let rows: Vec<u16> = merged.iter().map(|&(_, c)| c.unwrap()).collect();
            return RefTable { vars, rows, counts: self.counts.clone() };
        }
        // Copy contiguous source segments between constant inserts.
        #[derive(Clone, Copy)]
        enum Piece {
            Src { start: usize, len: usize },
            Const(u16),
        }
        let mut pieces: Vec<Piece> = Vec::new();
        let mut src = 0usize;
        for &(_, c) in &merged {
            match c {
                Some(val) => pieces.push(Piece::Const(val)),
                None => {
                    if let Some(Piece::Src { len, .. }) = pieces.last_mut() {
                        *len += 1;
                    } else {
                        pieces.push(Piece::Src { start: src, len: 1 });
                    }
                    src += 1;
                }
            }
        }
        let mut rows = Vec::with_capacity(self.len() * nw);
        for i in 0..self.len() {
            let r = self.row(i);
            for &p in &pieces {
                match p {
                    Piece::Const(val) => rows.push(val),
                    Piece::Src { start, len } => rows.extend_from_slice(&r[start..start + len]),
                }
            }
        }
        RefTable { vars, rows, counts: self.counts.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_ct() {
        let ct = CtTable::from_raw(vec![2, 7], vec![0, 1, 1, 0, 0, 0], vec![3, 4, 5]);
        let r = RefTable::from(&ct);
        assert_eq!(r.len(), 3);
        assert_eq!(r.to_ct(), ct);
    }

    #[test]
    fn ref_ops_mirror_seed_semantics() {
        let t = RefTable::from_raw(
            vec![1, 3],
            vec![0, 0, 0, 1, 1, 0, 1, 1],
            vec![10, 11, 12, 13],
        );
        let s = t.select(&[(3, 1)]);
        assert_eq!(s.len(), 2);
        let p = t.project(&[1]);
        assert_eq!(p.total(), t.total());
        let c = t.condition(&[(3, 0)]);
        assert_eq!(c.vars, vec![1]);
        let sum = t.add(&t);
        assert_eq!(sum.total(), 2 * t.total());
        let back = sum.subtract(&t).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_and_empty_to_ct() {
        assert_eq!(RefTable::scalar(4).to_ct(), CtTable::scalar(4));
        assert_eq!(RefTable::empty(vec![1]).to_ct(), CtTable::empty(vec![1]));
    }

    // ---------- two-word (65–128-bit) tier vs row-major oracle ----------
    //
    // The property tests in `algebra.rs` cover the one-word tier; these
    // drive every operator on layouts wider than 64 bits, where the packed
    // path runs the u128-monomorphized kernels, and compare each result
    // bit-for-bit against this module's row-major implementations.

    use crate::util::proptest::run_prop;
    use crate::util::Pcg64;
    use crate::schema::NA;

    const WIDE_COLS: usize = 24;

    /// Random table over `WIDE_COLS` columns whose observed layout is
    /// always 65..=128 bits wide: one forced row pins every column's cap to
    /// 6 (3-bit fields, NA on odd columns), so 24 columns never fit 64 bits.
    fn random_wide_ct(rng: &mut Pcg64, vars: &[VarId]) -> CtTable {
        debug_assert_eq!(vars.len(), WIDE_COLS);
        let n = rng.index(14) + 1;
        let mut rows = Vec::new();
        let mut counts = Vec::new();
        for _ in 0..n {
            for c in 0..WIDE_COLS {
                if c % 2 == 1 && rng.chance(0.25) {
                    rows.push(NA);
                } else {
                    rows.push(rng.below(6) as u16);
                }
            }
            counts.push(rng.below(20) + 1);
        }
        // The cap-pinning row: max real code everywhere.
        rows.extend(std::iter::repeat(5u16).take(WIDE_COLS));
        counts.push(1);
        let t = CtTable::from_raw(vars.to_vec(), rows, counts);
        assert!(t.is_packed2(), "expected the two-word tier, got {}", t.tier());
        t
    }

    /// Oracle comparison with invariant checking on the packed side.
    fn expect_same(got: &CtTable, want: &RefTable, what: &str) -> Result<(), String> {
        got.check_invariants().map_err(|e| format!("{what}: invariant broken: {e}"))?;
        if got != &want.to_ct() {
            return Err(format!("{what}: packed != reference\n got {got:?}\nwant {want:?}"));
        }
        Ok(())
    }

    #[test]
    fn prop_wide_unary_ops_match_reference() {
        let vars: Vec<VarId> = (0..WIDE_COLS).collect();
        run_prop(
            "wide_unary_ops_match_reference",
            120,
            0x51DE_01,
            |r| random_wide_ct(r, &vars),
            |t| {
                let rt = RefTable::from(t);
                expect_same(&t.select(&[(2, 1)]), &rt.select(&[(2, 1)]), "select")?;
                expect_same(&t.select(&[(3, NA)]), &rt.select(&[(3, NA)]), "select NA")?;
                expect_same(&t.select(&[(0, 9)]), &rt.select(&[(0, 9)]), "select unrep")?;
                // Projections that stay two-word (drop one column), narrow
                // back to one word, and drop everything.
                let wide_keep: Vec<VarId> = (0..WIDE_COLS - 1).collect();
                let narrow_keep: Vec<VarId> = (0..4).collect();
                for keep in [wide_keep, narrow_keep, vec![7], vec![]] {
                    let p = t.project(&keep);
                    expect_same(&p, &rt.project(&keep), "project")?;
                    // Results always land on the narrowest tier the kept
                    // columns allow.
                    if !keep.is_empty() {
                        if !p.is_packed() {
                            return Err("projection left the packed tiers".into());
                        }
                        if p.is_packed2() != (p.layout().total_bits() > 64) {
                            return Err(format!(
                                "projection tier {} inconsistent with {} layout bits",
                                p.tier(),
                                p.layout().total_bits()
                            ));
                        }
                    }
                }
                for cond in [vec![(2usize, 0u16)], vec![(0, 1), (5, 1)], vec![(3, NA)]] {
                    expect_same(&t.condition(&cond), &rt.condition(&cond), "condition")?;
                }
                expect_same(
                    &t.extend_const(&[(100, 3), (101, NA)]),
                    &rt.extend_const(&[(100, 3), (101, NA)]),
                    "extend_const",
                )?;
                expect_same(&t.scale(3), &rt.scale(3), "scale")?;
                Ok(())
            },
        );
    }

    #[test]
    fn prop_wide_binary_ops_match_reference() {
        let vars: Vec<VarId> = (0..WIDE_COLS).collect();
        run_prop(
            "wide_binary_ops_match_reference",
            100,
            0x51DE_02,
            |r| (random_wide_ct(r, &vars), random_wide_ct(r, &vars)),
            |(a, b)| {
                let (ra, rb) = (RefTable::from(a), RefTable::from(b));
                expect_same(&a.add(b), &ra.add(&rb), "add")?;
                let sum = a.add(b);
                let rsum = ra.add(&rb);
                expect_same(
                    &sum.subtract(b).map_err(|e| e.to_string())?,
                    &rsum.subtract(&rb).map_err(|e| e.to_string())?,
                    "subtract",
                )?;
                if !sum.is_packed2() {
                    return Err("wide add left the two-word tier".into());
                }
                // Cross with a small disjoint table stays within 128 bits
                // and on the packed path.
                let small = CtTable::from_raw(vec![200, 201], vec![0, 0, 1, 1], vec![2, 3]);
                let x = a.cross(&small);
                expect_same(&x, &ra.cross(&RefTable::from(&small)), "cross small")?;
                if !x.is_packed2() {
                    return Err("wide cross left the two-word tier".into());
                }
                // Wide × wide exceeds 128 bits: the reference fallback must
                // still agree with the oracle end to end.
                let b_shift = {
                    let mut s = b.clone();
                    s.vars = s.vars.iter().map(|v| v + 300).collect();
                    s
                };
                let big = a.cross(&b_shift);
                expect_same(&big, &ra.cross(&RefTable::from(&b_shift)), "cross wide")?;
                if big.is_packed() {
                    return Err(">128-bit cross should be row-major".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_wide_union_disjoint_matches_reference() {
        let vars: Vec<VarId> = (0..WIDE_COLS).collect();
        run_prop(
            "wide_union_matches_reference",
            80,
            0x51DE_03,
            |r| random_wide_ct(r, &vars),
            |t| {
                if t.len() < 2 {
                    return Ok(());
                }
                let rt = RefTable::from(t);
                let (mut ar, mut ac, mut br, mut bc) = (vec![], vec![], vec![], vec![]);
                for i in 0..rt.len() {
                    if i % 2 == 0 {
                        ar.extend_from_slice(rt.row(i));
                        ac.push(rt.counts[i]);
                    } else {
                        br.extend_from_slice(rt.row(i));
                        bc.push(rt.counts[i]);
                    }
                }
                let ra = RefTable { vars: rt.vars.clone(), rows: ar, counts: ac };
                let rb = RefTable { vars: rt.vars.clone(), rows: br, counts: bc };
                let got = ra.to_ct().union_disjoint(&rb.to_ct());
                expect_same(&got, &ra.union_disjoint(&rb), "union_disjoint")?;
                if &got != t {
                    return Err("union of halves != whole".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_mixed_width_merges_match_reference() {
        // One operand fits 64 bits, the other does not: the merge must
        // widen the narrow side into the two-word union layout and agree
        // with the oracle (the regression this guards is a silent fallback
        // to row-major for mixed-width operands).
        const COLS: usize = 20;
        let vars: Vec<VarId> = (0..COLS).collect();
        let gen_at = |rng: &mut Pcg64, max_code: u64, pin: u16| {
            let n = rng.index(10) + 1;
            let mut rows = Vec::new();
            let mut counts = Vec::new();
            for _ in 0..n {
                for _ in 0..COLS {
                    rows.push(rng.below(max_code) as u16);
                }
                counts.push(rng.below(20) + 1);
            }
            rows.extend(std::iter::repeat(pin).take(COLS));
            counts.push(1);
            CtTable::from_raw(vars.clone(), rows, counts)
        };
        run_prop(
            "mixed_width_merges_match_reference",
            100,
            0x51DE_04,
            |r| (gen_at(r, 8, 7), gen_at(r, 8, 31)),
            |(a, b)| {
                // a: 3-bit fields x20 = 60 bits; b: caps pinned to 32 ->
                // 5-bit fields x20 = 100 bits.
                if a.is_packed2() || !b.is_packed2() {
                    return Err(format!(
                        "unexpected tiers: a={} b={}",
                        a.tier(),
                        b.tier()
                    ));
                }
                let (ra, rb) = (RefTable::from(a), RefTable::from(b));
                let sum = a.add(b);
                expect_same(&sum, &ra.add(&rb), "mixed add")?;
                if !sum.is_packed2() {
                    return Err("mixed add should land on the two-word tier".into());
                }
                expect_same(
                    &sum.subtract(a).map_err(|e| e.to_string())?,
                    &ra.add(&rb).subtract(&ra).map_err(|e| e.to_string())?,
                    "mixed subtract",
                )?;
                Ok(())
            },
        );
    }
}
