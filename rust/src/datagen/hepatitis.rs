//! Hepatitis (PKDD'02) analogue: 4 entity tables (Patient, Bio, Indis,
//! Flup), 3 relationships fanning out from Patient, ~12.9K tuples,
//! 19 attributes — a small database with a *dense* statistical space (the
//! paper's second-largest contingency table despite its tuple count).
//! Target: `sex(P)`.
//!
//! Planted structure: biopsy fibrosis tracks patient type; lab indicator
//! bands track patient age; follow-up duration tracks activity.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_PATIENTS: usize = 960;
const BASE_BIO: usize = 820;
const BASE_INDIS: usize = 4_600;
const BASE_FLUP: usize = 200;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("hepatitis");
    let p = b.population("Patient");
    b.attr(p, "sex", &["f", "m"]);
    b.attr(p, "age_band", &["under40", "40to60", "over60"]);
    b.attr(p, "type", &["B", "C"]);
    b.attr(p, "activity", &["low", "high"]);
    let bio = b.population("Bio");
    b.attr(bio, "fibros", &["f0", "f1", "f2plus"]);
    b.attr(bio, "activ", &["a0", "a1", "a2plus"]);
    b.attr(bio, "got", &["normal", "high"]);
    b.attr(bio, "gpt", &["normal", "high"]);
    let indis = b.population("Indis");
    b.attr(indis, "dbil", &["normal", "high"]);
    b.attr(indis, "alb", &["low", "normal"]);
    b.attr(indis, "che", &["low", "mid", "high"]);
    b.attr(indis, "tbil", &["low", "mid", "high"]);
    let f = b.population("Flup");
    b.attr(f, "duration", &["short", "mid", "long"]);
    b.attr(f, "outcome", &["stable", "progressed"]);
    let hasbio = b.relationship("HasBio", p, bio);
    b.rel_attr(hasbio, "when", &["early", "mid", "late"]);
    b.rel_attr(hasbio, "seq", &["first", "repeat"]);
    let hasindis = b.relationship("HasIndis", p, indis);
    b.rel_attr(hasindis, "freq", &["once", "recurrent"]);
    let hasflup = b.relationship("HasFlup", p, f);
    b.rel_attr(hasflup, "ab_type", &["igg", "igm"]);
    b.rel_attr(hasflup, "resolved", &["no", "yes"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_pat = ctx.n(BASE_PATIENTS);
    let n_bio = ctx.n(BASE_BIO);
    let n_ind = ctx.n(BASE_INDIS);
    let n_flup = ctx.n(BASE_FLUP);

    for _ in 0..n_pat {
        let sex = if ctx.rng.chance(0.62) { 1 } else { 0 };
        let age = ctx.skewed(3, 0.4);
        let ptype = ctx.dep(sex, 2, 0.3);
        let activity = ctx.dep(age, 2, 0.35);
        b.add_entity(0, &[sex, age, ptype, activity]);
    }
    for _ in 0..n_bio {
        let fibros = ctx.skewed(3, 0.6);
        let activ = ctx.dep(fibros, 3, 0.5);
        let got = ctx.dep(activ, 2, 0.4);
        let gpt = ctx.dep(got, 2, 0.6);
        b.add_entity(1, &[fibros, activ, got, gpt]);
    }
    for _ in 0..n_ind {
        let dbil = ctx.uniform(2);
        let alb = ctx.dep(dbil, 2, 0.3);
        let che = ctx.skewed(3, 0.5);
        let tbil = ctx.dep(che, 3, 0.45);
        b.add_entity(2, &[dbil, alb, che, tbil]);
    }
    for _ in 0..n_flup {
        let duration = ctx.skewed(3, 0.5);
        let outcome = ctx.dep(duration, 2, 0.4);
        b.add_entity(3, &[duration, outcome]);
    }

    // Each exam record belongs to one patient; patients with type C get
    // biopsies more often (existence correlation with a patient attribute).
    for bio in 0..n_bio as u32 {
        let mut pat = ctx.rng.below(n_pat as u64) as u32;
        for _ in 0..4 {
            if b.peek_entity_attr(0, 2, pat) == 1 {
                break; // prefer type C
            }
            pat = ctx.rng.below(n_pat as u64) as u32;
        }
        let when = ctx.skewed(3, 0.4);
        let seq = ctx.dep(when, 2, 0.3);
        b.add_rel(0, pat, bio, &[when, seq]);
    }
    for ind in 0..n_ind as u32 {
        let pat = (ctx.rng.f64().powf(1.3) * n_pat as f64) as u32 % n_pat as u32;
        let age = b.peek_entity_attr(0, 1, pat);
        let freq = ctx.dep(if age == 2 { 1 } else { 0 }, 2, 0.5);
        b.add_rel(1, pat, ind, &[freq]);
    }
    for f in 0..n_flup as u32 {
        let mut pat = ctx.rng.below(n_pat as u64) as u32;
        for _ in 0..4 {
            if b.peek_entity_attr(0, 3, pat) == 1 {
                break; // prefer high-activity patients
            }
            pat = ctx.rng.below(n_pat as u64) as u32;
        }
        let ab = ctx.uniform(2);
        let resolved = ctx.dep(ab, 2, 0.35);
        b.add_rel(2, pat, f, &[ab, resolved]);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_near_table2() {
        let db = generate(1.0, 7);
        let t = db.total_tuples() as f64;
        assert!((t - 12_927.0).abs() / 12_927.0 < 0.15, "tuples = {t}");
    }

    #[test]
    fn exams_fan_out_from_patient() {
        let db = generate(0.2, 7);
        // All three relationships share the Patient FO variable: the full
        // rel set is one connected chain.
        let comps = crate::lattice::components(&db.schema, &[0, 1, 2]);
        assert_eq!(comps.len(), 1);
    }

    #[test]
    fn biopsies_prefer_type_c() {
        let db = generate(1.0, 7);
        let hb = &db.rels[0];
        let mut c = 0u64;
        let mut bcount = 0u64;
        for &[pat, _] in &hb.pairs {
            if db.entity_attr(0, 2, pat) == 1 {
                c += 1;
            } else {
                bcount += 1;
            }
        }
        assert!(c > bcount);
    }
}
