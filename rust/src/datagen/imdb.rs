//! IMDB analogue: the largest and most complex benchmark — 4 entity tables
//! (User, Movie, Actor, Director), 3 relationships all sharing the Movie
//! variable (`Rated(U,M)`, `ActsIn(A,M)`, `Directs(D,M)`), ~1.35M tuples,
//! 17 attributes (paper Table 2: MovieLens 1M joined with IMDB following
//! Peralta 2007). Target: `avg_revenue(D)`.
//!
//! Planted structure: rating depends on user age and director quality;
//! high-quality directors work with high-quality actors; revenue tracks
//! director quality — the A2R dependencies the paper's IMDB BN finds.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_USERS: usize = 6_040;
const BASE_MOVIES: usize = 3_832;
const BASE_ACTORS: usize = 95_000;
const BASE_DIRECTORS: usize = 2_201;
const BASE_RATINGS: usize = 1_000_000;
const BASE_CASTS: usize = 243_000;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("imdb");
    let u = b.population("User");
    b.attr(u, "age", &["young", "mid", "old"]);
    b.attr(u, "gender", &["f", "m"]);
    b.attr(u, "occupation", &["tech", "edu", "other"]);
    let m = b.population("Movie");
    b.attr(m, "year", &["pre80", "80s90s", "recent"]);
    b.attr(m, "genre", &["drama", "comedy", "action", "horror"]);
    b.attr(m, "is_english", &["no", "yes"]);
    b.attr(m, "runtime", &["short", "mid", "long"]);
    let a = b.population("Actor");
    b.attr(a, "gender", &["f", "m"]);
    b.attr(a, "quality", &["low", "mid", "high"]);
    b.attr(a, "age", &["young", "mid", "old"]);
    let d = b.population("Director");
    b.attr(d, "quality", &["low", "mid", "high"]);
    b.attr(d, "avg_revenue", &["low", "mid", "high"]);
    b.attr(d, "experience", &["junior", "senior"]);
    let rated = b.relationship("Rated", u, m);
    b.rel_attr(rated, "rating", &["low", "mid", "high"]);
    let actsin = b.relationship("ActsIn", a, m);
    b.rel_attr(actsin, "position", &["lead", "support", "minor"]);
    b.rel_attr(actsin, "credited", &["no", "yes"]);
    let directs = b.relationship("Directs", d, m);
    b.rel_attr(directs, "first_credit", &["no", "yes"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_users = ctx.n(BASE_USERS);
    let n_movies = ctx.n(BASE_MOVIES);
    let n_actors = ctx.n(BASE_ACTORS);
    let n_dirs = ctx.n(BASE_DIRECTORS);

    for _ in 0..n_users {
        let age = ctx.skewed(3, 0.8);
        let gender = ctx.uniform(2);
        let occupation = ctx.dep(age, 3, 0.3);
        b.add_entity(0, &[age, gender, occupation]);
    }
    for _ in 0..n_movies {
        let year = ctx.skewed(3, 0.6);
        let genre = ctx.skewed(4, 0.7);
        let is_english = if ctx.rng.chance(0.8) { 1 } else { 0 };
        let runtime = ctx.dep(genre, 3, 0.3);
        b.add_entity(1, &[year, genre, is_english, runtime]);
    }
    for _ in 0..n_actors {
        let gender = ctx.uniform(2);
        let quality = ctx.skewed(3, 0.9);
        let age = ctx.skewed(3, 0.5);
        b.add_entity(2, &[gender, quality, age]);
    }
    for _ in 0..n_dirs {
        let quality = ctx.skewed(3, 0.8);
        let avg_revenue = ctx.dep(quality, 3, 0.65); // revenue tracks quality
        let experience = ctx.dep(quality / 2, 2, 0.4);
        b.add_entity(3, &[quality, avg_revenue, experience]);
    }

    // Directs: each movie has exactly one director; quality directors get
    // recent, English-language movies. Remember each movie's director
    // quality for the cast/rating correlations below.
    let mut movie_dir_quality = vec![0u16; n_movies];
    for m in 0..n_movies as u32 {
        let mut d = (ctx.rng.f64().powf(1.5) * n_dirs as f64) as u32 % n_dirs as u32;
        let year = b.peek_entity_attr(1, 0, m);
        if year == 2 {
            // Recent movies: retry toward high-quality directors.
            for _ in 0..3 {
                if b.peek_entity_attr(3, 0, d) == 2 {
                    break;
                }
                d = ctx.rng.below(n_dirs as u64) as u32;
            }
        }
        movie_dir_quality[m as usize] = b.peek_entity_attr(3, 0, d);
        let first = ctx.dep(b.peek_entity_attr(3, 2, d), 2, 0.4);
        b.add_rel(2, d, m, &[first]);
    }

    // ActsIn: casts skew toward popular movies; actor quality correlates
    // with the director's quality through shared movies.
    let n_casts = ctx.n(BASE_CASTS);
    let mut added = 0;
    let mut attempts = 0;
    while added < n_casts && attempts < n_casts * 10 {
        attempts += 1;
        let a = (ctx.rng.f64().powf(1.8) * n_actors as f64) as u32 % n_actors as u32;
        let m = (ctx.rng.f64().powf(1.5) * n_movies as f64) as u32 % n_movies as u32;
        let dq = movie_dir_quality[m as usize];
        let aq = b.peek_entity_attr(2, 1, a);
        let p = if dq == aq { 0.95 } else { 0.55 };
        if !ctx.rng.chance(p) {
            continue;
        }
        let position = ctx.dep(2 - aq.min(2), 3, 0.5);
        let credited = ctx.dep(if position == 0 { 1 } else { 0 }, 2, 0.6);
        if b.add_rel(1, a, m, &[position, credited]) {
            added += 1;
        }
    }

    // Rated: 1M ratings; value depends on user age and director quality.
    let n_ratings = ctx.n(BASE_RATINGS);
    let mut added = 0;
    let mut attempts = 0;
    while added < n_ratings && attempts < n_ratings * 8 {
        attempts += 1;
        let u = (ctx.rng.f64().powf(1.3) * n_users as f64) as u32 % n_users as u32;
        let m = (ctx.rng.f64().powf(1.9) * n_movies as f64) as u32 % n_movies as u32;
        let dq = movie_dir_quality[m as usize];
        let age = b.peek_entity_attr(0, 0, u);
        let base = if dq == 2 { 2 } else { ctx.dep(age, 3, 0.5) };
        let rating = ctx.dep(base, 3, 0.6);
        if b.add_rel(0, u, m, &[rating]) {
            added += 1;
        }
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_scale_shape() {
        let db = generate(0.005, 7);
        assert_eq!(db.schema.num_rel_vars(), 3);
        assert_eq!(db.schema.num_attributes(), 17);
        // All three relationships share Movie: single connected component.
        let comps = crate::lattice::components(&db.schema, &[0, 1, 2]);
        assert_eq!(comps.len(), 1);
    }

    #[test]
    fn every_movie_has_one_director() {
        let db = generate(0.02, 7);
        for m in 0..db.entity_counts[1] {
            assert_eq!(db.rels[2].tuples_by_second(m).len(), 1);
        }
    }

    #[test]
    fn revenue_tracks_quality() {
        let db = generate(0.05, 7);
        let mut same = 0u64;
        let mut diff = 0u64;
        for d in 0..db.entity_counts[3] {
            if db.entity_attr(3, 0, d) == db.entity_attr(3, 1, d) {
                same += 1;
            } else {
                diff += 1;
            }
        }
        assert!(same > diff);
    }
}
