//! UW-CSE analogue: the smallest benchmark (712 tuples) with **two
//! self-relationships** over Person (`AdvisedBy(P1,P2)`,
//! `TempAdvisedBy(P1,P2)`) plus an *isolated* Course entity table (its
//! attributes join the statistical space only through the cross product) —
//! 4 tables, 14 attributes. Target: `courseLevel(C)`.
//!
//! Entities are drawn from a small set of latent profiles so the observed
//! attribute-combination count stays low — that is what keeps the paper's
//! UW-CSE joint table at only ~2.8K statistics. The two advisor relations
//! almost never hold simultaneously (paper Table 4: only 2 link-off
//! statistics); we plant exactly two overlapping pairs.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_PERSONS: usize = 278;
const BASE_COURSES: usize = 132;
const BASE_ADVISED: usize = 113;
const BASE_TEMP: usize = 187;
const N_PERSON_PROFILES: usize = 10;
const N_COURSE_PROFILES: usize = 8;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("uwcse");
    let p = b.population("Person");
    b.attr(p, "position", &["faculty", "staff", "student"]);
    b.attr(p, "inphase", &["pre_quals", "post_quals", "post_generals", "n_a"]);
    b.attr(p, "years", &["y1", "y2to4", "y5plus"]);
    b.attr(p, "student", &["no", "yes"]);
    b.attr(p, "quals_done", &["no", "yes"]);
    b.attr(p, "area", &["systems", "theory", "ai"]);
    let c = b.population("Course");
    b.attr(c, "courseLevel", &["level100", "level400", "level500"]);
    b.attr(c, "area", &["systems", "theory", "ai"]);
    b.attr(c, "size", &["small", "large"]);
    b.attr(c, "eval", &["low", "high"]);
    let adv = b.relationship("AdvisedBy", p, p);
    b.rel_attr(adv, "strength", &["weak", "strong"]);
    b.rel_attr(adv, "co_paper", &["no", "yes"]);
    let tmp = b.relationship("TempAdvisedBy", p, p);
    b.rel_attr(tmp, "reason", &["rotation", "interim"]);
    b.rel_attr(tmp, "quarter", &["fall", "spring"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_p = ctx.n(BASE_PERSONS);
    let n_c = ctx.n(BASE_COURSES);

    // Latent-profile entity generation keeps observed combos ~= #profiles.
    for _ in 0..n_p {
        let prof = ctx.skewed(N_PERSON_PROFILES, 0.8) as u16;
        let student = if prof < 3 { 0u16 } else { 1 };
        let position = if student == 0 { ctx.dep(prof, 2, 0.9) } else { 2 };
        let inphase = if student == 0 { 3 } else { ctx.dep(prof, 3, 0.9) };
        let years = ctx.dep(prof, 3, 0.9);
        let quals = if inphase >= 1 && inphase < 3 { 1 } else { 0 };
        let area = ctx.dep(prof, 3, 0.9);
        b.add_entity(0, &[position, inphase, years, student, quals, area]);
    }
    for _ in 0..n_c {
        let prof = ctx.skewed(N_COURSE_PROFILES, 0.7) as u16;
        let level = ctx.dep(prof, 3, 0.9);
        let area = ctx.dep(prof, 3, 0.9);
        let size = ctx.dep(level, 2, 0.8);
        let eval = ctx.dep(prof, 2, 0.85);
        b.add_entity(1, &[level, area, size, eval]);
    }

    // AdvisedBy: student -> faculty, same research area preferred.
    let faculty: Vec<u32> =
        (0..n_p as u32).filter(|&e| b.peek_entity_attr(0, 3, e) == 0).collect();
    let students: Vec<u32> =
        (0..n_p as u32).filter(|&e| b.peek_entity_attr(0, 3, e) == 1).collect();
    if faculty.is_empty() || students.is_empty() {
        return b.finish();
    }
    let n_adv = ctx.n(BASE_ADVISED);
    let mut added = 0;
    let mut attempts = 0;
    let mut advised_pairs: Vec<(u32, u32)> = Vec::new();
    while added < n_adv && attempts < n_adv * 30 {
        attempts += 1;
        let s = students[ctx.rng.index(students.len())];
        let f = faculty[ctx.rng.index(faculty.len())];
        let same_area = b.peek_entity_attr(0, 5, s) == b.peek_entity_attr(0, 5, f);
        if !ctx.rng.chance(if same_area { 0.9 } else { 0.2 }) {
            continue;
        }
        let strength = ctx.dep(b.peek_entity_attr(0, 1, s), 2, 0.5);
        let co_paper = ctx.dep(strength, 2, 0.6);
        if b.add_rel(0, s, f, &[strength, co_paper]) {
            advised_pairs.push((s, f));
            added += 1;
        }
    }

    // TempAdvisedBy: early students get temporary advisors; overlap with
    // AdvisedBy planted at exactly two pairs (paper: 2 link-off stats).
    let n_tmp = ctx.n(BASE_TEMP);
    let mut added = 0;
    let mut attempts = 0;
    for &(s, f) in advised_pairs.iter().take(2) {
        if b.add_rel(1, s, f, &[0, 0]) {
            added += 1;
        }
    }
    while added < n_tmp && attempts < n_tmp * 30 {
        attempts += 1;
        let s = students[ctx.rng.index(students.len())];
        let f = faculty[ctx.rng.index(faculty.len())];
        if b.has_rel(0, s, f) {
            continue; // keep the planted overlap exact
        }
        let early = b.peek_entity_attr(0, 1, s) == 0;
        if !ctx.rng.chance(if early { 0.85 } else { 0.25 }) {
            continue;
        }
        let reason = ctx.dep(b.peek_entity_attr(0, 2, s), 2, 0.5);
        let quarter = ctx.uniform(2);
        if b.add_rel(1, s, f, &[reason, quarter]) {
            added += 1;
        }
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_near_table2() {
        let db = generate(1.0, 7);
        let t = db.total_tuples() as f64;
        assert!((t - 712.0).abs() / 712.0 < 0.15, "tuples = {t}");
        assert_eq!(db.schema.num_self_rels(), 2);
    }

    #[test]
    fn two_rels_share_person_vars() {
        let s = schema();
        assert_eq!(s.relationships[0].fo_vars, s.relationships[1].fo_vars);
        // Course participates in no relationship.
        let covered = s.fo_vars_of_rels(&[0, 1]);
        let course_fo = s.populations[1].fo_vars[0];
        assert!(!covered.contains(&course_fo));
    }

    #[test]
    fn overlap_is_exactly_two() {
        let db = generate(1.0, 7);
        let adv: std::collections::HashSet<(u32, u32)> =
            db.rels[0].pairs.iter().map(|p| (p[0], p[1])).collect();
        let overlap = db.rels[1].pairs.iter().filter(|p| adv.contains(&(p[0], p[1]))).count();
        assert_eq!(overlap, 2);
    }

    #[test]
    fn person_combos_stay_small() {
        let db = generate(1.0, 7);
        let ct = db.ct_entity(db.schema.populations[0].fo_vars[0]);
        assert!(ct.len() <= 40, "observed {} person combos", ct.len());
    }
}
