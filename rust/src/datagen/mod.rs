//! Synthetic benchmark databases mirroring the paper's seven real-world
//! datasets (Table 2).
//!
//! The original benchmark databases (MovieLens, Mutagenesis, Financial,
//! Hepatitis, IMDB, Mondial, UW-CSE) are not redistributable here, so each
//! generator reproduces the *schema shape* that drives the Möbius Join's
//! behaviour — number of relationship tables, self-relationships, attribute
//! counts and arities, entity/tuple counts at `scale = 1.0` — plus
//! attribute↔relationship correlations so the statistical applications
//! (feature selection, rule mining, BN learning) have real structure to
//! find. See DESIGN.md §2 for the substitution argument.
//!
//! All generation is deterministic in `(scale, seed)`.

mod movielens;
mod mutagenesis;
mod financial;
mod hepatitis;
mod imdb;
mod mondial;
mod uwcse;

use crate::bail;
use crate::db::Database;
use crate::schema::Schema;
use crate::util::error::Result;
use crate::util::Pcg64;

/// Static description of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchmarkInfo {
    pub name: &'static str,
    /// Display name of the classification target variable (paper Table 5).
    pub target: &'static str,
    /// Paper Table 2 reference values at scale 1.0 (for reporting).
    pub paper_tuples: u64,
    pub paper_statistics: u64,
}

/// The seven benchmarks, in the paper's Table 2 order.
pub const BENCHMARKS: [BenchmarkInfo; 7] = [
    BenchmarkInfo {
        name: "movielens",
        target: "horror(M)",
        paper_tuples: 1_010_051,
        paper_statistics: 252,
    },
    BenchmarkInfo {
        name: "mutagenesis",
        target: "inda(M)",
        paper_tuples: 14_540,
        paper_statistics: 1_631,
    },
    BenchmarkInfo {
        name: "financial",
        target: "balance(T)",
        paper_tuples: 225_932,
        paper_statistics: 3_013_011,
    },
    BenchmarkInfo {
        name: "hepatitis",
        target: "sex(P)",
        paper_tuples: 12_927,
        paper_statistics: 12_374_892,
    },
    BenchmarkInfo {
        name: "imdb",
        target: "avg_revenue(D)",
        paper_tuples: 1_354_134,
        paper_statistics: 15_538_430,
    },
    BenchmarkInfo {
        name: "mondial",
        target: "percentage(C1)",
        paper_tuples: 870,
        paper_statistics: 1_746_870,
    },
    BenchmarkInfo {
        name: "uwcse",
        target: "courseLevel(C)",
        paper_tuples: 712,
        paper_statistics: 2_828,
    },
];

/// Look up a benchmark by name.
pub fn info(name: &str) -> Option<&'static BenchmarkInfo> {
    BENCHMARKS.iter().find(|b| b.name == name)
}

/// The schema of a named benchmark.
pub fn schema_of(name: &str) -> Result<Schema> {
    Ok(match name {
        "movielens" => movielens::schema(),
        "mutagenesis" => mutagenesis::schema(),
        "financial" => financial::schema(),
        "hepatitis" => hepatitis::schema(),
        "imdb" => imdb::schema(),
        "mondial" => mondial::schema(),
        "uwcse" => uwcse::schema(),
        other => bail!("unknown benchmark `{other}`"),
    })
}

/// Generate a benchmark database instance. `scale` multiplies entity and
/// tuple counts (1.0 reproduces the paper's Table 2 sizes); `seed` makes
/// runs reproducible.
pub fn generate(name: &str, scale: f64, seed: u64) -> Result<Database> {
    assert!(scale > 0.0, "scale must be positive");
    Ok(match name {
        "movielens" => movielens::generate(scale, seed),
        "mutagenesis" => mutagenesis::generate(scale, seed),
        "financial" => financial::generate(scale, seed),
        "hepatitis" => hepatitis::generate(scale, seed),
        "imdb" => imdb::generate(scale, seed),
        "mondial" => mondial::generate(scale, seed),
        "uwcse" => uwcse::generate(scale, seed),
        other => bail!("unknown benchmark `{other}`"),
    })
}

// ---------- shared generation helpers ----------

/// Generation context: RNG + scale.
pub(crate) struct GenCtx {
    pub rng: Pcg64,
    pub scale: f64,
}

impl GenCtx {
    pub fn new(scale: f64, seed: u64) -> Self {
        GenCtx { rng: Pcg64::seeded(seed ^ 0x5EED_DA7A), scale }
    }

    /// Scaled count with a floor of 2 (populations must be non-trivial).
    pub fn n(&self, base: usize) -> usize {
        ((base as f64 * self.scale).round() as usize).max(2)
    }

    /// Draw a code in `[0, arity)` biased toward a parent code: with
    /// probability `strength`, return a value deterministically derived
    /// from `parent`; otherwise uniform. This plants detectable mutual
    /// information between attributes (and between attributes and
    /// relationship existence) for the statistical applications.
    pub fn dep(&mut self, parent: u16, arity: usize, strength: f64) -> u16 {
        if self.rng.chance(strength) {
            (parent as usize % arity) as u16
        } else {
            self.rng.below(arity as u64) as u16
        }
    }

    /// Zipf-skewed code (realistic category imbalance).
    pub fn skewed(&mut self, arity: usize, s: f64) -> u16 {
        self.rng.zipf(arity, s) as u16
    }

    /// Uniform code.
    pub fn uniform(&mut self, arity: usize) -> u16 {
        self.rng.below(arity as u64) as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_generate_at_tiny_scale() {
        for b in BENCHMARKS {
            let db = generate(b.name, 0.01, 7).unwrap();
            assert!(db.total_tuples() > 0, "{} generated empty db", b.name);
            // Every relationship key must be in range (DatabaseBuilder
            // asserts this at insert; reaching here means it held).
            let s = &db.schema;
            assert_eq!(s.name, b.name);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate("mutagenesis", 0.05, 42).unwrap();
        let b = generate("mutagenesis", 0.05, 42).unwrap();
        assert_eq!(a.total_tuples(), b.total_tuples());
        assert_eq!(a.rels[0].pairs, b.rels[0].pairs);
        assert_eq!(a.entity_attrs, b.entity_attrs);
        let c = generate("mutagenesis", 0.05, 43).unwrap();
        assert_ne!(a.rels[0].pairs, c.rels[0].pairs);
    }

    #[test]
    fn table2_shapes_match_paper() {
        // (#rel tables, #total tables, #self rels, #attributes) per Table 2.
        let expect = [
            ("movielens", 1, 3, 0, 7),
            ("mutagenesis", 2, 4, 0, 11),
            ("financial", 3, 7, 0, 15),
            ("hepatitis", 3, 7, 0, 19),
            ("imdb", 3, 7, 0, 17),
            ("mondial", 2, 4, 1, 18),
            ("uwcse", 2, 4, 2, 14),
        ];
        for (name, rels, total, selfs, attrs) in expect {
            let s = schema_of(name).unwrap();
            assert_eq!(s.num_rel_vars(), rels, "{name} #rels");
            assert_eq!(s.num_tables(), total, "{name} #tables");
            assert_eq!(s.num_self_rels(), selfs, "{name} #self-rels");
            assert_eq!(s.num_attributes(), attrs, "{name} #attributes");
        }
    }

    #[test]
    fn scale_one_tuple_counts_near_paper() {
        // Allow 20% deviation from Table 2 (generators are calibrated, not
        // exact — duplicates rejected during pair sampling etc.).
        for b in ["mutagenesis", "mondial", "uwcse", "hepatitis"] {
            let info = info(b).unwrap();
            let db = generate(b, 1.0, 7).unwrap();
            let got = db.total_tuples() as f64;
            let want = info.paper_tuples as f64;
            assert!(
                (got - want).abs() / want < 0.2,
                "{b}: {got} tuples vs paper {want}"
            );
        }
    }

    #[test]
    fn targets_resolve_to_variables() {
        for b in BENCHMARKS {
            let s = schema_of(b.name).unwrap();
            assert!(
                s.var_by_name(b.target).is_some(),
                "{}: target {} not found; vars: {:?}",
                b.name,
                b.target,
                (0..s.random_vars.len()).map(|v| s.var_name(v)).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn unknown_benchmark_errors() {
        assert!(generate("nope", 1.0, 1).is_err());
        assert!(schema_of("nope").is_err());
    }

    #[test]
    fn mondial_all_true_join_is_empty() {
        // Paper §6.3.1: Mondial has no case where all relationship variables
        // are simultaneously true (our generator engineers this).
        let db = generate("mondial", 0.5, 11).unwrap();
        let jc = crate::db::JoinCounter::new(&db);
        let all: Vec<usize> = (0..db.schema.num_rel_vars()).collect();
        let ct = jc.positive_ct(&all);
        assert!(ct.is_empty());
    }

    #[test]
    fn uwcse_link_off_is_tiny() {
        // Paper Table 4: UW-CSE has only 2 link-off statistics — advisedBy
        // and tempAdvisedBy almost never hold simultaneously.
        let db = generate("uwcse", 1.0, 7).unwrap();
        let jc = crate::db::JoinCounter::new(&db);
        let ct = jc.positive_ct(&[0, 1]);
        assert!(ct.len() <= 8, "got {} link-off stats", ct.len());
    }
}
