//! Mondial analogue: tiny database (870 tuples), huge statistical space —
//! 2 entity tables (Country, Religion), a **self-relationship**
//! `Borders(C1,C2)` plus `HasReligion(C1,R)`, 18 attributes. Because the
//! Country population is instantiated with two FO variables, its attributes
//! appear twice in the joint table, which is why this 870-tuple database
//! yields ~1.7M sufficient statistics with a compression ratio near 1
//! (paper Table 3: CP is actually *faster* here). Target: `percentage(C1)`.
//!
//! Faithful quirk (paper §6.3.1): there is **no case where all relationship
//! variables are simultaneously true** — we engineer border-countries and
//! religion-countries to be disjoint on the shared FO variable, so the
//! link-analysis-off contingency table is empty.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_COUNTRIES: usize = 220;
const BASE_RELIGIONS: usize = 30;
const BASE_BORDERS: usize = 320;
const BASE_HASREL: usize = 300;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("mondial");
    let c = b.population("Country");
    b.attr(c, "continent", &["africa", "asia", "europe"]);
    b.attr(c, "government", &["republic", "monarchy", "other"]);
    b.attr(c, "pop_band", &["small", "mid", "large"]);
    b.attr(c, "gdp_band", &["low", "mid", "high"]);
    b.attr(c, "inflation", &["low", "high"]);
    b.attr(c, "percentage", &["minor", "split", "dominant"]);
    b.attr(c, "coastal", &["no", "yes"]);
    b.attr(c, "landlocked", &["no", "yes"]);
    b.attr(c, "organization", &["none", "some"]);
    b.attr(c, "climate", &["arid", "temperate"]);
    let r = b.population("Religion");
    b.attr(r, "kind", &["k1", "k2", "k3"]);
    b.attr(r, "age_band", &["ancient", "medieval"]);
    b.attr(r, "spread", &["regional", "global"]);
    b.attr(r, "size_band", &["small", "mid", "large"]);
    let borders = b.relationship("Borders", c, c);
    b.rel_attr(borders, "length", &["short", "long"]);
    b.rel_attr(borders, "water", &["no", "yes"]);
    let hasrel = b.relationship("HasReligion", c, r);
    b.rel_attr(hasrel, "share", &["low", "mid", "high"]);
    b.rel_attr(hasrel, "official", &["no", "yes"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_c = ctx.n(BASE_COUNTRIES);
    let n_r = ctx.n(BASE_RELIGIONS);
    for _ in 0..n_c {
        let continent = ctx.skewed(3, 0.4);
        let government = ctx.dep(continent, 3, 0.35);
        let pop = ctx.skewed(3, 0.6);
        let gdp = ctx.dep(continent, 3, 0.4);
        let inflation = ctx.dep(gdp, 2, 0.4);
        let percentage = ctx.dep(continent, 3, 0.45);
        let coastal = ctx.uniform(2);
        let landlocked = 1 - coastal; // consistent geography
        let organization = ctx.dep(gdp, 2, 0.5);
        let climate = ctx.dep(continent, 2, 0.5);
        b.add_entity(
            0,
            &[continent, government, pop, gdp, inflation, percentage, coastal, landlocked,
              organization, climate],
        );
    }
    for _ in 0..n_r {
        let kind = ctx.skewed(3, 0.5);
        let age = ctx.uniform(2);
        let spread = ctx.dep(kind, 2, 0.4);
        let size = ctx.skewed(3, 0.7);
        b.add_entity(1, &[kind, age, spread, size]);
    }

    // Split countries: the first `split` have borders, the rest have
    // religions — the shared FO variable C1 never satisfies both, so the
    // all-true join is empty (paper §6.3.1).
    let split = (n_c * 2) / 3;

    let n_borders = ctx.n(BASE_BORDERS);
    let mut added = 0;
    let mut attempts = 0;
    while added < n_borders && attempts < n_borders * 20 {
        attempts += 1;
        let a = ctx.rng.below(split as u64) as u32;
        let c2 = ctx.rng.below(n_c as u64) as u32;
        if a == c2 {
            continue;
        }
        // Countries on the same continent border far more often.
        let same = b.peek_entity_attr(0, 0, a) == b.peek_entity_attr(0, 0, c2);
        if !ctx.rng.chance(if same { 0.9 } else { 0.15 }) {
            continue;
        }
        let length = ctx.dep(b.peek_entity_attr(0, 2, a), 2, 0.4);
        let water = ctx.dep(b.peek_entity_attr(0, 6, a), 2, 0.6);
        if b.add_rel(0, a, c2, &[length, water]) {
            added += 1;
        }
    }

    let n_hasrel = ctx.n(BASE_HASREL);
    let mut added = 0;
    let mut attempts = 0;
    while added < n_hasrel && attempts < n_hasrel * 20 {
        attempts += 1;
        if split >= n_c {
            break;
        }
        let c = split as u32 + ctx.rng.below((n_c - split) as u64) as u32;
        let r = ctx.rng.below(n_r as u64) as u32;
        let share = ctx.dep(b.peek_entity_attr(0, 5, c), 3, 0.6); // tracks percentage
        let official = ctx.dep(b.peek_entity_attr(0, 1, c), 2, 0.4);
        if b.add_rel(1, c, r, &[share, official]) {
            added += 1;
        }
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_exact_table2_shape() {
        let db = generate(1.0, 7);
        let t = db.total_tuples() as f64;
        assert!((t - 870.0).abs() / 870.0 < 0.15, "tuples = {t}");
        assert_eq!(db.schema.num_self_rels(), 1);
    }

    #[test]
    fn border_and_religion_countries_disjoint() {
        let db = generate(1.0, 7);
        let borders_first: std::collections::HashSet<u32> =
            db.rels[0].pairs.iter().map(|p| p[0]).collect();
        let rel_first: std::collections::HashSet<u32> =
            db.rels[1].pairs.iter().map(|p| p[0]).collect();
        assert!(borders_first.is_disjoint(&rel_first));
    }

    #[test]
    fn self_rel_uses_two_fo_vars() {
        let s = schema();
        let r = &s.relationships[0];
        assert!(r.is_self());
        assert_ne!(r.fo_vars[0], r.fo_vars[1]);
        // HasReligion binds the same C1 that Borders binds first.
        assert_eq!(s.relationships[1].fo_vars[0], r.fo_vars[0]);
    }
}
