//! MovieLens analogue: the simplest benchmark — 2 entity tables, 1
//! relationship, ~1.01M tuples at scale 1.0 (paper Table 2).
//!
//! Schema: `User(age, gender, occupation)`, `Movie(horror, year, drama)`,
//! `Rated(U, M)` with 2Att `rating`. Target for feature selection:
//! `horror(M)`.
//!
//! Planted structure: young users rate horror movies more often (existence
//! correlation) and the rating value depends on user age and movie genre
//! (2Att correlation) — mirroring the real MovieLens signal the paper mines.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_USERS: usize = 6_040;
const BASE_MOVIES: usize = 3_883;
const BASE_RATINGS: usize = 1_000_000;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("movielens");
    let u = b.population("User");
    b.attr(u, "age", &["young", "mid", "old"]);
    b.attr(u, "gender", &["f", "m"]);
    b.attr(u, "occupation", &["tech", "edu", "arts", "admin", "other"]);
    let m = b.population("Movie");
    b.attr(m, "horror", &["no", "yes"]);
    b.attr(m, "year", &["pre80", "80s90s", "recent"]);
    b.attr(m, "drama", &["no", "yes"]);
    let rated = b.relationship("Rated", u, m);
    b.rel_attr(rated, "rating", &["low", "mid", "high"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_users = ctx.n(BASE_USERS);
    let n_movies = ctx.n(BASE_MOVIES);
    for _ in 0..n_users {
        let age = ctx.skewed(3, 0.8);
        let gender = ctx.uniform(2);
        let occupation = ctx.dep(age, 5, 0.3);
        b.add_entity(0, &[age, gender, occupation]);
    }
    for _ in 0..n_movies {
        let horror = if ctx.rng.chance(0.18) { 1 } else { 0 };
        let year = ctx.skewed(3, 0.6);
        let drama = ctx.dep(1 - horror, 2, 0.55);
        b.add_entity(1, &[horror, year, drama]);
    }

    // Ratings: power-law popularity on movies, mild skew on users; horror
    // movies preferentially rated by young users.
    let n_ratings = ctx.n(BASE_RATINGS);
    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < n_ratings && attempts < n_ratings * 12 {
        attempts += 1;
        let u = (ctx.rng.f64().powf(1.4) * n_users as f64) as u32 % n_users as u32;
        let m = (ctx.rng.f64().powf(2.0) * n_movies as f64) as u32 % n_movies as u32;
        let age = b_entity_attr(&b, 0, 0, u);
        let horror = b_entity_attr(&b, 1, 0, m);
        // Existence correlation: young x horror boosted, old x horror damped.
        let p = match (age, horror) {
            (0, 1) => 1.0,
            (2, 1) => 0.25,
            _ => 0.75,
        };
        if !ctx.rng.chance(p) {
            continue;
        }
        // Rating value: horror lovers (young) rate horror high; drama + old
        // rate high; otherwise noisy mid.
        let drama = b_entity_attr(&b, 1, 2, m);
        let base = if horror == 1 {
            if age == 0 {
                2
            } else {
                0
            }
        } else if drama == 1 && age == 2 {
            2
        } else {
            1
        };
        let rating = ctx.dep(base, 3, 0.65);
        if b.add_rel(0, u, m, &[rating]) {
            added += 1;
        }
    }
    b.finish()
}

/// Peek at an already-inserted entity attribute during generation.
pub(crate) fn b_entity_attr(b: &DatabaseBuilder, pop: usize, attr_idx: usize, e: u32) -> u16 {
    b.peek_entity_attr(pop, attr_idx, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_tuples_near_paper() {
        let db = generate(0.02, 3);
        // 2% scale: ~121 users, ~78 movies, ~20k ratings target (capped by
        // pair space 121*78=9438, so fewer; just sanity-check shape).
        assert_eq!(db.schema.num_rel_vars(), 1);
        assert!(db.total_tuples() > 500);
    }

    #[test]
    fn horror_rating_correlation_planted() {
        let db = generate(0.05, 3);
        // Young users' horror ratings skew high vs old users' horror ratings.
        let rated = &db.rels[0];
        let (mut young_high, mut young_all, mut old_high, mut old_all) = (0f64, 0f64, 0f64, 0f64);
        for (t, &[u, m]) in rated.pairs.iter().enumerate() {
            if db.entity_attr(1, 0, m) != 1 {
                continue; // horror only
            }
            let age = db.entity_attr(0, 0, u);
            let high = (rated.attrs[0][t] == 2) as u64 as f64;
            if age == 0 {
                young_all += 1.0;
                young_high += high;
            } else if age == 2 {
                old_all += 1.0;
                old_high += high;
            }
        }
        assert!(young_all > 10.0 && old_all > 10.0);
        assert!(young_high / young_all > old_high / old_all + 0.2);
    }
}
