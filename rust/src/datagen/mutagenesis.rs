//! Mutagenesis analogue: 2 entity tables (Molecule, Atom), 2 relationships
//! (`Contains(M,A)`, `Methyl(M,A)`), ~14.5K tuples, 11 attributes
//! (paper Table 2). Target: `inda(M)`.
//!
//! Planted structure: an atom's element distribution depends on its
//! molecule's `inda` flag, and molecules with many methyl attachments skew
//! `ind1` — echoing the structure-activity signal of the real dataset.

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_MOLECULES: usize = 230;
const BASE_ATOMS: usize = 4_900;
const BASE_METHYL: usize = 4_400;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("mutagenesis");
    let m = b.population("Molecule");
    b.attr(m, "ind1", &["no", "yes"]);
    b.attr(m, "inda", &["no", "yes"]);
    b.attr(m, "logp", &["low", "mid", "high"]);
    b.attr(m, "lumo", &["low", "mid", "high"]);
    let a = b.population("Atom");
    b.attr(a, "element", &["c", "h", "o", "n", "other"]);
    b.attr(a, "atype", &["t1", "t2", "t3", "t4"]);
    b.attr(a, "charge", &["neg", "zero", "pos"]);
    b.attr(a, "hydro", &["no", "yes"]);
    let contains = b.relationship("Contains", m, a);
    b.rel_attr(contains, "btype", &["single", "double", "aromatic"]);
    b.rel_attr(contains, "strand", &["main", "side"]);
    let methyl = b.relationship("Methyl", m, a);
    b.rel_attr(methyl, "orient", &["ortho", "meta", "para"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_mol = ctx.n(BASE_MOLECULES);
    let n_atom = ctx.n(BASE_ATOMS);
    for _ in 0..n_mol {
        let inda = if ctx.rng.chance(0.4) { 1 } else { 0 };
        let ind1 = ctx.dep(inda, 2, 0.5);
        let logp = ctx.dep(inda * 2, 3, 0.4);
        let lumo = ctx.skewed(3, 0.7);
        b.add_entity(0, &[ind1, inda, logp, lumo]);
    }
    for _ in 0..n_atom {
        // Assign each atom to a home molecule up front so its attributes can
        // correlate with the molecule's activity.
        let element = ctx.skewed(5, 1.0);
        let atype = ctx.dep(element, 4, 0.5);
        let charge = ctx.uniform(3);
        let hydro = if element == 1 { 1 } else { ctx.dep(0, 2, 0.7) };
        b.add_entity(1, &[element, atype, charge, hydro]);
    }

    // Contains: each atom belongs to one molecule (functional relationship),
    // molecule chosen with skew; bond type depends on molecule's inda.
    for atom in 0..n_atom as u32 {
        let mol = (ctx.rng.f64().powf(1.2) * n_mol as f64) as u32 % n_mol as u32;
        let inda = b.peek_entity_attr(0, 1, mol);
        let btype = ctx.dep(inda * 2, 3, 0.5);
        let strand = ctx.dep(inda, 2, 0.45);
        b.add_rel(0, mol, atom, &[btype, strand]);
    }

    // Methyl attachments: biased toward active (inda = yes) molecules and
    // carbon atoms.
    let n_methyl = ctx.n(BASE_METHYL);
    let mut added = 0;
    let mut attempts = 0;
    while added < n_methyl && attempts < n_methyl * 15 {
        attempts += 1;
        let mol = ctx.rng.below(n_mol as u64) as u32;
        let atom = ctx.rng.below(n_atom as u64) as u32;
        let inda = b.peek_entity_attr(0, 1, mol);
        let element = b.peek_entity_attr(1, 0, atom);
        let p = if inda == 1 { 0.9 } else { 0.35 } * if element == 0 { 1.0 } else { 0.55 };
        if !ctx.rng.chance(p) {
            continue;
        }
        let orient = ctx.dep(element, 3, 0.4);
        if b.add_rel(1, mol, atom, &[orient]) {
            added += 1;
        }
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_near_table2() {
        let db = generate(1.0, 7);
        let t = db.total_tuples();
        assert!((t as i64 - 14_540).unsigned_abs() < 1_500, "tuples = {t}");
    }

    #[test]
    fn contains_is_functional_per_atom() {
        let db = generate(0.1, 7);
        let contains = &db.rels[0];
        for atom in 0..db.entity_counts[1] {
            assert_eq!(contains.tuples_by_second(atom).len(), 1);
        }
    }

    #[test]
    fn methyl_prefers_active_molecules() {
        let db = generate(0.5, 7);
        let methyl = &db.rels[1];
        let mut active = 0u64;
        let mut inactive = 0u64;
        for &[m, _] in &methyl.pairs {
            if db.entity_attr(0, 1, m) == 1 {
                active += 1;
            } else {
                inactive += 1;
            }
        }
        assert!(active > inactive);
    }
}
