//! Financial (PKDD'99) analogue: 4 entity tables (Account, Client, Loan,
//! Trans), 3 relationships (`HasLoan(A,L)`, `Disp(C,A)`, `HasTrans(A,T)`),
//! ~220K tuples, 15 attributes. Target: `balance(T)`.
//!
//! Planted structure: loan status depends on the account's statement
//! frequency; transaction balance bands depend on account frequency and
//! client wealth — the cross-table dependencies the paper's Table 8 BN
//! discovers (link analysis on finds a superior model here).

use super::GenCtx;
use crate::db::{Database, DatabaseBuilder};
use crate::schema::{Schema, SchemaBuilder};
use std::sync::Arc;

const BASE_ACCOUNTS: usize = 4_500;
const BASE_CLIENTS: usize = 5_369;
const BASE_LOANS: usize = 682;
const BASE_TRANS: usize = 104_000;

pub fn schema() -> Schema {
    let mut b = SchemaBuilder::new("financial");
    let a = b.population("Account");
    b.attr(a, "statement_freq", &["monthly", "weekly", "after_trans"]);
    b.attr(a, "region", &["urban", "suburban", "rural"]);
    let c = b.population("Client");
    b.attr(c, "gender", &["f", "m"]);
    b.attr(c, "age_band", &["young", "mid", "senior"]);
    b.attr(c, "wealth", &["low", "mid", "high"]);
    let l = b.population("Loan");
    b.attr(l, "amount", &["small", "mid", "large"]);
    b.attr(l, "duration", &["short", "mid", "long"]);
    b.attr(l, "status", &["ok", "default"]);
    let t = b.population("Trans");
    b.attr(t, "type", &["credit", "withdrawal", "transfer"]);
    b.attr(t, "op", &["cash", "card", "remittance"]);
    b.attr(t, "amount", &["small", "mid", "large"]);
    b.attr(t, "balance", &["low", "mid", "high"]);
    let hasloan = b.relationship("HasLoan", a, l);
    b.rel_attr(hasloan, "payments", &["few", "some", "many"]);
    let disp = b.relationship("Disp", c, a);
    b.rel_attr(disp, "disp_type", &["owner", "user"]);
    let hastrans = b.relationship("HasTrans", a, t);
    b.rel_attr(hastrans, "channel", &["branch", "online"]);
    b.finish()
}

pub fn generate(scale: f64, seed: u64) -> Database {
    let schema = Arc::new(schema());
    let mut ctx = GenCtx::new(scale, seed);
    let mut b = DatabaseBuilder::new(schema.clone());

    let n_acc = ctx.n(BASE_ACCOUNTS);
    let n_cli = ctx.n(BASE_CLIENTS);
    let n_loan = ctx.n(BASE_LOANS);
    let n_trans = ctx.n(BASE_TRANS);

    for _ in 0..n_acc {
        let freq = ctx.skewed(3, 1.1);
        let region = ctx.uniform(3);
        b.add_entity(0, &[freq, region]);
    }
    for _ in 0..n_cli {
        let gender = ctx.uniform(2);
        let age = ctx.skewed(3, 0.5);
        let wealth = ctx.dep(age, 3, 0.4);
        b.add_entity(1, &[gender, age, wealth]);
    }
    for _ in 0..n_loan {
        let amount = ctx.skewed(3, 0.8);
        let duration = ctx.dep(amount, 3, 0.5);
        let status = ctx.uniform(2); // refined below via HasLoan
        b.add_entity(2, &[amount, duration, status]);
    }
    // Transactions are created together with their HasTrans edge so the
    // `balance` band can depend on the owning account's statement frequency
    // — a *cross-table* dependency that only link analysis can surface
    // (the paper's Table 5/8 financial findings).

    // HasLoan: each loan belongs to one account; payments band depends on
    // the account's statement frequency (monthly accounts pay more often).
    for loan in 0..n_loan as u32 {
        let acc = ctx.rng.below(n_acc as u64) as u32;
        let freq = b.peek_entity_attr(0, 0, acc);
        let payments = ctx.dep(2 - freq.min(2), 3, 0.55);
        b.add_rel(0, acc, loan, &[payments]);
    }

    // Disp: each client holds 1-2 accounts (owner first).
    for cli in 0..n_cli as u32 {
        let acc = ctx.rng.below(n_acc as u64) as u32;
        b.add_rel(1, cli, acc, &[0]);
        if ctx.rng.chance(0.18) {
            let acc2 = ctx.rng.below(n_acc as u64) as u32;
            b.add_rel(1, cli, acc2, &[1]);
        }
    }

    // HasTrans: each transaction belongs to one account, skewed toward
    // active accounts; channel depends on region; balance depends on the
    // account's statement frequency (cross-table signal).
    for _ in 0..n_trans {
        let acc = (ctx.rng.f64().powf(1.6) * n_acc as f64) as u32 % n_acc as u32;
        let freq = b.peek_entity_attr(0, 0, acc);
        let region = b.peek_entity_attr(0, 1, acc);
        let ttype = ctx.skewed(3, 0.9);
        let op = ctx.dep(ttype, 3, 0.45);
        let amount = ctx.skewed(3, 0.7);
        let balance = ctx.dep(2 - freq.min(2), 3, 0.6);
        let t = b.add_entity(3, &[ttype, op, amount, balance]);
        let channel = ctx.dep(if region == 0 { 1 } else { 0 }, 2, 0.5);
        b.add_rel(2, acc, t, &[channel]);
    }

    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale1_near_table2() {
        let db = generate(1.0, 7);
        let t = db.total_tuples() as f64;
        assert!((t - 225_932.0).abs() / 225_932.0 < 0.1, "tuples = {t}");
    }

    #[test]
    fn every_loan_has_account() {
        let db = generate(0.1, 7);
        for loan in 0..db.entity_counts[2] {
            assert_eq!(db.rels[0].tuples_by_second(loan).len(), 1);
        }
    }

    #[test]
    fn payments_correlate_with_freq() {
        let db = generate(1.0, 7);
        let hl = &db.rels[0];
        let mut freq0_many = 0u64;
        let mut freq0_all = 0u64;
        let mut freq2_many = 0u64;
        let mut freq2_all = 0u64;
        for (t, &[acc, _]) in hl.pairs.iter().enumerate() {
            let f = db.entity_attr(0, 0, acc);
            let many = (hl.attrs[0][t] == 2) as u64;
            if f == 0 {
                freq0_all += 1;
                freq0_many += many;
            } else if f == 2 {
                freq2_all += 1;
                freq2_many += many;
            }
        }
        assert!(freq0_all > 0 && freq2_all > 0);
        assert!(
            freq0_many as f64 / freq0_all as f64 > freq2_many as f64 / freq2_all as f64
        );
    }
}
