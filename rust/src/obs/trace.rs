//! Structured span tracing with per-thread, lock-free recording.
//!
//! A *trace* is the tree of named spans one request passes through:
//! protocol parse, query planning, FO-group factorization, table
//! loads vs. cache hits, ADtree builds and probes, Möbius subtraction,
//! response render. The worker that executes a request [`begin`]s a
//! trace on its own thread; every instrumented site between `begin`
//! and [`end`] records into that thread-local trace — no locks, no
//! channels, no allocation unless a span actually records.
//!
//! Cost discipline (the overhead gate in CI holds the serving stack to
//! this): when **no** trace is active anywhere in the process, a span
//! site costs exactly one relaxed atomic load ([`enabled`]) and
//! returns a disarmed guard. Detail strings are built behind
//! closures ([`span_detailed`], [`event`]) so formatting work happens
//! only on the sampled path. Traces cap at [`MAX_SPANS`] spans;
//! overflow increments `dropped` instead of growing without bound.
//!
//! Spans are recorded when their guard drops (post-order); [`end`]
//! sorts by the entry sequence stamped at span open so consumers see
//! the tree in execution order, with nesting carried by `depth`.

use crate::serve::protocol::json_escape;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Hard cap on spans recorded per trace; past it, `Trace::dropped`
/// counts what was lost (a deep Möbius recursion over a big batch can
/// emit hundreds of table probes).
pub const MAX_SPANS: usize = 256;

/// Traces ever started (sampled + EXPLAIN-forced), for `METRICS`.
pub static TRACES_STARTED: AtomicU64 = AtomicU64::new(0);
/// Spans discarded by the [`MAX_SPANS`] cap, process-wide.
pub static SPANS_DROPPED: AtomicU64 = AtomicU64::new(0);

/// Number of traces currently active across all threads. The single
/// relaxed load every disarmed span site pays.
static ACTIVE_TRACES: AtomicU64 = AtomicU64::new(0);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// One recorded span: a named interval relative to the trace start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRec {
    /// Instrumentation-site name, e.g. `plan.fo_groups`.
    pub name: &'static str,
    /// Site-specific payload (table key, group count, …); empty when
    /// the site had nothing to add.
    pub detail: String,
    /// Nesting depth at entry (0 = top level).
    pub depth: u16,
    /// Microseconds from trace start to span entry.
    pub start_us: u64,
    /// Span duration in microseconds (0 for point events).
    pub dur_us: u64,
    /// Entry order within the trace. Spans record on guard *drop*
    /// (post-order) and `start_us` has only µs resolution, so this is
    /// what [`end`] sorts by to present execution order.
    seq: u64,
}

impl SpanRec {
    fn to_json(&self, out: &mut String) {
        out.push_str("{\"name\":\"");
        out.push_str(self.name);
        out.push('"');
        if !self.detail.is_empty() {
            out.push_str(",\"detail\":\"");
            out.push_str(&json_escape(&self.detail));
            out.push('"');
        }
        out.push_str(&format!(
            ",\"depth\":{},\"start_us\":{},\"dur_us\":{}}}",
            self.depth, self.start_us, self.dur_us
        ));
    }
}

/// A finished request trace, as published to the flight recorder and
/// rendered by `EXPLAIN` / `DUMP`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    /// Process-unique trace id (monotonic).
    pub id: u64,
    /// The query text the trace covers.
    pub query: String,
    /// `ok`, `error`, `panic`, or `deadline_exceeded`.
    pub outcome: &'static str,
    /// Wall microseconds from [`begin`] to [`end`].
    pub total_us: u64,
    /// Recorded spans, in entry (execution) order.
    pub spans: Vec<SpanRec>,
    /// Spans lost to the [`MAX_SPANS`] cap.
    pub dropped: u32,
    /// Resource accounting for the traced query, attached by the worker
    /// via [`set_cost`] before [`end`] (`None` for minimal traces and
    /// requests that executed before cost accounting armed).
    pub cost: Option<crate::obs::cost::QueryCost>,
}

impl Trace {
    /// A span-less trace for requests that were *not* sampled but hit
    /// an outcome the flight recorder must keep anyway (panic,
    /// deadline): the shape is on record even when the phases are not.
    pub fn minimal(query: &str, outcome: &'static str, total_us: u64) -> Trace {
        Trace {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            query: query.to_string(),
            outcome,
            total_us,
            spans: Vec::new(),
            dropped: 0,
            cost: None,
        }
    }

    /// One JSON object, every string routed through
    /// [`json_escape`](crate::serve::protocol::json_escape).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(96 + self.spans.len() * 72);
        out.push_str(&format!(
            "{{\"id\":{},\"query\":\"{}\",\"outcome\":\"{}\",\"total_us\":{},\"dropped\":{},",
            self.id,
            json_escape(&self.query),
            json_escape(self.outcome),
            self.total_us,
            self.dropped
        ));
        if let Some(c) = &self.cost {
            out.push_str(&format!("\"cost\":{},", c.to_json()));
        }
        out.push_str("\"spans\":[");
        for (i, sp) in self.spans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            sp.to_json(&mut out);
        }
        out.push_str("]}");
        out
    }
}

struct Active {
    trace: Trace,
    t0: Instant,
    depth: u16,
    next_seq: u64,
}

impl Active {
    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }
}

thread_local! {
    static ACTIVE: RefCell<Option<Active>> = const { RefCell::new(None) };
}

/// True when at least one thread has an active trace. The only cost a
/// span site pays on the untraced path.
#[inline]
pub fn enabled() -> bool {
    ACTIVE_TRACES.load(Ordering::Relaxed) > 0
}

/// Start a trace on the calling thread. A prior unfinished trace on
/// this thread (a bug upstream, not a supported nesting) is discarded.
pub fn begin(query: &str) {
    TRACES_STARTED.fetch_add(1, Ordering::Relaxed);
    let act = Active {
        trace: Trace {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            query: query.to_string(),
            outcome: "ok",
            total_us: 0,
            spans: Vec::new(),
            dropped: 0,
            cost: None,
        },
        t0: Instant::now(),
        depth: 0,
        next_seq: 0,
    };
    let prev = ACTIVE.with(|a| a.borrow_mut().replace(act));
    if prev.is_none() {
        ACTIVE_TRACES.fetch_add(1, Ordering::Relaxed);
    }
}

/// Finish the calling thread's trace, stamping the outcome and total
/// wall time. Returns `None` when no trace was active.
pub fn end(outcome: &'static str) -> Option<Trace> {
    let act = ACTIVE.with(|a| a.borrow_mut().take())?;
    ACTIVE_TRACES.fetch_sub(1, Ordering::Relaxed);
    let mut trace = act.trace;
    trace.outcome = outcome;
    trace.total_us = act.t0.elapsed().as_micros() as u64;
    // Guards record on drop (post-order); the entry sequence stamped
    // at span open restores execution order — `start_us` alone cannot,
    // since a parent and its children often share a microsecond.
    trace.spans.sort_by_key(|s| s.seq);
    Some(trace)
}

/// Attach the query's resource accounting to the calling thread's
/// active trace (no-op when none). The worker calls this with the
/// [`cost::take`](crate::obs::cost::take) result right after execution,
/// before [`end`] publishes the trace.
pub fn set_cost(cost: crate::obs::cost::QueryCost) {
    ACTIVE.with(|a| {
        if let Some(act) = a.borrow_mut().as_mut() {
            act.trace.cost = Some(cost);
        }
    });
}

/// RAII span: created at site entry, records its interval into the
/// thread's active trace when dropped. Disarmed (free) when the thread
/// has no active trace.
///
/// Independently of trace arming, every guard publishes its name to
/// the sampling profiler's per-thread span stack
/// ([`crate::obs::profile::push_frame`]) — a couple of relaxed stores
/// on profiler-registered threads, a thread-local load and branch
/// everywhere else — so `PROFILE` sees the live stack even on the
/// untraced fast path.
pub struct SpanGuard {
    name: &'static str,
    detail: String,
    depth: u16,
    start_us: u64,
    seq: u64,
    armed: bool,
    /// Whether this guard pushed a profiler frame (pop exactly once).
    published: bool,
}

impl SpanGuard {
    fn disarmed(name: &'static str, published: bool) -> SpanGuard {
        SpanGuard {
            name,
            detail: String::new(),
            depth: 0,
            start_us: 0,
            seq: 0,
            armed: false,
            published,
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.published {
            crate::obs::profile::pop_frame();
        }
        if !self.armed {
            return;
        }
        ACTIVE.with(|a| {
            if let Some(act) = a.borrow_mut().as_mut() {
                act.depth = act.depth.saturating_sub(1);
                let end_us = act.t0.elapsed().as_micros() as u64;
                push_span(
                    act,
                    SpanRec {
                        name: self.name,
                        detail: std::mem::take(&mut self.detail),
                        depth: self.depth,
                        start_us: self.start_us,
                        dur_us: end_us.saturating_sub(self.start_us),
                        seq: self.seq,
                    },
                );
            }
        });
    }
}

fn push_span(act: &mut Active, rec: SpanRec) {
    if act.trace.spans.len() < MAX_SPANS {
        act.trace.spans.push(rec);
    } else {
        act.trace.dropped += 1;
        SPANS_DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Open a span with no detail payload.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    let published = crate::obs::profile::push_frame(name);
    if !enabled() {
        return SpanGuard::disarmed(name, published);
    }
    span_armed(name, String::new, published)
}

/// Open a span whose detail is built only if the calling thread is
/// actually tracing — the closure never runs on the untraced path.
#[inline]
pub fn span_detailed<F: FnOnce() -> String>(name: &'static str, detail: F) -> SpanGuard {
    let published = crate::obs::profile::push_frame(name);
    if !enabled() {
        return SpanGuard::disarmed(name, published);
    }
    span_armed(name, detail, published)
}

fn span_armed<F: FnOnce() -> String>(
    name: &'static str,
    detail: F,
    published: bool,
) -> SpanGuard {
    ACTIVE.with(|a| {
        let mut b = a.borrow_mut();
        match b.as_mut() {
            Some(act) => {
                let depth = act.depth;
                act.depth += 1;
                SpanGuard {
                    name,
                    detail: detail(),
                    depth,
                    start_us: act.t0.elapsed().as_micros() as u64,
                    seq: act.take_seq(),
                    armed: true,
                    published,
                }
            }
            None => SpanGuard::disarmed(name, published),
        }
    })
}

/// Record a zero-duration point event (cache hit, coalesced wait) at
/// the current offset. The detail closure runs only when tracing.
#[inline]
pub fn event<F: FnOnce() -> String>(name: &'static str, detail: F) {
    if !enabled() {
        return;
    }
    ACTIVE.with(|a| {
        if let Some(act) = a.borrow_mut().as_mut() {
            let at = act.t0.elapsed().as_micros() as u64;
            let depth = act.depth;
            let seq = act.take_seq();
            push_span(act, SpanRec { name, detail: detail(), depth, start_us: at, dur_us: 0, seq });
        }
    });
}

/// Inject a span that happened *before* the trace began (the reactor
/// parses the request line before the worker starts the trace). It is
/// pinned at offset 0 with the externally measured duration.
pub fn event_us(name: &'static str, dur_us: u64) {
    ACTIVE.with(|a| {
        if let Some(act) = a.borrow_mut().as_mut() {
            let depth = act.depth;
            let seq = act.take_seq();
            push_span(act, SpanRec { name, detail: String::new(), depth, start_us: 0, dur_us, seq });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_sort_in_execution_order() {
        begin("q1");
        event_us("parse", 7);
        {
            let _plan = span("plan");
            {
                let _t = span_detailed("table.count", || "chain_0".to_string());
            }
            event("adtree.hit", || "chain_0".to_string());
        }
        let t = end("ok").expect("trace was active");
        assert_eq!(t.query, "q1");
        assert_eq!(t.outcome, "ok");
        let names: Vec<&str> = t.spans.iter().map(|s| s.name).collect();
        assert_eq!(names, ["parse", "plan", "table.count", "adtree.hit"]);
        let depths: Vec<u16> = t.spans.iter().map(|s| s.depth).collect();
        assert_eq!(depths, [0, 0, 1, 1]);
        assert_eq!(t.spans[0].dur_us, 7);
        assert_eq!(t.spans[2].detail, "chain_0");
        assert_eq!(t.dropped, 0);
        assert!(!enabled(), "end() must release the active-trace gate");
    }

    #[test]
    fn untraced_thread_records_nothing_and_detail_closure_never_runs() {
        assert!(end("ok").is_none());
        {
            let _s = span("plan");
            let _d = span_detailed("table.count", || panic!("detail built while disarmed"));
            event("adtree.hit", || panic!("event detail built while disarmed"));
        }
        assert!(end("ok").is_none());
    }

    #[test]
    fn span_cap_counts_dropped_instead_of_growing() {
        begin("deep");
        for _ in 0..(MAX_SPANS + 5) {
            let _s = span("probe");
        }
        let t = end("ok").unwrap();
        assert_eq!(t.spans.len(), MAX_SPANS);
        assert_eq!(t.dropped, 5);
    }

    #[test]
    fn trace_json_escapes_query_and_detail() {
        begin("q=\"x\"");
        event("note", || "a\\b\"c".to_string());
        let t = end("error").unwrap();
        let j = t.to_json();
        assert!(j.contains("\"query\":\"q=\\\"x\\\"\""), "{j}");
        assert!(j.contains("\"detail\":\"a\\\\b\\\"c\""), "{j}");
        assert!(j.contains("\"outcome\":\"error\""), "{j}");
    }

    #[test]
    fn minimal_trace_has_shape_but_no_spans() {
        let t = Trace::minimal("boom", "panic", 1234);
        assert_eq!(t.outcome, "panic");
        assert_eq!(t.total_us, 1234);
        assert!(t.spans.is_empty());
        assert!(t.to_json().contains("\"spans\":[]"));
        assert!(!t.to_json().contains("\"cost\""), "minimal traces carry no cost block");
    }

    #[test]
    fn set_cost_attaches_the_block_to_the_active_trace() {
        set_cost(crate::obs::cost::QueryCost::default()); // no trace: no-op
        begin("costed");
        let cost = crate::obs::cost::QueryCost {
            subtract_depth: 2,
            fo_groups: 1,
            ..Default::default()
        };
        set_cost(cost);
        let t = end("ok").unwrap();
        assert_eq!(t.cost, Some(cost));
        let j = t.to_json();
        assert!(j.contains("\"cost\":{\"tables_loaded\":0,"), "{j}");
        assert!(j.contains("\"subtract_depth\":2"), "{j}");
        assert!(j.contains("\"spans\":[]"), "{j}");
    }
}
