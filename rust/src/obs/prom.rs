//! Prometheus text-format exposition — the `METRICS` wire verb.
//!
//! Renders every ServeMetrics / StoreStats / TreeStats / MjMetrics
//! counter, gauge, and histogram in the text exposition format
//! (`# HELP` + `# TYPE` per family, cumulative `le` buckets with
//! `_sum`/`_count` for histograms), so a standard scraper pointed at
//! ctserve works without any client library on either side. The body
//! ends with a `# EOF` line: the wire protocol is line-delimited and
//! `METRICS` is its only multi-line response, so clients read until
//! that terminator.
//!
//! [`validate`] is the ~40-line format checker CI runs against a live
//! scrape: every sample line must belong to a declared `# TYPE`
//! family, every value must parse, and every histogram's `+Inf`
//! bucket must equal its `_count`.

use crate::mobius::metrics::ALL_OPS;
use crate::mobius::MjMetrics;
use crate::serve::metrics::{ServeMetrics, ServeSnapshot};
use std::collections::HashMap;

/// Terminator line for the `METRICS` wire response.
pub const EOF_LINE: &str = "# EOF";

/// Incremental builder for one exposition document.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    pub fn counter(&mut self, name: &str, help: &str, v: u64) {
        self.family(name, "counter", help);
        self.out.push_str(&format!("{name} {v}\n"));
    }

    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.family(name, "gauge", help);
        self.out.push_str(&format!("{name} {}\n", fmt_f64(v)));
    }

    /// One family, one sample per `(label_value, value)` pair.
    pub fn labeled_counter(&mut self, name: &str, help: &str, label: &str, samples: &[(&str, f64)]) {
        self.family(name, "counter", help);
        for (lv, v) in samples {
            self.out.push_str(&format!("{name}{{{label}=\"{lv}\"}} {}\n", fmt_f64(*v)));
        }
    }

    /// A histogram from `(upper_bound, per-bucket count)` pairs — the
    /// shape [`LatencyHistogram::buckets`](crate::serve::metrics::LatencyHistogram::buckets)
    /// returns. Bucket counts are cumulated here; `sum` is the exact
    /// recorded total (the histogram tracks it alongside the buckets).
    pub fn histogram(&mut self, name: &str, help: &str, buckets: &[(u64, u64)], sum: u64) {
        self.family(name, "histogram", help);
        let mut cum = 0u64;
        for (upper, count) in buckets {
            cum += count;
            self.out.push_str(&format!("{name}_bucket{{le=\"{upper}\"}} {cum}\n"));
        }
        self.out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
        self.out.push_str(&format!("{name}_sum {sum}\n{name}_count {cum}\n"));
    }

    /// Finish the document with the `# EOF` terminator.
    pub fn finish(mut self) -> String {
        self.out.push_str(EOF_LINE);
        self.out.push('\n');
        self.out
    }
}

/// Render the full serving exposition: live histograms from `m`, the
/// consistent counter view from `snap`, and the Möbius ct-op counters
/// from `mj` (zero at serve time unless a join ran in-process — the
/// families exist either way so dashboards need no conditionals).
pub fn render(m: &ServeMetrics, snap: &ServeSnapshot, mj: &MjMetrics) -> String {
    let mut p = PromText::new();
    p.gauge("mrss_uptime_seconds", "Seconds since the server started.", snap.uptime_secs);
    p.counter("mrss_queries_total", "Queries answered (errors included).", snap.queries);
    p.counter(
        "mrss_admin_requests_total",
        "Admin verbs served (STATS/METRICS/DUMP/TOP/HISTORY/EXPLAIN).",
        snap.admin_requests,
    );
    p.counter("mrss_errors_total", "Queries answered with an error line.", snap.errors);
    p.counter("mrss_busy_rejects_total", "Connections shed by admission control.", snap.busy_rejects);
    p.counter("mrss_connections_total", "Connections accepted since start.", snap.connections);
    p.gauge("mrss_active_connections", "Connections currently open.", snap.active as f64);
    p.counter("mrss_worker_panics_total", "Worker panics converted to ERR replies.", snap.worker_panics);
    p.counter("mrss_conn_timeouts_total", "Connections closed by --idle-timeout.", snap.conn_timeouts);
    p.counter(
        "mrss_request_timeouts_total",
        "Requests answered ERR deadline exceeded.",
        snap.request_timeouts,
    );
    p.counter("mrss_reactor_wakeups_total", "Poller waits that returned events.", snap.wakeups);
    p.gauge("mrss_registered_fds", "Fds registered across reactor shards.", snap.registered_fds as f64);
    p.gauge("mrss_run_queue_peak", "Deepest per-wakeup work batch.", snap.run_queue_peak as f64);
    p.gauge("mrss_batch_peak", "Most BATCH members in flight at once.", snap.batch_peak as f64);
    p.histogram(
        "mrss_exec_latency_us",
        "Query execution time on the worker pool, microseconds.",
        &m.latency.buckets(),
        m.latency.sum(),
    );
    p.histogram(
        "mrss_queue_wait_us",
        "Dispatch-to-execution queue wait, microseconds.",
        &m.queue_wait.buckets(),
        m.queue_wait.sum(),
    );
    p.histogram(
        "mrss_conns_at_accept",
        "Connections open when one more arrived.",
        &m.conns.buckets(),
        m.conns.sum(),
    );
    p.counter("mrss_store_hits_total", "Ct-table cache hits.", snap.store.hits);
    p.counter("mrss_store_misses_total", "Ct-table cache misses (disk loads).", snap.store.misses);
    p.counter("mrss_store_evictions_total", "Ct-tables evicted by the LRU budget.", snap.store.evictions);
    p.counter("mrss_store_bytes_read_total", "Bytes read from .ct files.", snap.store.bytes_read);
    p.gauge(
        "mrss_store_quarantined_tables",
        "Damaged tables quarantined to .ct.bad.",
        snap.store.quarantined_tables as f64,
    );
    p.counter("mrss_adtree_hits_total", "ADtree cache hits.", snap.trees.hits);
    p.counter("mrss_adtree_builds_total", "ADtrees built.", snap.trees.builds);
    p.gauge("mrss_adtree_building", "ADtree builds in progress.", snap.trees.building as f64);
    p.counter(
        "mrss_adtree_coalesced_waits_total",
        "Readers that waited on another thread's build.",
        snap.trees.coalesced_waits,
    );
    p.counter("mrss_adtree_evictions_total", "ADtrees evicted by the shared budget.", snap.trees.evictions);
    p.gauge("mrss_adtree_bytes", "Bytes charged by cached ADtrees.", snap.trees.bytes as f64);
    p.counter("mrss_cost_tables_loaded_total", "Ct-tables loaded/built for queries.", snap.cost.tables_loaded);
    p.counter("mrss_cost_tables_cached_total", "Query table probes served from cache.", snap.cost.tables_cached);
    p.counter("mrss_cost_bytes_scanned_total", "Bytes charged to query execution.", snap.cost.bytes_scanned);
    p.counter(
        "mrss_cost_adtree_nodes_probed_total",
        "ADtree nodes visited answering queries.",
        snap.cost.adtree_nodes_probed,
    );
    p.counter(
        "mrss_cost_subtract_depth_total",
        "Mobius subtraction peels across all queries.",
        snap.cost.subtract_depth,
    );
    p.counter("mrss_cost_rows_merged_total", "Ct rows merged on oversized-table paths.", snap.cost.rows_merged);
    p.counter("mrss_cost_fo_groups_total", "FO-group factorization passes.", snap.cost.fo_groups);
    let ops: Vec<(&str, f64)> =
        ALL_OPS.iter().map(|op| (op.name(), mj.op_count(*op) as f64)).collect();
    p.labeled_counter("mrss_mj_ct_ops_total", "Ct-algebra operator invocations.", "op", &ops);
    let op_secs: Vec<(&str, f64)> =
        ALL_OPS.iter().map(|op| (op.name(), mj.op_time(*op).as_secs_f64())).collect();
    p.labeled_counter("mrss_mj_ct_op_seconds_total", "Seconds spent per ct-algebra operator.", "op", &op_secs);
    p.counter(
        "mrss_mj_reference_fallbacks_total",
        "Packed-kernel operations that fell back to the row-major reference.",
        mj.reference_fallbacks,
    );
    p.counter(
        "mrss_traces_started_total",
        "Request traces started (sampled + EXPLAIN).",
        crate::obs::trace::TRACES_STARTED.load(std::sync::atomic::Ordering::Relaxed),
    );
    p.counter(
        "mrss_trace_spans_dropped_total",
        "Spans lost to the per-trace cap.",
        crate::obs::trace::SPANS_DROPPED.load(std::sync::atomic::Ordering::Relaxed),
    );
    p.counter(
        "mrss_traces_recorded_total",
        "Traces kept by the flight recorder.",
        crate::obs::recorder::recorded_count(),
    );
    p.counter(
        "mrss_flight_dumps_suppressed_total",
        "Auto-dumps suppressed by the 1/sec throttle.",
        crate::obs::recorder::DUMPS_SUPPRESSED.load(std::sync::atomic::Ordering::Relaxed),
    );
    let busy: Vec<(&str, f64)> = crate::obs::profile::ALL_ROLES
        .iter()
        .enumerate()
        .map(|(i, r)| (r.name(), snap.threads[i].busy_us as f64 / 1e6))
        .collect();
    p.labeled_counter(
        "mrss_thread_cpu_seconds_total",
        "CPU seconds burned per thread role (CLOCK_THREAD_CPUTIME_ID).",
        "role",
        &busy,
    );
    p.counter(
        "mrss_profile_samples_total",
        "Thread-samples taken by the span-stack profiler.",
        crate::obs::profile::samples_total(),
    );
    let kernels = crate::ct::ticks::snapshot();
    let labels: Vec<String> =
        kernels.iter().map(|(k, t, _, _)| format!("{k}_{t}")).collect();
    let kticks: Vec<(&str, f64)> = labels
        .iter()
        .zip(&kernels)
        .map(|(l, &(_, _, c, _))| (l.as_str(), c as f64))
        .collect();
    p.labeled_counter(
        "mrss_ct_kernel_ticks_total",
        "Ct-algebra kernel invocations per (operator, key-width tier).",
        "kernel",
        &kticks,
    );
    let ksecs: Vec<(&str, f64)> = labels
        .iter()
        .zip(&kernels)
        .map(|(l, &(_, _, _, n))| (l.as_str(), n as f64 / 1e9))
        .collect();
    p.labeled_counter(
        "mrss_ct_kernel_seconds_total",
        "Seconds inside ct-algebra kernels per (operator, tier).",
        "kernel",
        &ksecs,
    );
    let ps = crate::obs::proc::read_or_zero();
    p.gauge(
        "process_resident_memory_bytes",
        "Resident set size in bytes (VmRSS; 0 off Linux).",
        ps.rss_bytes as f64,
    );
    p.counter(
        "process_cpu_seconds_total",
        "User + system CPU seconds (whole seconds; /proc/self/stat).",
        (ps.utime_us + ps.stime_us) / 1_000_000,
    );
    p.gauge("process_open_fds", "Open file descriptors.", ps.open_fds as f64);
    p.gauge("process_threads", "OS threads in the process.", ps.threads as f64);
    p.counter(
        "process_voluntary_ctxt_switches_total",
        "Voluntary context switches (blocked on I/O or locks).",
        ps.voluntary_ctxt_switches,
    );
    p.counter(
        "process_nonvoluntary_ctxt_switches_total",
        "Involuntary context switches (scheduler preemptions).",
        ps.nonvoluntary_ctxt_switches,
    );
    p.finish()
}

/// The family checklist `mrss validate-metrics` runs against a *live
/// serving* scrape, on top of the format [`validate`]: the observability
/// families this crate promises (thread-CPU split, profiler samples,
/// ct-kernel timers, standard `process_*` telemetry) must all be
/// declared. Kept separate from `validate` so small hand-written test
/// documents remain valid.
pub fn validate_serving_families(text: &str) -> Result<(), String> {
    const REQUIRED: [&str; 11] = [
        "mrss_queries_total",
        "mrss_thread_cpu_seconds_total",
        "mrss_profile_samples_total",
        "mrss_ct_kernel_ticks_total",
        "mrss_ct_kernel_seconds_total",
        "process_resident_memory_bytes",
        "process_cpu_seconds_total",
        "process_open_fds",
        "process_threads",
        "process_voluntary_ctxt_switches_total",
        "process_nonvoluntary_ctxt_switches_total",
    ];
    for fam in REQUIRED {
        if !text.contains(&format!("# TYPE {fam} ")) {
            return Err(format!("serving exposition is missing family `{fam}`"));
        }
    }
    Ok(())
}

/// Validate one exposition document: every sample line must belong to
/// a declared `# TYPE` family (histogram series via their
/// `_bucket`/`_sum`/`_count` suffixes), every value must parse as a
/// number, histogram buckets must be cumulative, and each histogram's
/// `+Inf` bucket must equal its `_count`.
pub fn validate(text: &str) -> Result<(), String> {
    let mut types: HashMap<String, String> = HashMap::new();
    let mut inf: HashMap<String, f64> = HashMap::new();
    let mut counts: HashMap<String, f64> = HashMap::new();
    let mut prev_bucket: HashMap<String, f64> = HashMap::new();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
            if name.is_empty() || !matches!(kind, "counter" | "gauge" | "histogram") {
                return Err(format!("line {ln}: malformed TYPE declaration: {line}"));
            }
            types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP, EOF, free comments
        }
        let (series, value) = match line.find('{') {
            Some(b) => {
                let close = line.rfind('}').ok_or(format!("line {ln}: unclosed label set"))?;
                (&line[..b], line[close + 1..].trim())
            }
            None => {
                let sp = line.find(' ').ok_or(format!("line {ln}: no value: {line}"))?;
                (&line[..sp], line[sp + 1..].trim())
            }
        };
        let v: f64 =
            value.parse().map_err(|_| format!("line {ln}: bad value `{value}` for {series}"))?;
        let base = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let b = series.strip_suffix(suf)?;
                (types.get(b).map(String::as_str) == Some("histogram")).then_some(b)
            })
            .unwrap_or(series);
        match types.get(base).map(String::as_str) {
            None => return Err(format!("line {ln}: sample `{series}` has no # TYPE declaration")),
            Some("histogram") if base == series => {
                return Err(format!("line {ln}: bare sample for histogram `{series}`"))
            }
            _ => {}
        }
        if series.ends_with("_bucket") && types.get(base).map(String::as_str) == Some("histogram") {
            let prev = prev_bucket.insert(base.to_string(), v).unwrap_or(0.0);
            if v < prev {
                return Err(format!("line {ln}: histogram `{base}` buckets not cumulative"));
            }
            if line.contains("le=\"+Inf\"") {
                inf.insert(base.to_string(), v);
            }
        } else if series.ends_with("_count") && base != series {
            counts.insert(base.to_string(), v);
        }
    }
    for (name, kind) in &types {
        if kind == "histogram" {
            match (inf.get(name), counts.get(name)) {
                (Some(i), Some(c)) if i == c => {}
                (Some(i), Some(c)) => {
                    return Err(format!("histogram `{name}`: +Inf bucket {i} != _count {c}"))
                }
                _ => return Err(format!("histogram `{name}`: missing +Inf bucket or _count")),
            }
        }
    }
    Ok(())
}

/// Collect every *monotone* series of one document — counter samples
/// (labels included in the key) plus histogram `_bucket`/`_sum`/`_count`
/// series, whose values never decrease on a live server. Gauges are
/// excluded: they move both ways by design.
fn monotone_series(text: &str) -> Result<HashMap<String, f64>, String> {
    let mut types: HashMap<String, String> = HashMap::new();
    let mut out: HashMap<String, f64> = HashMap::new();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        let line = line.trim_end();
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
            types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // Key = series name including its label set, so labeled counters
        // (e.g. per-op) compare sample to sample.
        let (key, value) = match line.find('{') {
            Some(_) => {
                let close = line.rfind('}').ok_or(format!("line {ln}: unclosed label set"))?;
                (&line[..close + 1], line[close + 1..].trim())
            }
            None => {
                let sp = line.find(' ').ok_or(format!("line {ln}: no value: {line}"))?;
                (&line[..sp], line[sp + 1..].trim())
            }
        };
        let series = key.split('{').next().unwrap_or(key);
        let base = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let b = series.strip_suffix(suf)?;
                (types.get(b).map(String::as_str) == Some("histogram")).then_some(b)
            })
            .unwrap_or(series);
        let monotone = matches!(types.get(base).map(String::as_str), Some("counter" | "histogram"));
        if monotone {
            let v: f64 = value
                .parse()
                .map_err(|_| format!("line {ln}: bad value `{value}` for {series}"))?;
            out.insert(key.to_string(), v);
        }
    }
    Ok(out)
}

/// The two-scrape monotonicity check: every counter and histogram series
/// of the *earlier* scrape must still exist in the *later* one with a
/// value at least as large. Catches silent counter resets (a restarted or
/// wedged server between scrapes) that single-document validation cannot.
pub fn validate_monotonic(prev: &str, cur: &str) -> Result<(), String> {
    let p = monotone_series(prev)?;
    let c = monotone_series(cur)?;
    let mut keys: Vec<&String> = p.keys().collect();
    keys.sort();
    for k in keys {
        let pv = p[k];
        match c.get(k) {
            None => {
                return Err(format!(
                    "counter series `{k}` present in first scrape but missing in second"
                ))
            }
            Some(cv) if *cv < pv => {
                return Err(format!("counter series `{k}` went backwards: {pv} -> {cv}"))
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{StoreStats, TreeStats};
    use std::time::Duration;

    fn sample_doc() -> String {
        let m = ServeMetrics::default();
        m.queries.fetch_add(5, std::sync::atomic::Ordering::Relaxed);
        m.latency.record(Duration::from_micros(7));
        m.latency.record(Duration::from_micros(900));
        m.queue_wait.record(Duration::from_micros(2));
        m.conns.record_value(3);
        let snap = m.snapshot(
            StoreStats { hits: 2, ..Default::default() },
            TreeStats::default(),
            "uwcse",
        );
        render(&m, &snap, &MjMetrics::default())
    }

    #[test]
    fn rendered_exposition_passes_the_validator() {
        let doc = sample_doc();
        validate(&doc).unwrap_or_else(|e| panic!("{e}\n---\n{doc}"));
        assert!(doc.ends_with("# EOF\n"), "missing terminator");
        assert!(doc.contains("mrss_queries_total 5"), "{doc}");
        assert!(doc.contains("mrss_mj_ct_ops_total{op=\"project\"} 0"), "{doc}");
        assert!(doc.contains("mrss_exec_latency_us_count 2"), "{doc}");
    }

    #[test]
    fn validator_rejects_undeclared_samples_and_bad_values() {
        assert!(validate("orphan_metric 3\n").unwrap_err().contains("no # TYPE"));
        let bad = "# TYPE x counter\nx notanumber\n";
        assert!(validate(bad).unwrap_err().contains("bad value"));
    }

    #[test]
    fn validator_rejects_histogram_inconsistencies() {
        let doc = "# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 2\nh_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 5\n";
        assert!(validate(doc).unwrap_err().contains("!= _count"));
        let non_cum = "# TYPE h histogram\n\
                       h_bucket{le=\"1\"} 5\nh_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 2\n";
        assert!(validate(non_cum).unwrap_err().contains("not cumulative"));
        let missing = "# TYPE h histogram\nh_sum 3\n";
        assert!(validate(missing).unwrap_err().contains("missing"));
    }

    #[test]
    fn monotonic_check_accepts_growth_and_rejects_resets() {
        let a = "# TYPE q counter\nq 5\n\
                 # TYPE g gauge\ng 100\n\
                 # TYPE ops counter\nops{op=\"cross\"} 3\n\
                 # TYPE h histogram\nh_bucket{le=\"1\"} 2\nh_bucket{le=\"+Inf\"} 2\n\
                 h_sum 4\nh_count 2\n";
        let b = "# TYPE q counter\nq 9\n\
                 # TYPE g gauge\ng 1\n\
                 # TYPE ops counter\nops{op=\"cross\"} 3\n\
                 # TYPE h histogram\nh_bucket{le=\"1\"} 6\nh_bucket{le=\"+Inf\"} 6\n\
                 h_sum 11\nh_count 6\n";
        // Growth (and an equal labeled counter) passes; the shrinking
        // gauge is ignored by design.
        validate_monotonic(a, b).unwrap();
        // A counter going backwards is a reset.
        let err = validate_monotonic(b, a).unwrap_err();
        assert!(err.contains("went backwards"), "{err}");
        // Same for a histogram series.
        let shrunk = b.replace("h_count 6", "h_count 1");
        let err = validate_monotonic(b, &shrunk).unwrap_err();
        assert!(err.contains("h_count") && err.contains("backwards"), "{err}");
        // A series vanishing between scrapes is also an error.
        let err = validate_monotonic(a, "# TYPE q counter\nq 9\n").unwrap_err();
        assert!(err.contains("missing in second"), "{err}");
        // Two identical live renders are trivially monotone.
        let doc = sample_doc();
        validate_monotonic(&doc, &doc).unwrap();
    }

    #[test]
    fn rendered_exposition_carries_the_serving_families() {
        let doc = sample_doc();
        validate_serving_families(&doc).unwrap_or_else(|e| panic!("{e}\n---\n{doc}"));
        // Kernel families carry every (op, tier) label even when zero.
        assert!(doc.contains("mrss_ct_kernel_ticks_total{kernel=\"select_u64\"}"), "{doc}");
        assert!(doc.contains("mrss_ct_kernel_seconds_total{kernel=\"subtract_wide\"}"), "{doc}");
        assert!(doc.contains("mrss_thread_cpu_seconds_total{role=\"worker\"}"), "{doc}");
        // And the checker notices a family going missing.
        let gutted = doc.replace("# TYPE process_open_fds gauge", "# TYPE nope gauge");
        let err = validate_serving_families(&gutted).unwrap_err();
        assert!(err.contains("process_open_fds"), "{err}");
    }

    #[test]
    fn process_gauges_may_shrink_between_scrapes() {
        // RSS and fd-count fall as memory is returned and sockets close;
        // the --prev monotonicity pass must not flag them. Counters in
        // the same families stay checked.
        let a = "# TYPE process_resident_memory_bytes gauge\n\
                 process_resident_memory_bytes 90000000\n\
                 # TYPE process_open_fds gauge\nprocess_open_fds 40\n\
                 # TYPE process_cpu_seconds_total counter\nprocess_cpu_seconds_total 5\n";
        let b = "# TYPE process_resident_memory_bytes gauge\n\
                 process_resident_memory_bytes 1000000\n\
                 # TYPE process_open_fds gauge\nprocess_open_fds 6\n\
                 # TYPE process_cpu_seconds_total counter\nprocess_cpu_seconds_total 7\n";
        validate_monotonic(a, b).unwrap();
        // The CPU counter itself still may not reset.
        let err = validate_monotonic(b, a).unwrap_err();
        assert!(err.contains("process_cpu_seconds_total"), "{err}");
    }

    #[test]
    fn rendered_exposition_carries_cost_and_admin_counters() {
        let doc = sample_doc();
        for key in [
            "mrss_admin_requests_total",
            "mrss_cost_tables_loaded_total",
            "mrss_cost_bytes_scanned_total",
            "mrss_cost_subtract_depth_total",
            "mrss_cost_fo_groups_total",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
    }

    #[test]
    fn histogram_buckets_cumulate_and_close_with_inf() {
        let mut p = PromText::new();
        p.histogram("t_us", "test", &[(1, 3), (2, 0), (4, 2)], 11);
        let doc = p.finish();
        assert!(doc.contains("t_us_bucket{le=\"1\"} 3"), "{doc}");
        assert!(doc.contains("t_us_bucket{le=\"4\"} 5"), "{doc}");
        assert!(doc.contains("t_us_bucket{le=\"+Inf\"} 5"), "{doc}");
        assert!(doc.contains("t_us_sum 11"), "{doc}");
        assert!(doc.contains("t_us_count 5"), "{doc}");
        validate(&doc).unwrap();
    }
}
