//! Per-second metrics history ring behind the `HISTORY` verb.
//!
//! Prometheus counters are point-in-time: without an external scraper
//! there is no way to ask the server "what was qps thirty seconds ago?".
//! This module keeps a fixed ring of per-second aggregation slots — each
//! flushed by the shard-0 reactor tick — so rates, windowed latency
//! quantiles, queue depth, cache hit rate, cost throughput, and process
//! resources (RSS, user/sys CPU %, fds, context switches — sampled from
//! [`crate::obs::proc`] at flush time) are observable from the wire
//! alone (`HISTORY [secs]` returns the series as one JSON line).
//!
//! The ring is bounded at [`SLOTS`] entries (10 minutes at one slot per
//! second); older slots are overwritten. Storage is `SLOTS × Slot`
//! regardless of uptime. The server computes each slot's *deltas* from
//! its cumulative counters at flush time; this module only stores and
//! renders them.

/// Ring capacity: 10 minutes of one-second slots.
pub const SLOTS: usize = 600;

/// One second of aggregated serving activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Slot {
    /// Seconds since server start at the *end* of this slot's window.
    pub epoch_s: u64,
    /// Count queries answered in this second (admin verbs excluded).
    pub queries: u64,
    /// Error responses in this second.
    pub errors: u64,
    /// Admin verbs (STATS/METRICS/DUMP/TOP/HISTORY/EXPLAIN) in this second.
    pub admin: u64,
    /// Windowed exec-latency upper bounds over this second's requests, µs
    /// (0 when the window had no requests).
    pub p50_us: u64,
    pub p99_us: u64,
    /// Run-queue depth sampled at flush time.
    pub queue_depth: u64,
    /// ADtree cache hit rate ×100 (hits / (hits+builds)), cumulative at
    /// flush time; 0 before any probe.
    pub cache_hit_pct: u64,
    /// Abstract cost units charged in this second (see
    /// [`QueryCost::units`](crate::obs::cost::QueryCost::units)).
    pub cost_units: u64,
    /// Bytes scanned in this second.
    pub bytes_scanned: u64,
    /// Resident set size at flush time, bytes (0 off Linux).
    pub rss_bytes: u64,
    /// User-mode CPU over this second, percent of one core ×1 (0–100·n).
    pub cpu_user_pct: u64,
    /// Kernel-mode CPU over this second, percent of one core.
    pub cpu_sys_pct: u64,
    /// Open file descriptors at flush time.
    pub open_fds: u64,
    /// Context switches (voluntary + involuntary) in this second.
    pub ctx_switches: u64,
}

impl Slot {
    fn to_json(self) -> String {
        format!(
            "{{\"t\":{},\"queries\":{},\"errors\":{},\"admin\":{},\"p50_us\":{},\
             \"p99_us\":{},\"queue_depth\":{},\"cache_hit_pct\":{},\"cost_units\":{},\
             \"bytes_scanned\":{},\"rss_bytes\":{},\"cpu_user_pct\":{},\"cpu_sys_pct\":{},\
             \"open_fds\":{},\"ctx_switches\":{}}}",
            self.epoch_s,
            self.queries,
            self.errors,
            self.admin,
            self.p50_us,
            self.p99_us,
            self.queue_depth,
            self.cache_hit_pct,
            self.cost_units,
            self.bytes_scanned,
            self.rss_bytes,
            self.cpu_user_pct,
            self.cpu_sys_pct,
            self.open_fds,
            self.ctx_switches
        )
    }
}

/// Fixed-capacity ring of per-second slots.
#[derive(Debug)]
pub struct HistoryRing {
    slots: Vec<Slot>,
    /// Next write position.
    head: usize,
    /// Slots ever written, saturating at `slots.len()`.
    filled: usize,
}

impl Default for HistoryRing {
    fn default() -> Self {
        HistoryRing::new(SLOTS)
    }
}

impl HistoryRing {
    /// A ring of `capacity` (≥ 1) slots.
    pub fn new(capacity: usize) -> HistoryRing {
        HistoryRing { slots: vec![Slot::default(); capacity.max(1)], head: 0, filled: 0 }
    }

    /// Record one flushed second, overwriting the oldest slot when full.
    pub fn push(&mut self, slot: Slot) {
        self.slots[self.head] = slot;
        self.head = (self.head + 1) % self.slots.len();
        self.filled = (self.filled + 1).min(self.slots.len());
    }

    /// Slots currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.filled
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// The most recent `n` slots, oldest first.
    pub fn last(&self, n: usize) -> Vec<Slot> {
        let n = n.min(self.filled);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // head points at the next write; walk backwards n slots.
            let idx = (self.head + self.slots.len() - n + i) % self.slots.len();
            out.push(self.slots[idx]);
        }
        out
    }

    /// Render the `HISTORY secs` answer: the last `secs` slots (clamped
    /// to what the ring holds) as one JSON object.
    pub fn series_json(&self, secs: usize) -> String {
        let series = self.last(secs);
        let mut body = String::from("[");
        for (i, s) in series.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&s.to_json());
        }
        body.push(']');
        format!(
            "{{\"slots\":{},\"capacity\":{},\"window_secs\":{},\"series\":{}}}",
            series.len(),
            self.slots.len(),
            secs,
            body
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(t: u64, q: u64) -> Slot {
        Slot { epoch_s: t, queries: q, ..Default::default() }
    }

    #[test]
    fn default_ring_holds_ten_minutes() {
        let r = HistoryRing::default();
        assert_eq!(r.capacity(), 600);
        assert!(r.is_empty());
    }

    #[test]
    fn last_returns_newest_slots_oldest_first() {
        let mut r = HistoryRing::new(8);
        for t in 0..5 {
            r.push(slot(t, t * 10));
        }
        assert_eq!(r.len(), 5);
        let tail = r.last(3);
        assert_eq!(tail.iter().map(|s| s.epoch_s).collect::<Vec<_>>(), vec![2, 3, 4]);
        // Asking past the fill level clamps.
        assert_eq!(r.last(100).len(), 5);
    }

    #[test]
    fn ring_wraps_and_overwrites_the_oldest() {
        let mut r = HistoryRing::new(4);
        for t in 0..10 {
            r.push(slot(t, 1));
        }
        assert_eq!(r.len(), 4, "filled saturates at capacity");
        let all = r.last(4);
        assert_eq!(all.iter().map(|s| s.epoch_s).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn series_json_shape() {
        let mut r = HistoryRing::new(16);
        r.push(Slot {
            epoch_s: 1,
            queries: 7,
            errors: 1,
            admin: 2,
            p50_us: 100,
            p99_us: 900,
            queue_depth: 3,
            cache_hit_pct: 85,
            cost_units: 4200,
            bytes_scanned: 65536,
            rss_bytes: 8 << 20,
            cpu_user_pct: 41,
            cpu_sys_pct: 7,
            open_fds: 19,
            ctx_switches: 230,
        });
        r.push(slot(2, 0));
        let j = r.series_json(60);
        for key in [
            "\"slots\":2",
            "\"capacity\":16",
            "\"window_secs\":60",
            "\"series\":[{\"t\":1,\"queries\":7,\"errors\":1,\"admin\":2,\"p50_us\":100,\
             \"p99_us\":900,\"queue_depth\":3,\"cache_hit_pct\":85,\"cost_units\":4200,\
             \"bytes_scanned\":65536,\"rss_bytes\":8388608,\"cpu_user_pct\":41,\
             \"cpu_sys_pct\":7,\"open_fds\":19,\"ctx_switches\":230}",
            "{\"t\":2,\"queries\":0,",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(j.ends_with("}]}"), "{j}");
    }

    #[test]
    fn empty_ring_answers_an_empty_series() {
        let r = HistoryRing::new(4);
        assert_eq!(
            r.series_json(30),
            "{\"slots\":0,\"capacity\":4,\"window_secs\":30,\"series\":[]}"
        );
    }

    #[test]
    fn queries_sum_is_preserved_within_the_window() {
        // The integration contract: slot deltas over a window sum to the
        // counter delta. Model it here with direct pushes.
        let mut r = HistoryRing::new(600);
        let mut total = 0;
        for t in 0..20 {
            let q = (t * 3) % 7;
            total += q;
            r.push(slot(t, q));
        }
        let sum: u64 = r.last(600).iter().map(|s| s.queries).sum();
        assert_eq!(sum, total);
    }
}
