//! Space-bounded heavy-hitter sketch over query plan signatures.
//!
//! The server normalizes every count query to a *plan signature* (sorted
//! relationship set + sign pattern — see
//! [`CountServer::plan_signature`](crate::store::CountServer::plan_signature))
//! and feeds it here. A [`TopSketch`] is a Misra-Gries summary: it holds
//! at most `capacity` entries no matter how many distinct signatures the
//! workload has, so the `TOP` verb answers from O(k) memory on any
//! traffic. The classic guarantees carry over:
//!
//! * while the number of distinct keys ever seen stays ≤ `capacity`,
//!   every count is **exact**;
//! * past that, a surviving key's count undercounts its true frequency
//!   by at most `decrements` (reported in the JSON), and any key with
//!   true frequency > N/(capacity+1) is guaranteed to survive.
//!
//! Alongside the frequency count each entry accumulates total cost units
//! ([`QueryCost::units`](crate::obs::cost::QueryCost::units)) and total
//! latency, so `TOP` can rank shapes by *count*, *cost*, or *latency* —
//! the three questions capacity planning actually asks.

/// One tracked plan signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopEntry {
    pub key: String,
    /// Misra-Gries frequency (exact below capacity, else a lower bound
    /// within `decrements` of the truth).
    pub count: u64,
    /// Sum of per-query abstract cost units.
    pub cost_units: u64,
    /// Sum of per-query execution latency, µs.
    pub latency_us: u64,
}

/// What `top()` orders by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankBy {
    Count,
    Cost,
    Latency,
}

/// Misra-Gries heavy-hitter summary, bounded at `capacity` entries.
#[derive(Debug)]
pub struct TopSketch {
    capacity: usize,
    entries: Vec<TopEntry>,
    /// Total observations fed in.
    total: u64,
    /// Decrement rounds performed — the maximum undercount of any
    /// surviving entry.
    decrements: u64,
}

impl TopSketch {
    /// A sketch holding at most `capacity` (≥ 1) entries.
    pub fn new(capacity: usize) -> TopSketch {
        let capacity = capacity.max(1);
        TopSketch { capacity, entries: Vec::with_capacity(capacity), total: 0, decrements: 0 }
    }

    /// Feed one observation: a query with signature `key` that cost
    /// `cost_units` and took `latency_us`.
    pub fn observe(&mut self, key: &str, cost_units: u64, latency_us: u64) {
        self.total += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == key) {
            e.count += 1;
            e.cost_units += cost_units;
            e.latency_us += latency_us;
            return;
        }
        if self.entries.len() < self.capacity {
            self.entries.push(TopEntry {
                key: key.to_string(),
                count: 1,
                cost_units,
                latency_us,
            });
            return;
        }
        // Full and the key is new: the Misra-Gries decrement round. The
        // incoming observation is absorbed by the round (not stored), so
        // the entry count never exceeds `capacity`.
        self.decrements += 1;
        self.entries.retain_mut(|e| {
            e.count -= 1;
            e.count > 0
        });
    }

    /// Number of tracked entries (≤ capacity always).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total observations fed in.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Decrement rounds so far (= max undercount of a surviving entry).
    pub fn decrements(&self) -> u64 {
        self.decrements
    }

    /// The top `k` entries ordered by `by` (descending), ties broken by
    /// key so output is deterministic.
    pub fn top(&self, k: usize, by: RankBy) -> Vec<TopEntry> {
        let mut v = self.entries.clone();
        v.sort_by(|a, b| {
            let (ka, kb) = match by {
                RankBy::Count => (a.count, b.count),
                RankBy::Cost => (a.cost_units, b.cost_units),
                RankBy::Latency => (a.latency_us, b.latency_us),
            };
            kb.cmp(&ka).then_with(|| a.key.cmp(&b.key))
        });
        v.truncate(k);
        v
    }

    /// Render the `TOP k` answer: sketch health plus the three rankings.
    pub fn to_json(&self, k: usize) -> String {
        let list = |by: RankBy| {
            let mut out = String::from("[");
            for (i, e) in self.top(k, by).iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"sig\":\"{}\",\"count\":{},\"cost_units\":{},\"latency_us\":{}}}",
                    crate::serve::protocol::json_escape(&e.key),
                    e.count,
                    e.cost_units,
                    e.latency_us
                ));
            }
            out.push(']');
            out
        };
        format!(
            "{{\"entries\":{},\"capacity\":{},\"total\":{},\"decrements\":{},\
             \"by_count\":{},\"by_cost\":{},\"by_latency\":{}}}",
            self.entries.len(),
            self.capacity,
            self.total,
            self.decrements,
            list(RankBy::Count),
            list(RankBy::Cost),
            list(RankBy::Latency)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_counts_below_capacity() {
        let mut s = TopSketch::new(8);
        for _ in 0..6 {
            s.observe("hot", 10, 100);
        }
        s.observe("warm", 5, 50);
        s.observe("warm", 5, 50);
        s.observe("cold", 1, 10);
        assert_eq!(s.decrements(), 0, "below capacity nothing is evicted");
        let top = s.top(3, RankBy::Count);
        assert_eq!(top[0].key, "hot");
        assert_eq!(top[0].count, 6);
        assert_eq!(top[0].cost_units, 60);
        assert_eq!(top[0].latency_us, 600);
        assert_eq!(top[1].key, "warm");
        assert_eq!(top[1].count, 2);
        assert_eq!(top[2].key, "cold");
        assert_eq!(top[2].count, 1);
        assert_eq!(s.total(), 9);
    }

    #[test]
    fn rankings_differ_by_dimension() {
        let mut s = TopSketch::new(8);
        // "a": frequent but cheap; "b": rare but expensive; "c": slow.
        for _ in 0..5 {
            s.observe("a", 1, 1);
        }
        s.observe("b", 1000, 1);
        s.observe("c", 1, 9000);
        assert_eq!(s.top(1, RankBy::Count)[0].key, "a");
        assert_eq!(s.top(1, RankBy::Cost)[0].key, "b");
        assert_eq!(s.top(1, RankBy::Latency)[0].key, "c");
    }

    #[test]
    fn capacity_bound_holds_under_adversarial_interleaving() {
        // Adversary: a hot key interleaved with a never-repeating stream
        // of singletons, the pattern that churns a naive LRU/LFU table.
        // The sketch must (a) never exceed its capacity, (b) keep the hot
        // key, and (c) undercount it by at most `decrements`.
        const CAP: usize = 8;
        let mut s = TopSketch::new(CAP);
        let mut hot_true = 0u64;
        for i in 0..10_000u64 {
            if i % 3 == 0 {
                s.observe("hot", 2, 20);
                hot_true += 1;
            }
            s.observe(&format!("singleton-{i}"), 1, 1);
            assert!(s.len() <= CAP, "capacity exceeded at step {i}: {}", s.len());
        }
        let hot = s
            .entries
            .iter()
            .find(|e| e.key == "hot")
            .expect("a key with frequency > N/(cap+1) must survive");
        assert!(hot.count <= hot_true, "MG count is a lower bound");
        assert!(
            hot_true - hot.count <= s.decrements(),
            "undercount {} exceeds decrement bound {}",
            hot_true - hot.count,
            s.decrements()
        );
        // And it still ranks first by count.
        assert_eq!(s.top(1, RankBy::Count)[0].key, "hot");
    }

    #[test]
    fn second_heavy_key_also_survives_churn() {
        const CAP: usize = 8;
        let mut s = TopSketch::new(CAP);
        for i in 0..6_000u64 {
            s.observe("alpha", 1, 1); // 1/3 of traffic
            if i % 2 == 0 {
                s.observe("beta", 1, 1); // 1/6 of traffic
            }
            s.observe(&format!("noise-{i}"), 1, 1);
            assert!(s.len() <= CAP);
        }
        let top = s.top(2, RankBy::Count);
        assert_eq!(top[0].key, "alpha");
        assert_eq!(top[1].key, "beta");
    }

    #[test]
    fn json_shape_and_truncation() {
        let mut s = TopSketch::new(4);
        s.observe("r(A,B)=T", 3, 30);
        s.observe("r(A,B)=T", 3, 30);
        s.observe("attrs:1", 1, 5);
        let j = s.to_json(1);
        for key in [
            "\"entries\":2",
            "\"capacity\":4",
            "\"total\":3",
            "\"decrements\":0",
            "\"by_count\":[{\"sig\":\"r(A,B)=T\",\"count\":2",
            "\"by_cost\":[",
            "\"by_latency\":[",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // k=1: exactly one element per ranking.
        assert_eq!(j.matches("\"sig\":").count(), 3, "{j}");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut s = TopSketch::new(0);
        s.observe("x", 1, 1);
        assert_eq!(s.capacity(), 1);
        assert_eq!(s.len(), 1);
    }
}
