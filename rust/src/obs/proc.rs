//! Raw process/thread resource telemetry — no crates, no /usr/bin/ps.
//!
//! Two independent facilities:
//!
//! * **CPU clocks** via raw `clock_gettime(2)` declarations (the same
//!   no-dependency syscall idiom as [`crate::serve::reactor`]):
//!   [`thread_cpu_ns`] reads `CLOCK_THREAD_CPUTIME_ID` — the CPU time
//!   burned by *the calling thread alone* — and [`process_cpu_ns`]
//!   reads `CLOCK_PROCESS_CPUTIME_ID`. The profiler samples the thread
//!   clock at job boundaries to split busy from idle per role.
//! * **`/proc/self` readers**: [`read`] parses `stat` (user/sys CPU
//!   ticks), `status` (VmRSS, context switches, thread count), and
//!   counts `fd/` entries, returning one [`ProcessStats`]. The shard-0
//!   history tick samples it once per second; `METRICS` renders the
//!   standard `process_*` Prometheus families from it.
//!
//! Everything degrades to zeros off Linux: the serving stack and its
//! JSON shapes stay identical, the numbers just read 0.

/// Point-in-time process resource usage, as read from `/proc/self`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProcessStats {
    /// Resident set size in bytes (`VmRSS`).
    pub rss_bytes: u64,
    /// Cumulative user-mode CPU, microseconds (`stat` utime × tick).
    pub utime_us: u64,
    /// Cumulative kernel-mode CPU, microseconds (`stat` stime × tick).
    pub stime_us: u64,
    /// Open file descriptors right now (`/proc/self/fd` entries).
    pub open_fds: u64,
    /// Voluntary context switches (blocked on I/O, condvars, …).
    pub voluntary_ctxt_switches: u64,
    /// Involuntary context switches (preempted by the scheduler).
    pub nonvoluntary_ctxt_switches: u64,
    /// OS threads in the process.
    pub threads: u64,
}

impl ProcessStats {
    /// One JSON object — every field numeric, no escaping needed.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"rss_bytes\":{},\"utime_us\":{},\"stime_us\":{},\"open_fds\":{},\
             \"voluntary_ctxt_switches\":{},\"nonvoluntary_ctxt_switches\":{},\
             \"threads\":{}}}",
            self.rss_bytes,
            self.utime_us,
            self.stime_us,
            self.open_fds,
            self.voluntary_ctxt_switches,
            self.nonvoluntary_ctxt_switches,
            self.threads
        )
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_long};

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    extern "C" {
        pub fn clock_gettime(clockid: c_int, tp: *mut Timespec) -> c_int;
        pub fn sysconf(name: c_int) -> c_long;
    }

    #[cfg(target_os = "linux")]
    pub const CLOCK_PROCESS_CPUTIME_ID: c_int = 2;
    #[cfg(target_os = "linux")]
    pub const CLOCK_THREAD_CPUTIME_ID: c_int = 3;
    #[cfg(target_os = "linux")]
    pub const SC_CLK_TCK: c_int = 2;
}

#[cfg(all(unix, target_os = "linux"))]
fn clock_ns(clock: std::os::raw::c_int) -> u64 {
    let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
    // Safety: ts is a valid, writable Timespec; the kernel fills it.
    let rc = unsafe { sys::clock_gettime(clock, &mut ts) };
    if rc != 0 {
        return 0;
    }
    (ts.tv_sec as u64).saturating_mul(1_000_000_000).saturating_add(ts.tv_nsec as u64)
}

/// CPU nanoseconds consumed by the calling thread (0 off Linux).
#[cfg(all(unix, target_os = "linux"))]
pub fn thread_cpu_ns() -> u64 {
    clock_ns(sys::CLOCK_THREAD_CPUTIME_ID)
}

/// CPU nanoseconds consumed by the whole process (0 off Linux).
#[cfg(all(unix, target_os = "linux"))]
pub fn process_cpu_ns() -> u64 {
    clock_ns(sys::CLOCK_PROCESS_CPUTIME_ID)
}

#[cfg(not(all(unix, target_os = "linux")))]
pub fn thread_cpu_ns() -> u64 {
    0
}

#[cfg(not(all(unix, target_os = "linux")))]
pub fn process_cpu_ns() -> u64 {
    0
}

/// Clock ticks per second for `/proc/self/stat` CPU fields (100 on
/// every stock Linux; read once via `sysconf(_SC_CLK_TCK)`).
#[cfg(all(unix, target_os = "linux"))]
fn clk_tck() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
    static TCK: AtomicU64 = AtomicU64::new(0);
    let cached = TCK.load(Relaxed);
    if cached != 0 {
        return cached;
    }
    // Safety: plain sysconf query, no pointers involved.
    let v = unsafe { sys::sysconf(sys::SC_CLK_TCK) };
    let v = if v > 0 { v as u64 } else { 100 };
    TCK.store(v, Relaxed);
    v
}

/// `key:   1234 kB` → 1234 (any `/proc/self/status` numeric line).
#[cfg(target_os = "linux")]
fn status_field(status: &str, key: &str) -> u64 {
    status
        .lines()
        .find_map(|l| l.strip_prefix(key))
        .and_then(|rest| rest.trim_start_matches(':').split_whitespace().next())
        .and_then(|n| n.parse().ok())
        .unwrap_or(0)
}

/// Read the current process stats from `/proc/self`. `None` when the
/// proc filesystem is unavailable (non-Linux, or a locked-down mount).
#[cfg(target_os = "linux")]
pub fn read() -> Option<ProcessStats> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // stat field 2 (comm) may contain spaces; everything after the last
    // ')' is fields 3.. whitespace-separated, so utime (field 14) and
    // stime (field 15) are tokens 11 and 12 of that tail.
    let tail = &stat[stat.rfind(')').map(|i| i + 1).unwrap_or(0)..];
    let fields: Vec<&str> = tail.split_whitespace().collect();
    let ticks_us = 1_000_000 / clk_tck().max(1);
    let tick_field =
        |i: usize| fields.get(i).and_then(|f| f.parse::<u64>().ok()).unwrap_or(0) * ticks_us;
    let open_fds = std::fs::read_dir("/proc/self/fd").map(|d| d.count() as u64).unwrap_or(0);
    Some(ProcessStats {
        rss_bytes: status_field(&status, "VmRSS") * 1024,
        utime_us: tick_field(11),
        stime_us: tick_field(12),
        open_fds,
        voluntary_ctxt_switches: status_field(&status, "voluntary_ctxt_switches"),
        nonvoluntary_ctxt_switches: status_field(&status, "nonvoluntary_ctxt_switches"),
        threads: status_field(&status, "Threads"),
    })
}

#[cfg(not(target_os = "linux"))]
pub fn read() -> Option<ProcessStats> {
    None
}

/// [`read`] with a zeroed fallback — callers that render JSON shapes
/// (STATS, PROFILE, the history tick) use this so the fields exist on
/// every platform.
pub fn read_or_zero() -> ProcessStats {
    read().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_has_every_field() {
        let s = ProcessStats {
            rss_bytes: 4096,
            utime_us: 10,
            stime_us: 20,
            open_fds: 3,
            voluntary_ctxt_switches: 7,
            nonvoluntary_ctxt_switches: 1,
            threads: 5,
        };
        let j = s.to_json();
        for key in [
            "\"rss_bytes\":4096",
            "\"utime_us\":10",
            "\"stime_us\":20",
            "\"open_fds\":3",
            "\"voluntary_ctxt_switches\":7",
            "\"nonvoluntary_ctxt_switches\":1",
            "\"threads\":5",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn proc_reader_sees_a_live_process() {
        let s = read().expect("/proc/self should be readable on Linux");
        assert!(s.rss_bytes > 0, "a running test has resident memory: {s:?}");
        assert!(s.open_fds > 0, "at least the fd-dir handle is open: {s:?}");
        assert!(s.threads >= 1, "{s:?}");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn thread_cpu_clock_advances_under_load() {
        let a = thread_cpu_ns();
        // Burn a little CPU; the thread clock must move, and the process
        // clock must be at least the thread clock.
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_add(i ^ (x >> 3));
        }
        assert!(x != 42, "keep the loop alive");
        let b = thread_cpu_ns();
        assert!(b > a, "thread CPU clock did not advance: {a} -> {b}");
        assert!(process_cpu_ns() >= b - a, "process clock below thread delta");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn status_field_parses_kb_lines() {
        let status = "Name:\tmrss\nVmRSS:\t  1234 kB\nThreads:\t9\n\
                      voluntary_ctxt_switches:\t42\n";
        assert_eq!(status_field(status, "VmRSS"), 1234);
        assert_eq!(status_field(status, "Threads"), 9);
        assert_eq!(status_field(status, "voluntary_ctxt_switches"), 42);
        assert_eq!(status_field(status, "missing"), 0);
    }
}
