//! Per-query resource accounting.
//!
//! A [`QueryCost`] is accumulated in a thread-local cell while a worker
//! executes one count query: the planner, the store, and the ADtree
//! kernels call the `add_*` taps as they do work, and the worker collects
//! the struct with [`take`] when the query finishes. The same numbers are
//! then (a) attached to the query's trace — so `EXPLAIN` and the flight
//! recorder show *why* a query was slow, not just that it was — and
//! (b) charged into process-global totals (relaxed atomics) that feed the
//! `METRICS` cost counters, the `HISTORY` ring's cost series, and the
//! heavy-hitter sketch's cost ranking.
//!
//! The taps mirror the discipline of [`crate::obs::trace`]: a site whose
//! thread has no active accumulator pays one thread-local read and
//! nothing else, so instrumentation never shows up in the entity/chain
//! build paths (which run outside a query context).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Resource usage of one count query, broken down by where the work went.
///
/// All fields are plain counters; `Copy` so the thread-local cell stays a
/// `Cell` (no `RefCell` borrow bookkeeping on the hot path).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryCost {
    /// ADtrees built (table decoded from the store, tree constructed).
    pub tables_loaded: u64,
    /// ADtree cache hits (including coalesced waits on another thread's
    /// in-flight build — the work was done once, elsewhere).
    pub tables_cached: u64,
    /// Bytes decoded or walked to answer the query: freshly-built tree
    /// heap bytes plus oversized-table ct scans.
    pub bytes_scanned: u64,
    /// ADtree nodes visited by `count()` probes (incl. MCV-elision
    /// re-walks).
    pub adtree_nodes_probed: u64,
    /// Möbius subtraction peels: one per negative relationship indicator
    /// derived as `count(Q) − count(Q ∧ R=T)`.
    pub subtract_depth: u64,
    /// Rows merged/scanned outside the ADtree (oversized-table `select`
    /// path).
    pub rows_merged: u64,
    /// Independent FO-groups the planner factored the query into.
    pub fo_groups: u64,
}

impl QueryCost {
    /// Fold another cost into this one (used by tests and the totals
    /// snapshot).
    pub fn merge(&mut self, o: &QueryCost) {
        self.tables_loaded += o.tables_loaded;
        self.tables_cached += o.tables_cached;
        self.bytes_scanned += o.bytes_scanned;
        self.adtree_nodes_probed += o.adtree_nodes_probed;
        self.subtract_depth += o.subtract_depth;
        self.rows_merged += o.rows_merged;
        self.fo_groups += o.fo_groups;
    }

    /// Scalar "abstract cost units" for ranking query shapes against each
    /// other: node probes and merged rows cost 1 each, scanned bytes cost
    /// 1 per 64 B, a cold table load costs 256 (decode + build), a cache
    /// hit 1, a Möbius peel 32 (it doubles the subquery tree), and an FO
    /// group 4 (per-group planning overhead). The weights are heuristic
    /// but fixed, so rankings are comparable across runs.
    pub fn units(&self) -> u64 {
        self.adtree_nodes_probed
            + self.rows_merged
            + self.bytes_scanned / 64
            + self.tables_loaded * 256
            + self.tables_cached
            + self.subtract_depth * 32
            + self.fo_groups * 4
    }

    /// Render as a JSON object (one line, fixed key order).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"tables_loaded\":{},\"tables_cached\":{},\"bytes_scanned\":{},\
             \"adtree_nodes_probed\":{},\"subtract_depth\":{},\"rows_merged\":{},\
             \"fo_groups\":{},\"units\":{}}}",
            self.tables_loaded,
            self.tables_cached,
            self.bytes_scanned,
            self.adtree_nodes_probed,
            self.subtract_depth,
            self.rows_merged,
            self.fo_groups,
            self.units()
        )
    }

    /// Charge this query's cost into the process-global totals.
    pub fn charge_totals(&self) {
        TOTAL_TABLES_LOADED.fetch_add(self.tables_loaded, Ordering::Relaxed);
        TOTAL_TABLES_CACHED.fetch_add(self.tables_cached, Ordering::Relaxed);
        TOTAL_BYTES_SCANNED.fetch_add(self.bytes_scanned, Ordering::Relaxed);
        TOTAL_NODES_PROBED.fetch_add(self.adtree_nodes_probed, Ordering::Relaxed);
        TOTAL_SUBTRACT_DEPTH.fetch_add(self.subtract_depth, Ordering::Relaxed);
        TOTAL_ROWS_MERGED.fetch_add(self.rows_merged, Ordering::Relaxed);
        TOTAL_FO_GROUPS.fetch_add(self.fo_groups, Ordering::Relaxed);
    }
}

// Process-global running totals across all queries (served and CLI).
static TOTAL_TABLES_LOADED: AtomicU64 = AtomicU64::new(0);
static TOTAL_TABLES_CACHED: AtomicU64 = AtomicU64::new(0);
static TOTAL_BYTES_SCANNED: AtomicU64 = AtomicU64::new(0);
static TOTAL_NODES_PROBED: AtomicU64 = AtomicU64::new(0);
static TOTAL_SUBTRACT_DEPTH: AtomicU64 = AtomicU64::new(0);
static TOTAL_ROWS_MERGED: AtomicU64 = AtomicU64::new(0);
static TOTAL_FO_GROUPS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-global cost totals.
pub fn totals() -> QueryCost {
    QueryCost {
        tables_loaded: TOTAL_TABLES_LOADED.load(Ordering::Relaxed),
        tables_cached: TOTAL_TABLES_CACHED.load(Ordering::Relaxed),
        bytes_scanned: TOTAL_BYTES_SCANNED.load(Ordering::Relaxed),
        adtree_nodes_probed: TOTAL_NODES_PROBED.load(Ordering::Relaxed),
        subtract_depth: TOTAL_SUBTRACT_DEPTH.load(Ordering::Relaxed),
        rows_merged: TOTAL_ROWS_MERGED.load(Ordering::Relaxed),
        fo_groups: TOTAL_FO_GROUPS.load(Ordering::Relaxed),
    }
}

thread_local! {
    /// The accumulator for the query currently executing on this thread
    /// (`None` outside a query context — taps are no-ops then).
    static ACTIVE: Cell<Option<QueryCost>> = const { Cell::new(None) };
}

/// Install a fresh accumulator on this thread. Call before executing a
/// query; pair with [`take`].
pub fn begin() {
    ACTIVE.with(|c| c.set(Some(QueryCost::default())));
}

/// Collect and clear this thread's accumulator. Returns `None` when
/// [`begin`] was never called (or the cost was already taken) — callers
/// that must always have a cost use `take().unwrap_or_default()`.
pub fn take() -> Option<QueryCost> {
    ACTIVE.with(|c| c.take())
}

/// Is an accumulator active on this thread? Lets expensive taps (e.g.
/// exact byte walks) skip their argument computation when nobody is
/// counting.
pub fn active() -> bool {
    ACTIVE.with(|c| {
        let v = c.get();
        let on = v.is_some();
        c.set(v);
        on
    })
}

#[inline]
fn bump(f: impl FnOnce(&mut QueryCost)) {
    ACTIVE.with(|c| {
        if let Some(mut q) = c.get() {
            f(&mut q);
            c.set(Some(q));
        }
    });
}

/// An ADtree was built from a stored table to answer this query.
pub fn add_tables_loaded(n: u64) {
    bump(|q| q.tables_loaded += n);
}

/// The query's table was already cached (or another thread built it).
pub fn add_tables_cached(n: u64) {
    bump(|q| q.tables_cached += n);
}

/// Bytes decoded or walked on behalf of this query.
pub fn add_bytes_scanned(n: u64) {
    bump(|q| q.bytes_scanned += n);
}

/// ADtree nodes visited by a count probe.
pub fn add_nodes_probed(n: u64) {
    bump(|q| q.adtree_nodes_probed += n);
}

/// One Möbius subtraction peel.
pub fn add_subtract_depth(n: u64) {
    bump(|q| q.subtract_depth += n);
}

/// Rows merged/scanned outside the ADtree.
pub fn add_rows_merged(n: u64) {
    bump(|q| q.rows_merged += n);
}

/// FO-groups the planner factored the query into.
pub fn add_fo_groups(n: u64) {
    bump(|q| q.fo_groups += n);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taps_are_noops_without_begin() {
        assert!(take().is_none());
        add_nodes_probed(5);
        add_subtract_depth(1);
        assert!(take().is_none(), "taps outside begin/take must not create a cost");
    }

    #[test]
    fn begin_accumulate_take_roundtrip() {
        begin();
        assert!(active());
        add_tables_loaded(1);
        add_tables_cached(2);
        add_bytes_scanned(4096);
        add_nodes_probed(10);
        add_subtract_depth(3);
        add_rows_merged(7);
        add_fo_groups(2);
        let c = take().unwrap();
        assert!(!active());
        assert_eq!(c.tables_loaded, 1);
        assert_eq!(c.tables_cached, 2);
        assert_eq!(c.bytes_scanned, 4096);
        assert_eq!(c.adtree_nodes_probed, 10);
        assert_eq!(c.subtract_depth, 3);
        assert_eq!(c.rows_merged, 7);
        assert_eq!(c.fo_groups, 2);
        // units: 10 + 7 + 64 + 256 + 2 + 96 + 8
        assert_eq!(c.units(), 10 + 7 + 64 + 256 + 2 + 96 + 8);
        assert!(take().is_none(), "take must clear");
    }

    #[test]
    fn json_has_every_field_and_units() {
        begin();
        add_nodes_probed(1);
        let j = take().unwrap().to_json();
        for key in [
            "\"tables_loaded\":0",
            "\"tables_cached\":0",
            "\"bytes_scanned\":0",
            "\"adtree_nodes_probed\":1",
            "\"subtract_depth\":0",
            "\"rows_merged\":0",
            "\"fo_groups\":0",
            "\"units\":1",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn accumulators_are_thread_local() {
        begin();
        add_nodes_probed(1);
        let h = std::thread::spawn(|| {
            // Fresh thread: no accumulator until its own begin().
            assert!(!active());
            add_nodes_probed(100);
            begin();
            add_nodes_probed(5);
            take().unwrap().adtree_nodes_probed
        });
        assert_eq!(h.join().unwrap(), 5);
        assert_eq!(take().unwrap().adtree_nodes_probed, 1);
    }

    #[test]
    fn totals_accumulate_across_charges() {
        let before = totals();
        let mut c = QueryCost::default();
        c.tables_loaded = 2;
        c.subtract_depth = 3;
        c.charge_totals();
        let after = totals();
        assert_eq!(after.tables_loaded - before.tables_loaded, 2);
        assert_eq!(after.subtract_depth - before.subtract_depth, 3);
    }

    #[test]
    fn merge_adds_fieldwise() {
        let mut a = QueryCost { tables_loaded: 1, rows_merged: 5, ..Default::default() };
        let b = QueryCost { tables_loaded: 2, fo_groups: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.tables_loaded, 3);
        assert_eq!(a.rows_merged, 5);
        assert_eq!(a.fo_groups, 1);
    }
}
