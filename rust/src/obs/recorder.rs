//! The flight recorder: always-on retention of recent request traces.
//!
//! Two rings, both tiny and bounded: the **last-N** ring keeps the
//! most recent finished traces in arrival order, and the **slowest-K**
//! ring keeps the worst total times seen since startup — so a latency
//! cliff that happened an hour ago is still on record even after the
//! last-N ring has cycled past it.
//!
//! The recorder is process-global behind one mutex, touched only when
//! a trace actually finishes (the sampled path, plus every panic and
//! blown deadline) — never on the per-span hot path. [`dump_json`]
//! backs the `DUMP` wire verb; [`auto_dump`] writes the same document
//! to stderr when a worker panics or a request blows its deadline,
//! throttled to at most one dump per second so a panic storm cannot
//! flood the log.

use crate::obs::trace::Trace;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Depth of the most-recent ring.
pub const LAST_N: usize = 32;
/// Depth of the slowest-ever ring.
pub const SLOWEST_K: usize = 8;

/// Auto-dumps suppressed by the 1/sec throttle, for `METRICS`.
pub static DUMPS_SUPPRESSED: AtomicU64 = AtomicU64::new(0);

struct Inner {
    last: Vec<Trace>,
    /// Next insertion slot once `last` is full.
    next: usize,
    /// Sorted descending by `total_us`, at most [`SLOWEST_K`] long.
    slowest: Vec<Trace>,
    recorded: u64,
    last_dump: Option<Instant>,
}

static GLOBAL: Mutex<Inner> =
    Mutex::new(Inner { last: Vec::new(), next: 0, slowest: Vec::new(), recorded: 0, last_dump: None });

fn lock() -> std::sync::MutexGuard<'static, Inner> {
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Publish a finished trace into both rings.
pub fn record(t: Trace) {
    let mut g = lock();
    g.recorded += 1;
    let tail_us = g.slowest.last().map_or(0, |s| s.total_us);
    if g.slowest.len() < SLOWEST_K || t.total_us > tail_us {
        g.slowest.push(t.clone());
        g.slowest.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        g.slowest.truncate(SLOWEST_K);
    }
    if g.last.len() < LAST_N {
        g.last.push(t);
    } else {
        let i = g.next;
        g.last[i] = t;
    }
    g.next = (g.next + 1) % LAST_N;
}

/// Traces recorded since startup (or [`reset`]).
pub fn recorded_count() -> u64 {
    lock().recorded
}

fn dump_locked(g: &Inner) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str(&format!("{{\"recorded\":{},\"last\":[", g.recorded));
    // Oldest-to-newest: the ring's insertion point splits the order.
    let (a, b) = if g.last.len() < LAST_N {
        (&g.last[..], &g.last[..0])
    } else {
        (&g.last[g.next..], &g.last[..g.next])
    };
    for (i, t) in a.iter().chain(b.iter()).enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&t.to_json());
    }
    out.push_str("],\"slowest\":[");
    for (i, t) in g.slowest.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&t.to_json());
    }
    out.push_str("]}");
    out
}

/// The full recorder state as one JSON line — the `DUMP` verb's body.
pub fn dump_json() -> String {
    dump_locked(&lock())
}

/// Dump to stderr on an abnormal outcome (panic, blown deadline),
/// throttled to one per second; suppressed dumps are counted, not lost
/// silently.
pub fn auto_dump(reason: &str) {
    let doc = {
        let mut g = lock();
        let now = Instant::now();
        if g.last_dump.is_some_and(|t| now.duration_since(t) < Duration::from_secs(1)) {
            DUMPS_SUPPRESSED.fetch_add(1, Ordering::Relaxed);
            return;
        }
        g.last_dump = Some(now);
        dump_locked(&g)
    };
    eprintln!("mrss: flight recorder dump ({reason}): {doc}");
}

/// Clear all recorder state. Test-only seam: the recorder is
/// process-global, so tests sharing a binary must start clean.
#[doc(hidden)]
pub fn reset() {
    let mut g = lock();
    g.last.clear();
    g.next = 0;
    g.slowest.clear();
    g.recorded = 0;
    g.last_dump = None;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::MutexGuard;

    // The recorder is process-global; serialize tests that mutate it.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> MutexGuard<'static, ()> {
        let g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        reset();
        g
    }

    fn mk(query: &str, total_us: u64) -> Trace {
        Trace::minimal(query, "ok", total_us)
    }

    #[test]
    fn last_ring_keeps_the_newest_n_in_order() {
        let _g = guard();
        for i in 0..(LAST_N + 3) {
            record(mk(&format!("q{i}"), 10));
        }
        let dump = dump_json();
        assert!(dump.contains(&format!("\"recorded\":{}", LAST_N + 3)), "{dump}");
        // The three oldest have been overwritten...
        for i in 0..3 {
            assert!(!dump.contains(&format!("\"query\":\"q{i}\"")), "q{i} survived: {dump}");
        }
        // ...and the survivors appear oldest-first.
        let p3 = dump.find("\"query\":\"q3\"").expect("q3 present");
        let p_last = dump.find(&format!("\"query\":\"q{}\"", LAST_N + 2)).expect("newest present");
        assert!(p3 < p_last, "ring not in arrival order: {dump}");
    }

    #[test]
    fn slowest_ring_keeps_the_worst_k_ever() {
        let _g = guard();
        // Slow traces first, then enough fast ones to cycle the last-N
        // ring completely: the slow ones must survive in `slowest`.
        for i in 0..SLOWEST_K {
            record(mk(&format!("slow{i}"), 1_000_000 + i as u64));
        }
        for i in 0..LAST_N {
            record(mk(&format!("fast{i}"), 5));
        }
        let dump = dump_json();
        let slowest_at = dump.find("\"slowest\":[").unwrap();
        for i in 0..SLOWEST_K {
            assert!(dump[slowest_at..].contains(&format!("\"query\":\"slow{i}\"")), "{dump}");
        }
    }

    #[test]
    fn auto_dump_throttles_and_counts_suppressions() {
        let _g = guard();
        record(mk("q", 10));
        let before = DUMPS_SUPPRESSED.load(Ordering::Relaxed);
        auto_dump("test");
        auto_dump("test"); // within the 1s window: suppressed
        assert_eq!(DUMPS_SUPPRESSED.load(Ordering::Relaxed), before + 1);
    }

    #[test]
    fn empty_recorder_dumps_a_valid_skeleton() {
        let _g = guard();
        assert_eq!(dump_json(), "{\"recorded\":0,\"last\":[],\"slowest\":[]}");
    }
}
