//! `obs` — dependency-free observability for the serving stack.
//!
//! The paper's claim is about *where time goes* — the Möbius Virtual
//! Join answers negative-relationship counts without materializing
//! joins — yet aggregate counters (`STATS`, `MjMetrics::breakdown`)
//! cannot show, for one slow query, which FO-groups factorized, which
//! ct-tables were loaded vs. cache-hit, or whether Möbius subtraction
//! or a joint derivation produced the answer. This module makes each
//! request explain itself:
//!
//! * [`trace`] — structured span tracing: a per-thread trace of named,
//!   nested spans (`parse`, `plan.*`, `table.*`, `adtree.*`,
//!   `mobius.subtract`, `render`) recorded without locks. A span site
//!   costs one relaxed atomic load when no trace is active anywhere in
//!   the process, so instrumentation can stay in the hot planning and
//!   store paths permanently.
//! * [`recorder`] — an always-on flight recorder holding the last-N
//!   finished traces plus a slowest-K ring, dumped over the wire via
//!   the `DUMP` verb and automatically (throttled, to stderr) on a
//!   worker panic or a blown request deadline.
//! * [`prom`] — Prometheus text-format exposition (`# TYPE`/`# HELP`,
//!   counters, gauges, cumulative-bucket histograms) for the `METRICS`
//!   verb, plus the format validator CI runs against a live scrape and
//!   the two-scrape monotonicity checker that catches silent counter
//!   resets.
//! * [`cost`] — per-query resource accounting: a [`QueryCost`]
//!   accumulated through the planner, store, and ADtree taps while a
//!   worker executes one query, attached to its trace (so `EXPLAIN`
//!   reports *what the query spent*, not just where time went) and
//!   charged into process-global totals.
//! * [`sketch`] — a Misra-Gries heavy-hitter summary over query *plan
//!   signatures* (sorted relationship set + sign pattern): the `TOP`
//!   verb's O(k)-memory answer to "which query shapes dominate by
//!   count / cost / latency".
//! * [`history`] — a per-second aggregation ring (10 minutes of slots:
//!   qps, windowed p50/p99, queue depth, cache hit rate, cost totals,
//!   process RSS/CPU/fds) flushed by the shard-0 reactor tick and
//!   served by `HISTORY` as a JSON series, so rates are observable
//!   without an external scraper.
//! * [`profile`] — the third tier: a span-stack *sampling profiler*.
//!   Registered threads publish their live span stack into seqlock
//!   slots (two relaxed stores per push/pop); a sampler thread walks
//!   the registry at `--profile-hz` and folds samples into collapsed
//!   flamegraph stacks, served by `PROFILE [secs]` as a timed capture.
//!   Also owns per-role thread-CPU accounting (busy/idle split via
//!   `CLOCK_THREAD_CPUTIME_ID`).
//! * [`proc`] — raw `clock_gettime` CPU clocks and the
//!   `/proc/self/{stat,status,fd}` reader behind the `process_*`
//!   Prometheus families and the history ring's resource columns.
//!
//! The wire surface lives in [`crate::serve::protocol`] (`EXPLAIN`,
//! `METRICS`, `DUMP`, `TOP`, `HISTORY`, `PROFILE`) and the sampling
//! policy (`--trace-sample 1/N`, `--access-log PATH`, `--profile-hz`)
//! in [`crate::serve::server`]; this module owns only the mechanisms.

pub mod cost;
pub mod history;
pub mod proc;
pub mod profile;
pub mod prom;
pub mod recorder;
pub mod sketch;
pub mod trace;

pub use cost::QueryCost;
pub use history::HistoryRing;
pub use proc::ProcessStats;
pub use prom::PromText;
pub use recorder::dump_json;
pub use sketch::TopSketch;
pub use trace::{SpanGuard, SpanRec, Trace};
