//! `obs` — dependency-free observability for the serving stack.
//!
//! The paper's claim is about *where time goes* — the Möbius Virtual
//! Join answers negative-relationship counts without materializing
//! joins — yet aggregate counters (`STATS`, `MjMetrics::breakdown`)
//! cannot show, for one slow query, which FO-groups factorized, which
//! ct-tables were loaded vs. cache-hit, or whether Möbius subtraction
//! or a joint derivation produced the answer. This module makes each
//! request explain itself:
//!
//! * [`trace`] — structured span tracing: a per-thread trace of named,
//!   nested spans (`parse`, `plan.*`, `table.*`, `adtree.*`,
//!   `mobius.subtract`, `render`) recorded without locks. A span site
//!   costs one relaxed atomic load when no trace is active anywhere in
//!   the process, so instrumentation can stay in the hot planning and
//!   store paths permanently.
//! * [`recorder`] — an always-on flight recorder holding the last-N
//!   finished traces plus a slowest-K ring, dumped over the wire via
//!   the `DUMP` verb and automatically (throttled, to stderr) on a
//!   worker panic or a blown request deadline.
//! * [`prom`] — Prometheus text-format exposition (`# TYPE`/`# HELP`,
//!   counters, gauges, cumulative-bucket histograms) for the `METRICS`
//!   verb, plus the format validator CI runs against a live scrape.
//!
//! The wire surface lives in [`crate::serve::protocol`] (`EXPLAIN`,
//! `METRICS`, `DUMP`) and the sampling policy (`--trace-sample 1/N`,
//! `--access-log PATH`) in [`crate::serve::server`]; this module owns
//! only the mechanisms.

pub mod prom;
pub mod recorder;
pub mod trace;

pub use prom::PromText;
pub use recorder::dump_json;
pub use trace::{SpanGuard, SpanRec, Trace};
