//! Continuous in-process span-stack sampling profiler.
//!
//! `EXPLAIN` (PR 8) shows where *one sampled request* spent its time;
//! the cost layer (PR 9) shows what requests *consumed*. Neither
//! answers "where is the CPU right now, across everything the process
//! does?" — the question every perf PR (SIMD kernels, sharding) has to
//! start from. This module answers it without any dependency and
//! without stopping threads:
//!
//! * **Publication** — every registered thread owns one seqlock-style
//!   [`Slot`] in a process-global registry and publishes its current
//!   span stack into it: a span push/pop is two relaxed pointer-word
//!   stores plus two sequence bumps, no locks. The span sites already
//!   exist — [`crate::obs::trace`] guards call [`push_frame`] /
//!   [`pop_frame`] whether or not a trace is armed. When the profiler
//!   is off (`--profile-hz 0`) no thread claims a slot and the publish
//!   path is a thread-local load and a branch.
//! * **Sampling** — a dedicated sampler thread walks the registry at
//!   `--profile-hz` (default 99, deliberately co-prime with common
//!   periodic work), seqlock-reads each thread's stack, and folds it
//!   into cumulative collapsed-stack counts — the exact
//!   `frame;frame;frame N` format `flamegraph.pl` and inferno consume.
//!   A torn read (writer mid-update after retries) is counted under
//!   the `<torn>` pseudo-stack and an empty stack under
//!   `<role>.idle`, so **every sample lands in exactly one folded
//!   bucket**: folded counts always sum to the sampler's tick count.
//! * **Capture** — [`capture`] (the `PROFILE [secs]` wire verb and
//!   `mrss profile` client) diffs the cumulative aggregate across a
//!   timed window and renders folded stacks + a top-N self-time table
//!   (leaf-frame attribution, idle/torn excluded) + a process resource
//!   snapshot as one JSON line.
//! * **Per-thread CPU accounting** — [`register`]ed threads call
//!   [`note_cpu`] at job boundaries; the delta of
//!   `CLOCK_THREAD_CPUTIME_ID` ([`crate::obs::proc`]) splits wall time
//!   into busy (CPU actually burned) vs idle (blocked) per role,
//!   surfaced in `STATS` (`"threads"`) and
//!   `mrss_thread_cpu_seconds_total{role=…}`.

use crate::obs::proc;
use crate::serve::protocol::json_escape;
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Deepest span stack a slot publishes; pushes past it are counted in
/// `depth` but not stored, so pop stays symmetric and the sampler just
/// sees a truncated stack.
pub const MAX_DEPTH: usize = 32;
/// Registry capacity. Threads past it profile nothing (CPU accounting
/// still works); serving uses a few dozen threads at most.
const MAX_THREADS: usize = 256;
/// `Slot::role` value for an unclaimed slot.
const FREE: usize = usize::MAX;

/// What kind of thread a registration represents — the label on CPU
/// accounting and the `<role>.idle` folded bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Worker,
    Shard,
    Sampler,
}

pub const ALL_ROLES: [Role; 3] = [Role::Worker, Role::Shard, Role::Sampler];

impl Role {
    fn idx(self) -> usize {
        match self {
            Role::Worker => 0,
            Role::Shard => 1,
            Role::Sampler => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Role::Worker => "worker",
            Role::Shard => "shard",
            Role::Sampler => "sampler",
        }
    }

    fn idle_name(self) -> &'static str {
        match self {
            Role::Worker => "worker.idle",
            Role::Shard => "shard.idle",
            Role::Sampler => "sampler.idle",
        }
    }

    fn from_idx(i: usize) -> Role {
        ALL_ROLES[i]
    }
}

/// One thread's published span stack. The owning thread is the only
/// writer; the sampler validates `seq` around its reads (classic
/// seqlock), so a frame is only materialized from a consistent
/// `(ptr, len)` pair — and span names are `&'static str` literals, so
/// any consistent pair is valid forever.
struct FrameCell {
    ptr: AtomicUsize,
    len: AtomicUsize,
}

struct Slot {
    /// Even = stable, odd = writer mid-update.
    seq: AtomicU64,
    /// Frames pushed (may exceed [`MAX_DEPTH`]; storage truncates).
    depth: AtomicUsize,
    /// Owning role index, or [`FREE`].
    role: AtomicUsize,
    frames: Vec<FrameCell>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            depth: AtomicUsize::new(0),
            role: AtomicUsize::new(FREE),
            frames: (0..MAX_DEPTH)
                .map(|_| FrameCell { ptr: AtomicUsize::new(0), len: AtomicUsize::new(0) })
                .collect(),
        }
    }

    #[inline]
    fn push(&self, name: &'static str) {
        let d = self.depth.load(Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
        if d < MAX_DEPTH {
            self.frames[d].ptr.store(name.as_ptr() as usize, Ordering::Relaxed);
            self.frames[d].len.store(name.len(), Ordering::Relaxed);
        }
        self.depth.store(d + 1, Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
    }

    #[inline]
    fn pop(&self) {
        let d = self.depth.load(Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
        self.depth.store(d.saturating_sub(1), Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
    }

    /// Seqlock read of the published stack. `Some(frames)` on a
    /// consistent snapshot (empty = idle), `None` after repeated torn
    /// reads — the writer was mid-update every attempt.
    fn sample(&self) -> Option<Vec<&'static str>> {
        for _ in 0..4 {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let depth = self.depth.load(Ordering::Relaxed).min(MAX_DEPTH);
            let mut raw = [(0usize, 0usize); MAX_DEPTH];
            for (i, cell) in self.frames.iter().enumerate().take(depth) {
                raw[i] = (cell.ptr.load(Ordering::Relaxed), cell.len.load(Ordering::Relaxed));
            }
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) != s1 {
                continue;
            }
            let mut out = Vec::with_capacity(depth);
            for &(p, l) in raw.iter().take(depth) {
                if p == 0 {
                    return None; // never-written cell in a claimed slot: treat as torn
                }
                // Safety: the seqlock validated that (p, l) is a pair the
                // owning thread published together from a `&'static str`,
                // which lives (and stays valid UTF-8) for the process
                // lifetime.
                out.push(unsafe {
                    std::str::from_utf8_unchecked(std::slice::from_raw_parts(p as *const u8, l))
                });
            }
            return Some(out);
        }
        None
    }

    fn release(&self) {
        self.seq.fetch_add(1, Ordering::Release);
        self.depth.store(0, Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
        self.role.store(FREE, Ordering::Release);
    }
}

fn slots() -> &'static [Slot] {
    static SLOTS: OnceLock<Box<[Slot]>> = OnceLock::new();
    SLOTS.get_or_init(|| (0..MAX_THREADS).map(|_| Slot::new()).collect())
}

/// Samplers currently running (multiple servers in one test process
/// each start their own). Non-zero ⇒ new registrations claim slots.
static ACTIVE_SAMPLERS: AtomicU64 = AtomicU64::new(0);
/// The sampling rate the most recent sampler was started with (for
/// capture rendering).
static CURRENT_HZ: AtomicU64 = AtomicU64::new(0);

/// True while at least one sampler is running.
#[inline]
pub fn active() -> bool {
    ACTIVE_SAMPLERS.load(Ordering::Relaxed) > 0
}

// Per-role CPU accounting (nanoseconds) + live thread-count gauges.
static BUSY_NS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
static IDLE_NS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
static THREADS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

#[derive(Clone, Copy)]
struct CpuState {
    role: usize,
    last_wall: Instant,
    last_cpu_ns: u64,
}

thread_local! {
    /// Fast-path cell the span publish sites read: `None` ⇒ the thread
    /// profiles nothing and push/pop cost a load and a branch.
    static PSLOT: Cell<Option<&'static Slot>> = const { Cell::new(None) };
    static CPU: Cell<Option<CpuState>> = const { Cell::new(None) };
}

/// Publish a span entry on the calling thread. Returns whether a frame
/// was actually published — the caller must [`pop_frame`] exactly when
/// it returned `true` (trace guards keep the flag).
#[inline]
pub fn push_frame(name: &'static str) -> bool {
    PSLOT.with(|c| match c.get() {
        Some(slot) => {
            slot.push(name);
            true
        }
        None => false,
    })
}

/// Publish a span exit on the calling thread.
#[inline]
pub fn pop_frame() {
    PSLOT.with(|c| {
        if let Some(slot) = c.get() {
            slot.pop();
        }
    });
}

/// RAII registration of the calling thread with the profiler. Claims a
/// publish slot when a sampler is active, and arms per-role CPU
/// accounting either way. Dropped when the thread exits its loop.
pub struct ThreadReg {
    slot: Option<&'static Slot>,
    role: Role,
}

/// Register the calling thread under `role`.
pub fn register(role: Role) -> ThreadReg {
    CPU.with(|c| {
        c.set(Some(CpuState {
            role: role.idx(),
            last_wall: Instant::now(),
            last_cpu_ns: proc::thread_cpu_ns(),
        }))
    });
    THREADS[role.idx()].fetch_add(1, Ordering::Relaxed);
    let slot = if active() { claim_slot(role) } else { None };
    if let Some(s) = slot {
        PSLOT.with(|c| c.set(Some(s)));
    }
    ThreadReg { slot, role }
}

fn claim_slot(role: Role) -> Option<&'static Slot> {
    slots().iter().find(|s| {
        s.role
            .compare_exchange(FREE, role.idx(), Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    })
}

impl Drop for ThreadReg {
    fn drop(&mut self) {
        note_cpu();
        CPU.with(|c| c.set(None));
        THREADS[self.role.idx()].fetch_sub(1, Ordering::Relaxed);
        if let Some(slot) = self.slot {
            PSLOT.with(|c| c.set(None));
            slot.release();
        }
    }
}

/// Sample the calling thread's CPU clock and attribute the interval
/// since the last call: thread-CPU delta ⇒ busy, the rest of the wall
/// delta ⇒ idle (blocked on the queue / poller / sleep). Workers and
/// shards call this at job boundaries, the sampler each tick. No-op on
/// unregistered threads.
pub fn note_cpu() {
    CPU.with(|c| {
        if let Some(mut st) = c.get() {
            let now = Instant::now();
            let cpu = proc::thread_cpu_ns();
            let dcpu = cpu.saturating_sub(st.last_cpu_ns);
            let dwall = now.duration_since(st.last_wall).as_nanos() as u64;
            BUSY_NS[st.role].fetch_add(dcpu, Ordering::Relaxed);
            IDLE_NS[st.role].fetch_add(dwall.saturating_sub(dcpu), Ordering::Relaxed);
            st.last_wall = now;
            st.last_cpu_ns = cpu;
            c.set(Some(st));
        }
    });
}

/// One role's accumulated CPU split plus its live thread count.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RoleCpu {
    pub busy_us: u64,
    pub idle_us: u64,
    pub threads: u64,
}

/// Per-role CPU accounting snapshot, indexed like [`ALL_ROLES`].
pub fn cpu_snapshot() -> [RoleCpu; 3] {
    std::array::from_fn(|i| RoleCpu {
        busy_us: BUSY_NS[i].load(Ordering::Relaxed) / 1_000,
        idle_us: IDLE_NS[i].load(Ordering::Relaxed) / 1_000,
        threads: THREADS[i].load(Ordering::Relaxed),
    })
}

/// Render the `STATS` `"threads"` object from a snapshot.
pub fn threads_json(roles: &[RoleCpu; 3]) -> String {
    let mut out = String::from("{");
    for (i, rc) in roles.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\"{}\":{{\"busy_us\":{},\"idle_us\":{},\"n\":{}}}",
            Role::from_idx(i).name(),
            rc.busy_us,
            rc.idle_us,
            rc.threads
        ));
    }
    out.push('}');
    out
}

// ---------------------------------------------------------------------------
// sampler + folded aggregation
// ---------------------------------------------------------------------------

#[derive(Default, Clone)]
struct Agg {
    /// Collapsed stack (`a;b;c`) → cumulative sample count.
    stacks: HashMap<String, u64>,
    /// Thread-samples taken (every one lands in exactly one stack).
    samples: u64,
    /// Samples that stayed torn after retries (also in `stacks` under
    /// `<torn>` — this is a convenience counter, not extra mass).
    torn: u64,
}

static AGG: Mutex<Option<Agg>> = Mutex::new(None);

fn with_agg<T>(f: impl FnOnce(&mut Agg) -> T) -> T {
    let mut guard = AGG.lock().unwrap_or_else(|e| e.into_inner());
    f(guard.get_or_insert_with(Agg::default))
}

/// Thread-samples taken since process start (`mrss_profile_samples_total`).
pub fn samples_total() -> u64 {
    with_agg(|a| a.samples)
}

/// Walk the registry once and fold every claimed slot's stack into the
/// cumulative aggregate. Separated from the sampler loop so tests can
/// drive ticks deterministically.
fn sample_once() {
    // Read all stacks before taking the aggregate lock: keeps the lock
    // hold time independent of seqlock retries.
    let mut sampled: Vec<Result<Vec<&'static str>, Role>> = Vec::new();
    let mut torn = 0u64;
    for slot in slots() {
        let role = slot.role.load(Ordering::Acquire);
        if role == FREE {
            continue;
        }
        match slot.sample() {
            Some(stack) if stack.is_empty() => sampled.push(Err(Role::from_idx(role))),
            Some(stack) => sampled.push(Ok(stack)),
            None => torn += 1,
        }
    }
    with_agg(|agg| {
        for s in &sampled {
            let key = match s {
                Ok(stack) => stack.join(";"),
                Err(role) => role.idle_name().to_string(),
            };
            *agg.stacks.entry(key).or_insert(0) += 1;
            agg.samples += 1;
        }
        for _ in 0..torn {
            *agg.stacks.entry("<torn>".to_string()).or_insert(0) += 1;
            agg.samples += 1;
            agg.torn += 1;
        }
    });
}

/// Handle to a running sampler thread; stop it via [`Sampler::stop`]
/// (or drop). The serving front-end owns one when `--profile-hz > 0`.
pub struct Sampler {
    stop: std::sync::Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Start a sampler at `hz` (None when `hz == 0`). While any sampler
/// runs, newly registered threads claim publish slots.
pub fn start(hz: u64) -> Option<Sampler> {
    if hz == 0 {
        return None;
    }
    slots(); // allocate the registry before anyone races to claim
    CURRENT_HZ.store(hz, Ordering::Relaxed);
    ACTIVE_SAMPLERS.fetch_add(1, Ordering::SeqCst);
    let stop = std::sync::Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let period = Duration::from_nanos(1_000_000_000 / hz.max(1));
    let join = std::thread::Builder::new()
        .name("mrss-profile-sampler".to_string())
        .spawn(move || {
            let _reg = register(Role::Sampler);
            let mut cpu_tick = 0u32;
            while !flag.load(Ordering::Relaxed) {
                sample_once();
                // Thread-CPU bookkeeping once a second, not per tick.
                cpu_tick += 1;
                if cpu_tick >= 100 {
                    cpu_tick = 0;
                    note_cpu();
                }
                std::thread::sleep(period);
            }
        })
        .expect("spawn profiler sampler");
    Some(Sampler { stop, join: Some(join) })
}

impl Sampler {
    /// Stop and join the sampler thread.
    pub fn stop(&mut self) {
        if let Some(join) = self.join.take() {
            self.stop.store(true, Ordering::Relaxed);
            let _ = join.join();
            ACTIVE_SAMPLERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------------
// timed capture (the PROFILE verb)
// ---------------------------------------------------------------------------

fn snapshot_agg() -> Agg {
    with_agg(|a| a.clone())
}

/// Run a timed capture: snapshot the cumulative aggregate, sleep
/// `secs`, snapshot again, render the delta as one JSON line (folded
/// stacks sorted by samples, top-`N` self-time leaves with idle/torn
/// excluded, and a fresh process-stats block). Returns an error object
/// when no sampler is running.
pub fn capture(secs: u64) -> String {
    if !active() {
        return "{\"error\":\"profiler disabled (--profile-hz 0)\"}".to_string();
    }
    let before = snapshot_agg();
    std::thread::sleep(Duration::from_secs(secs));
    let after = snapshot_agg();
    render_capture(secs, CURRENT_HZ.load(Ordering::Relaxed), &before, &after)
}

/// Leaf frame of a collapsed stack.
fn leaf(stack: &str) -> &str {
    stack.rsplit(';').next().unwrap_or(stack)
}

/// Frames that represent absence of work, excluded from the self-time
/// ranking (they still appear in the folded list — the sum invariant
/// needs them).
fn is_idle_frame(frame: &str) -> bool {
    frame == "<torn>" || frame.ends_with(".idle")
}

fn render_capture(secs: u64, hz: u64, before: &Agg, after: &Agg) -> String {
    let ticks = after.samples.saturating_sub(before.samples);
    let torn = after.torn.saturating_sub(before.torn);
    let mut folded: Vec<(&str, u64)> = after
        .stacks
        .iter()
        .filter_map(|(k, v)| {
            let d = v.saturating_sub(before.stacks.get(k).copied().unwrap_or(0));
            (d > 0).then_some((k.as_str(), d))
        })
        .collect();
    folded.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
    let mut self_time: HashMap<&str, u64> = HashMap::new();
    for (stack, n) in &folded {
        let f = leaf(stack);
        if !is_idle_frame(f) {
            *self_time.entry(f).or_insert(0) += n;
        }
    }
    let mut self_top: Vec<(&str, u64)> = self_time.into_iter().collect();
    self_top.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
    self_top.truncate(10);
    let threads: u64 = THREADS.iter().map(|t| t.load(Ordering::Relaxed)).sum();
    let mut out = format!(
        "{{\"secs\":{},\"hz\":{},\"ticks\":{},\"torn\":{},\"threads\":{},\"folded\":[",
        secs, hz, ticks, torn, threads
    );
    for (i, (stack, n)) in folded.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"stack\":\"{}\",\"samples\":{}}}", json_escape(stack), n));
    }
    out.push_str("],\"self\":[");
    for (i, (frame, n)) in self_top.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"frame\":\"{}\",\"samples\":{}}}", json_escape(frame), n));
    }
    out.push_str(&format!("],\"process\":{}}}", proc::read_or_zero().to_json()));
    out
}

/// Extract `(stack, samples)` pairs from a `PROFILE` response — the
/// client side of the folded format (`mrss profile --folded` writes
/// `stack count` lines flamegraph.pl consumes directly). Span names
/// never contain quotes, so a flat scan is exact.
pub fn parse_folded(json: &str) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    let body = match json.find("\"folded\":[") {
        Some(i) => &json[i + "\"folded\":[".len()..],
        None => return out,
    };
    let body = &body[..body.find(']').unwrap_or(body.len())];
    let mut rest = body;
    while let Some(i) = rest.find("{\"stack\":\"") {
        rest = &rest[i + "{\"stack\":\"".len()..];
        let Some(q) = rest.find('"') else { break };
        let stack = rest[..q].to_string();
        rest = &rest[q..];
        let Some(j) = rest.find("\"samples\":") else { break };
        rest = &rest[j + "\"samples\":".len()..];
        let end = rest.find(['}', ',']).unwrap_or(rest.len());
        if let Ok(n) = rest[..end].trim().parse::<u64>() {
            out.push((stack, n));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry, aggregate, and CPU totals are process-global;
    /// profile unit tests serialize on this and assert on *deltas*.
    static SEQ: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        SEQ.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Claim a slot directly (bypassing the `active()` gate) so tests
    /// can drive publication + sampling without a live sampler thread.
    fn claim_for_test(role: Role) -> &'static Slot {
        let slot = claim_slot(role).expect("free slot");
        PSLOT.with(|c| c.set(Some(slot)));
        slot
    }

    fn unclaim(slot: &'static Slot) {
        PSLOT.with(|c| c.set(None));
        slot.release();
    }

    // Other lib tests (server.rs starts real servers with samplers) run
    // concurrently in this process, so assertions on the *global*
    // aggregate are lower bounds on unique test-only frame names; exact
    // checks go straight against our own slot.

    #[test]
    fn push_pop_publish_and_sampler_folds_the_stack() {
        let _g = lock();
        let slot = claim_for_test(Role::Worker);
        assert!(push_frame("t.prof.outer"));
        assert!(push_frame("t.prof.inner"));
        assert_eq!(slot.sample().expect("stable"), vec!["t.prof.outer", "t.prof.inner"]);
        let before = snapshot_agg();
        sample_once();
        pop_frame();
        assert_eq!(slot.sample().expect("stable"), vec!["t.prof.outer"]);
        sample_once();
        pop_frame();
        assert_eq!(slot.sample().expect("stable").len(), 0);
        let after = snapshot_agg();
        let delta = |k: &str| {
            after.stacks.get(k).copied().unwrap_or(0)
                - before.stacks.get(k).copied().unwrap_or(0)
        };
        assert!(delta("t.prof.outer;t.prof.inner") >= 1);
        assert!(delta("t.prof.outer") >= 1);
        assert!(after.samples - before.samples >= 2, "ticks went unrecorded");
        unclaim(slot);
    }

    #[test]
    fn unregistered_threads_publish_nothing() {
        let _g = lock();
        assert!(!push_frame("t.prof.ghost"));
        pop_frame(); // must be a safe no-op
        sample_once();
        assert!(!snapshot_agg().stacks.contains_key("t.prof.ghost"));
    }

    #[test]
    fn depth_overflow_truncates_but_stays_symmetric() {
        let _g = lock();
        let slot = claim_for_test(Role::Worker);
        for _ in 0..(MAX_DEPTH + 8) {
            push_frame("deep");
        }
        let stack = slot.sample().expect("consistent read");
        assert_eq!(stack.len(), MAX_DEPTH);
        for _ in 0..(MAX_DEPTH + 8) {
            pop_frame();
        }
        assert_eq!(slot.sample().expect("consistent read").len(), 0);
        unclaim(slot);
    }

    #[test]
    fn capture_render_sums_folded_to_ticks_and_ranks_self_time() {
        let before = Agg::default();
        let mut after = Agg::default();
        for (k, v) in [
            ("serve.exec;worker.exec.delay", 40u64),
            ("serve.exec;table.count", 9),
            ("shard.idle", 30),
            ("<torn>", 1),
        ] {
            after.stacks.insert(k.to_string(), v);
        }
        after.samples = 80;
        after.torn = 1;
        let j = render_capture(2, 99, &before, &after);
        assert!(j.contains("\"secs\":2") && j.contains("\"hz\":99"), "{j}");
        assert!(j.contains("\"ticks\":80") && j.contains("\"torn\":1"), "{j}");
        // Folded entries sum to ticks and parse back losslessly.
        let folded = parse_folded(&j);
        assert_eq!(folded.iter().map(|(_, n)| n).sum::<u64>(), 80, "{j}");
        assert_eq!(folded[0], ("serve.exec;worker.exec.delay".to_string(), 40));
        // Self-time ranks the delay leaf first and excludes idle/torn.
        let self_at = j.find("\"self\":[").expect("self table");
        let self_body = &j[self_at..];
        assert!(
            self_body.starts_with("\"self\":[{\"frame\":\"worker.exec.delay\",\"samples\":40}"),
            "{j}"
        );
        assert!(!self_body.contains("idle") && !self_body.contains("<torn>"), "{j}");
        assert!(j.contains("\"process\":{\"rss_bytes\":"), "{j}");
    }

    #[test]
    fn capture_without_a_sampler_reports_disabled() {
        // No sampler started in unit tests unless a test starts one.
        if !active() {
            assert!(capture(1).contains("profiler disabled"));
        }
    }

    #[test]
    fn sampler_thread_runs_ticks_and_stops_cleanly() {
        let _g = lock();
        let t0 = samples_total();
        let mut s = start(200).expect("hz > 0 starts");
        assert!(active());
        // The sampler registers itself, so ticks accumulate even with
        // no other thread claimed.
        let deadline = Instant::now() + Duration::from_secs(2);
        while samples_total() == t0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(samples_total() > t0, "sampler never ticked");
        s.stop();
        s.stop(); // idempotent
        assert!(start(0).is_none());
    }

    #[test]
    fn cpu_accounting_attributes_busy_time_to_the_role() {
        let _g = lock();
        let before = cpu_snapshot()[Role::Shard.idx()];
        let reg = register(Role::Shard);
        let mut x = 0u64;
        for i in 0..3_000_000u64 {
            x = x.wrapping_add(i ^ (x >> 3));
        }
        assert!(x != 42);
        std::thread::sleep(Duration::from_millis(5));
        note_cpu();
        let mid = cpu_snapshot()[Role::Shard.idx()];
        // Thread counts fluctuate with concurrent server tests; this
        // registration alone guarantees at least one shard thread, and
        // the split never goes backwards (off-Linux busy stays flat at 0).
        assert!(mid.threads >= 1);
        assert!(mid.busy_us >= before.busy_us);
        assert!(mid.idle_us >= before.idle_us);
        #[cfg(target_os = "linux")]
        assert!(mid.busy_us > before.busy_us, "spin loop burned no CPU?");
        drop(reg);
    }

    #[test]
    fn threads_json_names_all_roles() {
        let j = threads_json(&[
            RoleCpu { busy_us: 1, idle_us: 2, threads: 3 },
            RoleCpu { busy_us: 4, idle_us: 5, threads: 6 },
            RoleCpu::default(),
        ]);
        assert_eq!(
            j,
            "{\"worker\":{\"busy_us\":1,\"idle_us\":2,\"n\":3},\
             \"shard\":{\"busy_us\":4,\"idle_us\":5,\"n\":6},\
             \"sampler\":{\"busy_us\":0,\"idle_us\":0,\"n\":0}}"
        );
    }

    #[test]
    fn parse_folded_handles_empty_and_missing() {
        assert!(parse_folded("{}").is_empty());
        assert!(parse_folded("{\"folded\":[]}").is_empty());
        let one = "{\"folded\":[{\"stack\":\"a;b\",\"samples\":7}],\"self\":[]}";
        assert_eq!(parse_folded(one), vec![("a;b".to_string(), 7)]);
    }
}
