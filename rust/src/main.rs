//! `mrss` — CLI for the Multi-Relational Sufficient Statistics system.
//!
//! ```text
//! mrss datasets                               # Table 2: benchmark shapes
//! mrss ct    --dataset imdb --scale 0.25      # Möbius Join + breakdown
//! mrss cp    --dataset movielens --scale 0.1  # cross-product baseline
//! mrss suite --scale 0.1 --workers 2          # all seven benchmarks
//! mrss mine  --dataset financial --scale 0.2  # CFS + association rules
//! mrss bn    --dataset financial --scale 0.2  # BN learning on vs off
//! ```
//!
//! Add `--engine xla` to route bulk ct-algebra through the AOT-compiled
//! PJRT artifacts (`make artifacts` first).

use mrss::apps::{apriori, bayesnet, cfs};
use mrss::bail;
use mrss::util::error::Result;
use mrss::baseline::cross_product_ct;
use mrss::config::{Config, EngineKind};
use mrss::coordinator::{run_suite, PoolConfig, SuiteJob};
use mrss::ct::render_ct;
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::runtime::{XlaEngine, XlaRuntime};
use mrss::util::format_duration;
use mrss::util::table::{commas, TextTable};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print_help();
        return;
    }
    let cfg = match Config::from_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cfg) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mrss {} — Möbius Join sufficient statistics (CIKM 2014 reproduction)\n\n\
         commands:\n\
         \x20 datasets                        print the benchmark catalogue (Table 2)\n\
         \x20 ct     --dataset D --scale S    compute all contingency tables (Möbius Join)\n\
         \x20 cp     --dataset D --scale S    cross-product baseline (Table 3)\n\
         \x20 suite  --scale S --workers N    run every benchmark\n\
         \x20 mine   --dataset D --scale S    feature selection + association rules\n\
         \x20 bn     --dataset D --scale S    Bayesian-network learning, link on vs off\n\n\
         common flags: --seed N --engine native|xla --excerpt N --max-chain-len L\n\
         \x20             --cp-budget-secs N --config FILE",
        mrss::VERSION
    );
}

/// Load the XLA runtime when requested (owned by the caller so engines can
/// borrow it).
fn maybe_runtime(cfg: &Config) -> Result<Option<XlaRuntime>> {
    match cfg.engine {
        EngineKind::Native => Ok(None),
        EngineKind::Xla => Ok(Some(XlaRuntime::load_default()?)),
    }
}

fn run(cfg: Config) -> Result<()> {
    match cfg.command.as_str() {
        "datasets" => cmd_datasets(),
        "ct" => cmd_ct(&cfg),
        "cp" => cmd_cp(&cfg),
        "suite" => cmd_suite(&cfg),
        "mine" => cmd_mine(&cfg),
        "bn" => cmd_bn(&cfg),
        other => bail!("unknown command `{other}` (try --help)"),
    }
}

fn cmd_datasets() -> Result<()> {
    let mut t = TextTable::new(vec![
        "Dataset",
        "#Rel/Total",
        "#Self",
        "#Tuples(paper)",
        "#Attrs",
        "Target",
    ]);
    for b in datagen::BENCHMARKS {
        let s = datagen::schema_of(b.name)?;
        t.row(vec![
            b.name.to_string(),
            format!("{} / {}", s.num_rel_vars(), s.num_tables()),
            s.num_self_rels().to_string(),
            commas(b.paper_tuples as u128),
            s.num_attributes().to_string(),
            b.target.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_ct(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    println!(
        "{} @ scale {}: {} tuples",
        cfg.dataset,
        cfg.scale,
        commas(db.total_tuples() as u128)
    );
    let rt = maybe_runtime(cfg)?;
    let res = match &rt {
        Some(rt) => {
            let engine = XlaEngine::new(rt);
            let mut mj = MobiusJoin::with_engine(&db, &engine).workers(cfg.workers);
            if let Some(l) = cfg.max_chain_len {
                mj = mj.max_chain_len(l);
            }
            mj.run()
        }
        None => {
            let mut mj = MobiusJoin::new(&db).workers(cfg.workers);
            if let Some(l) = cfg.max_chain_len {
                mj = mj.max_chain_len(l);
            }
            mj.run()
        }
    };
    println!(
        "{} chains in the lattice; engine = {}",
        res.lattice.len(),
        if rt.is_some() { "xla" } else { "native" }
    );
    if res.joint.is_some() {
        println!(
            "#statistics = {} (link-off {}, extra {})",
            commas(res.num_statistics() as u128),
            commas(res.link_off().len() as u128),
            commas(res.num_extra_statistics() as u128)
        );
    }
    println!("{}", res.metrics.breakdown());
    if cfg.excerpt > 0 {
        if let Some(joint) = &res.joint {
            println!("{}", render_ct(joint, &db.schema, cfg.excerpt));
        }
    }
    Ok(())
}

fn cmd_cp(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    let out = cross_product_ct(&db, cfg.cp_budget());
    match out {
        mrss::baseline::CpOutcome::Done { ref ct, cp_tuples, elapsed } => {
            println!(
                "CP done in {}: {} cross-product tuples -> {} statistics (ratio {:.2})",
                format_duration(elapsed),
                commas(cp_tuples),
                commas(ct.len() as u128),
                cp_tuples as f64 / ct.len() as f64
            );
        }
        mrss::baseline::CpOutcome::NonTermination { cp_tuples, elapsed } => {
            println!(
                "CP N.T. after {} ({} cross-product tuples)",
                format_duration(elapsed),
                commas(cp_tuples)
            );
        }
    }
    Ok(())
}

fn cmd_suite(cfg: &Config) -> Result<()> {
    // `--workers` fans out across jobs here; per-job lattice levels stay
    // serial to avoid oversubscription (use `ct --workers N` for that).
    let jobs: Vec<SuiteJob> = datagen::BENCHMARKS
        .iter()
        .map(|b| SuiteJob::new(b.name, cfg.scale, cfg.seed))
        .collect();
    let reports = run_suite(jobs, PoolConfig { workers: cfg.workers, queue_depth: 2 });
    let mut t = TextTable::new(vec![
        "Dataset", "#Tuples", "MJ-time", "#Stats", "LinkOff", "#Extra", "ExtraTime",
    ]);
    for rep in reports {
        match rep {
            Ok(r) => {
                t.row(vec![
                    r.dataset.clone(),
                    commas(r.tuples as u128),
                    format_duration(r.mj_time),
                    commas(r.statistics as u128),
                    commas(r.link_off_statistics as u128),
                    commas(r.extra_statistics as u128),
                    format_duration(r.extra_time),
                ]);
            }
            Err(e) => eprintln!("job failed: {e:#}"),
        }
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_mine(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    let schema = &db.schema;
    let res = MobiusJoin::new(&db).workers(cfg.workers).run();
    let rt = maybe_runtime(cfg)?;
    let rt = rt.as_ref();

    let target_name = datagen::info(&cfg.dataset).map(|b| b.target).unwrap_or("");
    let target = schema
        .var_by_name(target_name)
        .ok_or_else(|| mrss::anyhow!("target {target_name} not found"))?;

    // Feature selection, link off vs on (Table 5).
    let joint = res.joint_ct();
    let off_ct = res.link_off();
    let attrs: Vec<usize> = (0..schema.random_vars.len())
        .filter(|&v| !matches!(schema.random_vars[v], mrss::schema::RandomVar::RelInd { .. }))
        .collect();
    let all_vars: Vec<usize> = (0..schema.random_vars.len()).collect();
    let off = cfs::cfs_select(&off_ct, target, &attrs, rt);
    let on = cfs::cfs_select(joint, target, &all_vars, rt);
    println!("CFS target {target_name}:");
    let names =
        |vs: &[usize]| vs.iter().map(|&v| schema.var_name(v)).collect::<Vec<_>>().join(", ");
    println!("  link off: [{}]", names(&off.selected));
    println!("  link on : [{}]", names(&on.selected));
    println!("  distinctness = {:.2}", cfs::distinctness(&off.selected, &on.selected));

    // Association rules (Table 6).
    let min_support: f64 =
        cfg.extra.get("min-support").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let rules = apriori::apriori(
        schema,
        joint,
        apriori::AprioriConfig { min_support, ..Default::default() },
        rt,
    );
    let with_rel = rules.iter().filter(|r| r.uses_rel_var(schema)).count();
    println!("\nTop {} rules by lift ({} use relationship variables):", rules.len(), with_rel);
    for r in rules.iter().take(10) {
        println!("  lift {:.2}  {}", r.lift, r.render(schema));
    }
    Ok(())
}

fn cmd_bn(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    let schema = &db.schema;
    let res = MobiusJoin::new(&db).workers(cfg.workers).run();
    let rt = maybe_runtime(cfg)?;
    let rt = rt.as_ref();
    let joint = res.joint_ct();

    let mut t = TextTable::new(vec!["Mode", "learn-time", "log-lik", "#params", "R2R", "A2R"]);
    for link_on in [false, true] {
        let out = bayesnet::learn_structure(schema, &res, link_on, Default::default());
        let m = bayesnet::score_structure(schema, &out.bn, joint, rt);
        t.row(vec![
            if link_on { "Link Analysis On" } else { "Link Analysis Off" }.to_string(),
            format_duration(out.elapsed),
            format!("{:.2}", m.loglik),
            m.params.to_string(),
            m.r2r.to_string(),
            m.a2r.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
