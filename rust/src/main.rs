//! `mrss` — CLI for the Multi-Relational Sufficient Statistics system.
//!
//! ```text
//! mrss datasets                               # Table 2: benchmark shapes
//! mrss ct    --dataset imdb --scale 0.25      # Möbius Join + breakdown
//! mrss ct    --dataset uwcse --store ./stats  # …and persist the ct-store
//! mrss cp    --dataset movielens --scale 0.1  # cross-product baseline
//! mrss suite --scale 0.1 --workers 2          # all seven benchmarks
//! mrss query --store ./stats --dataset uwcse --queries q.txt   # counts, JSON
//! mrss serve --store ./stats --dataset uwcse  # stdin/stdout count service
//! mrss serve --store ./stats --listen 127.0.0.1:7171 --threads 8  # TCP server
//! mrss bench-serve --store ./stats --clients 8 --queries 200   # load generator
//! mrss mine  --dataset financial --scale 0.2  # CFS + association rules
//! mrss bn    --dataset financial --scale 0.2  # BN learning on vs off
//! ```
//!
//! Add `--engine xla` to route bulk ct-algebra through the AOT-compiled
//! PJRT artifacts (`make artifacts` first). `mine`/`bn` accept `--store`
//! to score from a warm ct-store instead of re-running the join.

use mrss::anyhow;
use mrss::apps::{apriori, bayesnet, cfs};
use mrss::bail;
use mrss::util::error::{Context, Result};
use mrss::baseline::cross_product_ct;
use mrss::config::{Config, EngineKind};
use mrss::coordinator::{run_suite, PoolConfig, SuiteJob};
use mrss::ct::render_ct;
use mrss::datagen;
use mrss::mobius::{MjResult, MobiusJoin};
use mrss::runtime::{XlaEngine, XlaRuntime};
use mrss::schema::Schema;
use mrss::serve::protocol::{json_escape, render_answers};
use mrss::serve::{self, LoadgenConfig, Mix, PollerKind, ServeConfig};
use mrss::store::{gen_queries, parse_query, CountServer, CtStore, PersistConfig, StoreSink};
use mrss::util::format_duration;
use mrss::util::table::{commas, TextTable};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print_help();
        return;
    }
    let cfg = match Config::from_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cfg) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mrss {} — Möbius Join sufficient statistics (CIKM 2014 reproduction)\n\n\
         commands:\n\
         \x20 datasets                        print the benchmark catalogue (Table 2)\n\
         \x20 ct     --dataset D --scale S    compute all contingency tables (Möbius Join)\n\
         \x20 cp     --dataset D --scale S    cross-product baseline (Table 3)\n\
         \x20 suite  --scale S --workers N    run every benchmark\n\
         \x20 query  --store DIR --dataset D  answer count queries from a ct-store (JSON)\n\
         \x20 serve  --store DIR --dataset D  stdin/stdout count-query service\n\
         \x20 serve  --store DIR --listen A   concurrent TCP count server (PING/BATCH/STATS/\n\
         \x20                                 TOP/HISTORY/SHUTDOWN wire protocol)\n\
         \x20 bench-serve --addr A|--store D  drive a count server with N concurrent clients,\n\
         \x20                                 emit BENCH_serve.json\n\
         \x20 validate-metrics --file F       check a Prometheus scrape of METRICS (stdin\n\
         \x20                                 without --file); exit 1 on format errors;\n\
         \x20                                 --prev EARLIER asserts counter monotonicity\n\
         \x20 profile --addr A --secs S       capture S seconds of folded span stacks from a\n\
         \x20                                 running server (`PROFILE` verb); --folded FILE\n\
         \x20                                 writes flamegraph.pl/inferno collapsed input\n\
         \x20 mine   --dataset D --scale S    feature selection + association rules\n\
         \x20 bn     --dataset D --scale S    Bayesian-network learning, link on vs off\n\n\
         common flags: --seed N --engine native|xla --excerpt N --max-chain-len L\n\
         \x20             --cp-budget-secs N --config FILE --store DIR --progress\n\
         query flags:  --queries FILE --query STR --json FILE --gen N --fresh\n\
         \x20             --mem-budget BYTES\n\
         serve flags:  --listen HOST:PORT --threads N --shards N --max-conns N\n\
         \x20             --poller poll|epoll --queue-depth N --max-requests N\n\
         \x20             --wire text|json --idle-timeout MS --request-timeout MS\n\
         \x20             --failpoints SPEC (needs --features failpoints)\n\
         \x20             --trace-sample N|1/N --access-log FILE --profile-hz N\n\
         profile flags: --addr HOST:PORT --secs N --folded FILE --json FILE\n\
         bench flags:  --addr HOST:PORT --clients N --queries M --mix uniform|zipf:S\n\
         \x20             --idle N --bench-json FILE --json FILE --shutdown",
        mrss::VERSION
    );
}

/// Load the XLA runtime when requested (owned by the caller so engines can
/// borrow it).
fn maybe_runtime(cfg: &Config) -> Result<Option<XlaRuntime>> {
    match cfg.engine {
        EngineKind::Native => Ok(None),
        EngineKind::Xla => Ok(Some(XlaRuntime::load_default()?)),
    }
}

fn run(cfg: Config) -> Result<()> {
    match cfg.command.as_str() {
        "datasets" => cmd_datasets(),
        "ct" => cmd_ct(&cfg),
        "cp" => cmd_cp(&cfg),
        "suite" => cmd_suite(&cfg),
        "query" => cmd_query(&cfg),
        "serve" => cmd_serve(&cfg),
        "bench-serve" => cmd_bench_serve(&cfg),
        "validate-metrics" => cmd_validate_metrics(&cfg),
        "profile" => cmd_profile(&cfg),
        "mine" => cmd_mine(&cfg),
        "bn" => cmd_bn(&cfg),
        other => bail!("unknown command `{other}` (try --help)"),
    }
}

fn cmd_datasets() -> Result<()> {
    let mut t = TextTable::new(vec![
        "Dataset",
        "#Rel/Total",
        "#Self",
        "#Tuples(paper)",
        "#Attrs",
        "Target",
    ]);
    for b in datagen::BENCHMARKS {
        let s = datagen::schema_of(b.name)?;
        t.row(vec![
            b.name.to_string(),
            format!("{} / {}", s.num_rel_vars(), s.num_tables()),
            s.num_self_rels().to_string(),
            commas(b.paper_tuples as u128),
            s.num_attributes().to_string(),
            b.target.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_ct(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    println!(
        "{} @ scale {}: {} tuples",
        cfg.dataset,
        cfg.scale,
        commas(db.total_tuples() as u128)
    );
    // With --store, a write-on-complete sink persists every table as the
    // join produces it.
    let store = match &cfg.store {
        Some(root) => Some(CtStore::create(
            Path::new(root).join(&cfg.dataset),
            &cfg.dataset,
            cfg.scale,
            cfg.seed,
        )?),
        None => None,
    };
    let sink = store.as_ref().map(|s| StoreSink::new(s, &db.schema, PersistConfig::default()));
    let rt = maybe_runtime(cfg)?;
    let res = match &rt {
        Some(rt) => {
            let engine = XlaEngine::new(rt);
            let mut mj =
                MobiusJoin::with_engine(&db, &engine).workers(cfg.workers).progress(cfg.progress);
            if let Some(l) = cfg.max_chain_len {
                mj = mj.max_chain_len(l);
            }
            if let Some(s) = &sink {
                mj = mj.sink(s);
            }
            mj.run()
        }
        None => {
            let mut mj = MobiusJoin::new(&db).workers(cfg.workers).progress(cfg.progress);
            if let Some(l) = cfg.max_chain_len {
                mj = mj.max_chain_len(l);
            }
            if let Some(s) = &sink {
                mj = mj.sink(s);
            }
            mj.run()
        }
    };
    println!(
        "{} chains in the lattice; engine = {}",
        res.lattice.len(),
        if rt.is_some() { "xla" } else { "native" }
    );
    if res.joint.is_some() {
        println!(
            "#statistics = {} (link-off {}, extra {})",
            commas(res.num_statistics() as u128),
            commas(res.link_off().len() as u128),
            commas(res.num_extra_statistics() as u128)
        );
    }
    println!("{}", res.metrics.breakdown());
    if let (Some(store), Some(sink)) = (&store, &sink) {
        sink.take_error()?;
        println!(
            "persisted {} tables ({} bytes) to {}",
            store.len(),
            commas(store.disk_bytes() as u128),
            store.dir().display()
        );
    }
    if cfg.excerpt > 0 {
        if let Some(joint) = &res.joint {
            println!("{}", render_ct(joint, &db.schema, cfg.excerpt));
        }
    }
    Ok(())
}

fn cmd_cp(cfg: &Config) -> Result<()> {
    let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
    let out = cross_product_ct(&db, cfg.cp_budget());
    match out {
        mrss::baseline::CpOutcome::Done { ref ct, cp_tuples, elapsed } => {
            println!(
                "CP done in {}: {} cross-product tuples -> {} statistics (ratio {:.2})",
                format_duration(elapsed),
                commas(cp_tuples),
                commas(ct.len() as u128),
                cp_tuples as f64 / ct.len() as f64
            );
        }
        mrss::baseline::CpOutcome::NonTermination { cp_tuples, elapsed } => {
            println!(
                "CP N.T. after {} ({} cross-product tuples)",
                format_duration(elapsed),
                commas(cp_tuples)
            );
        }
    }
    Ok(())
}

fn cmd_suite(cfg: &Config) -> Result<()> {
    // `--workers` fans out across jobs here; per-job lattice levels stay
    // serial to avoid oversubscription (use `ct --workers N` for that).
    let jobs: Vec<SuiteJob> = datagen::BENCHMARKS
        .iter()
        .map(|b| {
            let mut job = SuiteJob::new(b.name, cfg.scale, cfg.seed).with_progress(cfg.progress);
            if let Some(dir) = &cfg.store {
                job = job.with_store(dir);
            }
            job
        })
        .collect();
    let reports = run_suite(jobs, PoolConfig { workers: cfg.workers, queue_depth: 2 });
    let mut t = TextTable::new(vec![
        "Dataset", "#Tuples", "MJ-time", "#Stats", "LinkOff", "#Extra", "ExtraTime",
    ]);
    for rep in reports {
        match rep {
            Ok(r) => {
                if cfg.store.is_some() {
                    let (h, m, e) = r.store_counters();
                    eprintln!(
                        "{}: persisted + verified (store cache {h} hits / {m} misses / {e} evictions)",
                        r.dataset
                    );
                }
                t.row(vec![
                    r.dataset.clone(),
                    commas(r.tuples as u128),
                    format_duration(r.mj_time),
                    commas(r.statistics as u128),
                    commas(r.link_off_statistics as u128),
                    commas(r.extra_statistics as u128),
                    format_duration(r.extra_time),
                ]);
            }
            Err(e) => eprintln!("job failed: {e:#}"),
        }
    }
    print!("{}", t.render());
    Ok(())
}

/// Resolve a `--store` root to the directory that actually holds a
/// manifest: the root itself, or `<root>/<dataset>` (the layout `ct`/
/// `suite` write).
fn resolve_store_dir(root: &str, dataset: &str) -> Result<PathBuf> {
    let root = PathBuf::from(root);
    if root.join(mrss::store::MANIFEST).is_file() {
        return Ok(root);
    }
    let sub = root.join(dataset);
    if sub.join(mrss::store::MANIFEST).is_file() {
        return Ok(sub);
    }
    bail!(
        "no ctstore manifest under {} (looked there and in {}/) — run `mrss ct --store` first",
        root.display(),
        sub.display()
    )
}

/// An explicitly-passed `--dataset` must match the opened store's
/// manifest — otherwise a store root pointed one level too deep (e.g.
/// `--store ./stats/uwcse --dataset imdb`) would silently answer for the
/// wrong dataset.
fn check_store_dataset(cfg: &Config, store: &CtStore) -> Result<()> {
    if cfg.dataset_explicit && cfg.dataset != store.dataset {
        bail!(
            "--dataset {} does not match this store's dataset {} ({})",
            cfg.dataset,
            store.dataset,
            store.dir().display()
        );
    }
    Ok(())
}

fn cmd_query(cfg: &Config) -> Result<()> {
    let root = cfg.store.as_deref().context("query: --store DIR is required")?;
    let dir = resolve_store_dir(root, &cfg.dataset)?;
    let store = CtStore::open(&dir)?;
    check_store_dataset(cfg, &store)?;
    let schema = datagen::schema_of(&store.dataset)?;

    // --gen N: emit a deterministic query batch and stop.
    if let Some(n) = cfg.gen {
        for q in gen_queries(&schema, n, cfg.seed) {
            println!("{q}");
        }
        return Ok(());
    }

    let mut queries: Vec<String> = Vec::new();
    if let Some(f) = &cfg.queries {
        let text =
            std::fs::read_to_string(f).with_context(|| format!("reading query file {f}"))?;
        for line in text.lines() {
            let l = line.trim();
            if l.is_empty() || l.starts_with('#') {
                continue;
            }
            queries.push(l.to_string());
        }
    }
    if let Some(q) = &cfg.query {
        queries.push(q.clone());
    }
    if queries.is_empty() {
        bail!("query: nothing to answer (pass --queries FILE and/or --query STR)");
    }

    let answers: Vec<(String, u128)> = if cfg.fresh {
        // Baseline mode: recompute the joint in memory with the manifest's
        // exact (dataset, scale, seed) and answer by selection — what the
        // store-smoke CI job diffs the cold-store answers against.
        let db = datagen::generate(&store.dataset, store.scale, store.seed)?;
        let res = MobiusJoin::new(&db).workers(cfg.workers).run();
        let joint = res.joint_ct();
        queries
            .iter()
            .map(|q| Ok((q.clone(), joint.select(&parse_query(&db.schema, q)?).total())))
            .collect::<Result<_>>()?
    } else {
        let server = CountServer::new(store, schema)?;
        if let Some(b) = cfg.mem_budget {
            server.store().set_mem_budget(Some(b));
        }
        let out = queries
            .iter()
            .map(|q| Ok((q.clone(), server.count_query(q)?)))
            .collect::<Result<Vec<_>>>()?;
        let s = server.stats();
        eprintln!(
            "answered {} queries from the store: cache {} hits / {} misses / {} evictions / {} bytes read",
            out.len(),
            s.hits,
            s.misses,
            s.evictions,
            commas(s.bytes_read as u128)
        );
        out
    };

    let json = render_answers(&answers);
    match &cfg.json {
        Some(p) => std::fs::write(p, json).with_context(|| format!("writing {p}"))?,
        None => print!("{json}"),
    }
    Ok(())
}

/// Build the server tuning knobs shared by `serve --listen` and the
/// self-hosted `bench-serve` path.
fn serve_config(cfg: &Config, addr: String) -> Result<ServeConfig> {
    let poller = match cfg.poller.as_deref() {
        Some(s) => PollerKind::parse(s)?,
        None => PollerKind::os_default(),
    };
    if let Some(spec) = &cfg.failpoints {
        // Errors out on a production build: failpoints only exist behind
        // `--features failpoints`, and silently ignoring an armed spec
        // would make a chaos run look like a clean one.
        mrss::util::failpoint::arm(spec).context("--failpoints")?;
    }
    Ok(ServeConfig {
        addr,
        threads: cfg.serve_threads,
        shards: cfg.shards,
        queue_depth: cfg.queue_depth,
        max_conns: cfg.max_conns,
        max_requests: cfg.max_requests,
        json: !cfg.wire_text,
        poller,
        idle_timeout: cfg.idle_timeout_ms.map(Duration::from_millis),
        request_timeout: cfg.request_timeout_ms.map(Duration::from_millis),
        trace_sample: cfg.trace_sample,
        access_log: cfg.access_log.clone(),
        profile_hz: cfg.profile_hz,
        ..Default::default()
    })
}

/// Check a Prometheus text-exposition document (a `METRICS` scrape) with
/// the same validator the unit tests run — CI's guard that the wire
/// output stays scrapeable.
fn cmd_validate_metrics(cfg: &Config) -> Result<()> {
    let (text, source) = match &cfg.file {
        Some(p) => (
            std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
            p.clone(),
        ),
        None => {
            let mut s = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)
                .context("reading exposition from stdin")?;
            (s, "<stdin>".to_string())
        }
    };
    mrss::obs::prom::validate(&text).map_err(|e| anyhow!("{source}: {e}"))?;
    // A scrape that parses but lost a whole family (thread CPU split,
    // kernel timers, process_*) is a silent observability regression:
    // require every serving family the renderer emits.
    mrss::obs::prom::validate_serving_families(&text).map_err(|e| anyhow!("{source}: {e}"))?;
    let samples = text
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .count();
    eprintln!("{source}: valid exposition ({samples} samples, all serving families present)");
    // --prev EARLIER_SCRAPE: additionally require every counter series in
    // the earlier scrape to be present and non-decreasing in this one —
    // the monotonicity contract a restarting or double-registering server
    // would silently break.
    if let Some(p) = &cfg.prev {
        let prev = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        mrss::obs::prom::validate(&prev).map_err(|e| anyhow!("{p}: {e}"))?;
        mrss::obs::prom::validate_monotonic(&prev, &text)
            .map_err(|e| anyhow!("{p} -> {source}: {e}"))?;
        eprintln!("{p} -> {source}: counters monotone");
    }
    Ok(())
}

/// One-shot profiling client: ask a running server for `PROFILE secs`,
/// print the top self-time frames, and optionally write the folded
/// stacks as `stack count` lines (what flamegraph.pl / inferno's
/// `inferno-flamegraph` consume directly).
fn cmd_profile(cfg: &Config) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let addr = cfg.addr.as_deref().context("profile: --addr HOST:PORT is required")?;
    let stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to count server at {addr}"))?;
    let mut w = stream.try_clone().context("cloning profile connection")?;
    writeln!(w, "PROFILE {}", cfg.secs).context("sending PROFILE")?;
    w.flush().context("flushing PROFILE")?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).context("reading PROFILE response")?;
    // Both wire modes answer the same single-line JSON object; scan to
    // the brace so a text-mode prefix cannot confuse the parse.
    let json = match line.find('{') {
        Some(i) => line[i..].trim(),
        None => bail!("unexpected PROFILE response from {addr}: `{}`", line.trim()),
    };
    if json.contains("\"error\"") && !json.contains("\"folded\"") {
        bail!("{addr} refused PROFILE: {json}");
    }
    if let Some(p) = &cfg.json {
        std::fs::write(p, format!("{json}\n")).with_context(|| format!("writing {p}"))?;
    }
    let folded = mrss::obs::profile::parse_folded(json);
    let ticks: u64 = folded.iter().map(|&(_, n)| n).sum();
    eprintln!(
        "captured {} folded stacks / {} samples over {}s from {addr}",
        folded.len(),
        ticks,
        cfg.secs
    );
    if let Some(path) = &cfg.folded {
        let mut out = String::with_capacity(folded.len() * 48);
        for (stack, n) in &folded {
            out.push_str(stack);
            out.push(' ');
            out.push_str(&n.to_string());
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (collapsed format — pipe through flamegraph.pl or inferno)");
    }
    if ticks == 0 {
        eprintln!("no samples in the window — is the server idle, or started with --profile-hz 0?");
        return Ok(());
    }
    // Self time = leaf attribution, idle/torn buckets excluded; computed
    // client-side from the folded stacks so the table and the file agree.
    let mut self_time: std::collections::HashMap<&str, u64> = std::collections::HashMap::new();
    for (stack, n) in &folded {
        let frame = stack.rsplit(';').next().unwrap_or(stack);
        if frame != "<torn>" && !frame.ends_with(".idle") {
            *self_time.entry(frame).or_insert(0) += n;
        }
    }
    let mut rows: Vec<(&str, u64)> = self_time.into_iter().collect();
    rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
    let mut t = TextTable::new(vec!["frame", "self", "% of ticks"]);
    for (frame, n) in rows.iter().take(10) {
        t.row(vec![
            frame.to_string(),
            n.to_string(),
            format!("{:.1}", 100.0 * *n as f64 / ticks as f64),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    // Serving wants the per-operator kernel timers on: METRICS exposes
    // them and the final breakdown names the hottest kernel. The gate
    // stays off for one-shot CLI joins (enable nothing you don't read).
    mrss::ct::ticks::set_enabled(true);
    let root = cfg.store.as_deref().context("serve: --store DIR is required")?;
    let dir = resolve_store_dir(root, &cfg.dataset)?;
    let server = CountServer::open(&dir)?;
    check_store_dataset(cfg, server.store())?;
    if let Some(b) = cfg.mem_budget {
        server.store().set_mem_budget(Some(b));
    }

    // --listen ADDR: the concurrent TCP front-end. Blocks until SHUTDOWN
    // arrives on the wire, then drains and reports.
    if let Some(listen) = &cfg.listen {
        let dataset = server.store().dataset.clone();
        let tables = server.store().len();
        let scfg = serve_config(cfg, listen.clone())?;
        let poller_name = scfg.poller.name();
        let handle = serve::serve(Arc::new(server), scfg)?;
        eprintln!(
            "serving counts for {dataset} on {} ({} tables, {} workers, {} shards, \
             poller={poller_name}, wire={}) — send SHUTDOWN to stop",
            handle.addr(),
            tables,
            cfg.serve_threads,
            cfg.shards,
            if cfg.wire_text { "text" } else { "json" }
        );
        let snap = handle.wait();
        eprintln!("server drained: {}", snap.to_json());
        let mut m = mrss::mobius::MjMetrics::default();
        snap.merge_into(&mut m);
        eprint!("{}", m.breakdown());
        return Ok(());
    }

    eprintln!(
        "serving counts for {} from {} ({} tables); one query per line, e.g. `RA(P,S)=F`",
        server.store().dataset,
        dir.display(),
        server.store().len()
    );
    for line in std::io::stdin().lines() {
        let line = line?;
        let q = line.trim();
        if q.is_empty() {
            continue;
        }
        match server.count_query(q) {
            Ok(c) => println!("{{\"query\":\"{}\",\"count\":{c}}}", json_escape(q)),
            Err(e) => println!(
                "{{\"query\":\"{}\",\"error\":\"{}\"}}",
                json_escape(q),
                json_escape(&e.to_string())
            ),
        }
    }
    let s = server.stats();
    eprintln!(
        "store cache: {} hits / {} misses / {} evictions",
        s.hits, s.misses, s.evictions
    );
    Ok(())
}

/// Drive a count server with concurrent clients and a deterministic query
/// batch; emit `BENCH_serve.json` (and optionally the answers document for
/// diffing against `query --fresh`).
///
/// Target resolution: `--addr` hits a running server (`--dataset` names
/// the schema for query generation); without it, `--store` self-hosts a
/// server on an ephemeral port for the duration of the run.
fn cmd_bench_serve(cfg: &Config) -> Result<()> {
    // Same kernel-timer policy as `serve`: the self-hosted server's
    // METRICS and breakdown carry per-operator tick counters.
    mrss::ct::ticks::set_enabled(true);
    let n_queries: usize = match &cfg.queries {
        Some(s) => s
            .parse()
            .with_context(|| format!("bench-serve: --queries wants a count, got `{s}`"))?,
        None => 200,
    };

    // (addr, dataset, self-hosted handle to drain afterwards)
    let (addr, dataset, hosted) = match (&cfg.addr, &cfg.store) {
        (Some(addr), _) => (addr.clone(), cfg.dataset.clone(), None),
        (None, Some(root)) => {
            let dir = resolve_store_dir(root, &cfg.dataset)?;
            let server = CountServer::open(&dir)?;
            check_store_dataset(cfg, server.store())?;
            if let Some(b) = cfg.mem_budget {
                server.store().set_mem_budget(Some(b));
            }
            let dataset = server.store().dataset.clone();
            let handle =
                serve::serve(Arc::new(server), serve_config(cfg, "127.0.0.1:0".to_string())?)?;
            eprintln!("self-hosted a server on {} from {}", handle.addr(), dir.display());
            (handle.addr().to_string(), dataset, Some(handle))
        }
        (None, None) => bail!("bench-serve: pass --addr HOST:PORT or --store DIR"),
    };
    let schema = datagen::schema_of(&dataset)?;
    let mix = Mix::parse(&cfg.mix)?;

    let report = mrss::serve::loadgen::run(
        &schema,
        &LoadgenConfig {
            addr,
            clients: cfg.clients,
            queries: n_queries,
            seed: cfg.seed,
            mix,
            idle: cfg.idle,
            stats: true,
            shutdown: cfg.send_shutdown,
        },
    )?;
    if let Some(handle) = hosted {
        // The run may have shut it down already (--shutdown); this is
        // idempotent and guarantees the drain either way.
        handle.request_shutdown();
        handle.wait();
    }

    eprintln!(
        "bench-serve {}: {} clients x {} queries (mix={}, idle={}) in {} — {:.0} qps, \
         p50 ≤ {} µs, p99 ≤ {} µs, {} errors",
        dataset,
        report.clients,
        report.answers.len() + report.errors.len(),
        report.mix,
        report.idle_open,
        format_duration(report.wall),
        report.qps,
        report.p50_us,
        report.p99_us,
        report.errors.len(),
    );
    if let Some(stats) = &report.server_stats {
        eprintln!("server stats: {stats}");
    }

    let bench_path = cfg.bench_json.as_deref().unwrap_or("BENCH_serve.json");
    std::fs::write(bench_path, report.bench_json(&dataset))
        .with_context(|| format!("writing {bench_path}"))?;
    eprintln!("wrote {bench_path}");

    if let Some(p) = &cfg.json {
        if mix.is_uniform() {
            std::fs::write(p, report.answers_json()).with_context(|| format!("writing {p}"))?;
        } else {
            eprintln!(
                "skipping {p}: a {} mix repeats queries, so the answers document is not \
                 diffable against `query --fresh`",
                report.mix
            );
        }
    }
    if !report.errors.is_empty() {
        let (q, e) = &report.errors[0];
        bail!(
            "{} of {} queries answered with an error, first: `{q}` -> {e}",
            report.errors.len(),
            n_queries
        );
    }
    Ok(())
}

/// `mine`/`bn` input: either a fresh generate + Möbius Join, or — with
/// `--store` — the reassembled result of a persisted run, no database
/// needed.
fn load_or_run(cfg: &Config) -> Result<(String, Schema, MjResult)> {
    match &cfg.store {
        Some(root) => {
            let dir = resolve_store_dir(root, &cfg.dataset)?;
            let store = CtStore::open(&dir)?;
            check_store_dataset(cfg, &store)?;
            // The store serves the configuration it was persisted with:
            // explicitly asking for a different one must not be silently
            // ignored. (`query --gen` reuses --seed for query generation,
            // so this strict check applies only to mine/bn.)
            if cfg.scale_explicit && cfg.scale != store.scale {
                bail!(
                    "--scale {} does not match this store's scale {} — re-persist or drop the flag",
                    cfg.scale,
                    store.scale
                );
            }
            if cfg.seed_explicit && cfg.seed != store.seed {
                bail!(
                    "--seed {} does not match this store's seed {} — re-persist or drop the flag",
                    cfg.seed,
                    store.seed
                );
            }
            let schema = datagen::schema_of(&store.dataset)?;
            let res = store.load_mj_result(&schema)?;
            eprintln!(
                "scoring from warm store {} ({} tables, {} bytes)",
                dir.display(),
                store.len(),
                commas(store.disk_bytes() as u128)
            );
            Ok((store.dataset.clone(), schema, res))
        }
        None => {
            let db = datagen::generate(&cfg.dataset, cfg.scale, cfg.seed)?;
            let res = MobiusJoin::new(&db).workers(cfg.workers).run();
            Ok((cfg.dataset.clone(), (*db.schema).clone(), res))
        }
    }
}

fn cmd_mine(cfg: &Config) -> Result<()> {
    let (dataset, schema, res) = load_or_run(cfg)?;
    let schema = &schema;
    let rt = maybe_runtime(cfg)?;
    let rt = rt.as_ref();

    let target_name = datagen::info(&dataset).map(|b| b.target).unwrap_or("");
    let target = schema
        .var_by_name(target_name)
        .ok_or_else(|| anyhow!("target {target_name} not found"))?;

    // Feature selection, link off vs on (Table 5).
    let joint = res.joint_ct();
    let off_ct = res.link_off();
    let attrs: Vec<usize> = (0..schema.random_vars.len())
        .filter(|&v| !matches!(schema.random_vars[v], mrss::schema::RandomVar::RelInd { .. }))
        .collect();
    let all_vars: Vec<usize> = (0..schema.random_vars.len()).collect();
    let off = cfs::cfs_select(&off_ct, target, &attrs, rt);
    let on = cfs::cfs_select(joint, target, &all_vars, rt);
    println!("CFS target {target_name}:");
    let names =
        |vs: &[usize]| vs.iter().map(|&v| schema.var_name(v)).collect::<Vec<_>>().join(", ");
    println!("  link off: [{}]", names(&off.selected));
    println!("  link on : [{}]", names(&on.selected));
    println!("  distinctness = {:.2}", cfs::distinctness(&off.selected, &on.selected));

    // Association rules (Table 6).
    let min_support: f64 =
        cfg.extra.get("min-support").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let rules = apriori::apriori(
        schema,
        joint,
        apriori::AprioriConfig { min_support, ..Default::default() },
        rt,
    );
    let with_rel = rules.iter().filter(|r| r.uses_rel_var(schema)).count();
    println!("\nTop {} rules by lift ({} use relationship variables):", rules.len(), with_rel);
    for r in rules.iter().take(10) {
        println!("  lift {:.2}  {}", r.lift, r.render(schema));
    }
    Ok(())
}

fn cmd_bn(cfg: &Config) -> Result<()> {
    let (_dataset, schema, res) = load_or_run(cfg)?;
    let schema = &schema;
    let rt = maybe_runtime(cfg)?;
    let rt = rt.as_ref();
    let joint = res.joint_ct();

    let mut t = TextTable::new(vec!["Mode", "learn-time", "log-lik", "#params", "R2R", "A2R"]);
    for link_on in [false, true] {
        let out = bayesnet::learn_structure(schema, &res, link_on, Default::default());
        let m = bayesnet::score_structure(schema, &out.bn, joint, rt);
        t.row(vec![
            if link_on { "Link Analysis On" } else { "Link Analysis Off" }.to_string(),
            format_duration(out.elapsed),
            format!("{:.2}", m.loglik),
            m.params.to_string(),
            m.r2r.to_string(),
            m.a2r.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
