//! Instrumentation for the Möbius Join: wall-time attribution per phase and
//! per ct-algebra operator, plus operation counts.
//!
//! This is what regenerates the paper's Figure 8 (Pivot vs main loop;
//! subtraction/union vs cross product) and the complexity-analysis checks
//! of §4.3 (`#ct_ops` vs the `O(r log r)` bound).

use std::time::Duration;

/// Which ct-algebra operator a timing sample belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtOp {
    Project,
    Subtract,
    Cross,
    Condition,
    Extend,
    Union,
}

pub const ALL_OPS: [CtOp; 6] =
    [CtOp::Project, CtOp::Subtract, CtOp::Cross, CtOp::Condition, CtOp::Extend, CtOp::Union];

impl CtOp {
    pub fn name(self) -> &'static str {
        match self {
            CtOp::Project => "project",
            CtOp::Subtract => "subtract",
            CtOp::Cross => "cross",
            CtOp::Condition => "condition",
            CtOp::Extend => "extend",
            CtOp::Union => "union",
        }
    }

    fn idx(self) -> usize {
        match self {
            CtOp::Project => 0,
            CtOp::Subtract => 1,
            CtOp::Cross => 2,
            CtOp::Condition => 3,
            CtOp::Extend => 4,
            CtOp::Union => 5,
        }
    }
}

/// One lattice level's build telemetry: how many chains it held and what
/// they emitted. Pushed by [`MobiusJoin::run`](crate::mobius::MobiusJoin)
/// after each level completes (always — `--progress` only controls the
/// live stderr lines, not this record).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelStats {
    /// Lattice level (chain length), 1-based.
    pub level: usize,
    /// Chains completed at this level.
    pub chains: u64,
    /// Total rows across the level's finished tables.
    pub rows: u64,
    /// Total `mem_bytes` across the level's finished tables.
    pub bytes: u64,
    /// Wall time from the level's first chain starting to its last
    /// finishing.
    pub elapsed: Duration,
}

/// Möbius Join run metrics.
///
/// With `MobiusJoin::workers(n > 1)`, per-phase durations (`positive`,
/// `pivot`, `main_loop`, per-op times) are summed across worker threads —
/// they measure aggregate CPU time and can exceed `total` wall time.
#[derive(Debug, Default, Clone)]
pub struct MjMetrics {
    /// End-to-end wall time of the run.
    pub total: Duration,
    /// Time computing positive-only statistics (entity cts + all-true join
    /// tables): the paper's "Link Analysis Off" cost.
    pub positive: Duration,
    /// Time inside the Pivot function (Algorithm 1).
    pub pivot: Duration,
    /// Time building `ct_*` tables in the main loop (Algorithm 2 lines
    /// 13-19): conditioning shorter-chain tables + cross products.
    pub main_loop: Duration,
    /// ct-algebra operator calls that left the packed fast path for the
    /// row-major reference implementation during this run (delta of
    /// [`crate::ct::reference::reference_op_fallbacks`]). Zero for every
    /// schema whose tables stay within 128-bit layouts. Attribution is by
    /// process-global counter delta, so concurrent `MobiusJoin` runs in one
    /// process can cross-attribute each other's fallbacks — tests that
    /// assert on this live in their own binary (`rust/tests/wide_tier.rs`).
    pub reference_fallbacks: u64,
    /// Ct-store cache hits during this run's store traffic (persistence
    /// readback verification, or query serving attributed to the run).
    /// Zero when the run had no store attached.
    pub store_hits: u64,
    /// Ct-store cache misses (tables decoded from disk).
    pub store_misses: u64,
    /// Ct-store LRU evictions under the `mem_bytes` budget.
    pub store_evictions: u64,
    /// ADtrees built by the count service over this run's store traffic
    /// (at most one per table while cached — see
    /// [`TreeStats`](crate::store::TreeStats)).
    pub adtree_builds: u64,
    /// Readers that coalesced onto an ADtree build already in progress
    /// instead of duplicating it.
    pub adtree_coalesced: u64,
    /// ADtrees evicted under the shared `mem_bytes` budget.
    pub adtree_evictions: u64,
    /// Per-lattice-level build telemetry, in level order. Empty for
    /// assembled (not run) results and for serving-only records.
    pub levels: Vec<LevelStats>,
    counts: [u64; 6],
    times: [Duration; 6],
}

impl MjMetrics {
    /// Record one ct-algebra operation.
    pub fn record(&mut self, op: CtOp, d: Duration) {
        self.counts[op.idx()] += 1;
        self.times[op.idx()] += d;
    }

    pub fn op_count(&self, op: CtOp) -> u64 {
        self.counts[op.idx()]
    }

    pub fn op_time(&self, op: CtOp) -> Duration {
        self.times[op.idx()]
    }

    /// Total number of ct-algebra operations (the quantity bounded by
    /// `O(r log r)` in Proposition 2).
    pub fn total_ct_ops(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The paper's "Extra Time": total minus positive-only time. Only
    /// meaningful for serial runs — with `MobiusJoin::workers(n > 1)`,
    /// `positive` is summed CPU time across threads and can exceed the
    /// wall-clock `total`, saturating this to zero (run the Table-4/Fig-7
    /// measurements with workers = 1).
    pub fn extra_time(&self) -> Duration {
        self.total.saturating_sub(self.positive)
    }

    /// Merge another metrics record into this one (coordinator aggregation).
    pub fn merge(&mut self, other: &MjMetrics) {
        self.total += other.total;
        self.positive += other.positive;
        self.pivot += other.pivot;
        self.main_loop += other.main_loop;
        self.reference_fallbacks += other.reference_fallbacks;
        self.store_hits += other.store_hits;
        self.store_misses += other.store_misses;
        self.store_evictions += other.store_evictions;
        self.adtree_builds += other.adtree_builds;
        self.adtree_coalesced += other.adtree_coalesced;
        self.adtree_evictions += other.adtree_evictions;
        self.levels.extend(other.levels.iter().copied());
        for i in 0..6 {
            self.counts[i] += other.counts[i];
            self.times[i] += other.times[i];
        }
    }

    /// Render the Figure-8-style breakdown.
    ///
    /// These are *run-level aggregates* — one record for the whole join
    /// or serving session. For per-query phase attribution (which
    /// FO-groups factorized, table load vs. cache hit, whether Möbius
    /// subtraction answered), use the serving stack's `EXPLAIN <query>`
    /// wire verb, which returns the span tree recorded by
    /// [`crate::obs::trace`]; `METRICS` exposes these same counters in
    /// Prometheus text format ([`crate::obs::prom`]).
    pub fn breakdown(&self) -> String {
        use crate::util::format_duration as fd;
        let mut s = format!(
            "total {}  positive {}  pivot {}  main-loop {}  extra {}\n",
            fd(self.total),
            fd(self.positive),
            fd(self.pivot),
            fd(self.main_loop),
            fd(self.extra_time()),
        );
        for op in ALL_OPS {
            s.push_str(&format!(
                "  {:<10} x{:<6} {}\n",
                op.name(),
                self.op_count(op),
                fd(self.op_time(op))
            ));
        }
        if let Some((label, calls, nanos)) = crate::ct::ticks::hottest() {
            s.push_str(&format!(
                "  hottest ct kernel: {label} x{calls} {} (process-global timers)\n",
                fd(Duration::from_nanos(nanos))
            ));
        }
        s.push_str(&format!("  row-major reference fallbacks: {}\n", self.reference_fallbacks));
        s.push_str(&format!(
            "  ct-store cache: {} hits / {} misses / {} evictions\n",
            self.store_hits, self.store_misses, self.store_evictions
        ));
        s.push_str(&format!(
            "  adtree cache: {} builds / {} coalesced waits / {} evictions\n",
            self.adtree_builds, self.adtree_coalesced, self.adtree_evictions
        ));
        for l in &self.levels {
            s.push_str(&format!(
                "  level {:<2} {} chains  {} rows  {} bytes  {}\n",
                l.level,
                l.chains,
                l.rows,
                l.bytes,
                fd(l.elapsed)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = MjMetrics::default();
        m.record(CtOp::Subtract, Duration::from_millis(5));
        m.record(CtOp::Subtract, Duration::from_millis(7));
        m.record(CtOp::Cross, Duration::from_millis(1));
        assert_eq!(m.op_count(CtOp::Subtract), 2);
        assert_eq!(m.op_time(CtOp::Subtract), Duration::from_millis(12));
        assert_eq!(m.total_ct_ops(), 3);
    }

    #[test]
    fn extra_time_saturates() {
        let mut m = MjMetrics::default();
        m.positive = Duration::from_secs(5);
        m.total = Duration::from_secs(3); // degenerate, should not panic
        assert_eq!(m.extra_time(), Duration::ZERO);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = MjMetrics::default();
        a.record(CtOp::Union, Duration::from_millis(1));
        a.store_hits = 2;
        let mut b = MjMetrics::default();
        b.record(CtOp::Union, Duration::from_millis(2));
        b.total = Duration::from_secs(1);
        b.store_hits = 3;
        b.store_misses = 1;
        b.store_evictions = 4;
        b.adtree_builds = 2;
        b.adtree_coalesced = 6;
        b.adtree_evictions = 1;
        b.levels.push(LevelStats { level: 1, chains: 3, rows: 40, bytes: 512, elapsed: Duration::ZERO });
        a.merge(&b);
        assert_eq!(a.op_count(CtOp::Union), 2);
        assert_eq!(a.total, Duration::from_secs(1));
        assert_eq!((a.store_hits, a.store_misses, a.store_evictions), (5, 1, 4));
        assert_eq!(
            (a.adtree_builds, a.adtree_coalesced, a.adtree_evictions),
            (2, 6, 1)
        );
        assert_eq!(a.levels.len(), 1);
        assert_eq!(a.levels[0].rows, 40);
    }

    #[test]
    fn breakdown_renders_one_line_per_level() {
        let mut m = MjMetrics::default();
        m.levels.push(LevelStats {
            level: 1,
            chains: 3,
            rows: 120,
            bytes: 4096,
            elapsed: Duration::from_millis(2),
        });
        m.levels.push(LevelStats {
            level: 2,
            chains: 2,
            rows: 90,
            bytes: 2048,
            elapsed: Duration::from_millis(1),
        });
        let s = m.breakdown();
        assert!(s.contains("level 1  3 chains  120 rows  4096 bytes"), "{s}");
        assert!(s.contains("level 2  2 chains  90 rows  2048 bytes"), "{s}");
    }

    #[test]
    fn breakdown_mentions_store_counters() {
        let mut m = MjMetrics::default();
        m.store_hits = 7;
        m.store_evictions = 2;
        m.adtree_builds = 5;
        let s = m.breakdown();
        assert!(s.contains("ct-store cache: 7 hits"));
        assert!(s.contains("2 evictions"));
        assert!(s.contains("adtree cache: 5 builds"));
    }

    #[test]
    fn breakdown_mentions_all_ops() {
        let m = MjMetrics::default();
        let s = m.breakdown();
        for op in ALL_OPS {
            assert!(s.contains(op.name()));
        }
    }

    #[test]
    fn breakdown_names_the_hottest_kernel_once_timers_ran() {
        use crate::ct::{ticks, CtTable};
        let _gate = ticks::gate_lock();
        let prev = ticks::enabled();
        ticks::set_enabled(true);
        // Enough timed calls that cumulative nanos cannot round to zero.
        let t = CtTable::from_raw(vec![1], vec![0, 1], vec![5, 3]);
        for _ in 0..50 {
            let _ = t.add(&t).subtract(&t).unwrap();
        }
        ticks::set_enabled(prev);
        let s = MjMetrics::default().breakdown();
        assert!(s.contains("hottest ct kernel: "), "{s}");
    }
}
