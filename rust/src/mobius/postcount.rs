//! Postcounting (paper §8): rather than precompute one joint contingency
//! table for *all* variables, compute many small contingency tables for
//! variable subsets **on demand during learning**, with caching.
//!
//! `PostCounter` answers `ct(V)` requests for arbitrary variable subsets by
//! running the Möbius Join machinery only over the relationships a request
//! actually touches: it projects the (cached) chain tables of the minimal
//! relationship set covering `V`, crossing in entity tables for FO
//! variables outside every requested relationship. This is the
//! "alternative" the conclusion proposes for schemas where the full joint
//! table grows too large.

use super::{MjResult, MobiusJoin};
use crate::ct::CtTable;
use crate::db::Database;
use crate::lattice::components;
use crate::schema::{RandomVar, VarId};
use crate::util::fxhash::FxHashMap;
use std::cell::RefCell;

/// On-demand sufficient-statistics service over a database.
pub struct PostCounter<'a> {
    db: &'a Database,
    /// Full lattice tables (reused across requests; the §8 trade-off is
    /// depth-capping this precomputation).
    mj: MjResult,
    cache: RefCell<FxHashMap<Vec<VarId>, CtTable>>,
    hits: RefCell<usize>,
    misses: RefCell<usize>,
}

impl<'a> PostCounter<'a> {
    /// Build the service. `max_chain_len` caps the precomputed lattice
    /// depth (None = all levels); requests touching longer chains fail.
    pub fn new(db: &'a Database, max_chain_len: Option<usize>) -> Self {
        let mut mj = MobiusJoin::new(db);
        if let Some(l) = max_chain_len {
            mj = mj.max_chain_len(l);
        }
        PostCounter {
            db,
            mj: mj.run(),
            cache: RefCell::new(FxHashMap::default()),
            hits: RefCell::new(0),
            misses: RefCell::new(0),
        }
    }

    /// The contingency table for an arbitrary variable subset.
    /// Returns None if a required chain exceeds the precomputed depth.
    pub fn ct(&self, vars: &[VarId]) -> Option<CtTable> {
        let mut key: Vec<VarId> = vars.to_vec();
        key.sort_unstable();
        key.dedup();
        if let Some(hit) = self.cache.borrow().get(&key) {
            *self.hits.borrow_mut() += 1;
            return Some(hit.clone());
        }
        *self.misses.borrow_mut() += 1;
        let schema = &self.db.schema;

        // Relationships touched by the request: each requested var's own
        // relationship, plus — for entity attributes — every relationship
        // whose FO variables include the attribute's FO var (its value
        // distribution is relationship-dependent in the joint space).
        let mut rels: Vec<usize> = key
            .iter()
            .filter_map(|&v| schema.random_vars[v].rel())
            .collect();
        let fo_of_entity_vars: Vec<usize> = key
            .iter()
            .filter_map(|&v| match schema.random_vars[v] {
                RandomVar::EntityAttr { fo, .. } => Some(fo),
                _ => None,
            })
            .collect();
        for r in 0..schema.num_rel_vars() {
            if schema.relationships[r].fo_vars.iter().any(|f| fo_of_entity_vars.contains(f)) {
                rels.push(r);
            }
        }
        rels.sort_unstable();
        rels.dedup();

        // Assemble from chain-component tables (cross product), then cross
        // in untouched FO variables' entity tables, then project.
        let mut acc: Option<CtTable> = None;
        let mut covered_fos: Vec<usize> = Vec::new();
        if !rels.is_empty() {
            for comp in components(schema, &rels) {
                let table = self.mj.tables.get(&comp)?; // depth-capped miss
                acc = Some(match acc {
                    None => table.clone(),
                    Some(a) => a.cross(table),
                });
            }
            covered_fos = schema.fo_vars_of_rels(&rels);
        }
        for fo in fo_of_entity_vars {
            if !covered_fos.contains(&fo) {
                covered_fos.push(fo);
                let e = self.mj.entity_cts[&fo].clone();
                acc = Some(match acc {
                    None => e,
                    Some(a) => a.cross(&e),
                });
            }
        }
        let big = acc?;
        let out = big.project(&key);
        self.cache.borrow_mut().insert(key, out.clone());
        Some(out)
    }

    /// (cache hits, misses) — for the §8 trade-off analysis.
    pub fn cache_stats(&self) -> (usize, usize) {
        (*self.hits.borrow(), *self.misses.borrow())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::university_db;

    #[test]
    fn on_demand_matches_joint_projection() {
        let db = university_db();
        let pc = PostCounter::new(&db, None);
        let joint = MobiusJoin::new(&db).run();
        let joint = joint.joint_ct();
        let s = &db.schema;
        let queries: Vec<Vec<VarId>> = vec![
            vec![s.var_by_name("intelligence(S)").unwrap()],
            vec![
                s.var_by_name("intelligence(S)").unwrap(),
                s.var_by_name("RA(P,S)").unwrap(),
            ],
            vec![
                s.var_by_name("grade(S,C)").unwrap(),
                s.var_by_name("capability(P,S)").unwrap(),
            ],
            vec![
                s.var_by_name("popularity(P)").unwrap(),
                s.var_by_name("Registration(S,C)").unwrap(),
                s.var_by_name("ranking(S)").unwrap(),
            ],
        ];
        for q in queries {
            let got = pc.ct(&q).unwrap();
            let want = joint.project(&q);
            assert_eq!(got, want, "query {q:?}");
        }
    }

    #[test]
    fn entity_only_query_under_depth_cap() {
        let db = university_db();
        let pc = PostCounter::new(&db, Some(1));
        let s = &db.schema;
        // S participates in BOTH relationships, so even a single-attribute
        // query on S needs the length-2 chain: depth-capped -> None.
        let intel = s.var_by_name("intelligence(S)").unwrap();
        assert!(pc.ct(&[intel]).is_none());
        // C participates only in Registration: answerable at depth 1.
        let diff = s.var_by_name("difficulty(C)").unwrap();
        let got = pc.ct(&[diff]).unwrap();
        // Counts live in the covered FO-variable space (S x C here), so the
        // total is |S| x |C| and the distribution matches the uncapped joint
        // projection up to the |P| factor of the uncovered population.
        assert_eq!(got.total(), 9);
        let full = MobiusJoin::new(&db).run();
        let joint_proj = full.joint_ct().project(&[diff]);
        for (row, c) in got.iter() {
            assert_eq!(3 * c, joint_proj.count_of(&row), "row {row:?}");
        }
    }

    #[test]
    fn depth_cap_miss_returns_none() {
        let db = university_db();
        let pc = PostCounter::new(&db, Some(1));
        let s = &db.schema;
        // Query touching both relationships needs the length-2 chain.
        let q = vec![
            s.var_by_name("Registration(S,C)").unwrap(),
            s.var_by_name("RA(P,S)").unwrap(),
        ];
        assert!(pc.ct(&q).is_none());
    }

    #[test]
    fn cache_hits_on_repeat() {
        let db = university_db();
        let pc = PostCounter::new(&db, None);
        let s = &db.schema;
        let q = vec![s.var_by_name("intelligence(S)").unwrap()];
        pc.ct(&q).unwrap();
        pc.ct(&q).unwrap();
        let (hits, misses) = pc.cache_stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }
}
