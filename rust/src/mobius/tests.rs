//! Möbius Join correctness: hand-checked fixtures plus the central property
//! test — the MJ joint table must equal the brute-force cross-product table
//! exactly, on every schema shape (chains, triangles, self-relationships,
//! disconnected components, empty relationships).

use super::*;
use crate::baseline::{cross_product_ct, CpBudget};
use crate::db::{university_db, Database, DatabaseBuilder};
use crate::schema::SchemaBuilder;
use crate::util::proptest::run_prop;
use crate::util::Pcg64;
use std::sync::Arc;

#[test]
fn university_joint_total_is_population_product() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    assert_eq!(res.joint_ct().total(), 27);
    res.joint_ct().check_invariants().unwrap();
}

#[test]
fn university_joint_matches_cross_product() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let cp = cross_product_ct(&db, CpBudget::default());
    assert_eq!(res.joint_ct(), cp.ct().unwrap());
}

#[test]
fn level_stats_cover_the_lattice_and_match_the_tables() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let levels = &res.metrics.levels;
    assert_eq!(levels.len(), res.lattice.max_level(), "one record per lattice level");
    for (i, l) in levels.iter().enumerate() {
        assert_eq!(l.level, i + 1, "levels recorded in lattice order");
        let chains: Vec<_> = res.lattice.level(l.level).cloned().collect();
        assert_eq!(l.chains as usize, chains.len());
        let rows: u64 = chains.iter().map(|c| res.tables[c].len() as u64).sum();
        let bytes: u64 = chains.iter().map(|c| res.tables[c].mem_bytes() as u64).sum();
        assert_eq!(l.rows, rows, "level {} row total", l.level);
        assert_eq!(l.bytes, bytes, "level {} byte total", l.level);
    }
    // Parallel runs record the same telemetry (ordering is deterministic).
    let par = MobiusJoin::new(&db).workers(4).run();
    assert_eq!(par.metrics.levels.len(), levels.len());
    for (a, b) in par.metrics.levels.iter().zip(levels) {
        assert_eq!((a.level, a.chains, a.rows, a.bytes), (b.level, b.chains, b.rows, b.bytes));
    }
}

#[test]
fn university_link_off_matches_positive_join() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let off = res.link_off();
    assert_eq!(off.total(), 5); // join size of Reg x RA on S
    assert_eq!(res.num_statistics(), off.len() + res.num_extra_statistics());
}

#[test]
fn single_rel_table_conserves_counts() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    // ct for {RA}: total = |P| x |S| = 9; {Reg}: |S| x |C| = 9.
    assert_eq!(res.tables[&vec![1usize]].total(), 9);
    assert_eq!(res.tables[&vec![0usize]].total(), 9);
}

#[test]
fn paper_figure5_ra_false_counts() {
    // Figure 5: ct_F for RA(P,S) = F has total 9 - 4 = 5.
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let s = &db.schema;
    let ra = s.rel_ind_var(1);
    let f_part = res.tables[&vec![1usize]].select(&[(ra, 0)]);
    assert_eq!(f_part.total(), 5);
    // All F rows must have n/a 2Atts.
    let cap = s.var_by_name("capability(P,S)").unwrap();
    let col = f_part.col_of(cap).unwrap();
    for (row, _) in f_part.iter() {
        assert_eq!(row[col], crate::schema::NA);
    }
}

#[test]
fn depth_capped_run_has_no_joint() {
    let db = university_db();
    let res = MobiusJoin::new(&db).max_chain_len(1).run();
    assert!(res.joint.is_none());
    assert_eq!(res.tables.len(), 2); // two singleton chains only
}

#[test]
fn metrics_populated() {
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let m = &res.metrics;
    assert!(m.total_ct_ops() > 0);
    assert!(m.op_count(CtOp::Subtract) >= 2); // one per pivot at least
    assert!(m.total >= m.positive);
    // 2 pivots at level 1 + 2 pivots at level 2 = 4 unions.
    assert_eq!(m.op_count(CtOp::Union), 4);
}

#[test]
fn proposition2_op_bound_holds() {
    // #ct_ops = O(r log2 r) with r = #negative statistics; check the
    // concrete inequality with a generous constant on the fixture.
    let db = university_db();
    let res = MobiusJoin::new(&db).run();
    let r = res.num_extra_statistics() as f64;
    let ops = res.metrics.total_ct_ops() as f64;
    assert!(r > 0.0);
    assert!(ops <= 6.0 * r * r.log2().max(1.0) + 60.0, "ops={ops} r={r}");
}

// ---------- randomized schema shapes vs brute force ----------

/// Build a random database over a given schema: random entity counts,
/// random attribute codes, Bernoulli relationship tuples.
fn random_db(schema: Arc<crate::schema::Schema>, rng: &mut Pcg64, density: f64) -> Database {
    let mut b = DatabaseBuilder::new(schema.clone());
    for (pid, p) in schema.populations.iter().enumerate() {
        let n = rng.index(4) + 2; // 2..=5 entities
        for _ in 0..n {
            let codes: Vec<u16> = p
                .attrs
                .iter()
                .map(|&a| rng.below(schema.attributes[a].arity() as u64) as u16)
                .collect();
            b.add_entity(pid, &codes);
        }
    }
    for (rid, r) in schema.relationships.iter().enumerate() {
        let n1 = b.entity_count(r.pops[0]);
        let n2 = b.entity_count(r.pops[1]);
        for a in 0..n1 {
            for bb in 0..n2 {
                if rng.chance(density) {
                    let codes: Vec<u16> = r
                        .attrs
                        .iter()
                        .map(|&at| rng.below(schema.attributes[at].arity() as u64) as u16)
                        .collect();
                    b.add_rel(rid, a, bb, &codes);
                }
            }
        }
    }
    b.finish()
}

fn uni_schema() -> Arc<crate::schema::Schema> {
    Arc::new(crate::schema::university_schema())
}

fn triangle_schema() -> Arc<crate::schema::Schema> {
    // Figure 4: three pairwise-connected relationships.
    let mut b = SchemaBuilder::new("triangle");
    let s = b.population("Student");
    b.attr(s, "iq", &["1", "2"]);
    let c = b.population("Course");
    b.attr(c, "rating", &["1", "2"]);
    let p = b.population("Prof");
    b.attr(p, "pop", &["1", "2"]);
    let reg = b.relationship("Reg", s, c);
    b.rel_attr(reg, "grade", &["1", "2"]);
    b.relationship("RA", p, s);
    let t = b.relationship("Teaches", p, c);
    b.rel_attr(t, "eval", &["1", "2"]);
    Arc::new(b.finish())
}

fn selfrel_schema() -> Arc<crate::schema::Schema> {
    // Mondial shape: Borders(C,C) self-rel + HasReligion(C,R).
    let mut b = SchemaBuilder::new("selfrel");
    let c = b.population("Country");
    b.attr(c, "size", &["s", "m", "l"]);
    let r = b.population("Religion");
    b.attr(r, "age", &["old", "new"]);
    b.relationship("Borders", c, c);
    let hr = b.relationship("HasRel", c, r);
    b.rel_attr(hr, "pct", &["lo", "hi"]);
    Arc::new(b.finish())
}

fn disconnected_schema() -> Arc<crate::schema::Schema> {
    // UW-CSE shape: two self-relationships over disjoint populations.
    let mut b = SchemaBuilder::new("uw");
    let p = b.population("Person");
    b.attr(p, "position", &["fac", "stu"]);
    let c = b.population("Course");
    b.attr(c, "level", &["ug", "gr"]);
    b.relationship("AdvisedBy", p, p);
    b.relationship("Prereq", c, c);
    Arc::new(b.finish())
}

fn check_mj_equals_cp(db: &Database) -> Result<(), String> {
    let res = MobiusJoin::new(db).run();
    let cp = cross_product_ct(db, CpBudget::default());
    let cp_ct = cp.ct().ok_or("cp did not terminate")?;
    let joint = res.joint_ct();
    joint.check_invariants()?;
    if joint != cp_ct {
        return Err(format!(
            "MJ joint ({} rows, total {}) != CP ({} rows, total {})",
            joint.len(),
            joint.total(),
            cp_ct.len(),
            cp_ct.total()
        ));
    }
    Ok(())
}

#[test]
fn prop_mj_equals_cp_university() {
    run_prop(
        "mj_eq_cp_university",
        25,
        0xA11CE,
        |rng| {
            let d = rng.f64() * 0.6;
            random_db(uni_schema(), rng, d)
        },
        |db| check_mj_equals_cp(db),
    );
}

#[test]
fn prop_mj_equals_cp_triangle() {
    run_prop(
        "mj_eq_cp_triangle",
        20,
        0xB0B,
        |rng| {
            let d = rng.f64() * 0.5;
            random_db(triangle_schema(), rng, d)
        },
        |db| check_mj_equals_cp(db),
    );
}

#[test]
fn prop_mj_equals_cp_selfrel() {
    run_prop(
        "mj_eq_cp_selfrel",
        20,
        0xCAFE,
        |rng| {
            let d = rng.f64() * 0.5;
            random_db(selfrel_schema(), rng, d)
        },
        |db| check_mj_equals_cp(db),
    );
}

#[test]
fn prop_mj_equals_cp_disconnected() {
    run_prop(
        "mj_eq_cp_disconnected",
        20,
        0xD15C,
        |rng| {
            let d = rng.f64() * 0.5;
            random_db(disconnected_schema(), rng, d)
        },
        |db| check_mj_equals_cp(db),
    );
}

#[test]
fn empty_relationship_still_correct() {
    // One relationship has zero tuples: every row must have its indicator F.
    let mut rng = Pcg64::seeded(99);
    let schema = triangle_schema();
    let mut db = random_db(schema.clone(), &mut rng, 0.4);
    // Rebuild with rel 2 emptied.
    let mut b = DatabaseBuilder::new(schema.clone());
    for (pid, _) in schema.populations.iter().enumerate() {
        for e in 0..db.entity_counts[pid] {
            let codes: Vec<u16> = (0..schema.populations[pid].attrs.len())
                .map(|k| db.entity_attr(pid, k, e))
                .collect();
            b.add_entity(pid, &codes);
        }
    }
    for rid in 0..2 {
        let pairs = db.rels[rid].pairs.clone();
        for (t, &[x, y]) in pairs.iter().enumerate() {
            let codes: Vec<u16> =
                db.rels[rid].attrs.iter().map(|col| col[t]).collect();
            b.add_rel(rid, x, y, &codes);
        }
    }
    db = b.finish();
    assert!(db.rels[2].is_empty());
    check_mj_equals_cp(&db).unwrap();
    let res = MobiusJoin::new(&db).run();
    let ind2 = db.schema.rel_ind_var(2);
    let joint = res.joint_ct();
    let col = joint.col_of(ind2).unwrap();
    for (row, _) in joint.iter() {
        assert_eq!(row[col], 0, "empty relationship must be F everywhere");
    }
}

#[test]
fn pivot_conserves_totals_per_level() {
    // For every chain table: total == product of population sizes of its
    // FO variables (each instantiation counted exactly once).
    let mut rng = Pcg64::seeded(123);
    let db = random_db(triangle_schema(), &mut rng, 0.3);
    let res = MobiusJoin::new(&db).run();
    for (chain, table) in &res.tables {
        let expect: u128 = db
            .schema
            .fo_vars_of_rels(chain)
            .iter()
            .map(|&f| db.entity_counts[db.schema.fo_vars[f].pop] as u128)
            .product();
        assert_eq!(table.total(), expect, "chain {chain:?}");
    }
}
